"""Fault-tolerant training loop.

* checkpoint/restart: atomic sharded checkpoints every ``ckpt_every``
  steps; on start, the latest checkpoint (if any) is restored and the
  seekable data stream resumes at the exact step — restart reproduces the
  uninterrupted loss curve bit-for-bit (tests/test_fault_tolerance.py).
* preemption: if the cluster agent drops a PREEMPTED flag in the ckpt
  root, the loop saves and exits cleanly at the next step boundary.
* straggler watchdog: per-step wall time is tracked with an EWMA; steps
  slower than ``watchdog_factor``× the EWMA are counted and logged — on a
  real fleet this signal feeds the scheduler that re-shards around slow
  hosts (here it is surfaced in metrics).
* metrics: JSONL, one line per logged step.
"""

from __future__ import annotations

import json
import os
import time  # reprolint: ignore-file[wall-clock] -- training throughput logs report real step wall time
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager


@dataclass
class TrainerConfig:
    num_steps: int = 100
    ckpt_every: int = 50
    keep_ckpts: int = 3
    log_every: int = 10
    watchdog_factor: float = 3.0
    metrics_path: Optional[str] = None


class Trainer:
    def __init__(self, train_step: Callable, params, opt_state,
                 batch_at: Callable[[int], dict], ckpt_root: str,
                 tc: TrainerConfig, put_batch: Optional[Callable] = None):
        self.train_step = train_step
        self.params = params
        self.opt_state = opt_state
        self.batch_at = batch_at
        self.mgr = CheckpointManager(ckpt_root, keep=tc.keep_ckpts)
        self.tc = tc
        self.put_batch = put_batch or (lambda b: b)
        self.start_step = 0
        self.straggler_events = 0
        self._ewma = None

    def restore_if_available(self) -> int:
        step, tree, _meta = self.mgr.restore_latest(
            {"params": self.params, "opt": self.opt_state})
        if step is None:
            return 0
        self.params = jax.tree.map(
            lambda t, x: jax.device_put(np.asarray(x), getattr(t, "sharding", None)),
            self.params, tree["params"])
        self.opt_state = jax.tree.map(
            lambda t, x: jax.device_put(np.asarray(x), getattr(t, "sharding", None)),
            self.opt_state, tree["opt"])
        self.start_step = step
        return step

    def _save(self, step: int):
        self.mgr.save(step, {"params": self.params, "opt": self.opt_state},
                      meta={"straggler_events": self.straggler_events})

    def run(self) -> dict:
        tc = self.tc
        metrics_f = open(tc.metrics_path, "a") if tc.metrics_path else None
        last = {}
        step = self.start_step
        while step < tc.num_steps:
            if self.mgr.preempted():
                self._save(step)
                self.mgr.clear_preemption()
                if metrics_f:
                    metrics_f.close()
                return {"preempted_at": step, **last}
            batch = self.put_batch(self.batch_at(step))
            t0 = time.monotonic()
            self.params, self.opt_state, m = self.train_step(
                self.params, self.opt_state, batch)
            jax.block_until_ready(m["loss"])
            dt = time.monotonic() - t0
            if self._ewma is None:
                self._ewma = dt
            elif dt > self.tc.watchdog_factor * self._ewma:
                self.straggler_events += 1
            self._ewma = 0.9 * self._ewma + 0.1 * dt
            step += 1
            if step % tc.log_every == 0 or step == tc.num_steps:
                last = {k: float(v) for k, v in m.items()}
                last.update(step=step, sec_per_step=round(dt, 4),
                            stragglers=self.straggler_events)
                if metrics_f:
                    metrics_f.write(json.dumps(last) + "\n")
                    metrics_f.flush()
            if step % tc.ckpt_every == 0 or step == tc.num_steps:
                self._save(step)
        if metrics_f:
            metrics_f.close()
        return last
