from repro.train.train_step import build_train_step
from repro.train.trainer import Trainer, TrainerConfig

__all__ = ["build_train_step", "Trainer", "TrainerConfig"]
