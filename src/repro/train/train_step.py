"""Train-step builder: value_and_grad + clip + optional microbatch
accumulation + optional gradient compression + optimizer step.

The returned function is pure (params, opt_state, batch) →
(params, opt_state, metrics) and is jitted by the caller with whatever
in/out shardings the run wants (see repro.launch.dryrun / trainer).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.optim import clip_by_global_norm, compress_grads_bf16


def build_train_step(loss_fn: Callable, optimizer, *, clip: float = 1.0,
                     accum: int = 1, grad_bf16: bool = False):
    """loss_fn(params, batch) -> (loss, aux_dict)."""

    def grads_of(params, batch):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return loss, aux, grads

    def train_step(params, opt_state, batch):
        if accum > 1:
            def micro(carry, mb):
                gsum, lsum = carry
                loss, _aux, grads = grads_of(params, mb)
                gsum = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / accum, gsum, grads)
                return (gsum, lsum + loss / accum), None

            micro_batches = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch)
            gsum0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(micro, (gsum0, jnp.float32(0.0)),
                                            micro_batches)
            aux = {}
        else:
            loss, aux, grads = grads_of(params, batch)

        if grad_bf16:
            grads = compress_grads_bf16(grads)
        grads, gnorm = clip_by_global_norm(grads, clip)
        new_params, new_state = optimizer.step(params, grads, opt_state)
        metrics = {"loss": loss, "grad_norm": gnorm}
        for k, v in (aux or {}).items():
            metrics[k] = v
        return new_params, new_state, metrics

    return train_step
