from repro.utils.pytree import (
    tree_size,
    tree_bytes,
    tree_map_with_path,
    tree_flatten_with_names,
    pformat_tree,
    tree_allclose,
)
from repro.utils.rng import Keys

__all__ = [
    "tree_size",
    "tree_bytes",
    "tree_map_with_path",
    "tree_flatten_with_names",
    "pformat_tree",
    "tree_allclose",
    "Keys",
]
