"""Pytree helpers used across the framework."""

from __future__ import annotations

from typing import Any, Callable

import jax
import numpy as np


def tree_size(tree: Any) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree: Any) -> int:
    """Total bytes across all leaves (uses leaf dtype)."""
    total = 0
    for x in jax.tree.leaves(tree):
        total += int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize
    return total


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_map_with_path(fn: Callable[[str, Any], Any], tree: Any) -> Any:
    """Map ``fn(path_string, leaf)`` over a pytree."""
    return jax.tree_util.tree_map_with_path(lambda p, x: fn(_path_str(p), x), tree)


def tree_flatten_with_names(tree: Any):
    """Flatten to a list of (path_string, leaf)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(_path_str(p), x) for p, x in flat]


def pformat_tree(tree: Any) -> str:
    lines = []
    for name, leaf in tree_flatten_with_names(tree):
        lines.append(f"{name:<60s} {str(leaf.shape):<24s} {leaf.dtype}")
    return "\n".join(lines)


def tree_allclose(a: Any, b: Any, *, rtol=1e-5, atol=1e-5) -> bool:
    leaves_a, treedef_a = jax.tree.flatten(a)
    leaves_b, treedef_b = jax.tree.flatten(b)
    if treedef_a != treedef_b:
        return False
    return all(
        np.allclose(np.asarray(x), np.asarray(y), rtol=rtol, atol=atol)
        for x, y in zip(leaves_a, leaves_b)
    )
