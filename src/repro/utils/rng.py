"""Deterministic PRNG-key folding helper.

``Keys`` wraps a root key and hands out named subkeys; the same name always
yields the same subkey, so parameter initialization is order-independent.
"""

from __future__ import annotations

import hashlib

import jax


def _name_to_int(name: str) -> int:
    return int.from_bytes(hashlib.blake2s(name.encode(), digest_size=4).digest(), "little")


class Keys:
    def __init__(self, key_or_seed):
        if isinstance(key_or_seed, int):
            self.key = jax.random.key(key_or_seed)
        else:
            self.key = key_or_seed

    def __call__(self, name: str) -> jax.Array:
        return jax.random.fold_in(self.key, _name_to_int(name))

    def child(self, name: str) -> "Keys":
        return Keys(self(name))
