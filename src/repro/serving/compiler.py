"""Online prefix compiler: many-shot compression inside the serving loop.

The offline story (``launch/serve.py`` stage 1) assumes every ICL task's
compressed prefix was materialized ahead of time.  The
:class:`PrefixCompiler` removes that assumption: a :class:`~repro.serving
.scheduler.Request` may carry its **raw shot tokens** (``raw_shots``),
and the engine compiles them *on the inference path* —

    raw shots ──compress_chunk×N──▶ prefix O^i ──materialize_prefix──▶
    PrefixStore / PagedPrefixStore ──▶ waiting requests wake

— in fixed token-budget chunks interleaved with decode steps, so slots
already seated keep emitting tokens while a cold task compiles
(``ServingEngine(compile_token_budget=…)`` sets the per-iteration
budget; ``None`` compiles a whole task in one go, the stalled baseline
measured by ``benchmarks/serving_bench.py``'s ``online_compile``
section).

Single-flight dedup: jobs are keyed by prefix name — requests that name
the same task (or carry byte-identical shot sets, which hash to the same
auto-generated name) share one compilation, however many arrive while it
is in flight.

Compilation is the path of last resort: with a tiered prefix store
(``serving/tiers.py``) an *evicted* prefix is demoted down the memory
hierarchy rather than destroyed, and the engine routes a cold request
to the (much cheaper) promotion path first — the compiler only sees
tasks no tier has ever held.

The compiler is pure control plane + functional jax calls: it owns no
engine state.  The engine drives it (``step``), installs finished
prefixes into its store (handling paged LRU/`PrefixSeatedError`
deferral), and wakes the scheduler's ``waiting_on_prefix`` requests.
See docs/ARCHITECTURE.md for the request lifecycle.
"""

from __future__ import annotations

import itertools
from collections import OrderedDict
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.core import memcom
from repro.serving.prefix_store import materialize_prefix
from repro.sharding.rules import BASELINE_RULES
from repro.sharding.serving import constrain_cache


def pow2_bucket(n: int, floor: int) -> int:
    """Snap ``n`` up to a power of two, at least ``floor`` — the one
    bucketing rule for every shape the serving path compiles against
    (engine prefill widths, compiler source-cache lengths)."""
    return max(floor, 1 << (max(1, n) - 1).bit_length())


def _bucket_len(n: int) -> int:
    """Source-cache lengths snap to powers of two (min 16): the chunk
    programs are keyed by (offset, width, cache_len), so tasks of similar
    size share compilations; the unused cache tail is never read."""
    return pow2_bucket(n, 16)

#: job lifecycle (the ``compiling`` stage of the request lifecycle)
_STAGES = ("queued", "compiling", "compiled", "installed")


@dataclass
class CompileJob:
    """One task's compilation: raw shot tokens → materialized prefix.

    ``status``: ``queued`` (no chunk run yet) → ``compiling`` (source
    cache live, ``consumed`` of ``len(tokens)`` processed) → ``compiled``
    (materialized prefix ready, not yet resident in the engine's store —
    installation can be deferred under paged seat pressure) →
    ``installed``.
    """

    name: str
    tokens: np.ndarray                         # (T,) int32 shot tokens
    status: str = "queued"
    consumed: int = 0
    state: Optional[memcom.CompressionState] = None
    materialized: Optional[dict] = None        # set when status >= compiled
    widths: List[int] = field(default_factory=list)  # chunk widths run
    priority: int = 0                          # best class waiting on it
    seq: int = 0                               # submission order (FIFO ties)

    def __post_init__(self):
        self.tokens = np.asarray(self.tokens, np.int32).reshape(-1)
        if self.tokens.size == 0:
            raise ValueError(f"job {self.name!r}: empty shot set")

    @property
    def remaining(self) -> int:
        return len(self.tokens) - self.consumed


class PrefixCompiler:
    """Compiles raw many-shot prompts into materialized prefixes, a
    token-budgeted chunk at a time, with single-flight dedup per task.

    A mid-flight job always runs to completion first (one source cache
    lives at a time, so in-flight compile memory is bounded by one
    task's window regardless of queue depth); among queued jobs the best
    ``(priority, submission order)`` starts next — plain FIFO when every
    request shares one priority class.  ``step(budget)`` is the only
    compute entry point — the serving loop calls it between decode steps.
    """

    def __init__(self, compressor, cfg: ModelConfig, target_params, *,
                 impl: str = "auto", mesh=None, rules=None):
        if cfg.memcom is None:
            raise ValueError(f"{cfg.name}: ModelConfig.memcom is unset — "
                             "nothing to compile prefixes with")
        self.compressor = compressor
        self.cfg = cfg
        self.target_params = target_params
        self.impl = impl
        # tensor-parallel serving: the finish pass pins the materialized
        # per-layer KV to the engine's head-sharded pool layout, so a
        # fresh compile lands directly in the sharded store/pools — no
        # replicated detour (and no host gather) on the install path
        self.mesh = mesh
        self.rules = rules
        self._jobs: "OrderedDict[str, CompileJob]" = OrderedDict()
        self._seq = itertools.count()  # submission order for FIFO ties
        # compiled programs: chunk steps keyed by their static geometry
        # (offset, width, cache_len), the finish/materialize pass by its
        # chunk-width pattern.  All-but-last chunks share the budget width
        # and the cache length is pow2-bucketed, so same-bucket tasks
        # reuse programs; only the remainder chunk and the finish pass are
        # per-(T mod budget) — recurrent families forbid padding the last
        # chunk (pads would advance the SSM state).  Both caches are
        # LRU-bounded so a long-lived engine serving many task lengths
        # cannot accumulate programs forever.
        self._chunk_jit: "OrderedDict[Tuple[int, int, int], object]" = \
            OrderedDict()
        self._finish_jit: "OrderedDict[Tuple[Tuple[int, ...], int], object]" \
            = OrderedDict()
        self._jit_cache_cap = 64
        self.stats: Dict[str, int] = {
            "jobs": 0,          # distinct compilations started
            "deduped": 0,       # submits that joined an in-flight job
            "chunks": 0,        # compress_chunk calls
            "tokens": 0,        # source tokens consumed
            "compiled": 0,      # jobs finished (materialized)
        }

    # ---- queue side ----

    def submit(self, name: str, raw_shots, priority: int = 0) -> CompileJob:
        """Request compilation of ``raw_shots`` under ``name``.

        Single-flight: a second submit for a name whose job is still
        queued/compiling/compiled joins that job (first writer wins on
        the token content; the job takes the *best* priority class any
        joiner asked for).  Installed jobs were dropped from the table,
        so a name the store has since evicted is simply recompiled.
        """
        job = self._jobs.get(name)
        if job is not None:
            self.stats["deduped"] += 1
            job.priority = min(job.priority, priority)
            return job
        job = CompileJob(name=name, tokens=raw_shots, priority=priority,
                         seq=next(self._seq))
        self._jobs[name] = job
        self.stats["jobs"] += 1
        return job

    def job(self, name: str) -> CompileJob:
        return self._jobs[name]

    def has_compile_work(self) -> bool:
        """Any job still consuming source tokens?"""
        return any(j.status in ("queued", "compiling")
                   for j in self._jobs.values())

    def ready(self) -> List[str]:
        """Names compiled but not yet installed into the engine's store."""
        return [n for n, j in self._jobs.items() if j.status == "compiled"]

    def pending(self) -> bool:
        """Anything between submission and store residency?"""
        return any(j.status != "installed" for j in self._jobs.values())

    def mark_installed(self, name: str) -> None:
        """Drop a job once its prefix is store-resident.  The entry is
        deleted outright — keeping it would grow ``_jobs`` (and pin every
        task's shot tokens) for the engine's lifetime; a resubmit after a
        later store eviction simply opens a fresh job."""
        job = self._jobs.pop(name)
        assert job.status == "compiled", job.status
        job.status = "installed"
        job.materialized = None  # resident in the store now; drop our copy
        job.state = None

    # ---- compute side ----

    @staticmethod
    def _cached(cache: "OrderedDict", cap: int, key, make):
        fn = cache.get(key)
        if fn is None:
            fn = cache[key] = make()
            while len(cache) > cap:
                cache.popitem(last=False)  # drop the oldest program
        else:
            cache.move_to_end(key)
        return fn

    def chunk_body(self, offset: int):
        """The pure computation of one chunk step: ``(compressor, cache,
        tokens) -> (new_cache, hiddens)``.  Exposed unjitted so the
        engine's *fused* serving step can inline a compile chunk into
        the same program as the batched decode — one dispatch instead of
        a decode gap (see ``ServingEngine(fused_step=True)``)."""
        cfg, impl = self.cfg, self.impl

        def run(compressor, cache, tokens):
            state = memcom.CompressionState(cache=cache, offset=offset)
            state = memcom.compress_chunk(compressor, cfg, state, tokens,
                                          impl=impl)
            return state.cache, state.hiddens[0]

        return run

    def _chunk_fn(self, offset: int, width: int, cache_len: int):
        """One compiled chunk step.  Eager ``compress_chunk`` would
        re-trace its scans every call — the whole point of chunking
        (short, predictable gaps between decode steps) dies without jit —
        so chunk programs are compiled once per static geometry and
        reused across tasks."""
        body = self.chunk_body(offset)
        return self._cached(self._chunk_jit, self._jit_cache_cap,
                            (offset, width, cache_len),
                            lambda: jax.jit(body))

    def _finish_fn(self, widths: Tuple[int, ...], cache_len: int):
        """Compiled finish: Memory-LLM pass over the accumulated H^i +
        prefix packaging + materialization through the frozen target.
        One program in either budget mode — the Memory-LLM cross-attends
        *all* H^i at once, so this pass cannot be sliced the way the
        source pass can (the one decode gap chunking does not bound)."""
        cfg, impl, total = self.cfg, self.impl, sum(widths)
        mesh, rules = self.mesh, self.rules

        def make():
            def run(compressor, target_params, cache, hiddens):
                state = memcom.CompressionState(
                    cache=cache, offset=total, hiddens=list(hiddens))
                prefix, _ = memcom.finish_compress(compressor, cfg, state,
                                                   impl=impl)
                out = materialize_prefix(target_params, cfg, prefix)
                if mesh is not None:
                    out = constrain_cache(out, mesh,
                                          rules or BASELINE_RULES)
                return out

            return jax.jit(run)

        return self._cached(self._finish_jit, self._jit_cache_cap,
                            (widths, cache_len), make)

    def _live_job(self) -> Optional[CompileJob]:
        """The job the next chunk belongs to: one live source cache at a
        time, so a mid-flight job always runs to completion; otherwise
        the best ``(priority, seq)`` queued job starts — FIFO within a
        class."""
        job = next((j for j in self._jobs.values()
                    if j.status == "compiling"), None)
        if job is None:
            queued = [j for j in self._jobs.values() if j.status == "queued"]
            job = (min(queued, key=lambda j: (j.priority, j.seq))
                   if queued else None)
        return job

    def peek_chunk(self, token_budget: Optional[int] = None
                   ) -> Optional[Tuple[CompileJob, int, int, int]]:
        """Describe — and stage — the chunk the next :meth:`step` would
        run: ``(job, offset, width, cache_len)``, or None when no job
        has source tokens left.  Initializes the job's source cache
        (``begin_compress``) so ``job.state.cache`` is ready to feed a
        chunk program.  The engine's fused step uses this to key/trace
        its combined decode+compile program, then hands the result to
        :meth:`absorb_chunk`."""
        job = self._live_job()
        if job is None:
            return None
        if job.state is None:
            job.state = memcom.begin_compress(
                self.cfg, 1, _bucket_len(len(job.tokens)),
                mc_params=self.compressor, impl=self.impl)
            job.status = "compiling"
        w = (job.remaining if token_budget is None
             else min(job.remaining, token_budget))
        return job, job.consumed, w, _bucket_len(len(job.tokens))

    def chunk_tokens(self, job: CompileJob, width: int):
        """The (1, width) token slice the next chunk consumes."""
        return jnp.asarray(
            job.tokens[None, job.consumed:job.consumed + width])

    def absorb_chunk(self, job: CompileJob, cache, hid, width: int
                     ) -> List[str]:
        """Fold one chunk's result back into the job: advance the source
        state, bump the counters, and — when the last source token has
        been consumed — run the (jitted) finish/materialize pass.
        Returns ``[job.name]`` if the job just compiled, else ``[]``."""
        job.state = replace(job.state, cache=cache,
                            offset=job.consumed + width,
                            hiddens=job.state.hiddens + [hid])
        job.consumed += width
        job.widths.append(width)
        self.stats["chunks"] += 1
        self.stats["tokens"] += width
        if job.remaining:
            return []
        fn = self._finish_fn(tuple(job.widths),
                             _bucket_len(len(job.tokens)))
        job.materialized = fn(self.compressor, self.target_params,
                              job.state.cache, tuple(job.state.hiddens))
        job.state = None  # free the source cache
        job.status = "compiled"
        self.stats["compiled"] += 1
        return [job.name]

    def step(self, token_budget: Optional[int] = None) -> List[str]:
        """Advance compilation by up to ``token_budget`` source tokens
        (``None`` = run the head job to completion — the stalled
        baseline).  Returns the names that finished this call."""
        finished: List[str] = []
        budget = token_budget
        while budget is None or budget > 0:
            nxt = self.peek_chunk(budget)
            if nxt is None:
                break
            job, offset, w, cache_len = nxt
            fn = self._chunk_fn(offset, w, cache_len)
            cache, hid = fn(self.compressor, job.state.cache,
                            self.chunk_tokens(job, w))
            finished += self.absorb_chunk(job, cache, hid, w)
            if budget is not None:
                budget -= w
            elif finished:
                break  # None = one whole job, not the whole queue
        return finished
