"""Injectable clocks for the serving stack.

The engine and scheduler never call :func:`time.perf_counter` directly;
they call ``self.clock()``.  In production that *is* ``perf_counter``,
but tests and the traffic simulation inject a :class:`VirtualClock` so
every timestamp — arrival, TTFT, decode gap, aging — is a deterministic
function of the work performed, not of the host machine.

A bare fake clock (one that only ever returns what you set) would make
latency metrics degenerate: every decode step would take zero seconds
and the budget autotuner would have nothing to react to.  The virtual
clock therefore carries a *cost model*: the engine calls
``clock.charge(kind, units)`` at each work site (one decode step, one
prefilled token, one compiled token, one promoted chunk) and the clock
advances by ``costs[kind] * units``.  Simulated time then moves the way
wall time would — compile-heavy stretches stretch the decode gap, idle
waits jump with :meth:`VirtualClock.advance_to` — while staying
bit-reproducible across runs and machines.

On a real (wall) clock both hooks are absent; the engine detects that
with ``getattr`` and charging becomes a no-op while waits become short
sleeps.
"""

from __future__ import annotations

from typing import Dict, Optional

__all__ = ["VirtualClock", "DEFAULT_COSTS"]

# Rough relative costs (seconds per unit of work).  Absolute values are
# arbitrary — only the ratios matter for scheduling decisions — but they
# are chosen so a decode step dominates a prefilled token and a budgeted
# compile/promote slice lands in the same order of magnitude as a step,
# mirroring the interleaving the real engine exhibits.
DEFAULT_COSTS: Dict[str, float] = {
    "decode_step": 1e-3,     # one batched decode step
    "prefill_token": 2e-5,   # one token of (padded) prefill width
    "compile_token": 2e-4,   # one source token consumed by the compiler
    "promote_chunk": 1e-4,   # one layer-chunk copied up a tier
    "draft_step": 2e-4,      # one drafter step (speculative decoding) —
                             # the drafter is the small sibling config, so
                             # a step costs a fraction of the target's
}


class VirtualClock:
    """Deterministic simulated clock with a work cost model.

    Calling the instance returns the current simulated time in seconds,
    so it is a drop-in for ``time.perf_counter`` wherever a zero-arg
    callable is expected.
    """

    def __init__(self, costs: Optional[Dict[str, float]] = None,
                 start: float = 0.0):
        self._t = float(start)
        self.costs = dict(DEFAULT_COSTS)
        if costs:
            self.costs.update(costs)
        self._charged_seconds = None  # labeled counter, see attach_metrics
        self._charged_units = None
        self._attached: list = []

    def attach_metrics(self, registry) -> None:
        """Register charged-work counters into a MetricsRegistry (duck-
        typed: anything with ``counter(name, help, labelnames)``): how
        much simulated time and how many work units each ``kind`` has
        consumed.  Idempotent per registry — the engine calls this from
        its constructor, and one clock may drive several engines sharing
        a registry."""
        if any(r is registry for r in self._attached):
            return
        self._attached.append(registry)
        self._charged_seconds = registry.counter(
            "virtual_clock_charged_seconds_total",
            "simulated seconds charged, by work kind",
            labelnames=("kind",))
        self._charged_units = registry.counter(
            "virtual_clock_charged_units_total",
            "work units charged, by work kind", labelnames=("kind",))

    def __call__(self) -> float:
        return self._t

    @property
    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError("clock cannot run backwards")
        self._t += float(dt)

    def advance_to(self, t: float) -> None:
        """Jump forward to ``t`` (idle wait); never moves backwards."""
        self._t = max(self._t, float(t))

    def charge(self, kind: str, units: float = 1.0) -> None:
        """Advance by the modeled cost of ``units`` of work of ``kind``."""
        dt = self.costs.get(kind, 0.0) * float(units)
        self._t += dt
        if self._charged_seconds is not None:
            self._charged_seconds.inc(dt, kind=kind)
            self._charged_units.inc(float(units), kind=kind)

    def __repr__(self):  # pragma: no cover - debug aid
        return f"VirtualClock(t={self._t:.6f})"
