"""Per-phase profiler: fold flight-recorder spans into self-time.

Consumes a Chrome-trace dict (the :meth:`Tracer.chrome_trace` export —
the same artifact CI already schema-validates) and attributes time to
the serving subsystems:

=========  =====================================================
phase      spans
=========  =====================================================
decode     ``decode_step`` / ``fused_step`` on the engine track
prefill    per-slot ``admission`` spans (classic path; fused
           joins are *counted* but excluded from interval math —
           their work happens inside fused steps)
compile    ``compile_chunk`` on the compiler track
promote    ``promote_chunk`` on the promoter track
=========  =====================================================

``total_s`` is the union measure of a phase's intervals.  ``self_s``
subtracts time explainable by work that *rides* the phase's dispatch:
a fused compile chunk's span coincides exactly with its fused step, so
decode self-time excludes compile/promote/prefill overlap.  Speculative
decoding has no span of its own (acceptance is free within the fused
step) and is reported as an instant count.

On the virtual clock every number here is a pure function of
(scenario, seed) — the perf-regression gate (`tools/bench_compare.py`)
diffs these reports across commits with exact thresholds.
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Tuple

__all__ = ["profile_spans", "validate_profile_report",
           "PROFILE_REPORT_SCHEMA"]

PROFILE_REPORT_SCHEMA = "repro/profile-report/v1"

PHASES = ("decode", "prefill", "compile", "promote")

_PHASE_SPANS = {
    "decode_step": "decode",
    "fused_step": "decode",
    "admission": "prefill",
    "compile_chunk": "compile",
    "promote_chunk": "promote",
}

_COUNTED_INSTANTS = ("spec_accept", "preempt", "resume", "autotune")


def _merge(intervals: List[Tuple[float, float]]) -> List[Tuple[float, float]]:
    if not intervals:
        return []
    out: List[Tuple[float, float]] = []
    for lo, hi in sorted(intervals):
        if out and lo <= out[-1][1]:
            if hi > out[-1][1]:
                out[-1] = (out[-1][0], hi)
        else:
            out.append((lo, hi))
    return out


def _measure(merged: Iterable[Tuple[float, float]]) -> float:
    return sum(hi - lo for lo, hi in merged)


def _subtract(merged: List[Tuple[float, float]],
              cuts: List[Tuple[float, float]]) -> List[Tuple[float, float]]:
    """Set difference of two merged interval lists."""
    out: List[Tuple[float, float]] = []
    for lo, hi in merged:
        cur = lo
        for c0, c1 in cuts:
            if c1 <= cur or c0 >= hi:
                continue
            if c0 > cur:
                out.append((cur, c0))
            cur = max(cur, c1)
            if cur >= hi:
                break
        if cur < hi:
            out.append((cur, hi))
    return out


def profile_spans(trace: dict) -> dict:
    """Fold a Chrome-trace dict into a ``repro/profile-report/v1``."""
    events = trace.get("traceEvents", [])
    intervals: Dict[str, List[Tuple[float, float]]] = {p: [] for p in PHASES}
    spans: Dict[str, int] = {p: 0 for p in PHASES}
    counts: Dict[str, int] = {f"{n}s": 0 for n in _COUNTED_INSTANTS}
    counts["fused_joins"] = 0
    for ev in events:
        ph, name = ev.get("ph"), ev.get("name")
        if ph == "i" and name in _COUNTED_INSTANTS:
            counts[f"{name}s"] += 1
            continue
        if ph != "X":
            continue
        phase = _PHASE_SPANS.get(name)
        if phase is None:
            continue
        args = ev.get("args") or {}
        if name == "admission" and args.get("fused_join"):
            # the join's prompt streamed through fused steps: its span
            # covers the whole join window, which *is* decode time
            counts["fused_joins"] += 1
            continue
        t0 = float(ev["ts"]) * 1e-6
        t1 = t0 + float(ev.get("dur", 0.0)) * 1e-6
        intervals[phase].append((t0, t1))
        spans[phase] += 1

    merged = {p: _merge(intervals[p]) for p in PHASES}
    ridealong = _merge(merged["compile"] + merged["promote"]
                       + merged["prefill"])
    phases = {}
    for p in PHASES:
        total = _measure(merged[p])
        if p == "decode":
            self_s = _measure(_subtract(merged[p], ridealong))
        else:
            self_s = total
        phases[p] = {"spans": spans[p],
                     "total_s": round(total, 9),
                     "self_s": round(self_s, 9)}
    wall = _measure(_merge([iv for p in PHASES for iv in merged[p]]))
    return {"schema": PROFILE_REPORT_SCHEMA,
            "wall_s": round(wall, 9),
            "phases": phases,
            "counts": counts}


def validate_profile_report(doc: dict) -> List[str]:
    """Schema-check a profile report; returns problems (empty = valid).
    Shared by tests and ``benchmarks.validate_trace``."""
    errs: List[str] = []
    if doc.get("schema") != PROFILE_REPORT_SCHEMA:
        errs.append(f"schema != {PROFILE_REPORT_SCHEMA!r}: "
                    f"{doc.get('schema')!r}")
    phases = doc.get("phases")
    if not isinstance(phases, dict):
        return errs + ["phases missing or not a dict"]
    for p in PHASES:
        st = phases.get(p)
        if not isinstance(st, dict):
            errs.append(f"phase {p!r} missing")
            continue
        for field in ("spans", "total_s", "self_s"):
            v = st.get(field)
            if not isinstance(v, (int, float)) or v < 0:
                errs.append(f"phase {p}: bad {field!r}: {v!r}")
        if isinstance(st.get("self_s"), (int, float)) and \
                isinstance(st.get("total_s"), (int, float)) and \
                st["self_s"] > st["total_s"] + 1e-9:
            errs.append(f"phase {p}: self_s exceeds total_s")
    wall = doc.get("wall_s")
    if not isinstance(wall, (int, float)) or wall < 0:
        errs.append(f"bad wall_s: {wall!r}")
    elif isinstance(phases.get("decode", {}).get("total_s"), (int, float)) \
            and wall + 1e-9 < max(
                (st.get("total_s", 0.0) for st in phases.values()
                 if isinstance(st, dict)), default=0.0):
        errs.append("wall_s smaller than a single phase total")
    counts = doc.get("counts")
    if not isinstance(counts, dict):
        errs.append("counts missing or not a dict")
    else:
        for k, v in counts.items():
            if not isinstance(v, int) or v < 0:
                errs.append(f"counts[{k!r}]: bad value {v!r}")
    return errs
