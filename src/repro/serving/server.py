"""HTTP telemetry plane: scrape a live (or finished) engine in-process.

A deliberately small stdlib-asyncio HTTP/1.0 server — no framework, no
dependency — that runs its own event loop on a daemon thread next to
``ServingEngine.serve()`` and exposes read-only observability:

===================  ====================================================
endpoint             body
===================  ====================================================
``GET /metrics``     Prometheus text exposition from the engine's
                     :class:`MetricsRegistry` (``render_prometheus()``)
``GET /healthz``     JSON liveness: engine present, virtual ``now`` and
                     the age of the last decode step, both on the
                     *injected* clock
``GET /debug/state`` the deep-copied ``engine.stats()`` tree as JSON
``GET /debug/trace`` the flight recorder's Chrome-trace dump
===================  ====================================================

Thread-safety is by construction, not locks: every handler only *reads*
engine state; the GIL keeps individual dict/deque operations atomic, and
the only cross-thread hazard — "dict changed size during iteration"
while the engine mutates a registry mid-render — is handled by
retrying the snapshot a few times.  The serving loop itself never sees
the server: attaching one cannot change the token stream.

The server is the observability half of the ROADMAP's async front-end:
the future router scrapes these endpoints per replica.
"""

from __future__ import annotations

import asyncio
import json
import threading
from typing import Callable, Optional, Tuple

__all__ = ["TelemetryServer"]

_STATUS_TEXT = {200: "OK", 404: "Not Found", 405: "Method Not Allowed",
                500: "Internal Server Error"}

#: Attempts at a consistent read while the engine thread mutates state.
_SNAPSHOT_ATTEMPTS = 8


def _jsonable(obj):
    """Last-resort encoder: numpy scalars → python, else repr."""
    item = getattr(obj, "item", None)
    if callable(item):
        return item()
    return repr(obj)


class TelemetryServer:
    """Serve an engine's telemetry over HTTP from a background thread.

    ``port=0`` binds an ephemeral port; :meth:`start` returns the bound
    port and records it as :attr:`bound_port`.  Usable as a context
    manager::

        with TelemetryServer(engine, port=0) as srv:
            engine.serve(requests, seed=0)
            # curl http://127.0.0.1:{srv.bound_port}/metrics
    """

    def __init__(self, engine, host: str = "127.0.0.1", port: int = 0):
        self.engine = engine
        self.host = host
        self.port = port
        self.bound_port: Optional[int] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None
        self._server: Optional[asyncio.AbstractServer] = None

    # -- lifecycle -----------------------------------------------------

    def start(self, timeout_s: float = 10.0) -> int:
        if self._thread is not None:
            raise RuntimeError("TelemetryServer already started")
        self._loop = asyncio.new_event_loop()
        started = threading.Event()

        def _run():
            asyncio.set_event_loop(self._loop)
            try:
                self._server = self._loop.run_until_complete(
                    asyncio.start_server(self._handle, self.host, self.port))
                self.bound_port = \
                    self._server.sockets[0].getsockname()[1]
            finally:
                started.set()
            self._loop.run_forever()
            # drain: close the listener inside the loop it belongs to
            if self._server is not None:
                self._server.close()
                self._loop.run_until_complete(self._server.wait_closed())
            self._loop.close()

        self._thread = threading.Thread(target=_run, daemon=True,
                                        name="telemetry-http")
        self._thread.start()
        if not started.wait(timeout_s) or self.bound_port is None:
            raise RuntimeError(
                f"telemetry server failed to bind {self.host}:{self.port}")
        return self.bound_port

    def stop(self, timeout_s: float = 10.0) -> None:
        if self._thread is None:
            return
        self._loop.call_soon_threadsafe(self._loop.stop)
        self._thread.join(timeout_s)
        self._thread = None
        self._server = None
        self._loop = None

    def __enter__(self) -> "TelemetryServer":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- request handling ----------------------------------------------

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            request_line = await reader.readline()
            while True:  # drain headers; we never need them
                line = await reader.readline()
                if line in (b"\r\n", b"\n", b""):
                    break
            parts = request_line.split()
            if len(parts) < 2:
                return
            method, path = parts[0].decode("latin-1"), \
                parts[1].decode("latin-1")
            try:
                status, ctype, body = self._route(method, path)
            except Exception as e:  # surface, don't kill the server
                status, ctype = 500, "text/plain; charset=utf-8"
                body = f"internal error: {type(e).__name__}: {e}\n"
            payload = body.encode("utf-8")
            head = (f"HTTP/1.0 {status} {_STATUS_TEXT[status]}\r\n"
                    f"Content-Type: {ctype}\r\n"
                    f"Content-Length: {len(payload)}\r\n"
                    "Connection: close\r\n\r\n")
            writer.write(head.encode("latin-1") + payload)
            await writer.drain()
        except (ConnectionError, asyncio.IncompleteReadError):
            pass
        finally:
            try:
                writer.close()
            except Exception:
                pass

    def _route(self, method: str, path: str) -> Tuple[int, str, str]:
        if method != "GET":
            return 405, "text/plain; charset=utf-8", "GET only\n"
        path = path.split("?", 1)[0]
        if path == "/metrics":
            text = self._read(self.engine.metrics.render_prometheus)
            return 200, "text/plain; version=0.0.4; charset=utf-8", text
        if path == "/healthz":
            return 200, "application/json", self._healthz()
        if path == "/debug/state":
            state = self._read(self.engine.stats)
            return 200, "application/json", json.dumps(
                state, sort_keys=True, default=_jsonable) + "\n"
        if path == "/debug/trace":
            trace = self._read(self.engine.tracer.chrome_trace)
            return 200, "application/json", json.dumps(
                trace, sort_keys=True, default=_jsonable) + "\n"
        return 404, "text/plain; charset=utf-8", f"no route {path}\n"

    def _healthz(self) -> str:
        now = float(self.engine.clock())
        last = self.engine.last_step_t
        doc = {
            "status": "ok" if last is not None else "idle",
            "now": now,
            "last_step_t": last,
            "last_step_age_s": (now - last) if last is not None else None,
            "slots": int(self.engine.slots),
        }
        wd = getattr(self.engine, "watchdog", None)
        if wd is not None:
            doc["page_active"] = bool(wd.page_active)
            doc["alerts"] = len(wd.alert_log)
        return json.dumps(doc, sort_keys=True) + "\n"

    @staticmethod
    def _read(fn: Callable[[], object]):
        """Snapshot engine state while the serve loop mutates it: any
        single dict op is GIL-atomic, so the only failure mode is an
        iteration invalidated mid-walk — retry a bounded number of
        times, then let the error propagate to the 500 handler."""
        for _ in range(_SNAPSHOT_ATTEMPTS - 1):
            try:
                return fn()
            except RuntimeError:
                continue
        return fn()
