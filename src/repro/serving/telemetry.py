"""Flight-recorder tracing + metrics registry for the serving stack.

Two observability primitives, both stamped by the engine's *injected*
clock (``VirtualClock`` in simulation, wall time in production) so that
enabled telemetry on the virtual clock is a deterministic function of
(scenario, seed):

* :class:`Tracer` — structured spans/instants for the full request
  lifecycle (enqueue, park/wake on prefix, per-chunk compile and
  host→HBM promote, seat, preempt/resume, fused-step lanes, spec
  draft/verify/accept, finish), kept in a bounded ring buffer (the
  **flight recorder**: the last N events survive a crash and can be
  dumped on error or on demand) and exportable as Chrome-trace /
  Perfetto JSON — one track per slot plus engine / compiler / promoter
  / scheduler tracks.

* :class:`MetricsRegistry` — named counters, gauges and histograms
  with label sets.  The engine, scheduler, compiler, tiered store,
  block pool and SLO scoreboard register into one registry;
  ``ServingEngine.stats()`` is a view over it (schema preserved via
  :class:`MetricGroup`), and :meth:`MetricsRegistry.render_prometheus`
  emits the text exposition format for a future HTTP layer.

Disabled telemetry is the :data:`NULL_TRACER` no-op singleton — the
serving loop's token stream is bit-exact with tracing on or off,
because telemetry only ever *reads* the clock and never charges it.
"""

from __future__ import annotations

import json
import math
import os
import time
from collections import OrderedDict, deque
from typing import (Callable, Dict, Iterable, List, Mapping, MutableMapping,
                    Optional, Sequence, Tuple)

__all__ = [
    "Tracer", "NullTracer", "NULL_TRACER",
    "MetricsRegistry", "MetricGroup", "Counter", "Gauge", "Histogram",
    "DEFAULT_TIME_BUCKETS", "validate_chrome_trace", "REQUIRED_SPANS",
]


# ----------------------------------------------------------------------
# Tracer (flight recorder + Chrome-trace export)
# ----------------------------------------------------------------------

# Fixed Chrome-trace thread ids for the shared tracks; per-slot tracks
# ("slot0", "slot1", …) sit at _SLOT_TID_BASE + index so traces from
# engines of any slot count lay out identically.
_TRACK_TIDS = {"engine": 1, "compiler": 2, "promoter": 3, "scheduler": 4}
_SLOT_TID_BASE = 16
_PID = 1

#: Span names the serving loop guarantees for a traffic replay that
#: exercises online compile, tier promotion and priority preemption —
#: the CI schema-validation step asserts these (spec_accept additionally
#: when speculative decoding is on).
REQUIRED_SPANS = ("admission", "waiting_on_prefix", "compile_chunk",
                  "promote_chunk", "preempt", "resume", "decode_step")


def _track_tid(track: str) -> int:
    tid = _TRACK_TIDS.get(track)
    if tid is not None:
        return tid
    if track.startswith("slot"):
        try:
            return _SLOT_TID_BASE + int(track[4:])
        except ValueError:
            pass
    # unknown tracks get a stable tid from their name ordering at export
    return -1


class Tracer:
    """Structured event recorder over an injected clock.

    Events live in a ``deque(maxlen=capacity)`` — the flight recorder:
    with a finite capacity only the most recent events survive, which is
    exactly what a post-mortem wants.  ``capacity=None`` keeps
    everything (bench/trace-export mode).

    The tracer never advances or charges the clock; it only reads it.
    On a :class:`~repro.serving.clock.VirtualClock` every timestamp is
    therefore a pure function of the work the engine performed, and two
    runs of the same (scenario, seed) dump byte-identical JSON.
    """

    enabled = True

    def __init__(self, clock: Optional[Callable[[], float]] = None, *,
                 capacity: Optional[int] = None,
                 dump_path: Optional[str] = None):
        if capacity is not None and capacity < 1:
            raise ValueError("flight-recorder capacity must be >= 1")
        self.clock = clock
        self.capacity = capacity
        self.dump_path = dump_path
        self._events: "deque[dict]" = deque(maxlen=capacity)
        self.dropped = 0  # events pushed out of the ring buffer

    # -- recording -----------------------------------------------------

    def now(self) -> float:
        clock = self.clock if self.clock is not None else time.perf_counter
        return float(clock())

    def _push(self, ev: dict) -> None:
        if self.capacity is not None and len(self._events) == self.capacity:
            self.dropped += 1
        self._events.append(ev)

    def span(self, track: str, name: str, t0: float,
             t1: Optional[float] = None, **args) -> None:
        """A complete ("X") span on ``track`` from ``t0`` to ``t1``
        (default: now).  ``args`` land in the event's args dict."""
        if t1 is None:
            t1 = self.now()
        self._push({"ph": "X", "track": track, "name": name,
                    "t": float(t0), "dur": max(0.0, float(t1) - float(t0)),
                    "args": args})

    def instant(self, track: str, name: str,
                t: Optional[float] = None, **args) -> None:
        self._push({"ph": "i", "track": track, "name": name,
                    "t": self.now() if t is None else float(t),
                    "args": args})

    def begin_async(self, track: str, name: str, aid,
                    t: Optional[float] = None, **args) -> None:
        """Open an async ("b") span — e.g. ``waiting_on_prefix`` between a
        request's park and its wake, keyed by ``aid``."""
        self._push({"ph": "b", "track": track, "name": name, "id": str(aid),
                    "t": self.now() if t is None else float(t),
                    "args": args})

    def end_async(self, track: str, name: str, aid,
                  t: Optional[float] = None, **args) -> None:
        self._push({"ph": "e", "track": track, "name": name, "id": str(aid),
                    "t": self.now() if t is None else float(t),
                    "args": args})

    def clear(self) -> None:
        self._events.clear()
        self.dropped = 0

    def events(self) -> List[dict]:
        """The recorded events, oldest first (internal schema)."""
        return list(self._events)

    # -- export --------------------------------------------------------

    def chrome_trace(self) -> dict:
        """Render the ring buffer as a Chrome-trace / Perfetto JSON
        object: ``{"traceEvents": [...]}`` with one named thread per
        track.  Timestamps convert from clock seconds to microseconds.
        Event order (metadata first, then record order) and key order
        are deterministic."""
        tracks: List[str] = []
        for ev in self._events:
            if ev["track"] not in tracks:
                tracks.append(ev["track"])
        tids: Dict[str, int] = {}
        unknown = sorted(t for t in tracks if _track_tid(t) < 0)
        for t in tracks:
            tid = _track_tid(t)
            tids[t] = tid if tid >= 0 else 1024 + unknown.index(t)
        out: List[dict] = [{
            "ph": "M", "pid": _PID, "tid": 0, "name": "process_name",
            "args": {"name": "serving_engine"},
        }]
        for track in sorted(tracks, key=lambda t: tids[t]):
            out.append({"ph": "M", "pid": _PID, "tid": tids[track],
                        "name": "thread_name", "args": {"name": track}})
            out.append({"ph": "M", "pid": _PID, "tid": tids[track],
                        "name": "thread_sort_index",
                        "args": {"sort_index": tids[track]}})
        for ev in self._events:
            ce = {"ph": ev["ph"], "pid": _PID, "tid": tids[ev["track"]],
                  "name": ev["name"], "cat": "serving",
                  "ts": round(ev["t"] * 1e6, 3)}
            if ev["ph"] == "X":
                ce["dur"] = round(ev["dur"] * 1e6, 3)
            if ev["ph"] == "i":
                ce["s"] = "t"
            if "id" in ev:
                ce["id"] = ev["id"]
            if ev.get("args"):
                ce["args"] = ev["args"]
            out.append(ce)
        return {"traceEvents": out, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def dumps(self) -> str:
        """Serialize deterministically: two runs of the same virtual-
        clock scenario produce byte-identical output."""
        return json.dumps(self.chrome_trace(), sort_keys=True,
                          separators=(",", ":"))

    def dump(self, path: Optional[str] = None) -> str:
        path = path if path is not None else self.dump_path
        if path is None:
            raise ValueError("no dump path: pass one or set dump_path")
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(path, "w") as f:
            f.write(self.dumps())
        return path

    def dump_on_error(self) -> Optional[str]:
        """Best-effort flight-recorder dump from an exception path: write
        to ``dump_path`` if configured, swallow secondary failures."""
        if self.dump_path is None:
            return None
        try:
            return self.dump(self.dump_path)
        except OSError:
            return None


class NullTracer:
    """No-op tracer: the default.  Every method is a pass so disabled
    telemetry costs one attribute lookup per site and the serving loop
    is bit-exact with tracing off."""

    enabled = False
    clock = None
    capacity = None
    dump_path = None
    dropped = 0

    def now(self) -> float:
        return 0.0

    def span(self, *a, **k) -> None:
        pass

    def instant(self, *a, **k) -> None:
        pass

    def begin_async(self, *a, **k) -> None:
        pass

    def end_async(self, *a, **k) -> None:
        pass

    def clear(self) -> None:
        pass

    def events(self) -> List[dict]:
        return []

    def chrome_trace(self) -> dict:
        return {"traceEvents": []}

    def dump_on_error(self) -> None:
        return None


#: Shared no-op tracer — the engine default.
NULL_TRACER = NullTracer()


def validate_chrome_trace(trace: dict,
                          require_spans: Sequence[str] = ()) -> List[str]:
    """Schema-check a Chrome-trace dict; returns a list of problems
    (empty = valid).  Used by tests and the CI validation step."""
    errs: List[str] = []
    events = trace.get("traceEvents")
    if not isinstance(events, list) or not events:
        return ["traceEvents missing or empty"]
    names = set()
    for i, ev in enumerate(events):
        for field in ("ph", "pid", "tid", "name"):
            if field not in ev:
                errs.append(f"event {i}: missing {field!r}")
        ph = ev.get("ph")
        if ph != "M" and "ts" not in ev:
            errs.append(f"event {i}: missing 'ts'")
        if ph == "X" and "dur" not in ev:
            errs.append(f"event {i}: complete span missing 'dur'")
        if ph in ("b", "e") and "id" not in ev:
            errs.append(f"event {i}: async event missing 'id'")
        if ph != "M":
            names.add(ev.get("name"))
    for want in require_spans:
        if want not in names:
            errs.append(f"required span {want!r} absent from trace")
    return errs


# ----------------------------------------------------------------------
# Metrics registry
# ----------------------------------------------------------------------

#: 1-2-5 log ladder in seconds — decode gaps, TTFT and latency all fit.
DEFAULT_TIME_BUCKETS = (
    1e-5, 2e-5, 5e-5, 1e-4, 2e-4, 5e-4, 1e-3, 2e-3, 5e-3,
    1e-2, 2e-2, 5e-2, 0.1, 0.2, 0.5, 1.0, 2.0, 5.0, 10.0,
)


def _fmt_num(v) -> str:
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, int):
        return str(v)
    f = float(v)
    if f == math.inf:
        return "+Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 labelnames: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        # insertion-ordered so exposition order is first-use order
        self._values: "OrderedDict[Tuple[str, ...], object]" = OrderedDict()

    def _key(self, labels: Mapping[str, object]) -> Tuple[str, ...]:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: labels {sorted(labels)} != declared "
                f"{sorted(self.labelnames)}")
        return tuple(str(labels[n]) for n in self.labelnames)

    def value(self, **labels):
        return self._values.get(self._key(labels), 0)

    def series(self) -> Dict[Tuple[str, ...], object]:
        """label-values tuple → value (counters/gauges)."""
        return dict(self._values)

    def _render_labels(self, key: Tuple[str, ...],
                       extra: Sequence[Tuple[str, str]] = ()) -> str:
        pairs = list(zip(self.labelnames, key)) + list(extra)
        if not pairs:
            return ""
        body = ",".join(f'{n}="{v}"' for n, v in pairs)
        return "{" + body + "}"

    def render(self) -> List[str]:
        lines = [f"# HELP {self.name} {self.help}".rstrip(),
                 f"# TYPE {self.name} {self.kind}"]
        for key in sorted(self._values):
            v = self._values[key]
            if v is None:
                continue
            lines.append(
                f"{self.name}{self._render_labels(key)} {_fmt_num(v)}")
        return lines


class Counter(_Metric):
    kind = "counter"

    def inc(self, amount=1, **labels) -> None:
        if amount < 0:
            raise ValueError(f"{self.name}: counters only go up")
        key = self._key(labels)
        self._values[key] = self._values.get(key, type(amount)(0)) + amount


class Gauge(_Metric):
    kind = "gauge"

    def set(self, value, **labels) -> None:
        self._values[self._key(labels)] = value

    def inc(self, amount=1, **labels) -> None:
        key = self._key(labels)
        self._values[key] = self._values.get(key, type(amount)(0)) + amount

    def dec(self, amount=1, **labels) -> None:
        self.inc(-amount, **labels)


class Histogram(_Metric):
    """Fixed-bucket histogram (Prometheus classic style): ``le`` upper
    bounds plus an implicit +Inf bucket, a sum and a count per label
    set.  :meth:`quantile` interpolates linearly inside the containing
    bucket — the same estimator as PromQL ``histogram_quantile``."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labelnames: Sequence[str] = (),
                 buckets: Sequence[float] = DEFAULT_TIME_BUCKETS):
        super().__init__(name, help, labelnames)
        bounds = tuple(float(b) for b in buckets)
        if not bounds or any(b2 <= b1 for b1, b2 in zip(bounds, bounds[1:])):
            raise ValueError(f"{name}: buckets must be strictly increasing")
        self.bounds = bounds

    def _state(self, key: Tuple[str, ...]):
        st = self._values.get(key)
        if st is None:
            st = self._values[key] = {
                "counts": [0] * (len(self.bounds) + 1),
                "sum": 0.0, "count": 0,
            }
        return st

    def observe(self, value: float, **labels) -> None:
        st = self._state(self._key(labels))
        v = float(value)
        i = len(self.bounds)  # +Inf bucket by default
        for j, b in enumerate(self.bounds):
            if v <= b:
                i = j
                break
        st["counts"][i] += 1
        st["sum"] += v
        st["count"] += 1

    def snapshot(self, **labels) -> dict:
        """Plain-dict view for JSON artifacts: bucket bounds, per-bucket
        counts (last = +Inf), sum and count."""
        st = self._state(self._key(labels))
        return {"le": list(self.bounds) + ["+Inf"],
                "counts": list(st["counts"]),
                "sum": st["sum"], "count": st["count"]}

    def quantile(self, q: float, **labels) -> float:
        """Estimate the q-quantile (0..1) from the buckets: find the
        bucket where the cumulative count first reaches ``q * count``
        and interpolate linearly between its bounds (lower bound 0 for
        the first bucket; the +Inf bucket clamps to the highest finite
        bound)."""
        st = self._state(self._key(labels))
        total = st["count"]
        if total == 0:
            return 0.0
        if len(self.bounds) == 1:
            # A single finite bucket gives no interpolation basis: every
            # observation is either <= the bound or in +Inf, and a lower
            # edge of 0 would fabricate precision.  Report the bound.
            return self.bounds[0]
        rank = q * total
        cum = 0
        for i, c in enumerate(st["counts"]):
            prev = cum
            cum += c
            if cum >= rank and c > 0:
                if i >= len(self.bounds):  # +Inf bucket
                    return self.bounds[-1]
                lo = self.bounds[i - 1] if i > 0 else 0.0
                hi = self.bounds[i]
                return lo + (hi - lo) * (rank - prev) / c
        return self.bounds[-1]

    def percentile(self, p: float, **labels) -> float:
        return self.quantile(p / 100.0, **labels)

    def render(self) -> List[str]:
        lines = [f"# HELP {self.name} {self.help}".rstrip(),
                 f"# TYPE {self.name} {self.kind}"]
        for key in sorted(self._values):
            st = self._values[key]
            cum = 0
            for b, c in zip(list(self.bounds) + [math.inf], st["counts"]):
                cum += c
                le = self._render_labels(key, [("le", _fmt_num(b))])
                lines.append(f"{self.name}_bucket{le} {cum}")
            lab = self._render_labels(key)
            lines.append(f"{self.name}_sum{lab} {_fmt_num(st['sum'])}")
            lines.append(f"{self.name}_count{lab} {st['count']}")
        return lines


class MetricGroup(MutableMapping):
    """A dict-shaped stats facade backed by one registry gauge per key.

    The engine/store/compiler/tier counters were plain dicts mutated in
    ~50 places (``stats["hits"] += 1``); adopting them into a
    MetricGroup keeps every call site and the ``stats()`` schema intact
    while the values live in the registry (visible to the Prometheus
    renderer).  Values keep their python type (int stays int) so
    ``type(v)(0)`` resets still work."""

    def __init__(self, registry: "MetricsRegistry", prefix: str,
                 init: Mapping[str, object], help: str = ""):
        self._registry = registry
        self._prefix = prefix
        self._help = help
        self._metrics: "OrderedDict[str, Gauge]" = OrderedDict()
        for k, v in init.items():
            self[k] = v

    def _gauge(self, key: str) -> Gauge:
        g = self._metrics.get(key)
        if g is None:
            g = self._registry.gauge(f"{self._prefix}_{key}", self._help)
            self._metrics[key] = g
        return g

    def __getitem__(self, key: str):
        if key not in self._metrics:
            raise KeyError(key)
        return self._metrics[key].value()

    def __setitem__(self, key: str, value) -> None:
        self._gauge(key).set(value)

    def __delitem__(self, key: str) -> None:
        raise TypeError("MetricGroup keys are fixed at registration")

    def __iter__(self):
        return iter(self._metrics)

    def __len__(self) -> int:
        return len(self._metrics)

    def __repr__(self) -> str:
        return f"MetricGroup({dict(self)!r})"


class MetricsRegistry:
    """Process-local registry of named metrics.

    ``counter()``/``gauge()``/``histogram()`` are idempotent: asking for
    an existing name returns the existing metric (kind and labels must
    match), so components constructed per-serve keep accumulating into
    the same series.
    """

    def __init__(self):
        self._metrics: "OrderedDict[str, _Metric]" = OrderedDict()

    def _register(self, cls, name: str, help: str,
                  labelnames: Sequence[str], **kw):
        m = self._metrics.get(name)
        if m is not None:
            if not isinstance(m, cls) or m.labelnames != tuple(labelnames):
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind} "
                    f"with labels {m.labelnames}")
            return m
        m = self._metrics[name] = cls(name, help, labelnames, **kw)
        return m

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Counter:
        return self._register(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._register(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_TIME_BUCKETS
                  ) -> Histogram:
        return self._register(Histogram, name, help, labelnames,
                              buckets=buckets)

    def group(self, prefix: str, init: Mapping[str, object],
              help: str = "") -> MetricGroup:
        """Adopt a stats dict: returns a dict-compatible
        :class:`MetricGroup` whose values are registry gauges named
        ``{prefix}_{key}``."""
        return MetricGroup(self, prefix, init, help)

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    def names(self) -> List[str]:
        return list(self._metrics)

    def render_prometheus(self) -> str:
        """Prometheus text exposition (version 0.0.4): metrics in name
        order, label sets in sorted order — deterministic output."""
        lines: List[str] = []
        for name in sorted(self._metrics):
            lines.extend(self._metrics[name].render())
        return "\n".join(lines) + "\n"

    def snapshot(self) -> Dict[str, dict]:
        """Nested plain-dict view (JSON-friendly) of every series."""
        out: Dict[str, dict] = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            series = {}
            for key in sorted(m._values):
                label = ",".join(f"{n}={v}"
                                 for n, v in zip(m.labelnames, key)) or ""
                v = m._values[key]
                series[label] = dict(v) if isinstance(v, dict) else v
            out[name] = {"kind": m.kind, "series": series}
        return out
