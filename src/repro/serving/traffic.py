"""Production-traffic workload model: Zipf catalogs, timed arrivals, SLOs.

The serving value of compressed many-shot prefixes only shows up under
*load* — hundreds of ICL tasks contending for ``prefix_capacity`` and
``host_capacity`` so the online compiler and the tier hierarchy actually
churn.  This module builds that load deterministically:

* **catalog** — ``num_tasks`` synthetic many-shot ICL tasks (the same
  ``data/icl_tasks.py`` construction the eval path uses), each rendered
  to a raw shot-token context.  Requests carry these as ``raw_shots``,
  so a task's first request triggers an online compile and later
  requests dedup onto the stored prefix (or its cold-tier copy).
* **popularity** — task picks are Zipf(``zipf_alpha``) distributed: a
  hot head that stays HBM-resident and a long tail that churns through
  the demote/spill/promote path.
* **arrivals** — Poisson (``process="poisson"``) or bursty ON-OFF
  (``process="onoff"``: exponential ON/OFF phases, arrivals only while
  ON) at ``rate_rps``; each request gets an ``arrival_s`` offset the
  engine replays against its injected clock.
* **SLO metrics** — :func:`slo_metrics` reduces the engine's
  ``request_log`` to TTFT p50/p99, end-to-end latency percentiles,
  goodput (requests/s that met the TTFT SLO), decode-gap p99, and
  tokens/s/device — the numbers the ``traffic`` section of
  ``benchmarks/serving_bench.py`` reports and every later perf PR
  regresses against.

Everything is a pure function of ``(config, seed)``: two calls with the
same arguments produce byte-identical traces, and under a
:class:`~repro.serving.clock.VirtualClock` the whole simulation —
arrivals, preemptions, autotuning, metrics — is reproducible in CI.
Requests are greedy (temperature 0) so per-request token output is
independent of request uids and admission interleaving.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.data.icl_tasks import ICLTaskSpec, build_manyshot_prompt, \
    make_episode, make_query
from repro.data.synthetic import SyntheticVocab
from repro.serving.scheduler import Request
from repro.serving.telemetry import Histogram

__all__ = ["TrafficConfig", "Trace", "generate_trace", "make_catalog",
           "zipf_weights", "slo_metrics"]


@dataclass(frozen=True)
class TrafficConfig:
    """Knobs of one traffic scenario (all drawing is seeded elsewhere)."""

    num_tasks: int = 32            # catalog size (≫ capacity ⇒ churn)
    zipf_alpha: float = 1.1        # popularity skew (larger = hotter head)
    context_tokens: int = 48       # raw many-shot context budget per task
    num_requests: int = 64
    process: str = "poisson"       # "poisson" | "onoff"
    rate_rps: float = 200.0        # arrival rate (while ON, for onoff)
    on_mean_s: float = 0.05        # onoff: mean burst duration
    off_mean_s: float = 0.05       # onoff: mean silence duration
    prompt_len: Tuple[int, int] = (3, 8)   # per-request query length range
    max_new: Tuple[int, int] = (2, 5)      # per-request decode budget range
    priority_classes: int = 1
    # class draw weights (defaults to uniform); index = class
    priority_weights: Optional[Tuple[float, ...]] = None

    def __post_init__(self):
        if self.process not in ("poisson", "onoff"):
            raise ValueError(f"unknown arrival process {self.process!r}")
        if self.num_tasks < 1 or self.num_requests < 1:
            raise ValueError("need at least one task and one request")
        if self.rate_rps <= 0:
            raise ValueError("rate_rps must be positive")
        if self.zipf_alpha <= 0:
            raise ValueError("zipf_alpha must be positive")
        if self.context_tokens < 4:
            raise ValueError("context_tokens must hold at least one shot (4)")
        if self.priority_classes < 1:
            raise ValueError("priority_classes must be >= 1")
        if self.priority_weights is not None and \
                len(self.priority_weights) != self.priority_classes:
            raise ValueError("priority_weights length must equal "
                             "priority_classes")


@dataclass
class Trace:
    """One generated scenario: the task catalog plus the timed requests.

    ``task_ids[i]`` is the catalog index request ``requests[i]`` draws
    its ``raw_shots`` from — tests use it to replay single requests
    offline and to check the popularity skew."""

    catalog: List[np.ndarray]
    requests: List[Request]
    task_ids: List[int]


def zipf_weights(n: int, alpha: float) -> np.ndarray:
    """P(task k) ∝ (k+1)^-alpha, normalized — rank 0 is the hot head."""
    w = (np.arange(1, n + 1, dtype=np.float64)) ** (-float(alpha))
    return w / w.sum()


def make_catalog(cfg: TrafficConfig, vocab: SyntheticVocab,
                 rng: np.random.Generator) -> List[np.ndarray]:
    """``num_tasks`` distinct many-shot contexts.  Distinct *bytes*
    matter: the content-addressed prefix names must not collide, or two
    catalog entries would silently share one compiled prefix."""
    task = ICLTaskSpec(vocab, num_labels=min(8, vocab.num_labels),
                       keys_per_label=2)
    catalog: List[np.ndarray] = []
    seen = set()
    while len(catalog) < cfg.num_tasks:
        episode = make_episode(task, rng)
        shots = build_manyshot_prompt(task, episode, rng, cfg.context_tokens)
        key = shots.tobytes()
        if key in seen:
            continue  # resample (vanishingly rare for real budgets)
        seen.add(key)
        catalog.append(shots)
    return catalog


def _arrival_times(cfg: TrafficConfig,
                   rng: np.random.Generator) -> List[float]:
    """Arrival offsets in seconds.  Poisson: exponential inter-arrival
    gaps.  ON-OFF: the same gaps, but the process only accumulates them
    while an exponential ON phase lasts; crossing into an OFF phase
    inserts an exponential silence — the bursty open-loop model."""
    times: List[float] = []
    t = 0.0
    if cfg.process == "poisson":
        for _ in range(cfg.num_requests):
            t += rng.exponential(1.0 / cfg.rate_rps)
            times.append(t)
        return times
    on_left = rng.exponential(cfg.on_mean_s)
    for _ in range(cfg.num_requests):
        gap = rng.exponential(1.0 / cfg.rate_rps)
        while gap > on_left:
            gap -= on_left
            t += on_left + rng.exponential(cfg.off_mean_s)
            on_left = rng.exponential(cfg.on_mean_s)
        t += gap
        on_left -= gap
        times.append(t)
    return times


def generate_trace(cfg: TrafficConfig, seed: int,
                   vocab: Optional[SyntheticVocab] = None) -> Trace:
    """Build the full scenario from ``(cfg, seed)`` alone — same inputs,
    byte-identical trace (arrival times, task picks, prompts, priorities,
    budgets).  Prompts are valid queries over the picked task's context
    (``make_query``), so a served answer is an actual ICL prediction."""
    vocab = vocab if vocab is not None else SyntheticVocab()
    rng = np.random.default_rng(np.random.SeedSequence([int(seed), 0x7AF1C]))
    catalog = make_catalog(cfg, vocab, rng)
    weights = zipf_weights(cfg.num_tasks, cfg.zipf_alpha)
    times = _arrival_times(cfg, rng)
    if cfg.priority_weights is not None:
        pw = np.asarray(cfg.priority_weights, np.float64)
        pw = pw / pw.sum()
    else:
        pw = np.full((cfg.priority_classes,),
                     1.0 / cfg.priority_classes)
    task = ICLTaskSpec(vocab, num_labels=min(8, vocab.num_labels),
                       keys_per_label=2)
    requests: List[Request] = []
    task_ids: List[int] = []
    lo_p, hi_p = cfg.prompt_len
    lo_n, hi_n = cfg.max_new
    for t in times:
        tid = int(rng.choice(cfg.num_tasks, p=weights))
        shots = catalog[tid]
        # a real query against this task's shots, padded with extra SEP/
        # key tokens up to the drawn prompt length (ragged prompts are
        # what exercises the bucketing)
        episode = {"keys": (shots.reshape(-1, task.shot_tokens)[:, 1]
                            - vocab.key_base),
                   "labels": (shots.reshape(-1, task.shot_tokens)[:, 3]
                              - vocab.label_base)}
        query, _label = make_query(task, episode, shots, rng)
        plen = int(rng.integers(lo_p, hi_p + 1))
        if plen > len(query):
            pad = rng.integers(vocab.word_base, vocab.size,
                               size=plen - len(query))
            prompt = np.concatenate([pad.astype(np.int32), query])
        else:
            prompt = query[-plen:] if plen else query
        cls = (int(rng.choice(cfg.priority_classes, p=pw))
               if cfg.priority_classes > 1 else 0)
        requests.append(Request(
            tokens=prompt, max_new=int(rng.integers(lo_n, hi_n + 1)),
            raw_shots=shots, priority=cls, arrival_s=float(t)))
        task_ids.append(tid)
    return Trace(catalog=catalog, requests=requests, task_ids=task_ids)


def _pct(values: Sequence[float], q: float) -> float:
    """Percentile with numpy's default linear interpolation: for sorted
    x of length n, index = (n-1) * q/100, linearly interpolated between
    the straddling samples.  Kept as an explicit helper so the SLO
    arithmetic test can hand-compute expectations against the formula."""
    if len(values) == 0:
        return 0.0
    return float(np.percentile(np.asarray(values, np.float64), q))


def slo_metrics(request_log: Dict[int, dict], *, slo_ttft_s: float,
                devices: int = 1,
                gap_samples: Sequence[float] = ()) -> dict:
    """Reduce an engine ``request_log`` to the SLO scoreboard.

    * TTFT = first token time − arrival; latency = finish − arrival.
    * goodput = completed requests whose TTFT met ``slo_ttft_s``, per
      second of makespan (first arrival → last finish).
    * tokens/s/device = generated tokens over the same makespan, split
      across ``devices``.
    * decode-gap aggregates come from a registry
      :class:`~repro.serving.telemetry.Histogram` over the engine's
      per-step gap samples: ``decode_gap_p50/p95/p99_s`` are
      bucket-interpolated quantiles (the same estimator a Prometheus
      ``histogram_quantile`` would report from the exposed
      ``serving_decode_gap_seconds`` series), and ``decode_gap_hist``
      carries the raw buckets as a bench artifact.

    Per-class sub-scoreboards let the priority tests assert class 0's
    TTFT beats class 1's under overload.
    """
    entries = list(request_log.values())
    done = [e for e in entries if e["finish_s"] is not None]
    ttfts = [e["first_token_s"] - e["arrival_s"] for e in done]
    lats = [e["finish_s"] - e["arrival_s"] for e in done]
    if done:
        t0 = min(e["arrival_s"] for e in entries)
        t1 = max(e["finish_s"] for e in done)
        duration = max(t1 - t0, 1e-9)
    else:
        # no completions → no makespan: report zero rates rather than
        # dividing by a sentinel and emitting astronomical figures
        duration = 0.0
    tokens = sum(e["tokens"] for e in done)
    attained = sum(1 for t in ttfts if t <= slo_ttft_s)
    gap_hist = Histogram("decode_gap_seconds")
    for g in gap_samples:
        gap_hist.observe(float(g))
    out = {
        "requests": len(entries),
        "completed": len(done),
        "duration_s": float(duration),
        "ttft_p50_s": _pct(ttfts, 50),
        "ttft_p99_s": _pct(ttfts, 99),
        "latency_p50_s": _pct(lats, 50),
        "latency_p99_s": _pct(lats, 99),
        "slo_ttft_s": float(slo_ttft_s),
        "slo_attained": int(attained),
        "goodput_rps": float(attained / duration) if duration else 0.0,
        "offered_rps": float(len(entries) / duration) if duration else 0.0,
        "tokens_generated": int(tokens),
        "tokens_per_s_per_device": (
            float(tokens / duration / max(devices, 1)) if duration else 0.0),
        "decode_gap_p50_s": gap_hist.percentile(50),
        "decode_gap_p95_s": gap_hist.percentile(95),
        "decode_gap_p99_s": gap_hist.percentile(99),
        "decode_gap_hist": gap_hist.snapshot(),
        "preemptions": int(sum(e["preemptions"] for e in entries)),
    }
    classes = sorted({e["priority"] for e in entries})
    per_class = {}
    for cls in classes:
        ce = [e for e in entries if e["priority"] == cls]
        cd = [e for e in ce if e["finish_s"] is not None]
        ct = [e["first_token_s"] - e["arrival_s"] for e in cd]
        per_class[str(cls)] = {
            "requests": len(ce),
            "completed": len(cd),
            "ttft_p50_s": _pct(ct, 50),
            "ttft_p99_s": _pct(ct, 99),
            "slo_attained": int(sum(1 for t in ct if t <= slo_ttft_s)),
            "preemptions": int(sum(e["preemptions"] for e in ce)),
        }
    out["per_class"] = per_class
    return out
