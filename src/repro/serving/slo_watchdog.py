"""SLO burn-rate watchdog over the serving engine's injected clock.

Multi-window burn-rate alerting in the Google SRE mold: each
:class:`BurnRateRule` names a signal (TTFT, decode gap, goodput proxy),
a violation threshold and an error budget.  The **burn rate** over a
window is

    burn = (fraction of samples violating the SLO in the window) / budget

so burn 1.0 means "spending budget exactly as provisioned" and burn 10
means "the budget will be gone in a tenth of the window".  A rule fires
only when *both* a fast and a slow window exceed ``fire_burn`` (the fast
window gives low latency-to-detect, the slow window filters blips), and
clears with hysteresis when the fast window drops below ``clear_burn``.

Everything is timestamped by the injected clock.  On a
:class:`~repro.serving.clock.VirtualClock` the full alert sequence —
order, timestamps, burn values — is a pure function of (scenario, seed):
two runs of one scenario produce byte-identical :meth:`SLOWatchdog.dumps`
output, which is what the tests lock.

Alerts are observable three ways at once: a tracer instant on the
``watchdog`` track, a ``serving_alerts_total{rule,severity}`` counter
(registered eagerly so the metric name is scrapeable before the first
alert), and an append-only :attr:`SLOWatchdog.alert_log` exported by
:meth:`SLOWatchdog.report` as a ``repro/alert-log/v1`` artifact.

While a ``page``-severity alert is active a pluggable degradation hook
runs; the default :class:`ShedDegrade` tells the engine to shed
lowest-priority admissions (``engine.shed_floor``) and hints the
compile/promote budget autotuner to tighten, undoing both on clear.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, fields
from typing import Callable, Dict, List, Optional, Sequence, Tuple

__all__ = [
    "BurnRateRule", "SLOWatchdog", "ShedDegrade", "default_rules",
    "validate_alert_log", "ALERT_LOG_SCHEMA",
]

ALERT_LOG_SCHEMA = "repro/alert-log/v1"

#: Signals the engine feeds when a watchdog is attached.
SIGNALS = ("ttft", "decode_gap", "tokens_per_step")


@dataclass(frozen=True)
class BurnRateRule:
    """One SLO with two burn-rate windows.

    ``op`` gives the violation direction: ``"gt"`` for latency-style
    signals (a sample violates when it exceeds ``threshold``), ``"lt"``
    for throughput-style signals (violates when it falls below).
    """

    name: str
    metric: str
    threshold: float
    budget: float                # allowed violation fraction, in (0, 1]
    fast_window_s: float
    slow_window_s: float
    fire_burn: float = 1.0
    clear_burn: float = 0.5
    severity: str = "ticket"     # "ticket" | "page"
    op: str = "gt"               # "gt" | "lt"

    def __post_init__(self):
        if not 0.0 < self.budget <= 1.0:
            raise ValueError(f"{self.name}: budget must be in (0, 1]")
        if self.fast_window_s <= 0 or self.slow_window_s < self.fast_window_s:
            raise ValueError(
                f"{self.name}: need 0 < fast_window_s <= slow_window_s")
        if self.severity not in ("ticket", "page"):
            raise ValueError(f"{self.name}: severity must be ticket|page")
        if self.op not in ("gt", "lt"):
            raise ValueError(f"{self.name}: op must be gt|lt")
        if self.clear_burn > self.fire_burn:
            raise ValueError(f"{self.name}: clear_burn > fire_burn "
                             "defeats the hysteresis")

    def violates(self, value: float) -> bool:
        return (value > self.threshold if self.op == "gt"
                else value < self.threshold)


def default_rules(*, slo_ttft_s: float = 0.05,
                  slo_gap_s: float = 0.005,
                  min_tokens_per_step: float = 0.5) -> List[BurnRateRule]:
    """The stock rule set the launcher wires under ``--traffic``: a
    paging TTFT burn, a ticket decode-gap burn, and a ticket goodput
    floor (tokens emitted per engine step across all slots)."""
    return [
        BurnRateRule(name="ttft_burn", metric="ttft",
                     threshold=slo_ttft_s, budget=0.10,
                     fast_window_s=0.05, slow_window_s=0.25,
                     fire_burn=2.0, clear_burn=1.0, severity="page"),
        BurnRateRule(name="decode_gap_burn", metric="decode_gap",
                     threshold=slo_gap_s, budget=0.20,
                     fast_window_s=0.02, slow_window_s=0.10,
                     fire_burn=2.0, clear_burn=1.0, severity="ticket"),
        BurnRateRule(name="goodput_floor", metric="tokens_per_step",
                     threshold=min_tokens_per_step, budget=0.25,
                     fast_window_s=0.02, slow_window_s=0.10,
                     fire_burn=2.0, clear_burn=1.0, severity="ticket",
                     op="lt"),
    ]


class SLOWatchdog:
    """Evaluates :class:`BurnRateRule`\\ s over observed samples.

    The watchdog never reads wall time: ``clock`` is the same injected
    callable the engine runs on, and callers may also pass explicit
    timestamps to :meth:`observe`/:meth:`step`.  It never *charges* the
    clock either — attaching a watchdog does not change the token
    stream, only admissions (via the degradation hook, which is the
    point).
    """

    def __init__(self, rules: Sequence[BurnRateRule], *,
                 clock: Optional[Callable[[], float]] = None,
                 metrics=None, tracer=None, degrade_hook=None):
        names = [r.name for r in rules]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate rule names: {names}")
        self.rules = tuple(rules)
        self.clock = clock
        self.tracer = tracer
        self.degrade_hook = degrade_hook
        self.engine = None
        # metric -> deque-like list of (t, value), pruned on observe
        self._samples: Dict[str, List[Tuple[float, float]]] = {}
        self._keep: Dict[str, float] = {}
        for r in self.rules:
            self._keep[r.metric] = max(self._keep.get(r.metric, 0.0),
                                       r.slow_window_s)
        self._firing: Dict[str, bool] = {r.name: False for r in self.rules}
        self.alert_log: List[dict] = []
        self._alerts_total = None
        if metrics is not None:
            # eager registration: the name (HELP/TYPE) renders in
            # /metrics before any alert has fired
            self._alerts_total = metrics.counter(
                "serving_alerts_total",
                "SLO watchdog alerts fired, by rule and severity",
                labelnames=("rule", "severity"))

    # -- feeding -------------------------------------------------------

    def now(self) -> float:
        if self.clock is None:
            raise ValueError("watchdog has no clock: pass t= explicitly")
        return float(self.clock())

    def observe(self, metric: str, value: float,
                t: Optional[float] = None) -> None:
        if metric not in self._keep:
            return  # no rule watches this signal
        t = self.now() if t is None else float(t)
        buf = self._samples.setdefault(metric, [])
        buf.append((t, float(value)))
        # prune anything older than the widest slow window (plus slack
        # so a sample on the window edge is never dropped early)
        horizon = t - 2.0 * self._keep[metric]
        if buf and buf[0][0] < horizon:
            self._samples[metric] = [s for s in buf if s[0] >= horizon]

    def attach_engine(self, engine) -> None:
        """Bind the degradation hook's target (usually the engine that
        also feeds :meth:`observe`)."""
        self.engine = engine

    # -- evaluation ----------------------------------------------------

    def _burn(self, rule: BurnRateRule, window_s: float,
              now: float) -> Optional[float]:
        """Burn rate over ``[now - window_s, now]``; None with no
        samples (a silent window is not evidence either way)."""
        buf = self._samples.get(rule.metric, ())
        lo = now - window_s
        n = bad = 0
        for t, v in buf:
            if t < lo or t > now:
                continue
            n += 1
            if rule.violates(v):
                bad += 1
        if n == 0:
            return None
        return (bad / n) / rule.budget

    def step(self, now: Optional[float] = None) -> List[dict]:
        """Evaluate every rule at ``now``; returns the events (fire or
        clear) emitted by this step, already appended to
        :attr:`alert_log`."""
        now = self.now() if now is None else float(now)
        emitted: List[dict] = []
        for rule in self.rules:
            fast = self._burn(rule, rule.fast_window_s, now)
            slow = self._burn(rule, rule.slow_window_s, now)
            if not self._firing[rule.name]:
                if (fast is not None and slow is not None
                        and fast >= rule.fire_burn
                        and slow >= rule.fire_burn):
                    emitted.append(self._emit(rule, "fire", now, fast, slow))
            else:
                if fast is None or fast <= rule.clear_burn:
                    emitted.append(self._emit(rule, "clear", now,
                                              fast, slow))
        return emitted

    def _emit(self, rule: BurnRateRule, kind: str, now: float,
              fast: Optional[float], slow: Optional[float]) -> dict:
        self._firing[rule.name] = kind == "fire"
        event = {
            "t": now, "kind": kind, "rule": rule.name,
            "severity": rule.severity, "metric": rule.metric,
            "burn_fast": fast, "burn_slow": slow,
        }
        self.alert_log.append(event)
        if kind == "fire" and self._alerts_total is not None:
            self._alerts_total.inc(rule=rule.name, severity=rule.severity)
        if self.tracer is not None:
            self.tracer.instant(
                "watchdog", f"alert_{kind}:{rule.name}", t=now,
                severity=rule.severity,
                burn_fast=fast, burn_slow=slow)
        hook = self.degrade_hook
        if hook is not None:
            if kind == "fire":
                hook.on_fire(self, rule, event)
            else:
                hook.on_clear(self, rule, event)
        return event

    # -- state ---------------------------------------------------------

    def firing(self, name: str) -> bool:
        return self._firing[name]

    @property
    def page_active(self) -> bool:
        """True while any page-severity rule is firing."""
        return any(self._firing[r.name] for r in self.rules
                   if r.severity == "page")

    # -- export --------------------------------------------------------

    def report(self) -> dict:
        """The alert log as a schema'd JSON-ready artifact."""
        return {
            "schema": ALERT_LOG_SCHEMA,
            "rules": [{f.name: getattr(r, f.name) for f in fields(r)}
                      for r in self.rules],
            "events": list(self.alert_log),
            "fires": sum(1 for e in self.alert_log if e["kind"] == "fire"),
            "clears": sum(1 for e in self.alert_log
                          if e["kind"] == "clear"),
        }

    def dumps(self) -> str:
        """Deterministic serialization — byte-identical across runs of
        one (scenario, seed) on the virtual clock."""
        return json.dumps(self.report(), sort_keys=True,
                          separators=(",", ":"))


class ShedDegrade:
    """Default degradation hook: while a page alert is active, shed
    admissions below a priority floor and hint the budget autotuner.

    ``shed_floor`` semantics (enforced by the engine's admission gate):
    requests with ``priority >= floor`` wait in queue rather than admit,
    and only while at least one slot is still running — an idle engine
    always admits, so shedding can never deadlock the simulation.
    """

    def __init__(self, shed_priority: int = 1, tighten: bool = True):
        self.shed_priority = int(shed_priority)
        self.tighten = tighten

    def on_fire(self, wd: SLOWatchdog, rule: BurnRateRule,
                event: dict) -> None:
        eng = wd.engine
        if eng is None or rule.severity != "page":
            return
        eng.shed_floor = self.shed_priority
        if self.tighten:
            eng.degrade_hint = True
        if getattr(eng, "metrics", None) is not None:
            eng.metrics.counter(
                "serving_degradations_total",
                "degradation-hook actions taken on page alerts",
                labelnames=("action",)).inc(action="shed")

    def on_clear(self, wd: SLOWatchdog, rule: BurnRateRule,
                 event: dict) -> None:
        eng = wd.engine
        if eng is None or rule.severity != "page":
            return
        if not wd.page_active:
            eng.shed_floor = None
            eng.degrade_hint = False
            if getattr(eng, "metrics", None) is not None:
                eng.metrics.counter(
                    "serving_degradations_total",
                    "degradation-hook actions taken on page alerts",
                    labelnames=("action",)).inc(action="restore")


def validate_alert_log(doc: dict) -> List[str]:
    """Schema-check a ``repro/alert-log/v1`` artifact; returns problems
    (empty = valid).  Shared by tests and ``benchmarks.validate_trace``."""
    errs: List[str] = []
    if doc.get("schema") != ALERT_LOG_SCHEMA:
        errs.append(f"schema != {ALERT_LOG_SCHEMA!r}: "
                    f"{doc.get('schema')!r}")
    events = doc.get("events")
    if not isinstance(events, list):
        return errs + ["events missing or not a list"]
    rule_names = {r.get("name") for r in doc.get("rules", [])
                  if isinstance(r, dict)}
    last_t = None
    open_alerts = set()
    for i, ev in enumerate(events):
        for field in ("t", "kind", "rule", "severity", "metric"):
            if field not in ev:
                errs.append(f"event {i}: missing {field!r}")
        kind = ev.get("kind")
        if kind not in ("fire", "clear"):
            errs.append(f"event {i}: bad kind {kind!r}")
        if ev.get("severity") not in ("ticket", "page"):
            errs.append(f"event {i}: bad severity {ev.get('severity')!r}")
        if rule_names and ev.get("rule") not in rule_names:
            errs.append(f"event {i}: unknown rule {ev.get('rule')!r}")
        t = ev.get("t")
        if isinstance(t, (int, float)):
            if last_t is not None and t < last_t:
                errs.append(f"event {i}: timestamps not monotonic")
            last_t = t
        rule = ev.get("rule")
        if kind == "fire":
            if rule in open_alerts:
                errs.append(f"event {i}: double fire for {rule!r}")
            open_alerts.add(rule)
        elif kind == "clear":
            if rule not in open_alerts:
                errs.append(f"event {i}: clear without fire for {rule!r}")
            open_alerts.discard(rule)
    if doc.get("fires") is not None:
        n = sum(1 for e in events if e.get("kind") == "fire")
        if doc["fires"] != n:
            errs.append(f"fires count {doc['fires']} != {n}")
    return errs
