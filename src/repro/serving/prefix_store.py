"""Materialized compressed prefixes: projection, storage, per-slot seating.

The compress → serve handoff (paper §1) in three steps:

1. :func:`materialize_prefix` pushes the compressor's per-layer output
   O^i through the frozen target's projections, yielding the layer-family
   cache entries (``attn → k/v``, ``mla → ckv/kr``, ``mamba → ssm``
   passthrough; see docs/ARCHITECTURE.md for the exact shapes).
2. :class:`PrefixStore` caches one materialized prefix per ICL task — the
   "many users, each with their own compressed task memory" serving shape.
3. :func:`seat_prefix_row` installs a stored prefix into *one batch slot*
   of a live engine cache, so different slots of the same decode batch can
   serve different tasks (:func:`write_prefix_to_cache` is the batch-wide
   variant kept for single-task serving and parity tests).

Layer caches use the Layerwise layout (``{"prefix": [...], "period":
{"l0": stacked, ...}}``); prefix-section leaves carry the batch on axis 0,
period-section leaves on axis 1 (axis 0 is the scan's ``repeats`` dim).
"""

from __future__ import annotations

from collections import OrderedDict
from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.kernels import ops
from repro.models.attention import project_kv
from repro.models.mla import _latent  # shared latent-cache constructor
from repro.serving.block_pool import BlockAllocator

_KV_KEYS = ("k", "v", "ckv", "kr")


def materialize_prefix(target_params, cfg: ModelConfig, prefix):
    """Turn {"h": O^i} entries into precomputed compressed caches:
    attn -> {"k","v"}; mla -> {"ckv","kr"}; mamba -> passthrough state."""

    def project(desc, layer_params, entry):
        if "h" not in entry:
            return entry
        h = entry["h"]
        B, m = h.shape[0], h.shape[1]
        pos = jnp.broadcast_to(jnp.arange(m, dtype=jnp.int32), (B, m))
        if cfg.mrope_sections:
            pos = jnp.broadcast_to(pos, (3, B, m))
        if desc.mixer == "mla":
            ckv, kr = _latent(layer_params["attn"], cfg, h, pos)
            return {"ckv": ckv, "kr": kr[:, :, 0, :]}
        k, v = project_kv(layer_params["attn"], cfg, h, pos)
        return {"k": k, "v": v}

    out = {}
    if "prefix" in prefix:
        out["prefix"] = [
            project(desc, target_params[f"prefix_{i}"], prefix["prefix"][i])
            for i, desc in enumerate(cfg.layout.prefix)
        ]
    if "period" in prefix:
        period = {}
        for j, desc in enumerate(cfg.layout.period):
            key = f"l{j}"
            entry = prefix["period"][key]
            lp = jax.tree.map(lambda x: x, target_params["period"][key])
            fn = partial(project, desc)
            period[key] = jax.vmap(fn)(lp, entry)  # map over stacked layers
        out["period"] = period
    return out


def write_prefix_to_cache(cfg: ModelConfig, cache, prefix):
    """Seat compressed memory slots at cache positions [0, m) — batch-wide
    (row b of the materialized prefix lands in slot b)."""

    def seat(c, p):
        c = dict(c)
        for key in _KV_KEYS:
            if key in p:
                c[key] = jax.lax.dynamic_update_slice_in_dim(
                    c[key], p[key].astype(c[key].dtype), 0, axis=1)
        if "ssm" in p:
            c["ssm"] = p["ssm"].astype(c["ssm"].dtype)
        return c

    out = {}
    if "prefix" in cache:
        out["prefix"] = [seat(c, p) for c, p in
                         zip(cache["prefix"], prefix.get("prefix", []))]
    if "period" in cache:
        out["period"] = {}
        for key, c in cache["period"].items():
            p = prefix.get("period", {}).get(key)
            if p is None:
                out["period"][key] = c
                continue
            # both stacked on the layer dim: seat per-layer via vmap
            out["period"][key] = jax.vmap(seat)(c, p)
    return out


# ---------------------------------------------------------------------------
# Per-slot seating
# ---------------------------------------------------------------------------


def _map_rowwise(cache, other, fn):
    """Apply ``fn(cache_entry, other_entry, batch_axis)`` across both
    Layerwise sections (batch axis 0 for prefix entries, 1 for period)."""
    out = {}
    if "prefix" in cache:
        out["prefix"] = [
            fn(c, other["prefix"][i] if other else None, 0)
            for i, c in enumerate(cache["prefix"])
        ]
    if "period" in cache:
        out["period"] = {
            key: fn(c, (other or {}).get("period", {}).get(key), 1)
            for key, c in cache["period"].items()
        }
    return out


def clear_slot_state(cache, slot: int):
    """Zero one slot's recurrent state (mamba conv/ssm) ahead of a refill.

    KV entries don't need clearing — stale keys beyond a slot's length are
    masked by the per-slot decode path — but SSM/conv prefill *continues*
    from the cached state, so a refilled slot must not inherit its previous
    occupant's recurrence.
    """

    def clear(c, _p, axis):
        c = dict(c)
        for key in ("conv", "ssm"):
            if key in c:
                idx = (slot,) if axis == 0 else (slice(None), slot)
                c[key] = c[key].at[idx].set(0)
        return c

    return _map_rowwise(cache, None, clear)


def seat_prefix_row(cache, row, slot: int):
    """Install a single-task prefix (one :class:`PrefixStore` entry) into
    batch slot ``slot`` of a live cache: KV entries land at positions
    [0, m) of that slot's rows; SSM state replaces the slot's state."""

    def seat(c, p, axis):
        if p is None:
            return c
        c = dict(c)
        for key in _KV_KEYS:
            if key in p:
                # batch-free row leaves put m where the cache keeps batch
                m = p[key].shape[axis]
                idx = (slot, slice(0, m)) if axis == 0 else \
                    (slice(None), slot, slice(0, m))
                c[key] = c[key].at[idx].set(p[key].astype(c[key].dtype))
        if "ssm" in p:
            idx = (slot,) if axis == 0 else (slice(None), slot)
            c["ssm"] = c["ssm"].at[idx].set(p["ssm"].astype(c["ssm"].dtype))
        return c

    return _map_rowwise(cache, row, seat)


def take_prefix_row(materialized, batch_index: int = 0):
    """Extract one batch row of a :func:`materialize_prefix` output as a
    batch-free per-layer row dict."""

    def take_row(c, _p, axis):
        out = {}
        for key, x in c.items():
            out[key] = x[batch_index] if axis == 0 else x[:, batch_index]
        return out

    return _map_rowwise(materialized, None, take_row)


class PrefixStore:
    """In-memory cache of materialized compressed prefixes, one per task.

    Entries are stored batch-free (a single task's per-layer cache rows);
    :meth:`put` extracts one batch row from a :func:`materialize_prefix`
    output, and engines seat entries into individual slots via
    :func:`seat_prefix_row`.

    ``capacity`` (optional) bounds resident prefixes LRU-style, like the
    paged store: inserting past capacity evicts the least-recently-used
    entry not in :attr:`pinned`.  Dense seating *copies* a prefix into
    the slot's cache stripe, so — unlike the paged store — evicting a
    seated entry is safe and never raises.

    ``demote_hook`` (set by :class:`~repro.serving.tiers
    .TieredPrefixStore`) receives ``(name, row)`` just before an entry is
    dropped, so evictions demote the prefix down the memory hierarchy
    instead of destroying it.
    """

    def __init__(self, cfg: ModelConfig, capacity: Optional[int] = None):
        if capacity is not None and capacity < 1:
            raise ValueError("capacity must be >= 1 (or None)")
        self.cfg = cfg
        self.capacity = capacity
        self._entries: "OrderedDict[str, dict]" = OrderedDict()
        self._base_len: Dict[str, int] = {}
        self.stats = _new_store_stats()
        self.pinned: set = set()  # names the LRU must skip (engine-kept)
        self.demote_hook = None   # called (name, row) before an evict drops

    def put(self, name: str, materialized, batch_index: int = 0) -> str:
        return self.put_row(name, take_prefix_row(materialized, batch_index))

    def put_row(self, name: str, row) -> str:
        """Make an already batch-free per-layer row resident (the tiered
        promotion path lands here — no materialized batch to slice)."""
        if name not in self._entries:
            while self.capacity is not None and \
                    len(self._entries) >= self.capacity:
                self._evict_lru()
        self._entries[name] = row
        self._entries.move_to_end(name)
        self._base_len[name] = _row_base_len(row)
        self.stats["puts"] += 1
        return name

    def _evict_lru(self) -> None:
        for name in self._entries:  # oldest first
            if name not in self.pinned:
                self.evict(name)
                return
        raise PrefixSeatedError(
            f"PrefixStore at capacity ({self.capacity}) and every resident "
            "prefix is pinned by a queued or waiting request — grow the "
            "capacity or finish requests")

    def lookup(self, name: str) -> bool:
        """Counted residency check — the serve-path ``hit``/``miss``
        counters exposed through ``ServingEngine.stats()``."""
        hit = name in self._entries
        self.stats["hits" if hit else "misses"] += 1
        return hit

    def evict(self, name: str, demote: bool = True) -> None:
        """``demote=False`` skips the hook — for replace-path evictions,
        where fresh content supersedes the old copy and demoting it would
        only waste a device→host copy (and possibly spill an innocent
        LRU host row)."""
        self._check(name)
        if demote and self.demote_hook is not None:
            # Dense entries own their KV arrays outright — no pool blocks,
            # no seating — so eviction can never race a seated slot; the
            # raise-before-demote guard is a paged-store concern.
            # reprolint: ignore[demote-guard] -- dense KV is owned, not pooled
            self.demote_hook(name, self._entries[name])
        del self._entries[name]
        del self._base_len[name]
        self.stats["evictions"] += 1

    def get(self, name: str) -> dict:
        self._check(name)
        self._entries.move_to_end(name)  # LRU recency
        return self._entries[name]

    def base_len(self, name: str) -> int:
        """Memory-slot count the prefix occupies at the cache front
        (0 for pure state handoff, e.g. mamba-only prefixes)."""
        self._check(name)
        return self._base_len[name]

    def _check(self, name: str) -> None:
        if name not in self._entries:
            raise KeyError(f"unknown prefix {name!r}; registered: "
                           f"{sorted(self._entries) or '(none)'}")

    def __contains__(self, name) -> bool:
        return name in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    def names(self):
        return tuple(self._entries)


# ---------------------------------------------------------------------------
# Paged (block-resident) prefixes
# ---------------------------------------------------------------------------


def write_prefix_row_to_blocks(cache, row, block_ids: List[int]):
    """Scatter a batch-free prefix row's KV leaves into pool blocks.

    ``block_ids`` are the physical blocks holding logical positions
    ``[0, m)``; every layer writes the *same* block ids into its own pool
    (one block table resolves every layer, vLLM-style).  Non-KV leaves
    (ssm state) are left for per-slot seating via :func:`seat_prefix_row`.
    """
    ids = jnp.asarray(block_ids, jnp.int32)[None, :]  # (1, nbt)
    zero = jnp.zeros((1,), jnp.int32)

    def write(c, p, axis):
        c = dict(c)
        for key in _KV_KEYS:
            if key in p:
                if axis == 0:  # prefix section: pool (N, bs, ...), row (m, ...)
                    c[key] = ops.paged_scatter(c[key], p[key][None], ids, zero)
                else:  # period: pool (repeats, N, bs, ...), row (repeats, m, ...)
                    c[key] = jax.vmap(
                        lambda pool, new: ops.paged_scatter(pool, new[None],
                                                            ids, zero)
                    )(c[key], p[key])
        return c

    return _map_rowwise(cache, row, write)


def copy_paged_block(cache, src: int, dst: int):
    """Device-side copy of one physical block across every KV pool leaf —
    the copy-on-write when a slot must write into a shared partial block."""

    def cp(c, _p, axis):
        c = dict(c)
        for key in _KV_KEYS:
            if key in c:
                if axis == 0:
                    c[key] = c[key].at[dst].set(c[key][src])
                else:
                    c[key] = c[key].at[:, dst].set(c[key][:, src])
        return c

    return _map_rowwise(cache, None, cp)


def strip_kv_leaves(row) -> Optional[dict]:
    """Drop block-resident KV leaves from a prefix row, keeping per-slot
    state (ssm handoff).  Returns None when nothing remains to seat."""
    found = [False]

    def strip(c, _p, axis):
        out = {k: v for k, v in c.items() if k not in _KV_KEYS}
        if out:
            found[0] = True
        return out

    stripped = _map_rowwise(row, None, strip)
    return stripped if found[0] else None


class PrefixSeatedError(RuntimeError):
    """Refused to evict a prefix whose blocks are still seated in slots."""


class PagedPrefixStore:
    """Block-resident compressed prefixes with ref-counts and LRU eviction.

    The paged counterpart of :class:`PrefixStore`: ``put`` scatters a
    task's materialized KV into freshly allocated pool blocks *once*;
    engines seat a task into a slot by pointing the slot's block table at
    those blocks (``blocks()`` + ``BlockAllocator.incref``), so N slots on
    one task share one physical copy.  The store holds one reference per
    resident prefix; a block's refcount therefore exceeds 1 exactly while
    some slot is seated on it.

    ``capacity`` bounds the number of resident prefixes LRU-style:
    inserting past capacity evicts the least-recently-used *unseated*
    entry (seated entries are deferred — skipped over); if every resident
    prefix is seated, :class:`PrefixSeatedError` is raised.  Explicitly
    evicting a seated prefix always raises.
    """

    def __init__(self, cfg: ModelConfig, allocator: BlockAllocator,
                 capacity: Optional[int] = None):
        if capacity is not None and capacity < 1:
            raise ValueError("capacity must be >= 1 (or None)")
        self.cfg = cfg
        self.alloc = allocator
        self.capacity = capacity
        self._entries: "OrderedDict[str, dict]" = OrderedDict()
        self.stats = _new_store_stats()
        # names the LRU must skip even when unseated: the engine keeps this
        # set at the prefixes still referenced by queued or waiting_on_prefix
        # requests (a parked request's freshly compiled prefix must survive
        # until that request seats it)
        self.pinned: set = set()
        # tiered serving: called (name, entry) after the seated guard but
        # before the blocks are released, so an evicted prefix's KV can be
        # read back out of the pool and demoted to host instead of dropped
        self.demote_hook = None

    def lookup(self, name: str) -> bool:
        """Counted residency check (see :meth:`PrefixStore.lookup`)."""
        hit = name in self._entries
        self.stats["hits" if hit else "misses"] += 1
        return hit

    def put(self, name: str, materialized, cache, batch_index: int = 0):
        """Make ``materialized`` row ``batch_index`` block-resident under
        ``name``.  Returns the updated Layerwise cache (pools are
        functional jax arrays).  Re-putting an existing name replaces it —
        which requires the old entry to be unseated."""
        return self.put_row(name, take_prefix_row(materialized, batch_index),
                            cache)

    def put_row(self, name: str, row, cache):
        """:meth:`put` for an already batch-free row (the tiered
        promotion path: host leaves land on device pre-sharded, then
        scatter straight into pool blocks here)."""
        if name in self._entries:
            # replace: raises PrefixSeatedError if still seated; the old
            # copy is superseded, not demoted
            self.evict(name, demote=False)
        while self.capacity is not None and len(self._entries) >= self.capacity:
            self._evict_lru()
        base_len = _row_base_len(row)
        blocks = self.alloc.alloc(self.alloc.blocks_for(base_len))
        if blocks:
            cache = write_prefix_row_to_blocks(cache, row, blocks)
        self._entries[name] = {
            "blocks": blocks,
            "base_len": base_len,
            "state": strip_kv_leaves(row),
        }
        self.stats["puts"] += 1
        return cache

    def _evict_lru(self) -> None:
        for name, entry in self._entries.items():  # oldest first
            if name not in self.pinned and not self._seated(entry):
                self.evict(name)
                return
        raise PrefixSeatedError(
            f"PrefixStore at capacity ({self.capacity}) and every resident "
            "prefix is seated in a slot or pinned by a waiting request — "
            "grow the pool or finish requests")

    def _seated(self, entry) -> bool:
        return any(self.alloc.refcount(b) > 1 for b in entry["blocks"])

    def seated(self, name: str) -> bool:
        """True while at least one engine slot points at this prefix's
        blocks (the store's own reference is not counted)."""
        return self._seated(self._get(name, touch=False))

    def evict(self, name: str, demote: bool = True) -> None:
        """Release a prefix's blocks back to the pool.  Raises
        :class:`PrefixSeatedError` while any slot is still seated on it —
        freeing blocks under a live block table would let the allocator
        hand them to another slot mid-decode.  ``demote=False`` skips the
        hook (replace-path evictions supersede the old copy)."""
        entry = self._get(name, touch=False)
        if self._seated(entry):
            raise PrefixSeatedError(
                f"prefix {name!r} is seated in at least one slot")
        if demote and self.demote_hook is not None:
            # the hook gathers the KV out of the pool while the blocks
            # are still referenced (and therefore still hold this prefix)
            self.demote_hook(name, entry)
        for b in entry["blocks"]:
            self.alloc.decref(b)
        del self._entries[name]
        self.stats["evictions"] += 1

    # ---- lookups (refresh LRU recency) ----

    def blocks(self, name: str) -> List[int]:
        return list(self._get(name)["blocks"])

    def base_len(self, name: str) -> int:
        return self._get(name)["base_len"]

    def state_row(self, name: str) -> Optional[dict]:
        return self._get(name)["state"]

    def _get(self, name: str, touch: bool = True) -> dict:
        if name not in self._entries:
            raise KeyError(f"unknown prefix {name!r}; registered: "
                           f"{sorted(self._entries) or '(none)'}")
        if touch:
            self._entries.move_to_end(name)
        return self._entries[name]

    def __contains__(self, name) -> bool:
        return name in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    def names(self):
        return tuple(self._entries)


def _new_store_stats() -> Dict[str, int]:
    """Cache-behaviour counters both stores expose via
    ``ServingEngine.stats()``: serve-path residency ``hits``/``misses``
    (:meth:`PrefixStore.lookup`), entries made resident (``puts``) and
    entries released (``evictions`` — LRU, explicit, and re-put
    replacement alike)."""
    return {"hits": 0, "misses": 0, "puts": 0, "evictions": 0}


def _row_base_len(row) -> int:
    """Slot count of a batch-free prefix row: the m dim of its first KV
    leaf (prefix-section KV leaves are (m, ...); period (repeats, m, ...))."""
    for e in row.get("prefix", []):
        for key in _KV_KEYS:
            if key in e:
                return int(e[key].shape[0])
    for e in row.get("period", {}).values():
        for key in _KV_KEYS:
            if key in e:
                return int(e[key].shape[1])
    return 0
