"""Request queue and slot bookkeeping for the continuous-batching engine.

Pure-python control plane, deliberately free of jax: the
:class:`~repro.serving.engine.ServingEngine` owns the device arrays and
asks the scheduler three questions each step — which queued requests fit
into free slots (:meth:`Scheduler.admit`), which slots are mid-generation
(:meth:`Scheduler.active_slots`), and whether a freshly sampled token
finishes its slot (:meth:`Scheduler.record_token`: per-slot stop token or
per-slot token budget, *independently* of every other slot).

Finished slots return to the free pool immediately, so the next queued
request is admitted mid-decode — no drain barrier, no recompilation (the
decode step's shapes never change; only the per-slot length vector does).

Requests whose compressed prefix is not HBM-resident sit in a fourth
stage, **waiting_on_prefix** (:meth:`Scheduler.park`), until the engine
makes it resident and :meth:`Scheduler.wake`\\ s them into the head of
the FIFO queue.  Two producers feed the stage — the online
:class:`~repro.serving.compiler.PrefixCompiler` (requests carrying
``raw_shots`` for an uncompiled task) and the :class:`~repro.serving
.tiers.TieredPrefixStore` promotion path (a previously evicted prefix
copying back from the host or disk tier) — and the scheduler cannot
tell them apart: parking is keyed by prefix name alone.

    waiting_on_prefix ──wake──▶ queued ──admit──▶ running ──▶ finished
                                   ▲                  │
                                   └────preempt───────┘

Priority classes
----------------
``Request.priority`` is an integer class, **lower = more urgent**
(class 0 outranks class 1).  Admission picks the queued request with the
smallest ``(effective_class, arrival)`` key, so order stays strictly
FIFO *within* a class — with a single class this degrades to the plain
FIFO the engine shipped with.  An optional anti-starvation rule ages
parked work: with ``aging_interval_s`` set, a request's effective class
drops by one for every interval it has waited, bounding how long a
low-priority request can be starved by a stream of urgent arrivals.
Aging affects *admission order only* — preemption (below) compares base
classes, so an aged request never evicts a genuinely higher class.

Preemption
----------
:meth:`Scheduler.preempt` evicts a running slot: the request returns to
the queue at its original arrival position (same rule as :meth:`wake`)
and its already-emitted tokens are stashed.  When the request is later
re-admitted, the stash resumes the slot — :meth:`emitted_tokens` lets
the engine re-prefill ``prompt + emitted`` so decode continues from the
exact KV state it was evicted with, and :meth:`record_token` keeps
counting against the original ``max_new`` budget.  The engine drives the
policy (who gets preempted, and the KV/block cleanup); the scheduler
only guarantees the bookkeeping is token-exact.
"""

from __future__ import annotations

import hashlib
import itertools
import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.serving.sanitize import SanitizerError, sanitizer_enabled

_UIDS = itertools.count()

# ---------------------------------------------------------------------------
# Stage machine (machine-readable)
# ---------------------------------------------------------------------------
#
# The request lifecycle as data: reprolint's `state-machine` rule checks
# every `self._transition(uid, src, dst)` call site against this table,
# and under REPRO_SANITIZE=1 the scheduler validates each move as it
# happens — the static and the runtime checker read the same literals.
# "new" is the pre-scheduler stage (a Request object not yet submitted).
# Both literals must stay pure (no computed values): the linter
# evaluates them with ast.literal_eval.

STAGES = ("new", "queued", "waiting_on_prefix", "running", "finished")

LEGAL_TRANSITIONS = {
    ("new", "queued"),                # submit(): prefix resident (or none)
    ("new", "waiting_on_prefix"),     # park(): prefix compiling/promoting
    ("waiting_on_prefix", "queued"),  # wake(): prefix became resident
    ("queued", "running"),            # admit(): seated into a free slot
    ("running", "queued"),            # preempt(): evicted, tokens stashed
    ("running", "finished"),          # finish(): stop token or budget
}


@dataclass
class Request:
    """One generation request.

    ``prefix``: optional :class:`~repro.serving.prefix_store.PrefixStore`
    entry name — the compressed many-shot task memory this request attends
    to.  Requests with different prefixes batch together; each is seated
    per slot.

    ``raw_shots``: optional (T,) raw many-shot context tokens.  When the
    named prefix is not resident, the engine compiles these online
    (chunked, interleaved with decode) instead of failing — the public
    API for a cold task is *just submit the request*.  With no explicit
    ``prefix`` the name is content-addressed from the shot bytes, so
    byte-identical shot sets from different requests dedup onto one
    compilation and one stored prefix.

    ``priority``: integer class, lower = more urgent; 0 is the default
    and highest class.  ``arrival_s``: optional arrival time in seconds
    *relative to the start of* :meth:`~repro.serving.engine.ServingEngine
    .serve` — the engine holds the request until its clock reaches it,
    which is how the traffic harness replays a Poisson trace.
    """

    tokens: np.ndarray                 # (S,) int32 prompt
    max_new: int
    prefix: Optional[str] = None       # PrefixStore entry name
    stop_token: Optional[int] = None
    temperature: float = 0.0
    raw_shots: Optional[np.ndarray] = None  # (T,) int32 many-shot context
    priority: int = 0                  # class; lower admits/decodes first
    arrival_s: Optional[float] = None  # offset from serve() start
    uid: int = field(default_factory=lambda: next(_UIDS))

    def __post_init__(self):
        self.tokens = np.asarray(self.tokens, np.int32).reshape(-1)
        if self.tokens.size == 0:
            raise ValueError("prompt must contain at least one token")
        if self.max_new < 1:
            raise ValueError("max_new must be >= 1")
        if self.priority < 0:
            raise ValueError("priority classes are non-negative integers")
        if self.arrival_s is not None and self.arrival_s < 0:
            raise ValueError("arrival_s must be >= 0")
        if self.raw_shots is not None:
            self.raw_shots = np.asarray(self.raw_shots, np.int32).reshape(-1)
            if self.raw_shots.size == 0:
                raise ValueError("raw_shots must contain at least one token")
            if self.prefix is None:
                digest = hashlib.sha1(self.raw_shots.tobytes()).hexdigest()
                self.prefix = f"shots-{digest[:12]}"


@dataclass
class _SlotState:
    request: Request
    emitted: List[int] = field(default_factory=list)


class Scheduler:
    """Admits ragged requests into a fixed pool of batch slots."""

    def __init__(self, num_slots: int, *,
                 clock: Optional[Callable[[], float]] = None,
                 aging_interval_s: Optional[float] = None,
                 metrics=None):
        self.num_slots = num_slots
        self.clock = clock if clock is not None else time.perf_counter
        if aging_interval_s is not None and aging_interval_s <= 0:
            raise ValueError("aging_interval_s must be positive")
        self.aging_interval_s = aging_interval_s
        # optional telemetry: a MetricsRegistry (duck-typed — anything
        # with gauge()/counter()) receives queue-depth gauges and
        # lifecycle counters; None keeps the scheduler dependency-free
        self._m = None
        if metrics is not None:
            self._m = {
                "queued": metrics.gauge(
                    "serving_sched_queued", "requests in the FIFO queue"),
                "waiting": metrics.gauge(
                    "serving_sched_waiting_on_prefix",
                    "requests parked until their prefix is resident"),
                "running": metrics.gauge(
                    "serving_sched_running", "slots mid-generation"),
                "submitted": metrics.counter(
                    "serving_sched_submitted_total",
                    "requests entering the scheduler"),
                "preempted": metrics.counter(
                    "serving_sched_preemptions_total",
                    "running slots evicted for a higher class"),
            }
        self._queue: deque[Request] = deque()
        self._slots: List[Optional[_SlotState]] = [None] * num_slots
        # waiting_on_prefix stage: prefix name -> requests parked until the
        # online compiler makes that prefix resident
        self._waiting: "OrderedDict[str, List[Request]]" = OrderedDict()
        # arrival order (submit() and park() alike): woken requests re-enter
        # the queue at their original position, never overtaking a request
        # that arrived before them — whichever compile finished first
        self._arrival = itertools.count()
        self._order: dict = {}
        self._arrive_t: dict = {}   # uid -> clock time first seen (for aging)
        self._resume: dict = {}     # uid -> tokens emitted before preemption
        self.preemptions = 0
        # REPRO_SANITIZE=1: validate every stage move against
        # LEGAL_TRANSITIONS as it happens (sampled once at construction)
        self._sanitize = sanitizer_enabled()
        self._stage: dict = {}      # uid -> current stage (sanitizer only)

    # ---- stage machine ----

    def _transition(self, uid: int, src: str, dst: str) -> None:
        """Record one stage move.  The (src, dst) literals at every call
        site are what reprolint's `state-machine` rule checks against
        LEGAL_TRANSITIONS; under REPRO_SANITIZE=1 this also validates the
        move at runtime (edge legality + the request really being in
        ``src``).  A no-op on the hot path when the sanitizer is off."""
        if not self._sanitize:
            return
        if (src, dst) not in LEGAL_TRANSITIONS:
            raise SanitizerError(
                f"request {uid}: illegal stage transition {src!r} -> "
                f"{dst!r} (legal: {sorted(LEGAL_TRANSITIONS)})")
        cur = self._stage.get(uid, "new")
        if cur != src:
            raise SanitizerError(
                f"request {uid}: transition {src!r} -> {dst!r} but the "
                f"request is in stage {cur!r}")
        self._stage[uid] = dst

    # ---- queue side ----

    def _update_gauges(self) -> None:
        if self._m is None:
            return
        self._m["queued"].set(len(self._queue))
        self._m["waiting"].set(self.num_waiting)
        self._m["running"].set(
            sum(1 for s in self._slots if s is not None))

    def _stamp(self, request: Request) -> None:
        if request.uid not in self._order:
            self._order[request.uid] = next(self._arrival)
            self._arrive_t[request.uid] = self.clock()
            if self._m is not None:
                self._m["submitted"].inc()

    def submit(self, request: Request) -> int:
        self._stamp(request)
        self._transition(request.uid, "new", "queued")
        self._queue.append(request)
        self._update_gauges()
        return request.uid

    @property
    def pending(self) -> int:
        return len(self._queue)

    def has_work(self) -> bool:
        return (bool(self._queue) or bool(self._waiting)
                or any(s is not None for s in self._slots))

    # ---- priority / aging ----

    def effective_class(self, request: Request,
                        now: Optional[float] = None) -> int:
        """The priority class after anti-starvation aging: every
        ``aging_interval_s`` a request has waited shaves one class off,
        floored at 0.  With aging disabled this is just the base class."""
        if self.aging_interval_s is None or request.priority == 0:
            return request.priority
        now = self.clock() if now is None else now
        waited = max(0.0, now - self._arrive_t.get(request.uid, now))
        return max(0, request.priority - int(waited // self.aging_interval_s))

    def _best_index(self) -> int:
        """Index into the arrival-ordered queue of the request with the
        smallest (effective_class, arrival) key.  The queue itself stays
        arrival-ordered, so ties break FIFO for free."""
        now = self.clock()
        best, best_key = 0, None
        for i, req in enumerate(self._queue):
            key = (self.effective_class(req, now), self._order[req.uid])
            if best_key is None or key < best_key:
                best, best_key = i, key
        return best

    def best_queued(self) -> Optional[Request]:
        """The request admit() would pick next, or None — the engine's
        preemption policy compares this against the running slots."""
        return self._queue[self._best_index()] if self._queue else None

    # ---- waiting_on_prefix stage ----

    def park(self, request: Request) -> int:
        """Hold a request until its (compiling) prefix becomes resident."""
        assert request.prefix is not None, "parking needs a prefix name"
        self._stamp(request)
        self._transition(request.uid, "new", "waiting_on_prefix")
        self._waiting.setdefault(request.prefix, []).append(request)
        self._update_gauges()
        return request.uid

    @property
    def num_waiting(self) -> int:
        return sum(len(v) for v in self._waiting.values())

    def waiting_names(self) -> Tuple[str, ...]:
        return tuple(self._waiting)

    def waiting_on(self, name: str) -> List[Request]:
        return list(self._waiting.get(name, ()))

    def _insert_by_arrival(self, req: Request) -> None:
        """Re-enter the queue at the original arrival position: ahead of
        everything that arrived later, behind everything earlier."""
        seq = self._order[req.uid]
        idx = 0
        for queued in self._queue:
            if self._order[queued.uid] > seq:
                break
            idx += 1
        self._queue.insert(idx, req)

    def wake(self, name: str) -> List[Request]:
        """Move every request parked on ``name`` back into the FIFO queue
        at its *original arrival position*: a woken request precedes
        everything that arrived after it, but never overtakes a request
        that arrived earlier (e.g. one woken by a previous install and
        still queued).  Returns the woken requests."""
        woken = self._waiting.pop(name, [])
        for req in woken:
            self._transition(req.uid, "waiting_on_prefix", "queued")
            self._insert_by_arrival(req)
        if woken:
            self._update_gauges()
        return woken

    def referenced_prefixes(self) -> set:
        """Prefix names some not-yet-finished request still depends on —
        the engine pins these against LRU eviction (a running slot's
        prefix is also block-refcount-protected; queued/waiting ones are
        only protected by this set)."""
        names = {r.prefix for r in self._queue if r.prefix is not None}
        names.update(self._waiting)
        for s in self._slots:
            if s is not None and s.request.prefix is not None:
                names.add(s.request.prefix)
        return names

    # ---- slot side ----

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is None]

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is not None]

    def request_in(self, slot: int) -> Request:
        state = self._slots[slot]
        assert state is not None, f"slot {slot} is free"
        return state.request

    def admit(self, can_seat=None) -> List[Tuple[int, Request]]:
        """Seat queued requests into free slots. Returns the
        (slot, request) pairs admitted this call.

        Each free slot takes the queued request with the smallest
        ``(effective_class, arrival)`` key — plain FIFO when every
        request shares one class.  ``can_seat(request) -> bool`` gates
        admission on engine capacity (the paged engine passes its
        free-block check).  The first best-ranked request that does not
        fit stops the scan — later, smaller requests are *not* admitted
        around it, preserving the no-overtake guarantee within a class."""
        seated = []
        for slot in self.free_slots():
            if not self._queue:
                break
            idx = self._best_index()
            req = self._queue[idx]
            if can_seat is not None and not can_seat(req):
                break
            del self._queue[idx]
            self._transition(req.uid, "queued", "running")
            resumed = self._resume.pop(req.uid, None)
            self._slots[slot] = _SlotState(req, emitted=list(resumed or ()))
            seated.append((slot, req))
        if seated:
            self._update_gauges()
        return seated

    def emitted_tokens(self, slot: int) -> np.ndarray:
        """Tokens the seated request has already emitted — non-empty only
        for a preempted-and-resumed request, where the engine must
        re-prefill ``prompt + emitted`` to rebuild the evicted KV state."""
        state = self._slots[slot]
        assert state is not None, f"slot {slot} is free"
        return np.asarray(state.emitted, np.int32)

    def resume_len(self, uid: int) -> int:
        """How many stashed tokens a queued request will resume with (0
        for fresh requests) — the engine's block-capacity gate adds this
        to the prompt length before admission."""
        return len(self._resume.get(uid, ()))

    def preempt(self, slot: int) -> Request:
        """Evict a running slot back into the queue (token-exact): the
        emitted tokens are stashed for resumption and the request
        re-enters at its original arrival position.  The caller (engine)
        owns releasing the slot's KV/blocks."""
        state = self._slots[slot]
        assert state is not None, f"slot {slot} is free"
        self._slots[slot] = None
        req = state.request
        self._transition(req.uid, "running", "queued")
        self._resume[req.uid] = list(state.emitted)
        self._insert_by_arrival(req)
        self.preemptions += 1
        if self._m is not None:
            self._m["preempted"].inc()
            self._update_gauges()
        return req

    def record_token(self, slot: int, token: int) -> bool:
        """Append a sampled token to a slot's output. Returns True when the
        slot just finished — its own stop token or its own budget; other
        slots are unaffected."""
        state = self._slots[slot]
        assert state is not None, f"slot {slot} is free"
        state.emitted.append(int(token))
        req = state.request
        if req.stop_token is not None and int(token) == req.stop_token:
            return True
        return len(state.emitted) >= req.max_new

    def finish(self, slot: int) -> Tuple[Request, np.ndarray]:
        """Release a slot, returning (request, generated tokens)."""
        state = self._slots[slot]
        assert state is not None, f"slot {slot} is free"
        self._transition(state.request.uid, "running", "finished")
        self._slots[slot] = None
        self._update_gauges()
        return state.request, np.asarray(state.emitted, np.int32)
