"""Request queue and slot bookkeeping for the continuous-batching engine.

Pure-python control plane, deliberately free of jax: the
:class:`~repro.serving.engine.ServingEngine` owns the device arrays and
asks the scheduler three questions each step — which queued requests fit
into free slots (:meth:`Scheduler.admit`), which slots are mid-generation
(:meth:`Scheduler.active_slots`), and whether a freshly sampled token
finishes its slot (:meth:`Scheduler.record_token`: per-slot stop token or
per-slot token budget, *independently* of every other slot).

Finished slots return to the free pool immediately, so the next queued
request is admitted mid-decode — no drain barrier, no recompilation (the
decode step's shapes never change; only the per-slot length vector does).

Requests whose compressed prefix is not HBM-resident sit in a fourth
stage, **waiting_on_prefix** (:meth:`Scheduler.park`), until the engine
makes it resident and :meth:`Scheduler.wake`\\ s them into the head of
the FIFO queue.  Two producers feed the stage — the online
:class:`~repro.serving.compiler.PrefixCompiler` (requests carrying
``raw_shots`` for an uncompiled task) and the :class:`~repro.serving
.tiers.TieredPrefixStore` promotion path (a previously evicted prefix
copying back from the host or disk tier) — and the scheduler cannot
tell them apart: parking is keyed by prefix name alone.

    waiting_on_prefix ──wake──▶ queued ──admit──▶ running ──▶ finished
"""

from __future__ import annotations

import hashlib
import itertools
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

_UIDS = itertools.count()


@dataclass
class Request:
    """One generation request.

    ``prefix``: optional :class:`~repro.serving.prefix_store.PrefixStore`
    entry name — the compressed many-shot task memory this request attends
    to.  Requests with different prefixes batch together; each is seated
    per slot.

    ``raw_shots``: optional (T,) raw many-shot context tokens.  When the
    named prefix is not resident, the engine compiles these online
    (chunked, interleaved with decode) instead of failing — the public
    API for a cold task is *just submit the request*.  With no explicit
    ``prefix`` the name is content-addressed from the shot bytes, so
    byte-identical shot sets from different requests dedup onto one
    compilation and one stored prefix.
    """

    tokens: np.ndarray                 # (S,) int32 prompt
    max_new: int
    prefix: Optional[str] = None       # PrefixStore entry name
    stop_token: Optional[int] = None
    temperature: float = 0.0
    raw_shots: Optional[np.ndarray] = None  # (T,) int32 many-shot context
    uid: int = field(default_factory=lambda: next(_UIDS))

    def __post_init__(self):
        self.tokens = np.asarray(self.tokens, np.int32).reshape(-1)
        if self.tokens.size == 0:
            raise ValueError("prompt must contain at least one token")
        if self.max_new < 1:
            raise ValueError("max_new must be >= 1")
        if self.raw_shots is not None:
            self.raw_shots = np.asarray(self.raw_shots, np.int32).reshape(-1)
            if self.raw_shots.size == 0:
                raise ValueError("raw_shots must contain at least one token")
            if self.prefix is None:
                digest = hashlib.sha1(self.raw_shots.tobytes()).hexdigest()
                self.prefix = f"shots-{digest[:12]}"


@dataclass
class _SlotState:
    request: Request
    emitted: List[int] = field(default_factory=list)


class Scheduler:
    """Admits ragged requests into a fixed pool of batch slots."""

    def __init__(self, num_slots: int):
        self.num_slots = num_slots
        self._queue: deque[Request] = deque()
        self._slots: List[Optional[_SlotState]] = [None] * num_slots
        # waiting_on_prefix stage: prefix name -> requests parked until the
        # online compiler makes that prefix resident
        self._waiting: "OrderedDict[str, List[Request]]" = OrderedDict()
        # arrival order (submit() and park() alike): woken requests re-enter
        # the queue at their original position, never overtaking a request
        # that arrived before them — whichever compile finished first
        self._arrival = itertools.count()
        self._order: dict = {}

    # ---- queue side ----

    def _stamp(self, request: Request) -> None:
        if request.uid not in self._order:
            self._order[request.uid] = next(self._arrival)

    def submit(self, request: Request) -> int:
        self._stamp(request)
        self._queue.append(request)
        return request.uid

    @property
    def pending(self) -> int:
        return len(self._queue)

    def has_work(self) -> bool:
        return (bool(self._queue) or bool(self._waiting)
                or any(s is not None for s in self._slots))

    # ---- waiting_on_prefix stage ----

    def park(self, request: Request) -> int:
        """Hold a request until its (compiling) prefix becomes resident."""
        assert request.prefix is not None, "parking needs a prefix name"
        self._stamp(request)
        self._waiting.setdefault(request.prefix, []).append(request)
        return request.uid

    @property
    def num_waiting(self) -> int:
        return sum(len(v) for v in self._waiting.values())

    def waiting_names(self) -> Tuple[str, ...]:
        return tuple(self._waiting)

    def waiting_on(self, name: str) -> List[Request]:
        return list(self._waiting.get(name, ()))

    def wake(self, name: str) -> List[Request]:
        """Move every request parked on ``name`` back into the FIFO queue
        at its *original arrival position*: a woken request precedes
        everything that arrived after it, but never overtakes a request
        that arrived earlier (e.g. one woken by a previous install and
        still queued).  Returns the woken requests."""
        woken = self._waiting.pop(name, [])
        for req in woken:
            seq = self._order[req.uid]
            idx = 0
            for queued in self._queue:
                if self._order[queued.uid] > seq:
                    break
                idx += 1
            self._queue.insert(idx, req)
        return woken

    def referenced_prefixes(self) -> set:
        """Prefix names some not-yet-finished request still depends on —
        the engine pins these against LRU eviction (a running slot's
        prefix is also block-refcount-protected; queued/waiting ones are
        only protected by this set)."""
        names = {r.prefix for r in self._queue if r.prefix is not None}
        names.update(self._waiting)
        for s in self._slots:
            if s is not None and s.request.prefix is not None:
                names.add(s.request.prefix)
        return names

    # ---- slot side ----

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is None]

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is not None]

    def request_in(self, slot: int) -> Request:
        state = self._slots[slot]
        assert state is not None, f"slot {slot} is free"
        return state.request

    def admit(self, can_seat=None) -> List[Tuple[int, Request]]:
        """Seat queued requests into free slots (FIFO). Returns the
        (slot, request) pairs admitted this call.

        ``can_seat(request) -> bool`` gates admission on engine capacity
        (the paged engine passes its free-block check).  Admission stays
        strictly FIFO: the first request that does not fit stops the scan
        — later, smaller requests are *not* admitted around it."""
        seated = []
        for slot in self.free_slots():
            if not self._queue:
                break
            if can_seat is not None and not can_seat(self._queue[0]):
                break
            req = self._queue.popleft()
            self._slots[slot] = _SlotState(req)
            seated.append((slot, req))
        return seated

    def record_token(self, slot: int, token: int) -> bool:
        """Append a sampled token to a slot's output. Returns True when the
        slot just finished — its own stop token or its own budget; other
        slots are unaffected."""
        state = self._slots[slot]
        assert state is not None, f"slot {slot} is free"
        state.emitted.append(int(token))
        req = state.request
        if req.stop_token is not None and int(token) == req.stop_token:
            return True
        return len(state.emitted) >= req.max_new

    def finish(self, slot: int) -> Tuple[Request, np.ndarray]:
        """Release a slot, returning (request, generated tokens)."""
        state = self._slots[slot]
        assert state is not None, f"slot {slot} is free"
        self._slots[slot] = None
        return state.request, np.asarray(state.emitted, np.int32)
