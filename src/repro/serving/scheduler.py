"""Request queue and slot bookkeeping for the continuous-batching engine.

Pure-python control plane, deliberately free of jax: the
:class:`~repro.serving.engine.ServingEngine` owns the device arrays and
asks the scheduler three questions each step — which queued requests fit
into free slots (:meth:`Scheduler.admit`), which slots are mid-generation
(:meth:`Scheduler.active_slots`), and whether a freshly sampled token
finishes its slot (:meth:`Scheduler.record_token`: per-slot stop token or
per-slot token budget, *independently* of every other slot).

Finished slots return to the free pool immediately, so the next queued
request is admitted mid-decode — no drain barrier, no recompilation (the
decode step's shapes never change; only the per-slot length vector does).
"""

from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

_UIDS = itertools.count()


@dataclass
class Request:
    """One generation request.

    ``prefix``: optional :class:`~repro.serving.prefix_store.PrefixStore`
    entry name — the compressed many-shot task memory this request attends
    to.  Requests with different prefixes batch together; each is seated
    per slot.
    """

    tokens: np.ndarray                 # (S,) int32 prompt
    max_new: int
    prefix: Optional[str] = None       # PrefixStore entry name
    stop_token: Optional[int] = None
    temperature: float = 0.0
    uid: int = field(default_factory=lambda: next(_UIDS))

    def __post_init__(self):
        self.tokens = np.asarray(self.tokens, np.int32).reshape(-1)
        if self.tokens.size == 0:
            raise ValueError("prompt must contain at least one token")
        if self.max_new < 1:
            raise ValueError("max_new must be >= 1")


@dataclass
class _SlotState:
    request: Request
    emitted: List[int] = field(default_factory=list)


class Scheduler:
    """Admits ragged requests into a fixed pool of batch slots."""

    def __init__(self, num_slots: int):
        self.num_slots = num_slots
        self._queue: deque[Request] = deque()
        self._slots: List[Optional[_SlotState]] = [None] * num_slots

    # ---- queue side ----

    def submit(self, request: Request) -> int:
        self._queue.append(request)
        return request.uid

    @property
    def pending(self) -> int:
        return len(self._queue)

    def has_work(self) -> bool:
        return bool(self._queue) or any(s is not None for s in self._slots)

    # ---- slot side ----

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is None]

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is not None]

    def request_in(self, slot: int) -> Request:
        state = self._slots[slot]
        assert state is not None, f"slot {slot} is free"
        return state.request

    def admit(self, can_seat=None) -> List[Tuple[int, Request]]:
        """Seat queued requests into free slots (FIFO). Returns the
        (slot, request) pairs admitted this call.

        ``can_seat(request) -> bool`` gates admission on engine capacity
        (the paged engine passes its free-block check).  Admission stays
        strictly FIFO: the first request that does not fit stops the scan
        — later, smaller requests are *not* admitted around it."""
        seated = []
        for slot in self.free_slots():
            if not self._queue:
                break
            if can_seat is not None and not can_seat(self._queue[0]):
                break
            req = self._queue.popleft()
            self._slots[slot] = _SlotState(req)
            seated.append((slot, req))
        return seated

    def record_token(self, slot: int, token: int) -> bool:
        """Append a sampled token to a slot's output. Returns True when the
        slot just finished — its own stop token or its own budget; other
        slots are unaffected."""
        state = self._slots[slot]
        assert state is not None, f"slot {slot} is free"
        state.emitted.append(int(token))
        req = state.request
        if req.stop_token is not None and int(token) == req.stop_token:
            return True
        return len(state.emitted) >= req.max_new

    def finish(self, slot: int) -> Tuple[Request, np.ndarray]:
        """Release a slot, returning (request, generated tokens)."""
        state = self._slots[slot]
        assert state is not None, f"slot {slot} is free"
        self._slots[slot] = None
        return state.request, np.asarray(state.emitted, np.int32)
