"""Runtime sanitizer gate (``REPRO_SANITIZE=1``).

The static checks in ``tools/reprolint`` and the runtime checks guarded
by this module enforce the *same* contracts from two sides: the linter
proves every code path balances block refcounts and every scheduler
stage move names a legal edge, and the sanitizer asserts the resulting
runtime state actually satisfies the invariants (free/used partition of
the pool, positive refcounts, legal stage sequences per request).  A bug
the dataflow analysis cannot see (e.g. state corrupted through an alias)
still trips the sanitizer; a hazard that never happens to execute in a
test still trips the linter.

The flag is sampled once per *object construction* (allocator,
scheduler), not per operation, so the hot decode loop pays a single
attribute test per check site and nothing at all when disabled.  Tests
flip the environment variable and construct fresh objects.
"""

from __future__ import annotations

import os

__all__ = ["SanitizerError", "sanitizer_enabled"]


class SanitizerError(AssertionError):
    """A serving-protocol invariant (refcount partition, stage machine)
    was violated at runtime.  Subclasses AssertionError on purpose: these
    are impossible-by-construction states, not recoverable conditions."""


def sanitizer_enabled() -> bool:
    """True when ``REPRO_SANITIZE`` is set to anything but ''/'0'."""
    return os.environ.get("REPRO_SANITIZE", "") not in ("", "0")
