"""Tiered prefix cache: HBM ↔ pinned-host ↔ disk for compressed prefixes.

MemCom's value proposition is that a task's many-shots compress *once*
into a small per-layer soft-token summary reused across every request
for that task — but the HBM stores alone make eviction destructive:
under multi-tenant pressure an LRU'd prefix forces a full online
recompile (``serving/compiler.py``) on the next request, paying the
compression cost the paper amortized away.  :class:`TieredPrefixStore`
turns eviction into *demotion* down a memory hierarchy:

    HBM (PrefixStore / PagedPrefixStore)      seat-ready, device arrays
      │ evict ──▶ demote                 ▲ promote (chunked, async)
      ▼                                  │
    host tier (pinned RAM, numpy rows)  ─┘
      │ over host_capacity ──▶ spill     ▲ load (counted ``disk_loads``)
      ▼                                  │
    disk tier (one codec-compressed shard per prefix) ────────┘

* **Demote** — the stores' ``demote_hook`` fires on every evict (LRU and
  explicit alike): dense entries copy to host numpy; paged entries
  gather their KV back out of the pool blocks (plus the stripped
  per-slot state from ``strip_kv_leaves``) *before* the blocks are
  released, reconstructing the same batch-free row the dense store
  keeps.  A prefix seated in a live slot still raises
  :class:`~repro.serving.prefix_store.PrefixSeatedError` — nothing is
  ever demoted out from under a slot.
* **Spill** — past ``host_capacity`` the LRU host row is written to
  ``disk_dir`` as a single shard (msgpack header + one compressed blob,
  reusing :func:`repro.checkpoint.store.compress_bytes` — zstd with
  zlib fallback, codec recorded in the header).  Shards are committed
  with an atomic rename and re-indexed on startup, so a restarted
  server promotes yesterday's prefixes instead of recompiling them.
* **Promote** — a request naming a cold prefix parks in the scheduler's
  ``waiting_on_prefix`` stage (exactly like a compiling task) while the
  engine copies the row host→HBM in **per-layer chunks**, at most
  ``promote_layer_budget`` chunks between decode steps (mirroring
  ``compile_token_budget``), so seated slots keep emitting tokens
  through a promotion.  On a mesh each chunk is ``device_put`` with its
  pool-layout :func:`~repro.sharding.serving.leaf_sharding`, so
  promotion lands pre-sharded — no replicated detour, no host
  gather/scatter round-trip.

Tiers are **exclusive** (a name lives in exactly one tier) and moves
are **bit-exact**: the row that comes back up is byte-identical to the
one that went down, so a request's greedy output cannot depend on which
tier its prefix was served from (asserted in ``tests/test_tiers.py``).

The class fronts the HBM store: residency checks (``in``, ``lookup``)
and all seat-path lookups delegate, so the engine's seating/refcount
logic is tier-oblivious.  See docs/ARCHITECTURE.md §"Prefix memory
hierarchy".
"""

from __future__ import annotations

import hashlib
import itertools
import os
import struct
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

from repro.checkpoint.store import compress_bytes, decompress_bytes
from repro.serving.prefix_store import (
    _KV_KEYS,
    PagedPrefixStore,
    _map_rowwise,
    _row_base_len,
)

__all__ = ["TieredPrefixStore", "PromotionJob"]

_SHARD_SUFFIX = ".prefix"
_MAGIC = b"MCPF"  # MemCom prefix shard
_VERSION = 1


def _host_tree(tree):
    """Device tree → host numpy tree (bit-exact copy)."""
    return jax.tree.map(np.asarray, tree)


def _tree_nbytes(tree) -> int:
    return sum(int(x.nbytes) for x in jax.tree.leaves(tree))


@dataclass
class PromotionJob:
    """One prefix's asynchronous host→HBM copy.

    ``pending`` holds per-layer host chunks (prefix-section entries plus
    per-repeat slices of the stacked period sections); the engine drains
    up to ``promote_layer_budget`` of them between decode steps.  When
    the last chunk lands, the device row is assembled and the job turns
    ``ready`` — the engine installs it into the HBM store (with the same
    paged-pressure deferral as a compiled prefix) and wakes the parked
    requests.
    """

    name: str
    source: str                       # "host" | "disk"
    host_row: dict                    # the full host row (structure + state)
    base_len: int
    pending: deque = field(default_factory=deque)
    dev_prefix: Dict[int, dict] = field(default_factory=dict)
    dev_period: Dict[str, Dict[int, dict]] = field(default_factory=dict)
    status: str = "promoting"         # -> "ready" (installed jobs are dropped)
    row: Optional[dict] = None        # assembled device row when ready
    total_chunks: int = 0
    priority: int = 0                 # best class waiting on it
    seq: int = 0                      # submission order (FIFO ties)

    @property
    def remaining(self) -> int:
        return len(self.pending)


class TieredPrefixStore:
    """HBM store front with pinned-host and disk tiers behind it.

    Wraps a :class:`~repro.serving.prefix_store.PrefixStore` or
    :class:`~repro.serving.prefix_store.PagedPrefixStore` (``hbm``):
    every seat-path method the engine uses (``lookup``, ``put``,
    ``blocks``, ``base_len``, ``state_row``, ``evict``, ``in``, …)
    behaves exactly like the wrapped store, while evictions demote and
    :meth:`submit_promotion` / :meth:`promote_step` implement the
    budgeted upward path.

    ``host_capacity`` bounds the host tier (``None`` = unbounded; ``0``
    = demotions go straight to disk); past it the LRU host row spills to
    ``disk_dir`` (or, with no disk tier, is dropped — counted).
    """

    def __init__(self, hbm, *, host_capacity: Optional[int] = None,
                 disk_dir: Optional[str] = None, mesh=None, rules=None,
                 cache_ref=None):
        if host_capacity is not None and host_capacity < 0:
            raise ValueError("host_capacity must be >= 0 (or None)")
        self.hbm = hbm
        self.host_capacity = host_capacity
        self.disk_dir = disk_dir
        self.mesh = mesh
        self.rules = rules
        # paged demotion reads the evicted blocks back out of the live
        # pools, which the engine owns functionally — this thunk returns
        # the engine's current cache at demotion time
        self._cache_ref = cache_ref
        self._host: "OrderedDict[str, dict]" = OrderedDict()
        self._host_base: Dict[str, int] = {}
        self._disk: Dict[str, str] = {}       # name -> shard path
        self._disk_base: Dict[str, int] = {}
        self._jobs: "OrderedDict[str, PromotionJob]" = OrderedDict()
        self._job_seq = itertools.count()  # submission order for FIFO ties
        self.tier_stats: Dict[str, int] = {
            "hbm_hits": 0,        # serve-path lookups answered from HBM
            "host_promotes": 0,   # completed host→HBM promotions
            "disk_loads": 0,      # shards read (disk→promotion path)
            "demotes": 0,         # HBM evictions captured into the host tier
            "spills": 0,          # host rows written to disk
            "promote_bytes": 0,   # bytes copied host→HBM
            "promote_chunks": 0,  # per-layer chunks copied host→HBM
            "host_drops": 0,      # host-pressure casualties with no disk tier
        }
        hbm.demote_hook = self._demote
        if disk_dir:
            os.makedirs(disk_dir, exist_ok=True)
            self._scan_disk()

    # ------------------------------------------------------------------
    # HBM front (the engine's store API)
    # ------------------------------------------------------------------

    def __getattr__(self, attr):
        # everything not overridden (get/blocks/base_len/state_row/
        # seated/evict/capacity/alloc/...) behaves as the HBM store
        if attr == "hbm":  # guard: never recurse before __init__ ran
            raise AttributeError(attr)
        return getattr(self.hbm, attr)

    def __contains__(self, name) -> bool:
        return name in self.hbm  # residency == seatable == HBM

    def __len__(self) -> int:
        return len(self.hbm)

    @property
    def stats(self):
        return self.hbm.stats

    @property
    def pinned(self):
        return self.hbm.pinned

    @pinned.setter
    def pinned(self, names):
        self.hbm.pinned = names

    def names(self) -> Tuple[str, ...]:
        """Every tier's names, hottest tier first (HBM, host, disk)."""
        return tuple(dict.fromkeys(
            tuple(self.hbm.names()) + tuple(self._host) + tuple(self._disk)))

    def lookup(self, name: str) -> bool:
        hit = name in self.hbm
        if hit:
            self.tier_stats["hbm_hits"] += 1
        return self.hbm.lookup(name)

    def put(self, name: str, materialized, *args, **kwargs):
        out = self.hbm.put(name, materialized, *args, **kwargs)
        self._forget_cold(name)  # fresh content supersedes any cold copy
        return out

    def put_row(self, name: str, row, *args, **kwargs):
        out = self.hbm.put_row(name, row, *args, **kwargs)
        self._forget_cold(name)
        return out

    # ------------------------------------------------------------------
    # Cold residency
    # ------------------------------------------------------------------

    def tier_of(self, name: str) -> Optional[str]:
        """"hbm" | "host" | "disk" | "promoting" | None."""
        if name in self.hbm:
            return "hbm"
        if name in self._jobs:
            return "promoting"
        if name in self._host:
            return "host"
        if name in self._disk:
            return "disk"
        return None

    def cold_resident(self, name: str) -> bool:
        """True when ``name`` is recoverable without recompiling — in the
        host or disk tier, or already mid-promotion."""
        return self.tier_of(name) in ("host", "disk", "promoting")

    def cold_base_len(self, name: str) -> int:
        """base_len of a not-yet-promoted prefix (request validation)."""
        if name in self._jobs:
            return self._jobs[name].base_len
        if name in self._host:
            return self._host_base[name]
        if name in self._disk:
            return self._disk_base[name]
        raise KeyError(f"prefix {name!r} is not in a cold tier")

    def host_names(self) -> Tuple[str, ...]:
        return tuple(self._host)

    def disk_names(self) -> Tuple[str, ...]:
        return tuple(self._disk)

    def _forget_cold(self, name: str) -> None:
        self._host.pop(name, None)
        self._host_base.pop(name, None)
        self._jobs.pop(name, None)
        path = self._disk.pop(name, None)
        self._disk_base.pop(name, None)
        if path is not None and os.path.exists(path):
            os.remove(path)

    # ------------------------------------------------------------------
    # Downward path: demote (HBM→host) and spill (host→disk)
    # ------------------------------------------------------------------

    def demote(self, name: str) -> None:
        """Evict ``name`` from HBM, capturing it into the host tier
        (raises :class:`PrefixSeatedError` while any slot is seated on
        it — the hook only fires after the wrapped store's guard)."""
        self.hbm.evict(name)

    def _demote(self, name: str, payload) -> None:
        """The stores' ``demote_hook``: dense hands the device row, paged
        hands its ``{"blocks", "base_len", "state"}`` entry (blocks still
        referenced, so the pool still holds this prefix's KV)."""
        if isinstance(self.hbm, PagedPrefixStore):
            row = self._gather_paged(payload)
        else:
            row = _host_tree(payload)
        self._host_insert(name, row)
        self.tier_stats["demotes"] += 1

    def _gather_paged(self, entry) -> dict:
        """Read a paged prefix back out of the pool blocks into the same
        batch-free row layout the dense store keeps: KV gathered from
        positions ``[0, base_len)`` of the entry's blocks, merged with
        the stripped per-slot state (ssm handoff)."""
        cache = self._cache_ref()
        base = int(entry["base_len"])
        ids = jnp.asarray(list(entry["blocks"]), jnp.int32)

        def take(c, _p, axis):
            out = {}
            if base == 0:
                return out
            for key in _KV_KEYS:
                if key in c:
                    if axis == 0:     # pool (N, bs, ...), row (m, ...)
                        g = jnp.take(c[key], ids, axis=0)
                        g = g.reshape((-1,) + g.shape[2:])[:base]
                    else:             # pool (R, N, bs, ...), row (R, m, ...)
                        g = jnp.take(c[key], ids, axis=1)
                        g = g.reshape(g.shape[:1] + (-1,) + g.shape[3:])
                        g = g[:, :base]
                    out[key] = np.asarray(g)
            return out

        row = _map_rowwise(cache, None, take)
        state = entry.get("state")
        if state is not None:
            host_state = _host_tree(state)
            for i, e in enumerate(host_state.get("prefix", [])):
                row["prefix"][i].update(e)
            for key, e in host_state.get("period", {}).items():
                row["period"][key].update(e)
        return row

    def _host_insert(self, name: str, row: dict) -> None:
        self._host[name] = row
        self._host.move_to_end(name)
        self._host_base[name] = _row_base_len(row)
        while self.host_capacity is not None and \
                len(self._host) > self.host_capacity:
            if not self._spill_lru():
                break  # everything left is mid-promotion; run over budget

    def _spill_lru(self) -> bool:
        for name in self._host:  # oldest first
            if name in self._jobs:
                continue  # a promotion is reading this row; skip it
            row = self._host.pop(name)
            base = self._host_base.pop(name)
            if self.disk_dir:
                self.spill_row(name, row, base)
            else:
                self.tier_stats["host_drops"] += 1
            return True
        return False

    def spill(self, name: str) -> str:
        """Explicitly move one host row to disk; returns the shard path."""
        if name not in self._host:
            raise KeyError(f"prefix {name!r} is not in the host tier")
        row = self._host.pop(name)
        base = self._host_base.pop(name)
        return self.spill_row(name, row, base)

    def spill_row(self, name: str, row: dict, base_len: int) -> str:
        if not self.disk_dir:
            raise ValueError("no disk tier configured (disk_dir is unset)")
        path = self._shard_path(name)
        self._write_shard(path, name, row, base_len)
        self._disk[name] = path
        self._disk_base[name] = base_len
        self.tier_stats["spills"] += 1
        return path

    # ------------------------------------------------------------------
    # Upward path: budgeted, chunked promotion
    # ------------------------------------------------------------------

    def submit_promotion(self, name: str, priority: int = 0) -> PromotionJob:
        """Start (or join — single-flight per name) the host→HBM copy of
        a cold prefix.  A disk-resident prefix is loaded into the job
        first (counted ``disk_loads``); its shard stays on disk until the
        promoted row is installed.  The job takes the best priority class
        any joiner asked for; :meth:`promote_step` serves jobs in
        ``(priority, submission order)`` order."""
        job = self._jobs.get(name)
        if job is not None:
            job.priority = min(job.priority, priority)
            return job
        if name in self._host:
            row, source = self._host[name], "host"
            self._host.move_to_end(name)
        elif name in self._disk:
            row = self._read_shard(self._disk[name])
            self.tier_stats["disk_loads"] += 1
            source = "disk"
        else:
            raise KeyError(f"prefix {name!r} is not in a cold tier; "
                           f"tiers: {self.names() or '(none)'}")
        job = PromotionJob(name=name, source=source, host_row=row,
                           base_len=_row_base_len(row), priority=priority,
                           seq=next(self._job_seq))
        for i, entry in enumerate(row.get("prefix", [])):
            if entry:
                job.pending.append(("prefix", i, entry))
        for key, entry in row.get("period", {}).items():
            if not entry:
                continue
            repeats = next(iter(entry.values())).shape[0]
            for j in range(repeats):
                job.pending.append(
                    ("period", key, j, {k: v[j] for k, v in entry.items()}))
        job.total_chunks = len(job.pending)
        self._jobs[name] = job
        return job

    def has_promote_work(self) -> bool:
        return any(j.status == "promoting" for j in self._jobs.values())

    def ready_promotions(self) -> List[str]:
        return [n for n, j in self._jobs.items() if j.status == "ready"]

    def promoted_row(self, name: str) -> dict:
        job = self._jobs[name]
        assert job.status == "ready", job.status
        return job.row

    def promote_step(self, chunk_budget: Optional[int] = None) -> List[str]:
        """Copy up to ``chunk_budget`` per-layer chunks host→HBM (``None``
        = run the head job to completion — the stalled baseline).  Jobs
        advance in ``(priority, submission order)`` order — strictly FIFO
        when every request shares one class (already-copied chunks of a
        job a later, more urgent submission overtakes stay staged on
        device, so no work is lost).  Returns the names turned ready."""
        finished: List[str] = []
        budget = chunk_budget
        while True:
            promoting = [j for j in self._jobs.values()
                         if j.status == "promoting"]
            job = (min(promoting, key=lambda j: (j.priority, j.seq))
                   if promoting else None)
            if job is None or (budget is not None and budget <= 0):
                break
            n = job.remaining if budget is None else min(job.remaining, budget)
            for _ in range(n):
                self._copy_chunk(job, job.pending.popleft())
            if budget is not None:
                budget -= n
            if not job.pending:
                job.row = self._assemble(job)
                job.status = "ready"
                finished.append(job.name)
                if budget is None:
                    break  # None = one whole job, not the whole queue
        return finished

    def mark_promoted(self, name: str) -> None:
        """Count a completed promotion (the install's ``put_row`` already
        removed the job and the stale cold copies via ``_forget_cold`` —
        the move up the hierarchy is complete)."""
        self._jobs.pop(name, None)
        self.tier_stats["host_promotes"] += 1

    def _copy_chunk(self, job: PromotionJob, chunk) -> None:
        entry = chunk[-1]
        dev = {k: self._put_leaf(k, v) for k, v in entry.items()}
        if chunk[0] == "prefix":
            job.dev_prefix[chunk[1]] = dev
        else:
            job.dev_period.setdefault(chunk[1], {})[chunk[2]] = dev
        self.tier_stats["promote_chunks"] += 1
        self.tier_stats["promote_bytes"] += _tree_nbytes(entry)

    def _put_leaf(self, key: str, arr: np.ndarray):
        if self.mesh is None:
            return jnp.asarray(arr)
        return jax.device_put(arr, self._put_leaf_sharding(key, arr))

    def _assemble(self, job: PromotionJob) -> dict:
        """Reassemble the device row from the copied chunks, preserving
        the host row's structure (empty layer entries included)."""
        hr = job.host_row
        row: dict = {}
        if "prefix" in hr:
            row["prefix"] = [job.dev_prefix.get(i, {})
                             for i in range(len(hr["prefix"]))]
        if "period" in hr:
            row["period"] = {}
            for key, entry in hr["period"].items():
                layers = job.dev_period.get(key)
                if not layers:
                    row["period"][key] = {}
                    continue
                stacked = {
                    k: jnp.stack([layers[j][k] for j in range(len(layers))])
                    for k in layers[0]
                }
                if self.mesh is not None:
                    # device-to-device re-pin: stacking may have let GSPMD
                    # drift the layout; no host round-trip here
                    stacked = {k: jax.device_put(
                        v, self._put_leaf_sharding(k, v))
                        for k, v in stacked.items()}
                row["period"][key] = stacked
        return row

    def _put_leaf_sharding(self, key: str, arr):
        from repro.sharding.serving import BASELINE_RULES, leaf_sharding

        return leaf_sharding(key, arr, self.mesh,
                             self.rules or BASELINE_RULES)

    # ------------------------------------------------------------------
    # Disk shards (checkpoint codec machinery, one file per prefix)
    # ------------------------------------------------------------------

    def _shard_path(self, name: str) -> str:
        digest = hashlib.sha1(name.encode()).hexdigest()[:16]
        return os.path.join(self.disk_dir, digest + _SHARD_SUFFIX)

    def _write_shard(self, path: str, name: str, row: dict,
                     base_len: int) -> None:
        entries, raws, offset = [], [], 0
        for leaf_path, arr in _flatten_row(row):
            raw = np.asarray(arr).tobytes()
            entries.append({"path": leaf_path, "shape": list(arr.shape),
                            "dtype": str(arr.dtype), "offset": offset,
                            "nbytes": len(raw)})
            raws.append(raw)
            offset += len(raw)
        codec, blob = compress_bytes(b"".join(raws))
        # structure survives separately from the leaves: layer entries
        # with no leaves (and absent sections) must round-trip too
        structure = {"prefix_len": (len(row["prefix"])
                                    if "prefix" in row else None),
                     "period_keys": (sorted(row["period"])
                                     if "period" in row else None)}
        header = msgpack.packb({"version": _VERSION, "name": name,
                                "codec": codec, "base_len": base_len,
                                "structure": structure, "entries": entries})
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(_MAGIC + struct.pack("<I", len(header)))
            f.write(header)
            f.write(blob)
        os.replace(tmp, path)  # atomic commit (mirrors checkpoint/store.py)

    def _read_header(self, f) -> dict:
        magic = f.read(len(_MAGIC))
        if magic != _MAGIC:
            raise ValueError(f"{f.name}: not a prefix shard "
                             f"(bad magic {magic!r})")
        (hlen,) = struct.unpack("<I", f.read(4))
        return msgpack.unpackb(f.read(hlen))

    def _read_shard(self, path: str) -> dict:
        import ml_dtypes  # noqa: F401  (registers bfloat16 with numpy)

        with open(path, "rb") as f:
            header = self._read_header(f)
            data = decompress_bytes(f.read(), header["codec"])
        leaves = {}
        for e in header["entries"]:
            raw = data[e["offset"]:e["offset"] + e["nbytes"]]
            leaves[e["path"]] = np.frombuffer(
                raw, dtype=np.dtype(e["dtype"])).reshape(e["shape"])
        return _unflatten_row(leaves, header["structure"])

    def _scan_disk(self) -> None:
        """Index pre-existing shards so a restarted server promotes
        yesterday's prefixes instead of recompiling them."""
        for fname in sorted(os.listdir(self.disk_dir)):
            if not fname.endswith(_SHARD_SUFFIX):
                continue
            path = os.path.join(self.disk_dir, fname)
            try:
                with open(path, "rb") as f:
                    header = self._read_header(f)
            except (ValueError, struct.error):
                continue  # foreign file; leave it alone
            self._disk[header["name"]] = path
            self._disk_base[header["name"]] = int(header["base_len"])

    # ------------------------------------------------------------------
    # Introspection (ServingEngine.stats())
    # ------------------------------------------------------------------

    def tier_snapshot(self) -> Dict[str, int]:
        out = dict(self.tier_stats)
        out["hbm_resident"] = len(self.hbm)
        out["host_resident"] = len(self._host)
        out["disk_resident"] = len(self._disk)
        out["promotions_in_flight"] = len(self._jobs)
        return out


# ---------------------------------------------------------------------------
# Row (de)serialization helpers
# ---------------------------------------------------------------------------


def _flatten_row(row: dict) -> List[Tuple[str, np.ndarray]]:
    """Deterministic (path, leaf) pairs for a batch-free prefix row:
    ``prefix/<i>/<key>`` and ``period/<lkey>/<key>``."""
    flat: List[Tuple[str, np.ndarray]] = []
    for i, entry in enumerate(row.get("prefix", [])):
        for key in sorted(entry):
            flat.append((f"prefix/{i}/{key}", entry[key]))
    for lkey in sorted(row.get("period", {})):
        entry = row["period"][lkey]
        for key in sorted(entry):
            flat.append((f"period/{lkey}/{key}", entry[key]))
    return flat


def _unflatten_row(leaves: Dict[str, np.ndarray],
                   structure: Dict) -> dict:
    row: dict = {}
    if structure["prefix_len"] is not None:
        row["prefix"] = [{} for _ in range(structure["prefix_len"])]
    if structure["period_keys"] is not None:
        row["period"] = {k: {} for k in structure["period_keys"]}
    for path, arr in leaves.items():
        section, mid, key = path.split("/")
        if section == "prefix":
            row["prefix"][int(mid)][key] = arr
        else:
            row["period"][mid][key] = arr
    return row
