"""Ref-counted physical-block allocator for the paged KV cache.

Pure-python control plane (no jax): the device-side pools live in the
engine's Layerwise cache; this module only decides *which* pool blocks a
slot's block table points at.

Invariants (property-tested in ``tests/test_paged_properties.py``):

* every block is either free or has refcount >= 1 — never both;
* ``free_count + len(used) == num_blocks - 1`` (block 0 is reserved);
* ``alloc`` never hands out a block that is still referenced;
* ``decref`` below zero (double-free) raises instead of corrupting the
  free list.

A block's contents are only trustworthy while it is referenced: the
tiered store's demotion path therefore gathers an evicted prefix's KV
out of the pool *before* its ``decref``\\ s run (``serving/tiers.py``),
never after — a freed block may be re-allocated and re-written by the
very next prefill.

Block 0 is the **trash block**: it is never allocated, and every unused
block-table entry points at it.  The batched decode step writes each
slot's incoming token at ``lengths[slot]`` for *every* slot — idle and
finished slots included — so unused table positions must name a physical
block that is safe to clobber and is never read (reads are length-masked).
"""

from __future__ import annotations

from typing import Dict, List

from repro.serving.sanitize import SanitizerError, sanitizer_enabled

TRASH_BLOCK = 0


class OutOfBlocksError(RuntimeError):
    """The pool has no free blocks left for the requested allocation."""


class BlockAllocationError(RuntimeError):
    """Refcount misuse: double-free or touching an unallocated block."""


class BlockAllocator:
    """Free-list + refcount bookkeeping over ``num_blocks`` pool blocks.

    Refcounts express sharing: a compressed-prefix block seated in N slots
    while resident in the PrefixStore carries refcount N+1.  A block
    returns to the free list exactly when its count reaches zero.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("need at least 2 blocks (block 0 is reserved)")
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._ref: Dict[int, int] = {}
        # LIFO free list: recently freed blocks are re-used first (their
        # pool pages are the most likely to still be warm)
        self._free: List[int] = list(range(num_blocks - 1, TRASH_BLOCK, -1))
        # REPRO_SANITIZE=1: re-verify the free/used partition after every
        # mutation (sampled once at construction; see serving/sanitize.py)
        self._sanitize = sanitizer_enabled()

    # ---- queries ----

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def used_count(self) -> int:
        return len(self._ref)

    def refcount(self, block: int) -> int:
        return self._ref.get(block, 0)

    def blocks_for(self, num_tokens: int) -> int:
        """Blocks needed to hold ``num_tokens`` cache positions."""
        return -(-max(num_tokens, 0) // self.block_size)

    # ---- allocation ----

    def alloc(self, n: int) -> List[int]:
        """Take ``n`` fresh blocks (refcount 1 each)."""
        if n < 0:
            raise ValueError(n)
        if n > len(self._free):
            raise OutOfBlocksError(
                f"requested {n} blocks, {len(self._free)} free "
                f"(pool: {self.num_blocks}, block_size: {self.block_size})")
        out = [self._free.pop() for _ in range(n)]
        for b in out:
            self._ref[b] = 1
        if self._sanitize:
            self.check_invariants()
        return out

    def incref(self, block: int) -> None:
        if block == TRASH_BLOCK:
            raise BlockAllocationError("block 0 is the reserved trash block")
        if block not in self._ref:
            raise BlockAllocationError(f"incref of unallocated block {block}")
        self._ref[block] += 1
        if self._sanitize:
            self.check_invariants()

    def decref(self, block: int) -> None:
        """Drop one reference; frees the block at zero.  Raises on
        double-free (decref of a block that is already free)."""
        if block == TRASH_BLOCK:
            raise BlockAllocationError("block 0 is the reserved trash block")
        count = self._ref.get(block)
        if count is None:
            raise BlockAllocationError(f"double free of block {block}")
        if count == 1:
            del self._ref[block]
            self._free.append(block)
        else:
            self._ref[block] = count - 1
        if self._sanitize:
            self.check_invariants()

    # ---- snapshot/restore (stateless scoring runs a throwaway prefill) ----

    def snapshot(self) -> tuple:
        return dict(self._ref), list(self._free)

    def restore(self, snap: tuple) -> None:
        ref, free = snap
        self._ref = dict(ref)
        self._free = list(free)
        if self._sanitize:
            self.check_invariants()

    # ---- REPRO_SANITIZE=1 invariant check ----

    def check_invariants(self) -> None:
        """Assert the module-docstring invariants hold right now; raises
        :class:`SanitizerError` on the first violation.  Runs after every
        mutation under ``REPRO_SANITIZE=1`` (and on demand from tests) —
        the runtime half of reprolint's ``refcount-balance`` contract."""
        free, ref = self._free, self._ref
        if len(set(free)) != len(free):
            raise SanitizerError(
                f"free list holds duplicate blocks: {sorted(free)}")
        overlap = set(free) & set(ref)
        if overlap:
            raise SanitizerError(
                f"blocks both free and referenced: {sorted(overlap)}")
        if TRASH_BLOCK in ref or TRASH_BLOCK in free:
            raise SanitizerError("reserved trash block 0 entered the pool")
        bad = {b: c for b, c in ref.items() if c < 1}
        if bad:
            raise SanitizerError(f"non-positive refcounts: {bad}")
        oob = [b for b in list(free) + list(ref)
               if not 0 < b < self.num_blocks]
        if oob:
            raise SanitizerError(
                f"blocks outside the pool [1, {self.num_blocks}): {oob}")
        if len(free) + len(ref) != self.num_blocks - 1:
            raise SanitizerError(
                f"pool partition broken: {len(free)} free + {len(ref)} "
                f"used != {self.num_blocks} - 1 blocks — a block was "
                "lost or duplicated")
