from repro.serving.engine import ServingEngine, materialize_prefix
from repro.serving.prefix_store import PrefixStore, write_prefix_to_cache
from repro.serving.scheduler import Request, Scheduler

__all__ = [
    "ServingEngine", "PrefixStore", "Request", "Scheduler",
    "materialize_prefix", "write_prefix_to_cache",
]
