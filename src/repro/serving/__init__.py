"""Serving package: continuous batching over compressed task prefixes.

Two KV layouts share one engine API (``ServingEngine(kv_layout=...)``):

* ``dense`` — per-slot ``(slots, max_len, ...)`` cache stripes;
* ``paged`` — a shared block pool with per-slot block tables, ref-counted
  so slots seated on the same compressed task share its prefix blocks
  (`docs/ARCHITECTURE.md` has the layout).

Cold tasks need no offline step: a :class:`Request` carrying
``raw_shots`` is parked ``waiting_on_prefix`` while the engine's
:class:`PrefixCompiler` compresses the shots online — in fixed
token-budget chunks interleaved with decode steps, single-flight per
task — then materializes and seats the prefix and wakes the request.

Evicted prefixes need no recompile either: with
``ServingEngine(host_capacity=…, disk_dir=…)`` the HBM store is fronted
by a :class:`TieredPrefixStore` — evictions demote the compressed rows
to pinned host RAM and spill to codec-compressed disk shards, and a
request naming a cold prefix parks while the row promotes back
host→HBM in per-layer chunks interleaved with decode.

Everything imported here is CPU-safe: the pallas paged-attention kernel
is reached only through :func:`repro.kernels.ops.paged_decode_attention`'s
lazy dispatch (mirroring ``ops._resolve``), so ``from repro.serving
import *`` never pulls TPU kernel modules onto CPU-only hosts.
"""

from repro.serving.block_pool import (
    BlockAllocationError,
    BlockAllocator,
    OutOfBlocksError,
)
from repro.serving.compiler import CompileJob, PrefixCompiler
from repro.serving.engine import ServingEngine, materialize_prefix
from repro.serving.prefix_store import (
    PagedPrefixStore,
    PrefixSeatedError,
    PrefixStore,
    write_prefix_to_cache,
)
from repro.serving.clock import VirtualClock
from repro.serving.scheduler import Request, Scheduler
from repro.serving.telemetry import (
    Counter,
    Gauge,
    Histogram,
    MetricGroup,
    MetricsRegistry,
    Tracer,
    validate_chrome_trace,
)
from repro.serving.profiler import profile_spans, validate_profile_report
from repro.serving.server import TelemetryServer
from repro.serving.slo_watchdog import (
    BurnRateRule,
    SLOWatchdog,
    ShedDegrade,
    default_rules,
    validate_alert_log,
)
from repro.serving.tiers import PromotionJob, TieredPrefixStore
from repro.serving.traffic import (
    Trace,
    TrafficConfig,
    generate_trace,
    slo_metrics,
)

__all__ = [
    "ServingEngine", "Request", "Scheduler",
    "PrefixCompiler", "CompileJob",
    "PrefixStore", "PagedPrefixStore", "PrefixSeatedError",
    "TieredPrefixStore", "PromotionJob",
    "BlockAllocator", "BlockAllocationError", "OutOfBlocksError",
    "materialize_prefix", "write_prefix_to_cache",
    "VirtualClock", "TrafficConfig", "Trace", "generate_trace",
    "slo_metrics",
    "Tracer", "MetricsRegistry", "MetricGroup",
    "Counter", "Gauge", "Histogram", "validate_chrome_trace",
    "TelemetryServer", "SLOWatchdog", "BurnRateRule", "ShedDegrade",
    "default_rules", "validate_alert_log",
    "profile_spans", "validate_profile_report",
]
