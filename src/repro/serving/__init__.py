from repro.serving.engine import ServingEngine, materialize_prefix

__all__ = ["ServingEngine", "materialize_prefix"]
