"""Continuous-batching serving engine over compressed caches.

Deployment story (paper §1: cloud compresses offline, edge serves):

1. ``core.compress`` produces per-layer O^i once, offline, per ICL task.
2. :func:`~repro.serving.prefix_store.materialize_prefix` pushes O^i
   through the frozen target's K/V (or MLA latent) projections → a
   compressed KV cache of m slots (mamba layers keep their handed-off
   state).  A :class:`~repro.serving.prefix_store.PrefixStore` caches one
   such prefix per task.
3. :class:`ServingEngine` runs a fixed pool of batch slots.  Each request
   names the compressed task memory it wants; the engine seats that
   prefix into the request's slot, prefills the prompt *behind it*, and
   decodes.  Slots are fully independent:

   * **ragged admission** — prompts of any length enter whichever slot is
     free; prefill is per-slot (padded to a few static buckets, so no
     recompilation) while decode stays one batched step;
   * **per-slot masking** — every step attends to that slot's own
     ``base_len + tokens_consumed`` cache region only (a (slots,) length
     vector threaded down to :func:`repro.kernels.ops.decode_attention`),
     so two tasks seated in neighbouring slots can never cross-attend;
   * **per-slot stop** — a slot finishing (its stop token or its budget)
     frees immediately and the scheduler refills it mid-decode.

See docs/ARCHITECTURE.md for the cache layout and scheduling design.
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import transformer as tfm
from repro.serving.prefix_store import (  # re-exported for compatibility
    PrefixStore,
    _map_rowwise,
    clear_slot_state,
    materialize_prefix,
    seat_prefix_row,
    write_prefix_to_cache,
)
from repro.serving.scheduler import Request, Scheduler

__all__ = [
    "ServingEngine", "PrefixStore", "Request", "Scheduler",
    "materialize_prefix", "write_prefix_to_cache",
]


def _slice_slot(cache, slot):
    """View one batch slot of a Layerwise cache (keeps a size-1 batch dim)."""
    def f(c, _p, axis):
        return {k: jax.lax.dynamic_slice_in_dim(x, slot, 1, axis)
                for k, x in c.items()}
    return _map_rowwise(cache, None, f)


def _merge_slot(cache, row, slot):
    """Write a size-1-batch cache back into slot ``slot``."""
    def f(c, p, axis):
        return {k: jax.lax.dynamic_update_slice_in_dim(
            c[k], p[k].astype(c[k].dtype), slot, axis) for k in c}
    return _map_rowwise(cache, row, f)


def _bucket(n: int, cap: int) -> int:
    """Static prefill widths: next power of two (min 8), clamped to the
    slot's remaining cache space.  A handful of buckets ⇒ a handful of
    prefill compilations, ever."""
    return max(1, min(max(8, 1 << (max(1, n) - 1).bit_length()), cap))


class ServingEngine:
    def __init__(self, cfg: ModelConfig, target_params, *, slots: int,
                 max_len: int, impl: str = "auto",
                 prefix_store: Optional[PrefixStore] = None):
        self.cfg = cfg
        self.params = target_params
        self.slots = slots
        self.max_len = max_len
        self.impl = impl
        self.cache = tfm.init_cache(cfg, slots, max_len)
        self.store = prefix_store if prefix_store is not None else PrefixStore(cfg)
        self.base = np.zeros((slots,), np.int64)  # per-slot seated memory
        self.base_len = 0  # batch-wide seat_compressed() compat
        self._seated: List[Optional[str]] = [None] * slots  # named prefix
        self._dirty = np.zeros((slots,), bool)  # slot used since seating
        # recurrent layers can't absorb right-padding (the state would
        # advance over pad tokens), so prefill exact lengths for them
        descs = list(cfg.layout.prefix) + list(cfg.layout.period)
        self._recurrent = any(d.mixer == "mamba" for d in descs)
        self._pad_prefill = not self._recurrent

        def prefill_fn(params, cache, tokens, slot, base):
            row = _slice_slot(cache, slot)
            logits, aux = tfm.forward(
                params, cfg, tokens=tokens, cache=row, cache_index=base,
                mask_offset=base, impl=impl)
            return logits[0], _merge_slot(cache, aux["cache"], slot)

        def decode_fn(params, cache, tok, lengths):
            logits, aux = tfm.forward(
                params, cfg, tokens=tok, cache=cache, cache_index=lengths,
                decode=True, impl=impl)
            return logits[:, -1], aux["cache"]

        def decode_greedy_fn(params, cache, tok, lengths):
            logits, new_cache = decode_fn(params, cache, tok, lengths)
            # argmax on device: ship (slots,) token ids, not (slots, vocab)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_cache

        # base is static: prefill-continuation slices the seated cache
        # region with a python int (one trace per (bucket, base) pair);
        # slot and lengths are traced, so admission/refill never recompiles
        self._prefill = jax.jit(prefill_fn, static_argnums=(4,))
        self._decode = jax.jit(decode_fn)
        self._decode_greedy = jax.jit(decode_greedy_fn)

    # ------------------------------------------------------------------
    # Prefix seating
    # ------------------------------------------------------------------

    def add_prefix(self, name: str, materialized, batch_index: int = 0) -> str:
        """Register a materialized compressed prefix under ``name``."""
        return self.store.put(name, materialized, batch_index)

    def seat_prefix(self, slot: int, name: str) -> None:
        """Install task ``name``'s compressed memory into one slot."""
        self.cache = clear_slot_state(self.cache, slot)
        self.cache = seat_prefix_row(self.cache, self.store.get(name), slot)
        self.base[slot] = self.store.base_len(name)
        self._seated[slot] = name
        self._dirty[slot] = False

    def seat_compressed(self, prefix_materialized) -> None:
        """Compat: install an offline-compressed context batch-wide (row b
        of the materialized prefix seats slot b).  Rows are also kept in the
        PrefixStore so dirtied slots can be re-seated on later serves."""
        self.cache = write_prefix_to_cache(self.cfg, self.cache,
                                           prefix_materialized)
        assert self.cfg.memcom is not None
        self.base_len = self.cfg.memcom.num_memory_tokens
        self.base[:] = self.base_len
        for b in range(self.slots):
            self.store.put(self._COMPAT + str(b), prefix_materialized,
                           batch_index=b)
        self._seated = [None] * self.slots
        self._dirty[:] = False

    _COMPAT = "__seated_"  # reserved PrefixStore names for seat_compressed

    def _reset_slot(self, slot: int) -> None:
        """Prepare a slot for a request with no named prefix: restore the
        engine-wide seated context (seat_compressed) if the slot no longer
        holds it — a named prefix displaced it, or (recurrent families) a
        previous occupant advanced its state — else serve context-free."""
        if self._seated[slot] is None and not \
                (self._recurrent and self._dirty[slot]):
            return  # slot content still valid as-is
        if self._COMPAT + str(slot) in self.store:
            self.seat_prefix(slot, self._COMPAT + str(slot))
            self._seated[slot] = None  # engine-wide context, not request-named
        else:
            self.cache = clear_slot_state(self.cache, slot)
            self.base[slot] = 0
            self._seated[slot] = None
            self._dirty[slot] = False

    def _restore_slot(self, slot: int) -> None:
        """Refresh the context a slot already holds (named prefix, or the
        engine-wide seated one) when its recurrent state may have been
        advanced by earlier generation — attention KV at [0, m) is never
        overwritten, so only recurrent families need this."""
        if not (self._recurrent and self._dirty[slot]):
            return
        if self._seated[slot] is not None:
            self.seat_prefix(slot, self._seated[slot])
        elif self._COMPAT + str(slot) in self.store:
            self.seat_prefix(slot, self._COMPAT + str(slot))
            self._seated[slot] = None
        else:
            self.cache = clear_slot_state(self.cache, slot)
            self._dirty[slot] = False

    # ------------------------------------------------------------------
    # Continuous-batching serve loop
    # ------------------------------------------------------------------

    def serve(self, requests: Iterable[Request], *,
              seed: int = 0) -> Dict[int, np.ndarray]:
        """Serve a batch of ragged, per-task requests to completion.

        Returns {request.uid: generated tokens}.  Output includes the stop
        token when one fired.  More requests than slots is fine — finished
        slots are refilled mid-decode.
        """
        sched = Scheduler(self.slots)
        for req in requests:
            # no-prefix requests land on either the engine-wide seated base
            # or a slot reset to 0 — base_len is the worst case
            base = (self.store.base_len(req.prefix) if req.prefix
                    else self.base_len)
            need = base + len(req.tokens) + req.max_new
            if need > self.max_len:
                raise ValueError(
                    f"request {req.uid}: prefix+prompt+max_new={need} "
                    f"exceeds max_len={self.max_len}")
            sched.submit(req)

        rng = np.random.default_rng(seed)
        results: Dict[int, np.ndarray] = {}
        pending = np.zeros((self.slots,), np.int32)  # next token per slot
        lengths = self.base.copy()  # per-slot valid cache length

        def _finish(slot):
            req, toks = sched.finish(slot)
            results[req.uid] = toks

        while sched.has_work():
            for slot, req in sched.admit():
                if req.prefix is not None:
                    # skip the re-seat when the slot provably still holds
                    # this prefix (KV region [0, m) is never overwritten;
                    # only recurrent state can have been advanced)
                    if self._seated[slot] != req.prefix or self._recurrent:
                        self.seat_prefix(slot, req.prefix)
                else:
                    self._reset_slot(slot)
                row_logits = self._prefill_slot(slot, req.tokens)
                lengths[slot] = self.base[slot] + len(req.tokens)
                tok = self._sample_row(row_logits, req.temperature, rng)
                pending[slot] = tok
                if sched.record_token(slot, tok):
                    _finish(slot)
            active = sched.active_slots()
            if not active:
                continue  # admit the next queued requests (or exit)
            greedy = all(sched.request_in(s).temperature <= 0 for s in active)
            step = self._decode_greedy if greedy else self._decode
            out, self.cache = step(
                self.params, self.cache, jnp.asarray(pending[:, None]),
                jnp.asarray(lengths, jnp.int32))
            # the batched step advances *every* slot's recurrent state
            # (idle rows included), so all slots are dirty from here on
            self._dirty[:] = True
            out = np.asarray(out)  # greedy: (slots,) ids; else full logits
            for slot in active:
                lengths[slot] += 1  # the step consumed this slot's token
                tok = int(out[slot]) if greedy else self._sample_row(
                    out[slot], sched.request_in(slot).temperature, rng)
                pending[slot] = tok
                if sched.record_token(slot, tok):
                    _finish(slot)
        return results

    def _prefill_slot(self, slot: int, tokens: np.ndarray,
                      persist: bool = True) -> np.ndarray:
        """Prefill one slot's prompt behind its seated prefix; returns the
        last real token's logits row.  ``persist=False`` leaves the engine
        cache untouched (one-shot scoring)."""
        n = len(tokens)
        base = int(self.base[slot])
        cap = self.max_len - base
        assert 0 < n <= cap, (n, cap)
        width = _bucket(n, cap) if self._pad_prefill else n
        padded = np.zeros((1, width), np.int32)
        padded[0, :n] = tokens
        logits, new_cache = self._prefill(
            self.params, self.cache, jnp.asarray(padded),
            jnp.int32(slot), base)
        if persist:
            self.cache = new_cache
            self._dirty[slot] = True
        return np.asarray(logits[n - 1])

    @staticmethod
    def _sample_row(logits_row: np.ndarray, temperature: float,
                    rng: np.random.Generator) -> int:
        if temperature <= 0:
            return int(np.argmax(logits_row))
        z = logits_row.astype(np.float64) / temperature
        z -= z.max()
        p = np.exp(z)
        return int(rng.choice(len(p), p=p / p.sum()))

    # ------------------------------------------------------------------
    # Compat APIs (lock-step batch generation, label scoring)
    # ------------------------------------------------------------------

    def generate(self, prompts, max_new: int, temperature: float = 0.0,
                 seed: int = 0, stop_token: Optional[int] = None) -> np.ndarray:
        """Batch-generate over the slot pool.  ``prompts`` is a (slots, S)
        array or a list of ragged 1-D token arrays (one per slot).  Returns
        a (slots, n) array; with a stop token, slots now terminate
        *independently* and shorter rows are right-padded with the stop
        token."""
        rows: List[np.ndarray] = [np.asarray(p, np.int32) for p in prompts]
        assert len(rows) == self.slots, (len(rows), self.slots)
        reqs = [Request(tokens=r, max_new=max_new, stop_token=stop_token,
                        temperature=temperature) for r in rows]
        results = self.serve(reqs, seed=seed)
        outs = [results[r.uid] for r in reqs]
        n = max(len(o) for o in outs)
        fill = stop_token if stop_token is not None else 0
        return np.stack([np.pad(o, (0, n - len(o)), constant_values=fill)
                         for o in outs])

    def score_labels(self, context: np.ndarray, query: np.ndarray,
                     label_ids: np.ndarray) -> int:
        """Constrained classification: argmax over label token ids for the
        next token after [compressed prefix; context; query]."""
        toks = np.concatenate([context, query]).astype(np.int32)
        self._restore_slot(0)  # refresh stale recurrent state, keep context
        row = self._prefill_slot(0, toks, persist=False)  # stateless scoring
        return int(label_ids[np.argmax(row[label_ids])])
