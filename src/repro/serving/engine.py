"""Batched serving engine over compressed caches.

Deployment story (paper §1: cloud compresses offline, edge serves):

1. ``core.compress`` produces per-layer O^i once, offline.
2. ``materialize_prefix`` pushes O^i through the frozen target's K/V
   (or MLA latent) projections → a compressed KV cache of m slots
   (mamba layers keep their handed-off state).
3. ``ServingEngine`` seats the compressed cache in slots [0, m), prefills
   request tokens after it, and decodes — every step attends to m memory
   slots instead of t raw context tokens.

The engine keeps fixed batch slots (continuous-batching-lite): requests
are padded into slots; finished slots are refillable via ``reset_slots``.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import transformer as tfm
from repro.models.attention import project_kv
from repro.models.mla import _latent  # shared latent-cache constructor


def materialize_prefix(target_params, cfg: ModelConfig, prefix):
    """Turn {"h": O^i} entries into precomputed compressed caches:
    attn -> {"k","v"}; mla -> {"ckv","kr"}; mamba -> passthrough state."""

    def project(desc, layer_params, entry):
        if "h" not in entry:
            return entry
        h = entry["h"]
        B, m = h.shape[0], h.shape[1]
        pos = jnp.broadcast_to(jnp.arange(m, dtype=jnp.int32), (B, m))
        if cfg.mrope_sections:
            pos = jnp.broadcast_to(pos, (3, B, m))
        if desc.mixer == "mla":
            ckv, kr = _latent(layer_params["attn"], cfg, h, pos)
            return {"ckv": ckv, "kr": kr[:, :, 0, :]}
        k, v = project_kv(layer_params["attn"], cfg, h, pos)
        return {"k": k, "v": v}

    out = {}
    if "prefix" in prefix:
        out["prefix"] = [
            project(desc, target_params[f"prefix_{i}"], prefix["prefix"][i])
            for i, desc in enumerate(cfg.layout.prefix)
        ]
    if "period" in prefix:
        period = {}
        for j, desc in enumerate(cfg.layout.period):
            key = f"l{j}"
            entry = prefix["period"][key]
            lp = jax.tree.map(lambda x: x, target_params["period"][key])
            fn = partial(project, desc)
            period[key] = jax.vmap(fn)(lp, entry)  # map over stacked layers
        out["period"] = period
    return out


def write_prefix_to_cache(cfg: ModelConfig, cache, prefix):
    """Seat compressed memory slots at cache positions [0, m)."""

    def seat(c, p):
        c = dict(c)
        for key in ("k", "v", "ckv", "kr"):
            if key in p:
                axis = 1
                c[key] = jax.lax.dynamic_update_slice_in_dim(
                    c[key], p[key].astype(c[key].dtype), 0, axis=axis)
        if "ssm" in p:
            c["ssm"] = p["ssm"].astype(c["ssm"].dtype)
        return c

    out = {}
    if "prefix" in cache:
        out["prefix"] = [seat(c, p) for c, p in
                         zip(cache["prefix"], prefix.get("prefix", []))]
    if "period" in cache:
        out["period"] = {}
        for key, c in cache["period"].items():
            p = prefix.get("period", {}).get(key)
            if p is None:
                out["period"][key] = c
                continue
            # both stacked on the layer dim: seat per-layer via vmap
            out["period"][key] = jax.vmap(seat)(c, p)
    return out


class ServingEngine:
    def __init__(self, cfg: ModelConfig, target_params, *, slots: int,
                 max_len: int, impl: str = "auto"):
        self.cfg = cfg
        self.params = target_params
        self.slots = slots
        self.max_len = max_len
        self.impl = impl
        self.cache = tfm.init_cache(cfg, slots, max_len)
        self.base_len = 0  # memory-slot count seated at the front

        def prefill_fn(params, cache, tokens, start):
            logits, aux = tfm.forward(
                params, cfg, tokens=tokens, cache=cache, cache_index=start,
                mask_offset=start, impl=impl)
            return logits[:, -1], aux["cache"]

        def decode_fn(params, cache, tok, index):
            logits, aux = tfm.forward(
                params, cfg, tokens=tok, cache=cache, cache_index=index,
                decode=True, impl=impl)
            return logits[:, -1], aux["cache"]

        # start is static: prefill-continuation slices the seated cache
        # region with a python int (stable across calls ⇒ no recompiles)
        self._prefill = jax.jit(prefill_fn, static_argnums=(3,))
        self._decode = jax.jit(decode_fn)

    def seat_compressed(self, prefix_materialized):
        """Install an offline-compressed many-shot context for all slots."""
        self.cache = write_prefix_to_cache(self.cfg, self.cache,
                                           prefix_materialized)
        assert self.cfg.memcom is not None
        self.base_len = self.cfg.memcom.num_memory_tokens

    def generate(self, prompts: np.ndarray, max_new: int,
                 temperature: float = 0.0, seed: int = 0,
                 stop_token: Optional[int] = None) -> np.ndarray:
        """prompts: (slots, S) right-aligned token batch (no ragged support
        in this lite engine — pad upstream).  Greedy when temperature=0."""
        assert prompts.shape[0] == self.slots
        start = self.base_len
        logits, self.cache = self._prefill(
            self.params, self.cache, jnp.asarray(prompts), start)
        index = start + prompts.shape[1]
        out = []
        key = jax.random.key(seed)
        tok = self._sample(logits, temperature, key)
        for i in range(max_new):
            out.append(np.asarray(tok))
            logits, self.cache = self._decode(
                self.params, self.cache, tok[:, None], index + i)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, temperature, sub)
            if stop_token is not None and bool((np.asarray(tok) == stop_token).all()):
                break
        return np.stack(out, axis=1)

    @staticmethod
    def _sample(logits, temperature, key):
        if temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature).astype(jnp.int32)

    def score_labels(self, context: np.ndarray, query: np.ndarray,
                     label_ids: np.ndarray) -> int:
        """Constrained classification: argmax over label token ids for the
        next token after [compressed prefix; context; query]."""
        toks = np.concatenate([context, query])[None]
        toks = np.repeat(toks, self.slots, axis=0)
        start = self.base_len
        logits, _ = self._prefill(self.params, self.cache,
                                  jnp.asarray(toks), start)
        row = np.asarray(logits[0])
        return int(label_ids[np.argmax(row[label_ids])])
