"""Continuous-batching serving engine over compressed caches.

Deployment story (paper §1: cloud compresses offline, edge serves):

1. ``core.compress`` produces per-layer O^i once, offline, per ICL task.
2. :func:`~repro.serving.prefix_store.materialize_prefix` pushes O^i
   through the frozen target's K/V (or MLA latent) projections → a
   compressed KV cache of m slots (mamba layers keep their handed-off
   state).  A :class:`~repro.serving.prefix_store.PrefixStore` caches one
   such prefix per task.
3. :class:`ServingEngine` runs a fixed pool of batch slots.  Each request
   names the compressed task memory it wants; the engine seats that
   prefix into the request's slot, prefills the prompt *behind it*, and
   decodes.  Slots are fully independent:

   * **ragged admission** — prompts of any length enter whichever slot is
     free; prefill is per-slot (padded to a few static buckets, so no
     recompilation) while decode stays one batched step;
   * **per-slot masking** — every step attends to that slot's own
     ``base_len + tokens_consumed`` cache region only (a (slots,) length
     vector threaded down to :func:`repro.kernels.ops.decode_attention`),
     so two tasks seated in neighbouring slots can never cross-attend;
   * **per-slot stop** — a slot finishing (its stop token or its budget)
     frees immediately and the scheduler refills it mid-decode.

Two KV layouts (``kv_layout=``):

* ``dense`` — per-slot ``(slots, max_len, …)`` cache stripes; seating
  copies the prefix into the slot's rows (prefix memory O(slots)).
* ``paged`` — one ``(num_blocks, block_size, …)`` physical pool per
  layer plus per-slot block tables; slots seated on the same task share
  its ref-counted prefix blocks (prefix memory O(tasks)), with
  copy-on-write only for a partially-filled tail block, private blocks
  freed on refill, and admission gated on free blocks.

With ``host_capacity=``/``disk_dir=`` set, the HBM store is fronted by
a :class:`~repro.serving.tiers.TieredPrefixStore`: evictions demote the
compressed prefix to pinned host RAM (and under host pressure to disk)
instead of destroying it, and a request naming a cold prefix parks
``waiting_on_prefix`` while the row is promoted back host→HBM in
``promote_layer_budget``-chunk steps interleaved with decode — the same
stay-responsive contract as online compilation.

Scheduling under load (the traffic harness, ``serving/traffic.py``):

* ``Request.priority`` classes (lower = more urgent) with an optional
  anti-starvation aging rule (``priority_aging_s=``), FIFO within class;
* **preemption** — when the best queued request's class outranks a
  running slot's and it cannot be admitted, the worst victim slot is
  evicted: its paged blocks are released (the prefix itself stays
  store-resident and demotes through the normal tier path under
  pressure), the request re-queues at its arrival position, and on
  re-admission the engine re-prefills ``prompt + already-emitted`` so
  decode resumes token-exact — the same machinery as a mid-decode refill;
* ``Request.arrival_s`` replays a timed trace: serve() holds each
  request until the engine clock reaches its offset;
* an injected ``clock=`` (see :class:`~repro.serving.clock.VirtualClock`)
  makes every timing — arrivals, TTFT, decode gaps, aging, the budget
  autotuner — a deterministic function of the work performed, so the
  whole simulation is reproducible in CI; the default is wall time;
* ``autotune_budgets=`` trades ``compile_token_budget`` /
  ``promote_layer_budget`` against the observed decode gap: budgets are
  halved while the mean gap overshoots ``target_decode_gap_s`` and
  doubled back (capped at 8× the configured value) while it undershoots.

Fused step (``fused_step=True``, pure attention/MLA layouts): one jitted
program per bucketed lane width carries every seated slot's decode lane
*plus* one bounded token chunk — a joining request's prompt streaming in
``fused_chunk_tokens``-sized pieces, or a :class:`PrefixCompiler` compile
chunk — so admission and compile churn never open a decode gap.  With
``spec_draft=``/``spec_k=`` the same lanes carry speculative decoding: a
greedy drafter proposes k tokens per slot, the fused step scores k+1
positions at once, and acceptance (greedy prefix match, or Leviathan
residual sampling on the request's own rng stream) rolls the per-slot
length vector forward — rejection is an implicit KV rollback in both
layouts.  See docs/ARCHITECTURE.md §"Fused step & speculative decoding".

See docs/ARCHITECTURE.md for the cache layouts and scheduling design.
"""

from __future__ import annotations

import copy
import time
from collections import OrderedDict
from typing import Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import transformer as tfm
from repro.sharding import rules as sharding_rules
from repro.sharding.serving import constrain_cache, shard_cache
from repro.serving.block_pool import (
    TRASH_BLOCK,
    BlockAllocator,
    OutOfBlocksError,
)
from repro.serving.compiler import PrefixCompiler, pow2_bucket
from repro.serving.prefix_store import (  # re-exported for compatibility
    _KV_KEYS,
    PagedPrefixStore,
    PrefixSeatedError,
    PrefixStore,
    _map_rowwise,
    clear_slot_state,
    copy_paged_block,
    materialize_prefix,
    seat_prefix_row,
    write_prefix_to_cache,
)
from repro.serving.scheduler import Request, Scheduler
from repro.serving.telemetry import (
    NULL_TRACER,
    MetricGroup,
    MetricsRegistry,
    Tracer,
)
from repro.serving.tiers import TieredPrefixStore

__all__ = [
    "ServingEngine", "PrefixStore", "PagedPrefixStore", "PrefixCompiler",
    "Request", "Scheduler", "TieredPrefixStore", "materialize_prefix",
    "write_prefix_to_cache", "Tracer", "MetricsRegistry",
]


def _slice_slot(cache, slot):
    """View one batch slot of a Layerwise cache (keeps a size-1 batch dim)."""
    def f(c, _p, axis):
        return {k: jax.lax.dynamic_slice_in_dim(x, slot, 1, axis)
                for k, x in c.items()}
    return _map_rowwise(cache, None, f)


def _merge_slot(cache, row, slot):
    """Write a size-1-batch cache back into slot ``slot``."""
    def f(c, p, axis):
        return {k: jax.lax.dynamic_update_slice_in_dim(
            c[k], p[k].astype(c[k].dtype), slot, axis) for k in c}
    return _map_rowwise(cache, row, f)


def _slice_slot_paged(cache, slot):
    """Paged prefill view: per-slot leaves (conv/ssm/cross) sliced to a
    size-1 batch; pooled KV leaves pass through whole — the pool is global
    and the block-table row scopes the write to this slot's blocks."""
    def f(c, _p, axis):
        return {k: x if k in _KV_KEYS
                else jax.lax.dynamic_slice_in_dim(x, slot, 1, axis)
                for k, x in c.items()}
    return _map_rowwise(cache, None, f)


def _merge_slot_paged(cache, new, slot):
    """Merge a paged batch-1 prefill result back: pooled leaves are taken
    wholesale (the scatter already landed in the right blocks), per-slot
    leaves land back in their slot row."""
    def f(c, p, axis):
        return {k: p[k] if k in _KV_KEYS
                else jax.lax.dynamic_update_slice_in_dim(
                    c[k], p[k].astype(c[k].dtype), slot, axis)
                for k in c}
    return _map_rowwise(cache, new, f)


def _bucket(n: int, cap: int) -> int:
    """Static prefill widths: next power of two (min 8), clamped to the
    slot's remaining cache space.  A handful of buckets ⇒ a handful of
    prefill compilations, ever."""
    return max(1, min(pow2_bucket(n, 8), cap))


def _lane_capable(cfg: ModelConfig) -> bool:
    """Can this architecture absorb garbage decode lanes?  The fused step
    (and the drafter's masked decode) pad every slot to a shared lane
    width W and rely on (a) valid-masked KV scatters and (b) per-lane
    causal masking to make the padding invisible.  Recurrent mixers break
    (a)/(b) — the SSM state advances over garbage lanes — and
    cross-attention/encoder stacks have non-causal reads, so the fused
    path is gated to pure attention/MLA layouts."""
    descs = list(cfg.layout.prefix) + list(cfg.layout.period)
    return (cfg.encoder is None
            and all(d.mixer in ("attn", "mla") for d in descs)
            and not any(d.cross_attn for d in descs))


class ServingEngine:
    def __init__(self, cfg: ModelConfig, target_params, *, slots: int,
                 max_len: int, impl: str = "auto",
                 prefix_store: Optional[PrefixStore] = None,
                 kv_layout: str = "dense", block_size: int = 8,
                 num_blocks: Optional[int] = None,
                 prefix_capacity: Optional[int] = None,
                 compressor=None,
                 compile_token_budget: Optional[int] = None,
                 host_capacity: Optional[int] = None,
                 disk_dir: Optional[str] = None,
                 promote_layer_budget: Optional[int] = None,
                 mesh=None, rules=None,
                 clock=None, priority_aging_s: Optional[float] = None,
                 preemption: bool = True,
                 autotune_budgets: bool = False,
                 target_decode_gap_s: Optional[float] = None,
                 autotune_interval: int = 16,
                 fused_step: bool = False,
                 fused_chunk_tokens: int = 16,
                 spec_draft=None, spec_k: int = 0,
                 tracer: Optional[Tracer] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 watchdog=None):
        if kv_layout not in ("dense", "paged"):
            raise ValueError(f"kv_layout must be dense or paged, got "
                             f"{kv_layout!r}")
        if compile_token_budget is not None and compile_token_budget < 1:
            raise ValueError("compile_token_budget must be >= 1 (or None)")
        if promote_layer_budget is not None and promote_layer_budget < 1:
            raise ValueError("promote_layer_budget must be >= 1 (or None)")
        if autotune_budgets:
            if target_decode_gap_s is None or target_decode_gap_s <= 0:
                raise ValueError("autotune_budgets needs a positive "
                                 "target_decode_gap_s")
            if compile_token_budget is None and promote_layer_budget is None:
                raise ValueError("autotune_budgets needs at least one of "
                                 "compile_token_budget/promote_layer_budget")
            if autotune_interval < 1:
                raise ValueError("autotune_interval must be >= 1")
        if fused_chunk_tokens < 1:
            raise ValueError("fused_chunk_tokens must be >= 1")
        if spec_k < 0:
            raise ValueError("spec_k must be >= 0")
        if (spec_k > 0) != (spec_draft is not None):
            raise ValueError("speculative decoding needs both spec_draft "
                             "and spec_k >= 1 (or neither)")
        if spec_k > 0 and not fused_step:
            raise ValueError("speculative decoding rides the fused step — "
                             "pass fused_step=True with spec_k")
        if (fused_step or spec_k) and not _lane_capable(cfg):
            raise ValueError(
                f"{cfg.name}: fused_step/speculative decoding need a pure "
                "attention/MLA layout — recurrent (mamba), cross-attention "
                "and encoder stacks cannot absorb masked garbage lanes")
        # injected clock (VirtualClock in tests/simulation, wall time in
        # production).  charge()/advance_to() are duck-typed: absent on a
        # wall clock, charging is a no-op and waits become short sleeps.
        self.clock = clock if clock is not None else time.perf_counter
        charge = getattr(self.clock, "charge", None)
        self._charge = charge if charge is not None else (lambda *_: None)
        # telemetry: a no-op tracer by default (bit-exact serving, near-
        # zero cost) and a fresh registry unless the caller shares one.
        # The tracer reads the *engine's* clock so spans line up with
        # request_log / gap samples on the same timeline.
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        if self.tracer.enabled and self.tracer.clock is None:
            self.tracer.clock = self.clock
        attach = getattr(self.clock, "attach_metrics", None)
        if attach is not None:
            attach(self.metrics)  # charged-seconds counters by work kind
        # SLO burn-rate watchdog (opt-in): fed from the TTFT/gap observe
        # sites and stepped once per loop iteration.  Its degradation
        # hook may set shed_floor (admission shedding) / degrade_hint
        # (autotuner pressure) while a page alert is active.
        self.watchdog = watchdog
        self.shed_floor: Optional[int] = None
        self.degrade_hint = False
        self.last_step_t: Optional[float] = None  # /healthz liveness
        if watchdog is not None:
            if watchdog.clock is None:
                watchdog.clock = self.clock
            watchdog.attach_engine(self)
        self.priority_aging_s = priority_aging_s
        self.preemption = preemption
        self._autotune = autotune_budgets
        self.target_decode_gap_s = target_decode_gap_s
        self.autotune_interval = autotune_interval
        self._budget_init = (compile_token_budget, promote_layer_budget)
        self._gap_samples: List[float] = []  # every decode gap (p50/p99)
        self._gap_window: List[float] = []   # gaps since last autotune step
        self.request_log: Dict[int, dict] = {}  # per-request SLO timings
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.impl = impl
        self.kv_layout = kv_layout
        # tensor-parallel serving: params placed via their logical-axis
        # tree, KV caches/pools split by head over the mesh "model" axis,
        # block tables and per-slot lengths replicated host-side — the
        # python control plane (scheduler, allocator, stores) is
        # mesh-oblivious by construction
        self.mesh = mesh
        self.rules = None
        if mesh is not None:
            self.rules = rules if rules is not None else \
                sharding_rules.BASELINE_RULES
            target_params = jax.device_put(
                target_params,
                sharding_rules.logical_to_shardings(
                    target_params, tfm.param_specs(cfg), mesh, self.rules))
        elif rules is not None:
            raise ValueError("rules given without a mesh")
        self.params = target_params
        # online prefix compiler: requests carrying raw_shots compile their
        # compressed prefix *on the serving path*, at most
        # compile_token_budget source tokens per loop iteration (None =
        # whole task at once — decode stalls for the full compile)
        self.compile_token_budget = compile_token_budget
        self.compiler = (PrefixCompiler(compressor, cfg, self.params,
                                        impl=impl, mesh=mesh,
                                        rules=self.rules)
                         if compressor is not None else None)
        if self.compiler is not None:
            self.compiler.stats = self.metrics.group(
                "serving_compiler", self.compiler.stats,
                help="online prefix compiler counter")
        self.trace: List[Tuple] = []  # per-serve event log (tests/bench)
        # the counter "dict" is a registry-backed MetricGroup: every
        # `self._counters[k] += 1` site lands in a `serving_engine_*`
        # gauge, stats() stays a view over the registry, and the
        # Prometheus renderer sees live values
        self._counters = self.metrics.group("serving_engine", {
            "decode_steps": 0, "prefills": 0, "tokens_generated": 0,
            "decode_steps_during_compile": 0, "compile_chunks_interleaved": 0,
            "decode_steps_during_promote": 0, "promote_steps_interleaved": 0,
            "decode_gap_max_s": 0.0, "decode_gap_sum_s": 0.0,
            "decode_gaps": 0, "decode_time_s": 0.0,
            "preemptions": 0, "preempted_tokens_refilled": 0,
            "autotune_shrinks": 0, "autotune_grows": 0,
            # fused step: decode + chunk work in one dispatch
            "fused_steps": 0, "fused_chunks": 0,
            "fused_prefill_chunks": 0, "fused_prefill_tokens": 0,
            "fused_compile_chunks": 0,
            # speculative decoding
            "spec_rounds": 0, "draft_proposed": 0, "draft_accepted": 0,
        }, help="engine loop counter")
        self._m_gap = self.metrics.histogram(
            "serving_decode_gap_seconds",
            "non-decode time between consecutive decode steps")
        self._m_ttft = self.metrics.histogram(
            "serving_ttft_seconds", "arrival to first token",
            labelnames=("priority",))
        self._m_latency = self.metrics.histogram(
            "serving_request_latency_seconds", "arrival to finish",
            labelnames=("priority",))
        self._m_jit = self.metrics.counter(
            "serving_jit_compiles_total",
            "jitted-program builds by step-function family",
            labelnames=("family",))
        self.base = np.zeros((slots,), np.int64)  # per-slot seated memory
        self.base_len = 0  # batch-wide seat_compressed() compat
        self._seated: List[Optional[str]] = [None] * slots  # named prefix
        self._dirty = np.zeros((slots,), bool)  # slot used since seating
        # recurrent layers can't absorb right-padding (the state would
        # advance over pad tokens), so prefill exact lengths for them
        descs = list(cfg.layout.prefix) + list(cfg.layout.period)
        self._recurrent = any(d.mixer == "mamba" for d in descs)
        self._pad_prefill = not self._recurrent

        if kv_layout == "paged":
            if prefix_store is not None:
                raise ValueError(
                    "paged engines own their PagedPrefixStore (its blocks "
                    "live in the engine's pool); pass prefix_capacity instead")
            table_width = -(-max_len // block_size)
            if num_blocks is None:
                # every slot's worst case, headroom for 4 resident task
                # prefixes, plus the reserved trash block
                num_blocks = 1 + (slots + 4) * table_width
            self.block_size = block_size
            self.alloc = BlockAllocator(num_blocks, block_size)
            self.cache = tfm.init_paged_cache(cfg, num_blocks, block_size,
                                              slots)
            self.tables = np.full((slots, table_width), TRASH_BLOCK, np.int32)
            self._slot_blocks: List[List[int]] = [[] for _ in range(slots)]
            # blocks promised to admitted-but-unfinished requests: decode
            # allocations draw them down; _can_admit nets them off the
            # free count so concurrent slots can't race the pool empty
            self._reserved = np.zeros((slots,), np.int64)
            self._reserved_pending = 0  # admitted, not yet prefilled
            self.store = PagedPrefixStore(cfg, self.alloc,
                                          capacity=prefix_capacity)
        else:
            self.cache = tfm.init_cache(cfg, slots, max_len)
            self.store = (prefix_store if prefix_store is not None
                          else PrefixStore(cfg, capacity=prefix_capacity))
        # adopt the HBM store's hit/miss counters into the registry
        # *before* a TieredPrefixStore fronts it — the tiered facade's
        # `stats` property delegates to this same dict
        if not isinstance(self.store.stats, MetricGroup):
            self.store.stats = self.metrics.group(
                "serving_prefix_store", self.store.stats,
                help="HBM prefix store counter")
        # tiered prefix cache: with a host and/or disk tier configured,
        # the HBM store is fronted by a TieredPrefixStore — evictions
        # demote down the hierarchy instead of dropping, and cold
        # prefixes promote back asynchronously (budgeted per decode step)
        self.promote_layer_budget = promote_layer_budget
        self.tiers: Optional[TieredPrefixStore] = None
        if host_capacity is not None or disk_dir is not None:
            self.store = self.tiers = TieredPrefixStore(
                self.store, host_capacity=host_capacity, disk_dir=disk_dir,
                mesh=mesh, rules=self.rules, cache_ref=lambda: self.cache)
            self.tiers.tier_stats = self.metrics.group(
                "serving_prefix_tiers", self.tiers.tier_stats,
                help="tiered prefix cache counter")
        # KV stripes/pools split by head on the "model" axis, recurrent
        # state by channel/head; everything non-divisible replicates
        self.cache = shard_cache(self.cache, mesh, self.rules)
        rules = self.rules

        def pin(cache):
            # hold the step *outputs* to the seeded cache layout — left to
            # itself GSPMD drifts (e.g. re-sharding KV on head_dim), and
            # every later step then pays a reshard of the whole pool
            return constrain_cache(cache, mesh, rules)

        def prefill_fn(params, cache, tokens, slot, base):
            row = _slice_slot(cache, slot)
            logits, aux = tfm.forward(
                params, cfg, tokens=tokens, cache=row, cache_index=base,
                mask_offset=base, mesh=mesh, impl=impl)
            return logits[0], pin(_merge_slot(cache, aux["cache"], slot))

        def paged_prefill_fn(params, cache, tokens, slot, table_row, base):
            row = _slice_slot_paged(cache, slot)
            logits, aux = tfm.forward(
                params, cfg, tokens=tokens, cache=row, cache_index=base,
                mask_offset=base, block_tables=table_row[None, :], mesh=mesh,
                impl=impl)
            return logits[0], pin(_merge_slot_paged(cache, aux["cache"], slot))

        def decode_fn(params, cache, tok, lengths):
            logits, aux = tfm.forward(
                params, cfg, tokens=tok, cache=cache, cache_index=lengths,
                decode=True, mesh=mesh, impl=impl)
            return logits[:, -1], pin(aux["cache"])

        def paged_decode_fn(params, cache, tok, lengths, tables):
            logits, aux = tfm.forward(
                params, cfg, tokens=tok, cache=cache, cache_index=lengths,
                decode=True, block_tables=tables, mesh=mesh, impl=impl)
            return logits[:, -1], pin(aux["cache"])

        def greedy(step):
            def fn(params, cache, tok, lengths, *rest):
                logits, new_cache = step(params, cache, tok, lengths, *rest)
                # argmax on device: ship (slots,) ids, not (slots, vocab)
                return jnp.argmax(logits, -1).astype(jnp.int32), new_cache
            return fn

        # base is static: prefill-continuation slices the seated cache
        # region with a python int (one trace per (bucket, base) pair);
        # slot, lengths and block tables are traced, so admission/refill
        # (and block re-mapping) never recompile
        if kv_layout == "paged":
            self._prefill = jax.jit(paged_prefill_fn, static_argnums=(5,))
            self._decode = jax.jit(paged_decode_fn)
            self._decode_greedy = jax.jit(greedy(paged_decode_fn))
        else:
            self._prefill = jax.jit(prefill_fn, static_argnums=(4,))
            self._decode = jax.jit(decode_fn)
            self._decode_greedy = jax.jit(greedy(decode_fn))
        self._pin = pin

        # ---- fused step + speculative decoding ----
        # One jitted program family carries the batched decode lanes PLUS
        # an optional bounded token chunk (a joining slot's prefill, or a
        # PrefixCompiler compile chunk) in a single dispatch.  Lane widths
        # are pow2-bucketed so the program ladder stays small; the ladder
        # is observable through stats()["engine"]["jit_compiles"].
        self.fused = bool(fused_step)
        self.fused_chunk_tokens = int(fused_chunk_tokens)
        self._joining: "OrderedDict[int, dict]" = OrderedDict()
        self._programs: "OrderedDict[Tuple, object]" = OrderedDict()
        self._program_cap = 128  # LRU: evicting forces a later re-jit
        # per-family program-build counts (bucketed geometry keys).  These
        # are engine-lifetime — reset_stats() leaves them alone so the
        # bench/traffic harness can see recompile churn across serves.
        self._jit_compiles: Dict[str, int] = {}
        self._geom_seen: set = set()
        self.spec_k = int(spec_k)
        self._draft_cfg = None
        self._draft_params = None
        if self.spec_k:
            if spec_draft == "self":
                # self-speculation: the target drafts for itself (no
                # compressed prefix, plain positions) — the upper bound
                # for acceptance and the bench's greedy workload
                self._draft_cfg, self._draft_params = cfg, self.params
            else:
                self._draft_cfg, self._draft_params = spec_draft
            dcfg = self._draft_cfg
            if dcfg.vocab_size != cfg.vocab_size:
                raise ValueError(
                    f"drafter vocab {dcfg.vocab_size} != target vocab "
                    f"{cfg.vocab_size} — drafts would be meaningless")
            if not _lane_capable(dcfg):
                raise ValueError(
                    f"drafter {dcfg.name}: needs a pure attention/MLA "
                    "layout (its cache rolls forward by accepted length)")
            # the drafter keeps its own dense per-slot cache regardless of
            # the engine's KV layout: it is the small sibling config, so
            # slots × max_len of its KV is cheap, and it never shares
            # prefix blocks (it drafts from the plain prompt)
            self._draft_cache = tfm.init_cache(dcfg, slots, max_len)
            self._draft_len = np.zeros((slots,), np.int64)

    # ------------------------------------------------------------------
    # Prefix seating
    # ------------------------------------------------------------------

    def add_prefix(self, name: str, materialized, batch_index: int = 0) -> str:
        """Register a materialized compressed prefix under ``name``.  In
        the paged layout this scatters the prefix into pool blocks once —
        every slot later seated on it shares that single physical copy."""
        if self.kv_layout == "paged":
            self.cache = self.store.put(name, materialized, self.cache,
                                        batch_index)
            return name
        return self.store.put(name, materialized, batch_index)

    # ---- paged block bookkeeping ----

    def _release_slot_blocks(self, slot: int) -> None:
        """Drop this slot's references: private blocks return to the free
        pool; shared prefix blocks persist (the PrefixStore holds a ref)."""
        for b in self._slot_blocks[slot]:
            self.alloc.decref(b)
        self._slot_blocks[slot] = []
        self.tables[slot, :] = TRASH_BLOCK

    def _seat_blocks(self, slot: int, name: str) -> None:
        """Point one slot's block table at a resident prefix's blocks."""
        self._release_slot_blocks(slot)
        blocks = self.store.blocks(name)
        for b in blocks:
            self.alloc.incref(b)
        self._slot_blocks[slot] = blocks
        self.tables[slot, :len(blocks)] = blocks

    def seat_prefix(self, slot: int, name: str) -> None:
        """Install task ``name``'s compressed memory into one slot."""
        self.cache = clear_slot_state(self.cache, slot)
        if self.kv_layout == "paged":
            self._seat_blocks(slot, name)
            state = self.store.state_row(name)
            if state is not None:  # recurrent handoff stays per-slot
                self.cache = seat_prefix_row(self.cache, state, slot)
        else:
            self.cache = seat_prefix_row(self.cache, self.store.get(name), slot)
        self.base[slot] = self.store.base_len(name)
        self._seated[slot] = name
        self._dirty[slot] = False

    def seat_compressed(self, prefix_materialized) -> None:
        """Compat: install an offline-compressed context batch-wide (row b
        of the materialized prefix seats slot b).  Rows are also kept in the
        PrefixStore so dirtied slots can be re-seated on later serves."""
        assert self.cfg.memcom is not None
        self.base_len = self.cfg.memcom.num_memory_tokens
        if self.kv_layout == "paged":
            for b in range(self.slots):
                name = self._COMPAT + str(b)
                # unseat first so a re-put never trips the eviction guard
                self._release_slot_blocks(b)
                self.cache = self.store.put(name, prefix_materialized,
                                            self.cache, batch_index=b)
                self.seat_prefix(b, name)
        else:
            self.cache = write_prefix_to_cache(self.cfg, self.cache,
                                               prefix_materialized)
            self.base[:] = self.base_len
            for b in range(self.slots):
                self.store.put(self._COMPAT + str(b), prefix_materialized,
                               batch_index=b)
        self._seated = [None] * self.slots
        self._dirty[:] = False

    _COMPAT = "__seated_"  # reserved PrefixStore names for seat_compressed

    def _reset_slot(self, slot: int) -> None:
        """Prepare a slot for a request with no named prefix: restore the
        engine-wide seated context (seat_compressed) if the slot no longer
        holds it — a named prefix displaced it, or (recurrent families) a
        previous occupant advanced its state — else serve context-free."""
        if self._seated[slot] is None and not \
                (self._recurrent and self._dirty[slot]):
            return  # slot content still valid as-is
        if self._COMPAT + str(slot) in self.store:
            self.seat_prefix(slot, self._COMPAT + str(slot))
            self._seated[slot] = None  # engine-wide context, not request-named
        else:
            self.cache = clear_slot_state(self.cache, slot)
            if self.kv_layout == "paged":
                self._release_slot_blocks(slot)
            self.base[slot] = 0
            self._seated[slot] = None
            self._dirty[slot] = False

    def _restore_slot(self, slot: int) -> None:
        """Refresh the context a slot already holds (named prefix, or the
        engine-wide seated one) when its recurrent state may have been
        advanced by earlier generation — attention KV at [0, m) is never
        overwritten, so only recurrent families need this."""
        if not (self._recurrent and self._dirty[slot]):
            return
        if self._seated[slot] is not None:
            self.seat_prefix(slot, self._seated[slot])
        elif self._COMPAT + str(slot) in self.store:
            self.seat_prefix(slot, self._COMPAT + str(slot))
            self._seated[slot] = None
        else:
            self.cache = clear_slot_state(self.cache, slot)
            self._dirty[slot] = False

    # ------------------------------------------------------------------
    # Fused step + speculative decoding programs
    # ------------------------------------------------------------------

    def _note_geometry(self, family: str, key) -> None:
        """Count one jit compilation against a step-function family the
        first time a (bucketed) geometry key is seen — the per-family
        totals surface as ``stats()["engine"]["jit_compiles"]`` so
        recompile churn is visible in the traffic bench."""
        k = (family, key)
        if k not in self._geom_seen:
            self._geom_seen.add(k)
            self._jit_compiles[family] = self._jit_compiles.get(family, 0) + 1
            self._m_jit.inc(family=family)

    def _program(self, family: str, key: Tuple, make):
        """Geometry-keyed jitted-program registry (LRU-bounded)."""
        full = (family,) + key
        fn = self._programs.get(full)
        if fn is None:
            fn = self._programs[full] = make()
            self._jit_compiles[family] = self._jit_compiles.get(family, 0) + 1
            self._m_jit.inc(family=family)
            while len(self._programs) > self._program_cap:
                self._programs.popitem(last=False)
        else:
            self._programs.move_to_end(full)
        return fn

    def _fused_program(self, W: int, greedy: bool, comp_geom):
        """The fused step for lane width ``W``: batched decode lanes (+
        speculative verify lanes) for every slot, an optional prefill
        chunk lane for a joining slot, and — when ``comp_geom =
        (offset, width, cache_len)`` — a PrefixCompiler chunk, all in one
        jitted dispatch.  Ragged lanes are masked by ``valids``: invalid
        lanes' KV writes are dropped (dense) / trashed (paged) and their
        outputs ignored; the attention read needs no masking because lane
        ``s`` of slot ``b`` sits at query position ``starts[b] + s`` and
        causality hides everything an invalid lane could touch."""
        cfg, impl, mesh = self.cfg, self.impl, self.mesh
        pin = self._pin
        body = (self.compiler.chunk_body(comp_geom[0])
                if comp_geom is not None else None)

        def make():
            def run(params, cache, tokens, starts, valids, tables, comp):
                logits, aux = tfm.forward(
                    params, cfg, tokens=tokens, cache=cache,
                    cache_index=starts, decode=True, block_tables=tables,
                    lane_valid=valids, mesh=mesh, impl=impl)
                out = (jnp.argmax(logits, -1).astype(jnp.int32)
                       if greedy else logits)
                comp_out = None
                if body is not None:
                    compressor, src_cache, chunk = comp
                    comp_out = body(compressor, src_cache, chunk)
                return out, pin(aux["cache"]), comp_out

            return jax.jit(run)

        return self._program("fused", (W, bool(greedy), comp_geom), make)

    def _draft_prog(self, k: int):
        """k drafter proposal steps + one catch-up step, scanned in one
        program.  The catch-up step consumes the last draft (KV write
        only), so after a fully-accepted round the drafter cache already
        contains every token the target consumed — no position drift."""
        dcfg, impl, max_len = self._draft_cfg, self.impl, self.max_len

        def make():
            def run(dparams, dcache, pending, lens):
                def body(carry, _):
                    cache, tok, ln = carry
                    # drop writes past the drafter stripe: an unmasked
                    # scatter would *clamp* and corrupt the tail rows
                    ok = (ln < max_len).astype(jnp.int32)
                    logits, aux = tfm.forward(
                        dparams, dcfg, tokens=tok[:, None], cache=cache,
                        cache_index=ln, decode=True, lane_valid=ok,
                        impl=impl)
                    nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
                    return (aux["cache"], nxt, ln + 1), nxt

                (cache, _, _), drafts = jax.lax.scan(
                    body, (dcache, pending, lens), None, length=k + 1)
                # steps 0..k-1 emit d1..dk; step k only rolls the cache
                return jnp.swapaxes(drafts, 0, 1)[:, :k], cache

            return jax.jit(run)

        return self._program("draft", (k,), make)

    def _draft_prefill(self, slot: int, tokens) -> None:
        """(Re)build the drafter's stripe for one slot from position 0:
        the drafter sees the plain prompt (+ any resumed tokens), never
        the compressed prefix — that only lowers acceptance for prefixed
        tasks, never correctness, since every draft is verified."""
        dcfg, impl = self._draft_cfg, self.impl
        n = len(tokens)
        width = max(1, min(pow2_bucket(n, 8), self.max_len))
        padded = np.zeros((1, width), np.int32)
        padded[0, :n] = tokens

        def make():
            def run(dparams, dcache, toks, s):
                row = _slice_slot(dcache, s)
                _, aux = tfm.forward(dparams, dcfg, tokens=toks, cache=row,
                                     cache_index=0, mask_offset=0, impl=impl)
                return _merge_slot(dcache, aux["cache"], s)

            return jax.jit(run)

        prog = self._program("draft_prefill", (width,), make)
        self._draft_cache = prog(self._draft_params, self._draft_cache,
                                 jnp.asarray(padded), jnp.int32(slot))
        self._draft_len[slot] = n
        self._charge("draft_step", 1)

    @staticmethod
    def _softmax_row(logits_row: np.ndarray, temperature: float) -> np.ndarray:
        z = np.asarray(logits_row, np.float64) / temperature
        z -= z.max()
        p = np.exp(z)
        return p / p.sum()

    def _spec_sample(self, logits_rows: np.ndarray, drafts: np.ndarray,
                     temperature: float, rng: np.random.Generator):
        """Sampled (Leviathan-style) acceptance against a *greedy* drafter:
        the draft distribution is a point mass at d, so d is accepted with
        probability p(d) and a rejection resamples from the renormalized
        residual (p with d zeroed) — the emitted sequence is distributed
        exactly as token-by-token sampling from the target.  Returns
        (emitted tokens, number of accepted drafts); draws come from the
        request's own rng stream."""
        emitted: List[int] = []
        accepted = 0
        for j, d in enumerate(np.asarray(drafts, np.int64)):
            p = self._softmax_row(logits_rows[j], temperature)
            if rng.uniform() < p[d]:
                emitted.append(int(d))
                accepted += 1
                continue
            q = p.copy()
            q[d] = 0.0
            tot = q.sum()
            if tot <= 0.0:  # target is (numerically) a point mass at d too
                emitted.append(int(d))
                accepted += 1
                continue
            emitted.append(int(rng.choice(len(q), p=q / tot)))
            return emitted, accepted
        # every draft accepted: bonus token from the last verify lane
        p = self._softmax_row(logits_rows[len(drafts)], temperature)
        emitted.append(int(rng.choice(len(p), p=p)))
        return emitted, accepted

    # ------------------------------------------------------------------
    # Continuous-batching serve loop
    # ------------------------------------------------------------------

    def serve(self, requests: Iterable[Request], *,
              seed: int = 0) -> Dict[int, np.ndarray]:
        """Serve requests to completion (see :meth:`_serve_impl` for the
        full contract).  If the loop dies, the tracer's flight recorder
        dumps its ring buffer (when a dump path is configured) before
        the exception propagates — the last N events are the post-mortem."""
        try:
            return self._serve_impl(requests, seed=seed)
        except BaseException:
            self.tracer.dump_on_error()
            raise

    def _serve_impl(self, requests: Iterable[Request], *,
                    seed: int = 0) -> Dict[int, np.ndarray]:
        """Serve a batch of ragged, per-task requests to completion.

        Returns {request.uid: generated tokens}.  Output includes the stop
        token when one fired.  More requests than slots is fine — finished
        slots are refilled mid-decode.

        Requests carrying ``raw_shots`` whose prefix is not resident are
        parked (``waiting_on_prefix``) while the engine's
        :class:`PrefixCompiler` compiles them online: each loop iteration
        runs one batched decode step for the seated slots, then at most
        ``compile_token_budget`` source tokens of compilation — already-
        seated slots keep emitting tokens throughout a compile.

        With a tiered store, a request naming a demoted/spilled prefix
        parks the same way while the row is promoted back host→HBM, at
        most ``promote_layer_budget`` per-layer chunks per iteration —
        promotion beats recompiling even when the request carries
        ``raw_shots``.

        Requests carrying ``arrival_s`` are held until the engine clock
        reaches that offset from serve() start — that is how the traffic
        harness replays a timed Poisson/ON-OFF trace.  Per-request
        timings (arrival, first token, finish, preemption count) land in
        ``self.request_log`` for the SLO metrics.
        """
        epoch = self.clock()  # request_log times are offsets from here
        sched = Scheduler(self.slots, clock=self.clock,
                          aging_interval_s=self.priority_aging_s,
                          metrics=self.metrics)
        self.trace = []
        self.request_log = {}
        tr = self.tracer
        # trace ids are serve-local arrival ordinals, NOT Request.uid:
        # uids come from a process-global counter, so two runs of the
        # same scenario in one process would dump different JSON — rids
        # keep the trace a pure function of (scenario, seed)
        self._rids: Dict[int, int] = {}
        self._epoch = epoch
        requests = list(requests)
        # validate the whole batch before the first side effect: a bad
        # request must not leave earlier ones' compile jobs orphaned in
        # the (engine-lifetime) compiler with their waiters discarded
        for req in requests:
            self._check_request(req)

        def _arrive(req: Request) -> None:
            rid = self._rids[req.uid] = len(self._rids)
            self.request_log[req.uid] = {
                "priority": int(req.priority),
                "arrival_s": float(req.arrival_s if req.arrival_s is not None
                                   else self.clock() - epoch),
                "first_token_s": None, "finish_s": None,
                "tokens": 0, "preemptions": 0,
            }
            if tr.enabled:
                tr.instant("scheduler", "arrive", rid=rid,
                           priority=int(req.priority))
            self._submit(sched, req)

        # timed requests wait in arrival order until the clock reaches
        # them; untimed ones submit immediately (classic batch serve)
        future = sorted((r for r in requests if r.arrival_s is not None),
                        key=lambda r: (r.arrival_s, r.uid))
        for req in requests:
            if req.arrival_s is None:
                _arrive(req)

        # per-request sampling streams: folding Request.uid into the seed
        # makes each request's tokens a function of (seed, request) alone —
        # one shared stream would make sampled outputs depend on admission
        # order and slot interleaving (whichever slot sampled first stole
        # the next draw)
        streams: Dict[int, np.random.Generator] = {}

        def _stream(req: Request) -> np.random.Generator:
            rng = streams.get(req.uid)
            if rng is None:
                rng = streams[req.uid] = np.random.default_rng(
                    np.random.SeedSequence([int(seed), int(req.uid)]))
            return rng

        results: Dict[int, np.ndarray] = {}
        pending = np.zeros((self.slots,), np.int32)  # next token per slot
        lengths = self.base.copy()  # per-slot valid cache length
        paged = self.kv_layout == "paged"
        # a resumed request re-prefills prompt + already-emitted tokens,
        # so the paged gate must size its window on that longer prefill
        can_seat = ((lambda r: self._can_admit(r, sched.resume_len(r.uid)))
                    if paged else None)
        if self.watchdog is not None:
            base_seat = can_seat

            def can_seat(r, _base=base_seat):
                # degradation hook: while a page alert holds shed_floor,
                # park lower-priority admissions — but only while some
                # slot is still running, so shedding an idle engine can
                # never deadlock the simulation
                if (self.shed_floor is not None
                        and int(r.priority) >= self.shed_floor
                        and sched.active_slots()):
                    return False
                return True if _base is None else _base(r)
        last_decode_done: Optional[float] = None
        self.last_step_t = self.clock()

        def _finish(slot):
            req, toks = sched.finish(slot)
            if paged:
                self._reserved[slot] = 0  # unused decode headroom returns
            streams.pop(req.uid, None)
            results[req.uid] = toks
            log = self.request_log[req.uid]
            log["finish_s"] = self.clock() - epoch
            log["tokens"] = int(len(toks))
            self._m_latency.observe(log["finish_s"] - log["arrival_s"],
                                    priority=log["priority"])
            if tr.enabled:
                tr.instant(f"slot{slot}", "finish",
                           rid=self._rids[req.uid], tokens=len(toks))

        while sched.has_work() or future:
            wd = self.watchdog
            if wd is not None:
                wd_steps0 = self._counters["decode_steps"]
                wd_toks0 = self._counters["tokens_generated"]
            # release timed arrivals whose moment has come
            now_s = self.clock() - epoch
            while future and future[0].arrival_s <= now_s:
                _arrive(future.pop(0))
            if not sched.has_work():
                # idle until the next arrival: a virtual clock jumps
                # there, a wall clock sleeps in short slices
                self._advance_to(epoch + future[0].arrival_s)
                continue
            if self.compiler is not None:
                self._drain_compiler(sched)
            if self.tiers is not None:
                self._drain_promoter(sched)
            admitted = sched.admit(can_seat)
            if paged and not admitted and not sched.active_slots() \
                    and sched.pending:
                # nothing running and the head request doesn't pass the
                # free-block gate: reclaim every free slot's private
                # blocks, then retry once — fail fast instead of spinning
                self._reclaim_free_slots(sched)
                admitted = sched.admit(can_seat)
                if not admitted:
                    raise OutOfBlocksError(
                        f"paged KV pool ({self.alloc.num_blocks} blocks of "
                        f"{self.block_size}) cannot hold the next request "
                        "even with every free slot reclaimed — grow "
                        "num_blocks or evict resident prefixes")
            if self.preemption and sched.pending:
                admitted += self._preempt_for_priority(
                    sched, can_seat,
                    protected={s for s, _ in admitted} | set(self._joining))
            # fused chunked admission: while other slots are mid-decode, a
            # new request "joins" — its prompt streams through the fused
            # step in fused_chunk_tokens-sized chunk lanes instead of one
            # monolithic prefill gap.  A slot that is itself mid-join counts
            # as busy too: its chunks flow through fused steps, so a classic
            # prefill here would land between them as a gap.  Only with
            # nothing decoding *and* no join in flight does the classic
            # per-slot prefill stall nobody and stay the fast path.
            admitted_slots = {s for s, _ in admitted}
            busy_decode = any(s not in admitted_slots
                              and s not in self._joining
                              for s in sched.active_slots())
            for slot, req in admitted:
                t_adm = self.clock() if tr.enabled else 0.0
                if req.prefix is not None:
                    # skip the re-seat when the slot provably still holds
                    # this prefix (KV region [0, m) is never overwritten;
                    # only recurrent state can have been advanced)
                    if self._seated[slot] != req.prefix or self._recurrent:
                        self.seat_prefix(slot, req.prefix)
                else:
                    self._reset_slot(slot)
                # a preempted request resumes by re-prefilling everything
                # it had already consumed *and emitted* behind the seated
                # prefix — byte-for-byte the refill path, so the rebuilt
                # KV state (and thus every later token) is exact
                resumed = sched.emitted_tokens(slot)
                toks = (np.concatenate([req.tokens, resumed])
                        if resumed.size else req.tokens)
                if paged:
                    # the gate's pending reservation becomes this slot's:
                    # prefill allocates its share now, the rest stays
                    # reserved for the decode steps to draw down
                    self._reserved_pending -= self._blocks_needed(
                        req, self._req_base(req),
                        extra=resumed.size)  # what the gate added
                    base = int(self.base[slot])
                    need = self._blocks_needed(req, base, extra=resumed.size)
                if resumed.size:
                    self._counters["preempted_tokens_refilled"] += \
                        int(resumed.size)
                    self.trace.append(("resume", req.uid, slot,
                                       int(resumed.size)))
                    if tr.enabled:
                        tr.instant(f"slot{slot}", "resume",
                                   rid=self._rids[req.uid],
                                   tokens=int(resumed.size))
                if self.fused and (busy_decode or self._joining):
                    self._joining[slot] = {"req": req, "toks": toks,
                                           "consumed": 0, "t0": t_adm}
                    lengths[slot] = self.base[slot]
                    if paged:
                        # the whole window stays reserved; chunk prefills
                        # and decode steps draw it down as they allocate
                        self._reserved[slot] = need
                    self.trace.append(("admit", req.uid, slot))
                    self.trace.append(("join", req.uid, slot, len(toks)))
                    continue
                if paged:
                    n = len(toks)
                    width = (_bucket(n, self.max_len - base)
                             if self._pad_prefill else n)
                    covered = (self.alloc.blocks_for(base + width)
                               - self.alloc.blocks_for(base)
                               + (1 if base % self.block_size else 0))
                    self._reserved[slot] = max(0, need - covered)
                row_logits = self._prefill_slot(slot, toks)
                lengths[slot] = self.base[slot] + len(toks)
                if self.spec_k:
                    self._draft_prefill(slot, toks)
                tok = self._sample_row(row_logits, req.temperature,
                                       _stream(req))
                pending[slot] = tok
                self.trace.append(("admit", req.uid, slot))
                if tr.enabled:
                    tr.span(f"slot{slot}", "admission", t_adm,
                            rid=self._rids[req.uid], prefix=req.prefix,
                            prompt_tokens=len(toks),
                            resumed=int(resumed.size))
                log = self.request_log[req.uid]
                if log["first_token_s"] is None:
                    log["first_token_s"] = self.clock() - epoch
                    self._m_ttft.observe(
                        log["first_token_s"] - log["arrival_s"],
                        priority=log["priority"])
                    if wd is not None:
                        wd.observe("ttft",
                                   log["first_token_s"] - log["arrival_s"])
                if sched.record_token(slot, tok):
                    _finish(slot)
            active = sched.active_slots()
            compiling = (self.compiler is not None
                         and self.compiler.has_compile_work())
            promoting = (self.tiers is not None
                         and self.tiers.has_promote_work())
            if not active:
                if promoting:
                    # nothing decoding: chunking the host→HBM copy stalls
                    # nobody — run the head promotion to completion (it
                    # is the cheaper path to an admissible request, so it
                    # goes before compile work)
                    self._promote_step(None)
                elif compiling:
                    # nothing decoding: an iteration's worth of compile
                    # work stalls nobody — run the head job to completion
                    # so cold-task time-to-first-token is as low as it gets
                    self._compile_step(None)
                continue  # admit the next queued/woken requests (or exit)
            decode_lanes = [s for s in active if s not in self._joining]
            chunk_slot = next(iter(self._joining)) if self._joining else None
            comp = None
            if (self.fused and compiling and chunk_slot is None
                    and self.compile_token_budget is not None):
                # the chunk lane is free: stage a compile chunk to ride
                # the fused step (one dispatch, zero extra decode gap)
                comp = self.compiler.peek_chunk(self.compile_token_budget)
            spec = bool(self.spec_k and decode_lanes)
            use_fused = self.fused and (spec or chunk_slot is not None
                                        or comp is not None)
            if not use_fused:
                # ---- classic single-token decode step ----
                greedy = all(sched.request_in(s).temperature <= 0
                             for s in active)
                self._note_geometry("decode", (bool(greedy),))
                step = self._decode_greedy if greedy else self._decode
                step_args = ()
                if paged:
                    # grow each active slot's table before its write crosses
                    # into an unallocated block (idle slots write into their
                    # own stale blocks or the trash block — both masked)
                    self._ensure_decode_blocks(active, lengths)
                    step_args = (jnp.asarray(self.tables),)
                t_start = self.clock()
                out, self.cache = step(
                    self.params, self.cache, jnp.asarray(pending[:, None]),
                    jnp.asarray(lengths, jnp.int32), *step_args)
                self._charge("decode_step", 1)
                # the batched step advances *every* slot's recurrent state
                # (idle rows included), so all slots are dirty from here on
                self._dirty[:] = True
                out = np.asarray(out)  # greedy: (slots,) ids; else logits
                self._counters["decode_time_s"] += self.clock() - t_start
                if last_decode_done is not None:
                    # decode gap = non-decode time since the previous step —
                    # admissions, prefills, and (above all) compile chunks;
                    # the online_compile bench reads the dip off these
                    gap = t_start - last_decode_done
                    c = self._counters
                    c["decode_gap_max_s"] = max(c["decode_gap_max_s"], gap)
                    c["decode_gap_sum_s"] += gap
                    c["decode_gaps"] += 1
                    self._gap_samples.append(gap)
                    self._gap_window.append(gap)
                    self._m_gap.observe(gap)
                    if wd is not None:
                        wd.observe("decode_gap", gap)
                last_decode_done = self.last_step_t = self.clock()
                if tr.enabled:
                    tr.span("engine", "decode_step", t_start,
                            last_decode_done, active=len(active))
                self._counters["decode_steps"] += 1
                if compiling:
                    self._counters["decode_steps_during_compile"] += 1
                if promoting:
                    self._counters["decode_steps_during_promote"] += 1
                self.trace.append(("decode", len(active)))
                for slot in active:
                    lengths[slot] += 1  # the step consumed this slot's token
                    req = sched.request_in(slot)
                    tok = int(out[slot]) if greedy else self._sample_row(
                        out[slot], req.temperature, _stream(req))
                    pending[slot] = tok
                    self._counters["tokens_generated"] += 1
                    if self.spec_k:
                        self._draft_len[slot] += 1
                    if sched.record_token(slot, tok):
                        _finish(slot)
                if compiling:
                    # interleave: at most compile_token_budget source tokens
                    # of compilation behind this decode step, then decode
                    self._compile_step(self.compile_token_budget)
                    self._counters["compile_chunks_interleaved"] += 1
                if promoting:
                    # interleave: at most promote_layer_budget per-layer
                    # host→HBM chunks behind this decode step, then decode
                    self._promote_step(self.promote_layer_budget)
                    self._counters["promote_steps_interleaved"] += 1
            else:
                # ---- fused step: decode lanes + one chunk, one dispatch --
                # everything below up to the post-step bookkeeping happens
                # inside the decode-step timing window, so admission/compile
                # churn never widens the measured decode gap
                t_start = self.clock()
                drafts = None
                k_eff = np.zeros((self.slots,), np.int64)
                if spec:
                    for s in decode_lanes:
                        req = sched.request_in(s)
                        left = req.max_new - len(sched.emitted_tokens(s))
                        k_eff[s] = max(0, min(
                            self.spec_k, left - 1,
                            self.max_len - int(lengths[s]) - 1))
                    drafts, self._draft_cache = self._draft_prog(self.spec_k)(
                        self._draft_params, self._draft_cache,
                        jnp.asarray(pending),
                        jnp.asarray(self._draft_len, jnp.int32))
                    drafts = np.asarray(drafts)
                    self._charge("draft_step", self.spec_k + 1)
                    self._counters["spec_rounds"] += 1
                chunk_n, jn = 0, None
                if chunk_slot is not None:
                    jn = self._joining[chunk_slot]
                    chunk_n = min(len(jn["toks"]) - jn["consumed"],
                                  self.fused_chunk_tokens)
                lanes = 1 + (self.spec_k if spec else 0)
                W = pow2_bucket(max(lanes, chunk_n), 1)
                tokens_in = np.zeros((self.slots, W), np.int32)
                valids = np.zeros((self.slots,), np.int32)
                for s in decode_lanes:
                    tokens_in[s, 0] = pending[s]
                    kk = int(k_eff[s])
                    if kk:
                        tokens_in[s, 1:1 + kk] = drafts[s, :kk]
                    valids[s] = 1 + kk
                completing = False
                if chunk_slot is not None:
                    c0 = jn["consumed"]
                    tokens_in[chunk_slot, :chunk_n] = \
                        jn["toks"][c0:c0 + chunk_n]
                    valids[chunk_slot] = chunk_n
                    completing = c0 + chunk_n == len(jn["toks"])
                greedy = all(sched.request_in(s).temperature <= 0
                             for s in decode_lanes)
                if completing and jn["req"].temperature > 0:
                    greedy = False  # the chunk's first token is sampled
                if paged:
                    self._ensure_decode_blocks(decode_lanes, lengths,
                                               widths=valids)
                    if chunk_slot is not None:
                        got = self._prepare_prefill(
                            chunk_slot, int(lengths[chunk_slot]), chunk_n)
                        self._reserved[chunk_slot] = max(
                            0, int(self._reserved[chunk_slot]) - got)
                comp_geom = comp_args = None
                cw = 0
                if comp is not None:
                    job, offset, cw, clen = comp
                    comp_geom = (offset, cw, clen)
                    comp_args = (self.compiler.compressor, job.state.cache,
                                 self.compiler.chunk_tokens(job, cw))
                prog = self._fused_program(W, greedy, comp_geom)
                out, self.cache, comp_out = prog(
                    self.params, self.cache, jnp.asarray(tokens_in),
                    jnp.asarray(lengths, jnp.int32), jnp.asarray(valids),
                    jnp.asarray(self.tables) if paged else None, comp_args)
                self._charge("decode_step", 1)
                if chunk_n:
                    self._charge("prefill_token", chunk_n)
                if comp is not None:
                    self._charge("compile_token", cw)
                self._dirty[:] = True
                out = np.asarray(out)  # greedy: (slots, W) ids; else logits
                self._counters["decode_time_s"] += self.clock() - t_start
                if last_decode_done is not None:
                    gap = t_start - last_decode_done
                    c = self._counters
                    c["decode_gap_max_s"] = max(c["decode_gap_max_s"], gap)
                    c["decode_gap_sum_s"] += gap
                    c["decode_gaps"] += 1
                    self._gap_samples.append(gap)
                    self._gap_window.append(gap)
                    self._m_gap.observe(gap)
                    if wd is not None:
                        wd.observe("decode_gap", gap)
                last_decode_done = self.last_step_t = self.clock()
                if tr.enabled:
                    tr.span("engine", "fused_step", t_start,
                            last_decode_done, lanes=len(decode_lanes),
                            chunk_tokens=int(chunk_n),
                            compile_tokens=int(cw))
                self._counters["decode_steps"] += 1
                self._counters["fused_steps"] += 1
                if chunk_n or comp is not None:
                    self._counters["fused_chunks"] += 1
                if compiling:
                    self._counters["decode_steps_during_compile"] += 1
                if promoting:
                    self._counters["decode_steps_during_promote"] += 1
                self.trace.append(("fused", len(decode_lanes), int(chunk_n),
                                   int(cw)))
                if chunk_slot is not None:
                    jn["consumed"] += chunk_n
                    lengths[chunk_slot] += chunk_n
                    self._counters["fused_prefill_chunks"] += 1
                    self._counters["fused_prefill_tokens"] += int(chunk_n)
                    if completing:
                        del self._joining[chunk_slot]
                        req = jn["req"]
                        self._counters["prefills"] += 1
                        if greedy:
                            tok = int(out[chunk_slot, chunk_n - 1])
                        else:
                            tok = self._sample_row(
                                out[chunk_slot, chunk_n - 1],
                                req.temperature, _stream(req))
                        pending[chunk_slot] = tok
                        if self.spec_k:
                            self._draft_prefill(chunk_slot, jn["toks"])
                        self.trace.append(("join_done", req.uid, chunk_slot))
                        if tr.enabled:
                            tr.span(f"slot{chunk_slot}", "admission",
                                    jn["t0"], rid=self._rids[req.uid],
                                    prefix=req.prefix,
                                    prompt_tokens=len(jn["toks"]),
                                    fused_join=True)
                        log = self.request_log[req.uid]
                        if log["first_token_s"] is None:
                            log["first_token_s"] = self.clock() - epoch
                            self._m_ttft.observe(
                                log["first_token_s"] - log["arrival_s"],
                                priority=log["priority"])
                            if wd is not None:
                                wd.observe(
                                    "ttft",
                                    log["first_token_s"] - log["arrival_s"])
                        if sched.record_token(chunk_slot, tok):
                            _finish(chunk_slot)
                for s in decode_lanes:
                    req = sched.request_in(s)
                    kk = int(k_eff[s])
                    if kk == 0:  # plain decode lane (no drafts this round)
                        lengths[s] += 1
                        tok = (int(out[s, 0]) if greedy else self._sample_row(
                            out[s, 0], req.temperature, _stream(req)))
                        pending[s] = tok
                        self._counters["tokens_generated"] += 1
                        if self.spec_k:
                            self._draft_len[s] += 1
                        if sched.record_token(s, tok):
                            _finish(s)
                        continue
                    self._counters["draft_proposed"] += kk
                    dr = drafts[s, :kk]
                    if greedy or req.temperature <= 0:
                        # greedy acceptance: the longest prefix where the
                        # drafter matched the target's argmax — the emitted
                        # tokens are exactly the non-speculative sequence
                        g = (out[s, :kk + 1] if greedy else
                             np.argmax(out[s, :kk + 1], axis=-1))
                        a = 0
                        while a < kk and int(dr[a]) == int(g[a]):
                            a += 1
                        emitted = [int(t) for t in g[:a + 1]]
                    else:
                        emitted, a = self._spec_sample(
                            out[s, :kk + 1], dr, req.temperature, _stream(req))
                    self._counters["draft_accepted"] += a
                    if tr.enabled:
                        tr.instant(f"slot{s}", "spec_accept",
                                   rid=self._rids[req.uid],
                                   proposed=kk, accepted=int(a))
                    # implicit KV rollback: only the accepted prefix counts —
                    # rejected lanes' cache rows sit beyond the new length
                    # (dense) / in private tail blocks (paged) and are
                    # causally invisible until overwritten next round
                    lengths[s] += len(emitted)
                    self._draft_len[s] += len(emitted)
                    pending[s] = emitted[-1]
                    fin = False
                    for t in emitted:
                        self._counters["tokens_generated"] += 1
                        if sched.record_token(s, t):
                            fin = True
                            break
                    if fin:
                        _finish(s)
                if comp is not None:
                    self.compiler.absorb_chunk(job, comp_out[0], comp_out[1],
                                               cw)
                    self._counters["fused_compile_chunks"] += 1
                    self._counters["compile_chunks_interleaved"] += 1
                    self.trace.append(("compile", cw))
                    if tr.enabled:
                        # the chunk rode the fused dispatch: its span is
                        # the step's own window on the compiler track
                        tr.span("compiler", "compile_chunk", t_start,
                                last_decode_done, tokens=int(cw),
                                fused=True)
                elif compiling and self.compile_token_budget is None:
                    # unbudgeted compile cannot ride the chunk lane — run
                    # the whole job behind this step (the stalled baseline)
                    self._compile_step(None)
                    self._counters["compile_chunks_interleaved"] += 1
                if promoting:
                    self._promote_step(self.promote_layer_budget)
                    self._counters["promote_steps_interleaved"] += 1
            if wd is not None:
                # goodput proxy: tokens emitted per engine step this
                # iteration (spec acceptance raises it above 1/lane)
                dsteps = self._counters["decode_steps"] - wd_steps0
                if dsteps:
                    wd.observe(
                        "tokens_per_step",
                        (self._counters["tokens_generated"] - wd_toks0)
                        / dsteps)
                wd.step()
            if self._autotune and \
                    len(self._gap_window) >= self.autotune_interval:
                self._autotune_step()
        self._refresh_gauges()
        return results

    def _preempt_for_priority(self, sched: Scheduler, can_seat,
                              protected=frozenset()):
        """Evict at most one running slot when the best queued request's
        class strictly outranks it (base classes — aging never triggers
        preemption) and admission left it stuck.  The victim is the worst
        running request (lowest class, then most emitted tokens, then
        highest slot); its paged blocks are released (the prefix itself
        stays store-resident and demotes through the normal tier path
        under capacity pressure) and the scheduler stashes its emitted
        tokens for a token-exact resume.  Slots in ``protected`` — seated
        by this loop iteration's admit() but not yet prefilled, so the
        caller still holds (slot, request) pairs for them — are never
        picked as victims.  Returns the (slot, request) pairs the retried
        admission seated.  One victim per loop iteration bounds
        preemption thrash."""
        cand = sched.best_queued()
        if cand is None:
            return []
        victims = [s for s in sched.active_slots()
                   if s not in protected
                   and sched.request_in(s).priority > cand.priority]
        if not victims:
            return []
        victim = max(victims, key=lambda s: (sched.request_in(s).priority,
                                             len(sched.emitted_tokens(s)), s))
        req = sched.preempt(victim)
        if self.kv_layout == "paged":
            self._release_slot_blocks(victim)
            self._reserved[victim] = 0
            self.base[victim] = 0
            self._seated[victim] = None
        self._counters["preemptions"] += 1
        self.request_log[req.uid]["preemptions"] += 1
        self.trace.append(("preempt", req.uid, victim))
        if self.tracer.enabled:
            self.tracer.instant(f"slot{victim}", "preempt",
                                rid=self._rids[req.uid],
                                by_priority=int(cand.priority))
        return sched.admit(can_seat)

    def _advance_to(self, t: float) -> None:
        """Wait until the clock reads ``t``: a virtual clock jumps there;
        a wall clock sleeps one short slice (the loop re-checks)."""
        jump = getattr(self.clock, "advance_to", None)
        if jump is not None:
            jump(t)
            return
        dt = t - self.clock()
        if dt > 0:
            time.sleep(min(dt, 0.02))

    def _autotune_step(self) -> None:
        """Feedback controller on the compile/promote budgets: while the
        mean decode gap over the last window overshoots the target, halve
        the budgets (smaller interleaved slices → tighter gaps, slower
        compile/promote completion); while it undershoots half the
        target, double them back, capped at 8× their configured values."""
        window = self._gap_window
        mean_gap = sum(window) / len(window)
        del window[:]
        init_c, init_p = self._budget_init
        # a page alert's degradation hint counts as an overshoot: tighten
        # background budgets even when the mean gap still looks healthy
        if mean_gap > self.target_decode_gap_s or self.degrade_hint:
            changed = False
            if self.compile_token_budget is not None \
                    and self.compile_token_budget > 1:
                self.compile_token_budget = self.compile_token_budget // 2
                changed = True
            if self.promote_layer_budget is not None \
                    and self.promote_layer_budget > 1:
                self.promote_layer_budget = self.promote_layer_budget // 2
                changed = True
            if changed:
                self._counters["autotune_shrinks"] += 1
                self.trace.append(("autotune", "shrink",
                                   self.compile_token_budget,
                                   self.promote_layer_budget))
                if self.tracer.enabled:
                    self.tracer.instant(
                        "engine", "autotune", action="shrink",
                        compile_budget=self.compile_token_budget,
                        promote_budget=self.promote_layer_budget)
        elif mean_gap < self.target_decode_gap_s / 2:
            changed = False
            if init_c is not None and self.compile_token_budget < init_c * 8:
                self.compile_token_budget = min(
                    self.compile_token_budget * 2, init_c * 8)
                changed = True
            if init_p is not None and self.promote_layer_budget < init_p * 8:
                self.promote_layer_budget = min(
                    self.promote_layer_budget * 2, init_p * 8)
                changed = True
            if changed:
                self._counters["autotune_grows"] += 1
                self.trace.append(("autotune", "grow",
                                   self.compile_token_budget,
                                   self.promote_layer_budget))
                if self.tracer.enabled:
                    self.tracer.instant(
                        "engine", "autotune", action="grow",
                        compile_budget=self.compile_token_budget,
                        promote_budget=self.promote_layer_budget)

    # ------------------------------------------------------------------
    # Online prefix compilation (PrefixCompiler integration)
    # ------------------------------------------------------------------

    def _check_request(self, req: Request) -> None:
        """Side-effect-free validation of one request (no counters, no
        compile submission): raises the same errors `_submit` would."""
        if req.prefix is not None and req.prefix not in self.store:
            if self.tiers is not None and self.tiers.cold_resident(req.prefix):
                # demoted/spilled prefix: promotable, no recompile needed
                base = self.tiers.cold_base_len(req.prefix)
            elif req.raw_shots is None:
                raise KeyError(
                    f"unknown prefix {req.prefix!r}; registered: "
                    f"{sorted(self.store.names()) or '(none)'}")
            elif self.compiler is None:
                raise ValueError(
                    f"request {req.uid} carries raw_shots but the engine "
                    "has no compressor — pass ServingEngine(compressor=...)")
            else:
                # worst-case seat: m memory slots (0 for state-only tasks)
                base = (self.cfg.memcom.num_memory_tokens
                        if self.cfg.memcom else 0)
        elif req.prefix is not None:
            base = self.store.base_len(req.prefix)
        else:
            # no-prefix requests land on either the engine-wide seated base
            # or a slot reset to 0 — base_len is the worst case
            base = self.base_len
        self._validate_len(req, base)

    def _submit(self, sched: Scheduler, req: Request) -> None:
        """Route one (already validated) request into the scheduler:
        resident prefix (or no prefix) goes straight to the FIFO queue.
        A request whose prefix is not HBM-resident is parked
        ``waiting_on_prefix`` while the prefix is *promoted* from a cold
        tier (if the tiered store holds it — even when the request also
        carries raw_shots, promotion beats recompiling) or, failing
        that, compiled from its raw_shots.  Both paths are single-flight
        — N requests for one task trigger one promotion/compile."""
        if req.prefix is not None:
            hit = self.store.lookup(req.prefix)
            if not hit:
                if self.tiers is not None and \
                        self.tiers.cold_resident(req.prefix):
                    self.tiers.submit_promotion(req.prefix,
                                                priority=req.priority)
                else:
                    self.compiler.submit(req.prefix, req.raw_shots,
                                         priority=req.priority)
                sched.park(req)
                self.trace.append(("park", req.uid, req.prefix))
                if self.tracer.enabled:
                    self.tracer.begin_async(
                        "scheduler", "waiting_on_prefix",
                        self._rids[req.uid], prefix=req.prefix)
                return
        sched.submit(req)

    def _validate_len(self, req: Request, base: int) -> None:
        need = base + len(req.tokens) + req.max_new
        if need > self.max_len:
            raise ValueError(
                f"request {req.uid}: prefix+prompt+max_new={need} "
                f"exceeds max_len={self.max_len}")

    def _compile_step(self, token_budget: Optional[int]) -> None:
        before = self.compiler.stats["tokens"]
        t0 = self.clock()
        self.compiler.step(token_budget)
        consumed = self.compiler.stats["tokens"] - before
        if consumed:
            self._charge("compile_token", consumed)
            self.trace.append(("compile", consumed))
            if self.tracer.enabled:
                self.tracer.span("compiler", "compile_chunk", t0,
                                 tokens=int(consumed))

    # ------------------------------------------------------------------
    # Async tier promotion (TieredPrefixStore integration)
    # ------------------------------------------------------------------

    def _promote_step(self, chunk_budget: Optional[int]) -> None:
        before = self.tiers.tier_stats["promote_chunks"]
        t0 = self.clock()
        self.tiers.promote_step(chunk_budget)
        copied = self.tiers.tier_stats["promote_chunks"] - before
        if copied:
            self._charge("promote_chunk", copied)
            self.trace.append(("promote", copied))
            if self.tracer.enabled:
                self.tracer.span("promoter", "promote_chunk", t0,
                                 chunks=int(copied))

    def _drain_promoter(self, sched: Scheduler) -> None:
        """Install at most one finished promotion into the HBM store and
        wake its waiting requests (same one-per-call reasoning as
        :meth:`_drain_compiler`: the woken requests seat — and thereby
        pin — the promoted prefix before a later install's LRU runs)."""
        ready = self.tiers.ready_promotions()
        if not ready:
            return
        name = ready[0]
        row = self.tiers.promoted_row(name)
        if self.kv_layout == "paged":
            def put():
                self.cache = self.store.put_row(name, row, self.cache)
        else:
            def put():
                self.store.put_row(name, row)
        if not self._install(put, sched):
            return  # paged seat pressure: retry on a later iteration
        self.tiers.mark_promoted(name)
        self.trace.append(("promoted", name))
        if self.tracer.enabled:
            self.tracer.instant("promoter", "promoted", prefix=name)
        for req in sched.wake(name):
            self.trace.append(("wake", req.uid, name))
            if self.tracer.enabled:
                self.tracer.end_async("scheduler", "waiting_on_prefix",
                                      self._rids[req.uid])

    def _drain_compiler(self, sched: Scheduler) -> None:
        """Install at most one finished compilation into the store and
        wake its waiting requests.  One per call on purpose: the woken
        requests admit — and thereby seat/pin — the fresh prefix before a
        *later* install's LRU eviction could reclaim it."""
        ready = self.compiler.ready()
        if not ready:
            return
        name = ready[0]
        if not self._try_install(name, self.compiler.job(name).materialized,
                                 sched):
            return  # paged seat pressure: retry on a later iteration
        self.compiler.mark_installed(name)
        self.trace.append(("seat", name))
        if self.tracer.enabled:
            self.tracer.instant("compiler", "prefix_installed", prefix=name)
        for req in sched.wake(name):
            self.trace.append(("wake", req.uid, name))
            if self.tracer.enabled:
                self.tracer.end_async("scheduler", "waiting_on_prefix",
                                      self._rids[req.uid])

    def _try_install(self, name: str, materialized, sched: Scheduler) -> bool:
        """Make a compiled prefix store-resident (see :meth:`_install`)."""
        if self.kv_layout == "paged":
            def put():
                self.cache = self.store.put(name, materialized, self.cache)
        else:
            def put():
                self.store.put(name, materialized)
        return self._install(put, sched)

    def _install(self, put, sched: Scheduler) -> bool:
        """Run one store-residency ``put`` under capacity pressure.  An
        uncapped dense store never fails; a capped store can hit LRU
        capacity with every resident prefix seated or pinned
        (:class:`PrefixSeatedError`), and the paged pool can be exhausted
        (:class:`OutOfBlocksError`) — then free slots' stale references
        are released and the install retried; still failing, it is
        deferred while anything is running, and raised only when nothing
        ever could free capacity."""
        # queued/waiting requests' prefixes must survive this install's LRU;
        # the pin is scoped to the put calls (eviction only happens inside
        # them) so a stale set can never block later add_prefix calls
        self.store.pinned = sched.referenced_prefixes()
        try:
            try:
                put()
                return True
            except (PrefixSeatedError, OutOfBlocksError):
                # finished-but-not-reseated slots still hold block
                # references; releasing a *free* slot's blocks is always
                # safe (dense slots hold copies, nothing to reclaim)
                if self.kv_layout == "paged":
                    self._reclaim_free_slots(sched)
                    try:
                        put()
                        return True
                    except (PrefixSeatedError, OutOfBlocksError):
                        pass
                if sched.active_slots() or sched.pending:
                    # a running slot will free capacity when it finishes —
                    # and a *queued* request will run, finish, and unpin
                    # its prefix (the drain precedes admission, so the
                    # queue can be non-empty with every slot free); defer
                    return False
                raise
        finally:
            self.store.pinned = set()

    def reset_stats(self) -> None:
        """Zero every counter (engine, store, compiler) — benches call this
        after their untimed jit-warmup serves."""
        for k in self._counters:
            self._counters[k] = type(self._counters[k])(0)
        self._gap_samples = []
        self._gap_window = []
        for k in self.store.stats:
            self.store.stats[k] = 0
        if self.compiler is not None:
            for k in self.compiler.stats:
                self.compiler.stats[k] = 0
        if self.tiers is not None:
            for k in self.tiers.tier_stats:
                self.tiers.tier_stats[k] = 0

    def stats(self) -> Dict[str, Optional[dict]]:
        """Cache/compile behaviour counters: engine loop counts, the
        prefix store's hit/miss/put/eviction counters, the online
        compiler's job/chunk/dedup counters, and (paged) pool occupancy.
        Reported by ``launch/serve.py --stats`` and read by the
        ``online_compile`` section of ``benchmarks/serving_bench.py``.

        The counters live in the engine's :class:`MetricsRegistry`
        (``self.metrics``) — this dict is a *snapshot view* over it,
        deep-copied so callers can never mutate live counters through
        the returned reference."""
        self._refresh_gauges()
        engine = dict(self._counters)
        gaps = self._gap_samples
        engine["decode_gap_p50_s"] = \
            float(np.percentile(gaps, 50)) if gaps else 0.0
        engine["decode_gap_p99_s"] = \
            float(np.percentile(gaps, 99)) if gaps else 0.0
        # per step-function family jit-compile counts (bucketed geometry
        # keys).  Engine-lifetime — reset_stats() leaves them alone — so a
        # bench can assert the fused bucket ladder caps recompiles.
        engine["jit_compiles"] = dict(self._jit_compiles)
        prop = engine["draft_proposed"]
        engine["accept_rate"] = (engine["draft_accepted"] / prop
                                 if prop else 0.0)
        out: Dict[str, Optional[dict]] = {
            "engine": engine,
            "prefix_store": dict(self.store.stats),
            "compiler": (dict(self.compiler.stats)
                         if self.compiler is not None else None),
            # live budget values sit outside _counters: the autotuner
            # mutates them and reset_stats must not zero them
            "budgets": {
                "compile_token_budget": self.compile_token_budget,
                "promote_layer_budget": self.promote_layer_budget,
                "autotune": bool(self._autotune),
            },
        }
        if self.fused or self.spec_k:
            out["fused"] = {
                "enabled": self.fused,
                "chunk_tokens": self.fused_chunk_tokens,
                "spec_k": self.spec_k,
                "draft": (self._draft_cfg.name
                          if self._draft_cfg is not None else None),
            }
        if self.tiers is not None:
            out["prefix_tiers"] = self.tiers.tier_snapshot()
        if self.kv_layout == "paged":
            out["pool"] = {
                "num_blocks": self.alloc.num_blocks,
                "block_size": self.block_size,
                "blocks_used": self.alloc.used_count,
                "blocks_free": self.alloc.free_count,
            }
        if self.mesh is not None:
            out["mesh"] = {name: int(self.mesh.shape[name])
                           for name in self.mesh.axis_names}
        return copy.deepcopy(out)

    def _refresh_gauges(self) -> None:
        """Push point-in-time values (pool occupancy, live budgets) into
        registry gauges so a Prometheus scrape between serves is fresh."""
        g = self.metrics.gauge
        g("serving_budget_compile_tokens",
          "live compile token budget (autotuned)").set(
              self.compile_token_budget)
        g("serving_budget_promote_layers",
          "live promote layer-chunk budget (autotuned)").set(
              self.promote_layer_budget)
        if self.kv_layout == "paged":
            g("serving_pool_blocks_used",
              "paged KV pool blocks in use").set(self.alloc.used_count)
            g("serving_pool_blocks_free",
              "paged KV pool blocks free").set(self.alloc.free_count)

    @property
    def gap_samples(self) -> List[float]:
        """Every decode gap observed since the last reset_stats() — the
        traffic harness computes its decode-gap percentiles from these."""
        return list(self._gap_samples)

    def _prefill_slot(self, slot: int, tokens: np.ndarray,
                      persist: bool = True) -> np.ndarray:
        """Prefill one slot's prompt behind its seated prefix; returns the
        last real token's logits row.  ``persist=False`` leaves the engine
        cache untouched (one-shot scoring)."""
        n = len(tokens)
        base = int(self.base[slot])
        cap = self.max_len - base
        assert 0 < n <= cap, (n, cap)
        self._counters["prefills"] += 1
        width = _bucket(n, cap) if self._pad_prefill else n
        self._note_geometry("prefill", (width, base))
        self._charge("prefill_token", width)
        padded = np.zeros((1, width), np.int32)
        padded[0, :n] = tokens
        if self.kv_layout == "paged":
            snap = None
            if not persist:
                snap = (self.alloc.snapshot(), self.tables[slot].copy(),
                        list(self._slot_blocks[slot]))
            self._prepare_prefill(slot, base, width)
            logits, new_cache = self._prefill(
                self.params, self.cache, jnp.asarray(padded),
                jnp.int32(slot), jnp.asarray(self.tables[slot]), base)
            if snap is not None:
                # one-shot scoring: roll the allocator and table back; the
                # discarded blocks may hold scatter garbage, but a block is
                # only ever read after being re-allocated *and* re-written
                self.alloc.restore(snap[0])
                self.tables[slot] = snap[1]
                self._slot_blocks[slot] = snap[2]
        else:
            logits, new_cache = self._prefill(
                self.params, self.cache, jnp.asarray(padded),
                jnp.int32(slot), base)
        if persist:
            self.cache = new_cache
            self._dirty[slot] = True
        return np.asarray(logits[n - 1])

    # ------------------------------------------------------------------
    # Paged capacity management
    # ------------------------------------------------------------------

    def _reclaim_free_slots(self, sched: Scheduler) -> None:
        """Release every *free* slot's block references (finished-but-not-
        reseated slots still hold them) — always safe, and the shared
        recovery move when the pool or the prefix store is out of room."""
        for slot in sched.free_slots():
            self._release_slot_blocks(slot)
            self.base[slot] = 0
            self._seated[slot] = None

    def _cow_block(self, slot: int, table_index: int) -> None:
        """Copy-on-write one table entry: copy the physical block, drop
        this slot's reference to the shared original, re-point the table
        at the private copy."""
        blocks = self._slot_blocks[slot]
        new = self.alloc.alloc(1)[0]
        self.cache = copy_paged_block(self.cache, blocks[table_index], new)
        self.alloc.decref(blocks[table_index])
        blocks[table_index] = new
        self.tables[slot, table_index] = new

    def _prepare_prefill(self, slot: int, base: int, width: int) -> int:
        """Make the slot's table cover positions [0, base + width):
        copy-on-write a *shared* partial tail block (the prompt's first
        token would land inside it), then allocate fresh private blocks
        for the rest of the prefill window.  Returns how many blocks were
        drawn from the free pool (COW copy + fresh) so callers streaming
        a prompt chunkwise can draw down the slot's reservation."""
        bs = self.block_size
        blocks = self._slot_blocks[slot]
        got = 0
        if base % bs and blocks:
            ti = base // bs  # the partially-filled tail block's table index
            if self.alloc.refcount(blocks[ti]) > 1:  # shared: store/slots
                self._cow_block(slot, ti)
                got += 1
        need = self.alloc.blocks_for(base + width) - len(blocks)
        if need > 0:
            fresh = self.alloc.alloc(need)
            self.tables[slot, len(blocks):len(blocks) + need] = fresh
            blocks.extend(fresh)
            got += need
        return got

    def _ensure_decode_blocks(self, active, lengths, widths=None) -> None:
        """Before a decode step, extend each active slot's table so every
        incoming write position is block-backed — ``widths[slot]`` lanes
        starting at ``lengths[slot]`` (one token when ``widths`` is None;
        the fused step's speculative verify lanes pass more).  Allocations
        draw down the slot's admission-time reservation."""
        bs = self.block_size
        for slot in active:
            w = 1 if widths is None else max(1, int(widths[slot]))
            first = int(lengths[slot]) // bs
            last = (int(lengths[slot]) + w - 1) // bs
            blocks = self._slot_blocks[slot]
            while len(blocks) <= last:
                fresh = self.alloc.alloc(1)[0]
                self.tables[slot, len(blocks)] = fresh
                blocks.append(fresh)
                self._reserved[slot] = max(0, self._reserved[slot] - 1)
            for bi in range(first, last + 1):
                if self.alloc.refcount(blocks[bi]) > 1:
                    # defensive: a decode write into a still-shared block
                    # (cannot happen after a >=1-token prefill, but COW is
                    # cheaper than a corrupted shared prefix)
                    self._cow_block(slot, bi)

    def _blocks_needed(self, req: Request, base: int, extra: int = 0) -> int:
        """Worst-case private blocks for a request's whole window:
        prefill bucket, decode budget, and a possible tail-block COW.
        ``extra`` counts already-emitted tokens a preempted request will
        re-prefill on resume (they move from the decode budget into the
        prefill width, which can only widen the bucket)."""
        n = len(req.tokens) + extra
        cap = self.max_len - base
        width = _bucket(n, cap) if self._pad_prefill else n
        total = base + max(width, len(req.tokens) + req.max_new)
        return (self.alloc.blocks_for(total) - self.alloc.blocks_for(base)
                + (1 if base % self.block_size else 0))

    def _req_base(self, req: Request) -> int:
        return (self.store.base_len(req.prefix) if req.prefix
                else self.base_len)

    def _can_admit(self, req: Request, extra: int = 0) -> bool:
        """Free-block admission gate: the request's whole private window
        must fit in the pool *net of other active slots' outstanding
        reservations* — a seated slot never stalls (or dies) mid-decode
        waiting for memory.  A True return reserves the window: the
        scheduler admits exactly the requests this approves."""
        need = self._blocks_needed(req, self._req_base(req), extra=extra)
        outstanding = int(self._reserved.sum()) + self._reserved_pending
        if need > self.alloc.free_count - outstanding:
            return False
        self._reserved_pending += need
        return True

    @staticmethod
    def _sample_row(logits_row: np.ndarray, temperature: float,
                    rng: np.random.Generator) -> int:
        if temperature <= 0:
            return int(np.argmax(logits_row))
        z = logits_row.astype(np.float64) / temperature
        z -= z.max()
        p = np.exp(z)
        return int(rng.choice(len(p), p=p / p.sum()))

    # ------------------------------------------------------------------
    # Compat APIs (lock-step batch generation, label scoring)
    # ------------------------------------------------------------------

    def generate(self, prompts, max_new: int, temperature: float = 0.0,
                 seed: int = 0, stop_token: Optional[int] = None) -> np.ndarray:
        """Batch-generate over the slot pool.  ``prompts`` is a (slots, S)
        array or a list of ragged 1-D token arrays (one per slot).  Returns
        a (slots, n) array; with a stop token, slots now terminate
        *independently* and shorter rows are right-padded with the stop
        token.  ``max_new=0`` (or every slot producing nothing) returns a
        well-shaped ``(slots, 0)`` array instead of crashing in the pad."""
        rows: List[np.ndarray] = [np.asarray(p, np.int32) for p in prompts]
        assert len(rows) == self.slots, (len(rows), self.slots)
        if max_new == 0:  # Request requires max_new >= 1 — nothing to do
            return np.zeros((self.slots, 0), np.int32)
        reqs = [Request(tokens=r, max_new=max_new, stop_token=stop_token,
                        temperature=temperature) for r in rows]
        results = self.serve(reqs, seed=seed)
        outs = [results[r.uid] for r in reqs]
        n = max((len(o) for o in outs), default=0)
        if n == 0:
            return np.zeros((self.slots, 0), np.int32)
        fill = stop_token if stop_token is not None else 0
        return np.stack([np.pad(o, (0, n - len(o)), constant_values=fill)
                         for o in outs])

    def score_labels(self, context: np.ndarray, query: np.ndarray,
                     label_ids: np.ndarray) -> int:
        """Constrained classification: argmax over label token ids for the
        next token after [compressed prefix; context; query]."""
        toks = np.concatenate([context, query]).astype(np.int32)
        self._restore_slot(0)  # refresh stale recurrent state, keep context
        row = self._prefill_slot(0, toks, persist=False)  # stateless scoring
        return int(label_ids[np.argmax(row[label_ids])])
