"""Parameter builder: creates params and records logical sharding axes.

One code path serves real initialization and abstract (ShapeDtypeStruct)
construction for the dry-run, so the parameter tree and its logical-axis
tree can never drift apart.  Logical axes are mapped to mesh axes by
:mod:`repro.sharding.rules`.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.utils.rng import Keys


def _insert(tree: dict, path: Tuple[str, ...], leaf):
    node = tree
    for p in path[:-1]:
        node = node.setdefault(p, {})
    assert path[-1] not in node, f"duplicate param {'/'.join(path)}"
    node[path[-1]] = leaf


class ParamBuilder:
    """Hierarchical builder.  ``child(name)`` scopes; ``child(name, stack=n)``
    prepends a stacked-layer dim (logical axis "layers") to everything below
    — used for scan-over-period parameter stacking."""

    def __init__(self, keys: Keys, dtype, abstract: bool = False,
                 _store=None, _path: Tuple[str, ...] = (), _stack: Tuple[int, ...] = ()):
        self.keys = keys
        self.dtype = dtype
        self.abstract = abstract
        self.store = _store if _store is not None else {"params": {}, "axes": {}}
        self.path = _path
        self.stack = _stack

    def child(self, name: str, stack: Optional[int] = None) -> "ParamBuilder":
        st = self.stack + ((stack,) if stack else ())
        return ParamBuilder(self.keys, self.dtype, self.abstract,
                            _store=self.store, _path=self.path + (name,), _stack=st)

    def make(self, name: str, shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
             init: str = "fanin", scale: float = 1.0, fan_in: Optional[int] = None,
             dtype=None) -> jax.Array:
        assert len(shape) == len(axes), (name, shape, axes)
        full_shape = self.stack + tuple(shape)
        full_axes = ("layers",) * len(self.stack) + tuple(axes)
        dt = dtype or self.dtype
        path = self.path + (name,)
        if self.abstract:
            leaf = jax.ShapeDtypeStruct(full_shape, dt)
        else:
            key = self.keys("/".join(path))
            if init == "zeros":
                leaf = jnp.zeros(full_shape, dt)
            elif init == "ones":
                leaf = jnp.ones(full_shape, dt)
            elif init == "normal":
                leaf = (scale * jax.random.normal(key, full_shape, jnp.float32)).astype(dt)
            elif init == "fanin":
                fi = fan_in if fan_in is not None else (shape[-2] if len(shape) >= 2 else shape[-1])
                leaf = (scale * (fi**-0.5) * jax.random.normal(key, full_shape, jnp.float32)).astype(dt)
            elif init == "uniform":
                leaf = (scale * jax.random.uniform(key, full_shape, jnp.float32, -1, 1)).astype(dt)
            else:
                raise ValueError(init)
        _insert(self.store["params"], path, leaf)
        _insert(self.store["axes"], path, full_axes)
        return leaf

    def build(self):
        return self.store["params"], self.store["axes"]
