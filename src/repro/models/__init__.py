from repro.models.transformer import init_params, forward, init_cache, param_specs
from repro.models import layers, attention, moe, mamba2, mla

__all__ = [
    "init_params",
    "forward",
    "init_cache",
    "param_specs",
    "layers",
    "attention",
    "moe",
    "mamba2",
    "mla",
]
