"""Multi-head Latent Attention (DeepSeek-V2).

Train/prefill use the standard (non-absorbed) form; decode uses the
*absorbed* form, where attention runs directly in the compressed latent
space: queries are folded through W_uk so the whole step is MQA with one
shared (kv_lora + rope)-wide key and a kv_lora-wide value — this is the
memory/computation win that makes the 512-float-per-token cache usable.

MemCom composes naturally: the compressed memory representations O^i are
pushed through the frozen W_dkv, so the prefix cache is itself an MLA
latent cache (two-level compression — see DESIGN.md §4).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.kernels import ops
from repro.models.layers import apply_rope
from repro.models.param import ParamBuilder


def init_mla(b: ParamBuilder, cfg: ModelConfig, name: str = "attn") -> None:
    m = cfg.mla
    d, nh = cfg.d_model, cfg.num_heads
    ab = b.child(name)
    ab.make("wdq", (d, m.q_lora_rank), ("embed", "mla_lora"))
    ab.make("q_norm", (m.q_lora_rank,), ("mla_lora",), init="ones")
    ab.make("wuq", (m.q_lora_rank, nh * m.qk_head_dim), ("mla_lora", "heads"))
    ab.make("wdkv", (d, m.kv_lora_rank + m.qk_rope_head_dim), ("embed", "mla_lora"))
    ab.make("kv_norm", (m.kv_lora_rank,), ("mla_lora",), init="ones")
    ab.make("wukv", (m.kv_lora_rank, nh * (m.qk_nope_head_dim + m.v_head_dim)),
            ("mla_lora", "heads"))
    ab.make("wo", (nh * m.v_head_dim, d), ("heads", "embed"), fan_in=nh * m.v_head_dim)


def _rms(x, scale, eps):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt((xf**2).mean(-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def _latent(p, cfg: ModelConfig, x, positions):
    """x -> (ckv_norm (B,S,R), k_rope (B,S,1,rd)) — the MLA cache entries."""
    m = cfg.mla
    ckv_full = x @ p["wdkv"]
    ckv, k_rope = jnp.split(ckv_full, [m.kv_lora_rank], axis=-1)
    ckv = _rms(ckv, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    return ckv, k_rope


def _queries(p, cfg: ModelConfig, x, positions):
    m = cfg.mla
    nh = cfg.num_heads
    cq = _rms(x @ p["wdq"], p["q_norm"], cfg.norm_eps)
    q = (cq @ p["wuq"]).reshape(*x.shape[:-1], nh, m.qk_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _expand_kv(p, cfg: ModelConfig, ckv):
    m = cfg.mla
    nh = cfg.num_heads
    kv = (ckv @ p["wukv"]).reshape(*ckv.shape[:-1], nh, m.qk_nope_head_dim + m.v_head_dim)
    return jnp.split(kv, [m.qk_nope_head_dim], axis=-1)  # k_nope, v


def apply_mla(
    p,
    cfg: ModelConfig,
    x,
    *,
    positions,
    mask_offset=0,
    prefix: Optional[dict] = None,
    cache: Optional[dict] = None,
    cache_index=None,
    decode: bool = False,
    block_tables=None,
    lane_valid=None,
    mesh=None,
    impl: str = "auto",
):
    """Returns (out, new_cache_or_None).  Cache = {"ckv", "kr"}.

    ``lane_valid`` (B,) int32 (fused serving step, per-slot decode only):
    lanes ``s >= lane_valid[b]`` are geometry padding — their latent-cache
    writes are dropped (dense) or routed to the trash block (paged); the
    absorbed-MQA read is already causally masked per lane, exactly as in
    :func:`repro.models.attention.apply_attention`.

    ``mesh`` is accepted for decode-kernel parity with
    :func:`repro.models.attention.apply_attention` but the absorbed-MQA
    decode runs with a *single* shared latent KV head — nothing to split
    on the model axis, so the latent cache stays replicated and the
    kernels fall back to their unsharded form (the per-head q_abs/out
    einsums around them still partition under GSPMD).

    With ``block_tables`` the latent cache is paged: ``ckv``/``kr`` are
    ``(num_blocks, block_size, ...)`` pools indexed per slot through the
    table — the absorbed-MQA decode walks blocks instead of a contiguous
    stripe, and prefix blocks shared across slots are stored once.
    """
    m = cfg.mla
    B, S, _ = x.shape
    nh = cfg.num_heads
    scale = m.qk_head_dim**-0.5

    q_nope, q_rope = _queries(p, cfg, x, positions)

    if decode:  # ---------------- absorbed decode ----------------
        assert cache is not None and cache_index is not None
        ckv_new, kr_new = _latent(p, cfg, x, positions)
        per_slot = jnp.ndim(cache_index) == 1
        if block_tables is not None:
            assert per_slot, "paged decode needs (slots,) lengths"
            ckv_cache = ops.paged_scatter(cache["ckv"], ckv_new, block_tables,
                                          cache_index, valid=lane_valid)
            kr_cache = ops.paged_scatter(cache["kr"], kr_new[:, :, 0, :],
                                         block_tables, cache_index,
                                         valid=lane_valid)
        elif per_slot:
            from repro.models.attention import scatter_rows

            ckv_cache = scatter_rows(cache["ckv"], ckv_new, cache_index,
                                     valid=lane_valid)
            kr_cache = scatter_rows(cache["kr"], kr_new[:, :, 0, :],
                                    cache_index, valid=lane_valid)
        else:
            ckv_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["ckv"], ckv_new.astype(cache["ckv"].dtype), cache_index, axis=1)
            kr_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["kr"], kr_new[:, :, 0, :].astype(cache["kr"].dtype), cache_index, axis=1)
        # fold q through W_uk:  q_abs[b,s,h,R] = q_nope . wuk[h]
        wukv = p["wukv"].reshape(m.kv_lora_rank, nh, m.qk_nope_head_dim + m.v_head_dim)
        wuk = wukv[:, :, : m.qk_nope_head_dim]
        q_abs = jnp.einsum("bshd,rhd->bshr", q_nope, wuk)
        q_eff = jnp.concatenate([q_abs, q_rope], axis=-1)  # (B,S,nh,R+rd)
        # MQA: 1 shared kv head (dense caches: axis 1 = positions; paged:
        # the whole pool is concatenated — same O(cache) data movement as
        # dense; splitting the latent/rope dot inside the kernel would
        # remove it entirely)
        k_eff = jnp.concatenate([ckv_cache, kr_cache], axis=-1)[:, :, None, :]
        v_eff = ckv_cache[:, :, None, :]
        if block_tables is not None:
            o_lat = ops.paged_decode_attention(
                q_eff, k_eff.astype(q_eff.dtype), v_eff.astype(q_eff.dtype),
                block_tables=block_tables, lengths=cache_index + S,
                scale=scale, impl=impl, mesh=mesh)
        elif per_slot:
            o_lat = ops.decode_attention(
                q_eff, k_eff.astype(q_eff.dtype), v_eff.astype(q_eff.dtype),
                lengths=cache_index + S, scale=scale, impl=impl, mesh=mesh)
        else:
            max_len = k_eff.shape[1]
            slot = jnp.arange(max_len, dtype=jnp.int32)
            kv_pos = jnp.broadcast_to(jnp.where(slot < cache_index + S, slot, -1), (B, max_len))
            q_pos = jnp.broadcast_to(cache_index + jnp.arange(S, dtype=jnp.int32), (B, S))
            o_lat = ops.attention(q_eff, k_eff.astype(q_eff.dtype), v_eff.astype(q_eff.dtype),
                                  q_pos=q_pos, kv_pos=kv_pos, causal=True,
                                  scale=scale, impl=impl)  # (B,S,nh,R)
        wuv = wukv[:, :, m.qk_nope_head_dim :]
        out = jnp.einsum("bshr,rhd->bshd", o_lat, wuv)
        return out.reshape(B, S, -1) @ p["wo"], {"ckv": ckv_cache, "kr": kr_cache}

    # ---------------- train / prefill: non-absorbed ----------------
    if (prefix is None and cache is not None
            and isinstance(cache_index, int) and cache_index > 0):
        # prefill continuation over already-seated latent slots
        if block_tables is not None:
            bs_blk = cache["ckv"].shape[1]
            nbt = -(-cache_index // bs_blk)
            blk = block_tables[:, :nbt]
            prefix = {
                "ckv": ops.paged_gather(cache["ckv"], blk)[:, :cache_index],
                "kr": ops.paged_gather(cache["kr"], blk)[:, :cache_index],
            }
        else:
            prefix = {"ckv": cache["ckv"][:, :cache_index],
                      "kr": cache["kr"][:, :cache_index]}
    ckv, k_rope = _latent(p, cfg, x, positions)
    k_nope, v = _expand_kv(p, cfg, ckv)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (*k_nope.shape[:3], m.qk_rope_head_dim))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)

    if prefix is not None:
        if "ckv" in prefix:
            ckv_pre, kr_pre = prefix["ckv"], prefix["kr"]
        else:  # derive latent prefix from compressed memory hiddens O^i
            h_pre = prefix["h"]
            mlen = h_pre.shape[1]
            pre_pos = jnp.broadcast_to(jnp.arange(mlen, dtype=jnp.int32), (B, mlen))
            ckv_pre, kr4 = _latent(p, cfg, h_pre, pre_pos)
            kr_pre = kr4[:, :, 0, :]
        kn_pre, v_pre = _expand_kv(p, cfg, ckv_pre)
        mlen = ckv_pre.shape[1]
        k_pre = jnp.concatenate(
            [kn_pre, jnp.broadcast_to(kr_pre[:, :, None, :], (*kn_pre.shape[:3], m.qk_rope_head_dim))],
            axis=-1)
        out = ops.attention_with_prefix(
            q, k, v, k_pre.astype(q.dtype), v_pre.astype(q.dtype),
            offset=mask_offset if mask_offset else mlen, scale=scale, impl=impl)
    else:
        out = ops.self_attention_causal(q, k, v, offset=mask_offset,
                                        scale=scale, impl=impl)
    new_cache = None
    if cache is not None:
        start = cache_index if cache_index is not None else 0
        if block_tables is not None:
            starts = jnp.full((B,), start, jnp.int32)
            new_cache = {
                "ckv": ops.paged_scatter(cache["ckv"], ckv, block_tables,
                                         starts),
                "kr": ops.paged_scatter(cache["kr"], k_rope[:, :, 0, :],
                                        block_tables, starts),
            }
        else:
            new_cache = {
                "ckv": jax.lax.dynamic_update_slice_in_dim(
                    cache["ckv"], ckv.astype(cache["ckv"].dtype), start, axis=1),
                "kr": jax.lax.dynamic_update_slice_in_dim(
                    cache["kr"], k_rope[:, :, 0, :].astype(cache["kr"].dtype), start, axis=1),
            }
    return out.reshape(B, S, -1) @ p["wo"], new_cache


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "kr": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
    }


def init_paged_mla_cache(cfg: ModelConfig, num_blocks: int, block_size: int,
                         dtype) -> dict:
    m = cfg.mla
    return {
        "ckv": jnp.zeros((num_blocks, block_size, m.kv_lora_rank), dtype),
        "kr": jnp.zeros((num_blocks, block_size, m.qk_rope_head_dim), dtype),
    }
