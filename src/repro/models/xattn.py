"""MemCom's per-layer compression cross-attention (paper §4, App. D).

Variants: "1head" (paper default — a single head of width d_model),
"mha" (multi-head), "mqa" (multi-query).  Q comes from the Memory-LLM's
post-self-attention hidden state (pre-normed for stability), K = V are the
Source-LLM's *raw* layer-input representations, faithful to
``O^i = XAttn(Q=H_mem^i, K=H_src^i, V=H_src^i)``.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.config import ModelConfig
from repro.kernels import ops
from repro.models.layers import init_norm, apply_norm
from repro.models.param import ParamBuilder


def init_memcom_xattn(b: ParamBuilder, cfg: ModelConfig) -> None:
    d = cfg.d_model
    mc = cfg.memcom
    xb = b.child("memx")
    init_norm(xb, cfg, "norm")
    if mc.xattn_kind == "mqa":
        H = mc.xattn_heads
        hd = d // H
        # paper: modules are randomly initialized (trained in Phase-1);
        # wo gets a small scale so the initial perturbation of the memory
        # stream is mild but gradients flow to q/k/v from step one.
        xb.make("wq", (d, H * hd), ("embed", "heads"), scale=0.5)
        xb.make("wk", (d, hd), ("embed", "heads"), scale=0.5)
        xb.make("wv", (d, hd), ("embed", "heads"), scale=0.5)
        xb.make("wo", (H * hd, d), ("heads", "embed"), scale=0.1)
    else:  # "1head" (H=1) or "mha"
        xb.make("wq", (d, d), ("embed", "heads"), scale=0.5)
        xb.make("wk", (d, d), ("embed", "heads"), scale=0.5)
        xb.make("wv", (d, d), ("embed", "heads"), scale=0.5)
        xb.make("wo", (d, d), ("heads", "embed"), scale=0.1)


def apply_memcom_xattn(p, cfg: ModelConfig, mem_h, src_h, *, impl: str = "auto"):
    """mem_h: (B, m, D) memory residual; src_h: (B, T, D) source layer reps.
    Returns the cross-attention output (B, m, D) to be residually added."""
    mc = cfg.memcom
    q_in = apply_norm(p["norm"], cfg, mem_h)
    B, M, D = q_in.shape
    T = src_h.shape[1]

    if mc.xattn_kind == "1head":
        q = q_in @ p["wq"]
        k = src_h @ p["wk"]
        v = src_h @ p["wv"]
        o = ops.memcom_xattn(q, k, v, impl=impl)
        return o @ p["wo"]

    H = mc.xattn_heads
    kv_heads = 1 if mc.xattn_kind == "mqa" else H
    hd = D // H
    q = (q_in @ p["wq"]).reshape(B, M, H, hd)
    k = (src_h @ p["wk"]).reshape(B, T, kv_heads, hd)
    v = (src_h @ p["wv"]).reshape(B, T, kv_heads, hd)
    q_pos = jnp.zeros((B, M), jnp.int32)
    kv_pos = jnp.zeros((B, T), jnp.int32)
    o = ops.attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=False, impl=impl)
    return o.reshape(B, M, D) @ p["wo"]
