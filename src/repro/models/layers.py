"""Norms, positional embeddings (RoPE / M-RoPE / learned), MLPs."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.param import ParamBuilder


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(b: ParamBuilder, cfg: ModelConfig, name: str, dim: int | None = None):
    d = dim or cfg.d_model
    nb = b.child(name)
    nb.make("scale", (d,), ("embed",), init="ones")
    if cfg.norm_type == "layernorm":
        nb.make("bias", (d,), ("embed",), init="zeros")


def apply_norm(p, cfg: ModelConfig, x):
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        var = (xf**2).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + cfg.norm_eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               mrope_sections=()) -> jax.Array:
    """x: (B, S, H, Dh); positions: (B, S) or (3, B, S) for M-RoPE.

    M-RoPE (Qwen2-VL): the Dh/2 frequency slots are partitioned into
    (temporal, height, width) sections; each section uses the matching
    position stream.  Text tokens carry identical t/h/w positions, which
    reduces exactly to standard RoPE.
    """
    Dh = x.shape[-1]
    freqs = rope_freqs(Dh, theta)  # (Dh/2,)
    if positions.ndim == 3:
        assert mrope_sections, "3-D positions require mrope_sections"
        sec_ids = jnp.repeat(
            jnp.arange(len(mrope_sections)),
            jnp.asarray(mrope_sections),
            total_repeat_length=Dh // 2,
        )  # (Dh/2,) in {0,1,2}
        pos = positions.astype(jnp.float32)  # (3,B,S)
        # angle[b,s,f] = pos[sec(f), b, s] * freqs[f]
        angles = jnp.take(pos, sec_ids, axis=0)  # (Dh/2, B, S)
        angles = jnp.moveaxis(angles, 0, -1) * freqs  # (B,S,Dh/2)
    else:
        angles = positions.astype(jnp.float32)[..., None] * freqs  # (B,S,Dh/2)
    cos = jnp.cos(angles)[:, :, None, :]  # (B,S,1,Dh/2)
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos_embed(num_pos: int, dim: int) -> jax.Array:
    pos = jnp.arange(num_pos, dtype=jnp.float32)[:, None]
    i = jnp.arange(dim // 2, dtype=jnp.float32)[None, :]
    angle = pos / (10000 ** (2 * i / dim))
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def init_mlp(b: ParamBuilder, cfg: ModelConfig, d_ff: int | None = None,
             mlp_type: str | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    t = mlp_type or cfg.mlp_type
    mb = b.child("mlp")
    if t == "gelu_mlp":
        mb.make("wi", (d, f), ("embed", "ff"))
        mb.make("bi", (f,), ("ff",), init="zeros")
        mb.make("wo", (f, d), ("ff", "embed"))
        mb.make("bo", (d,), ("embed",), init="zeros")
    else:  # swiglu / geglu
        mb.make("wg", (d, f), ("embed", "ff"))
        mb.make("wi", (d, f), ("embed", "ff"))
        mb.make("wo", (f, d), ("ff", "embed"))


def apply_mlp(p, cfg: ModelConfig, x, mlp_type: str | None = None):
    t = mlp_type or cfg.mlp_type
    if t == "gelu_mlp":
        h = jax.nn.gelu(x @ p["wi"] + p["bi"])
        return h @ p["wo"] + p["bo"]
    act = jax.nn.silu if t == "swiglu" else jax.nn.gelu
    return (act(x @ p["wg"]) * (x @ p["wi"])) @ p["wo"]


def softcap(x, cap: float):
    if cap:
        return cap * jnp.tanh(x / cap)
    return x
