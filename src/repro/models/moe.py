"""Mixture-of-Experts with sort-based capacity dispatch.

No (tokens × experts × capacity) dispatch einsum: tokens are argsorted by
assigned expert, windowed into per-expert capacity buffers, pushed through a
grouped matmul (Pallas kernel on TPU, einsum oracle elsewhere), and
scatter-combined back.  Compiled FLOPs ≈ capacity_factor × ideal.

Expert weights are stacked on a leading "expert" logical axis → sharded on
the mesh "model" axis (expert parallelism).

Dispatch locality (``MoEConfig.dispatch_groups``): routing is per-token,
but the argsort/cumsum/scatter chain runs within G independent token
groups.  G = 1 is the classic global sort; with G = data-shard count the
whole dispatch carries a leading sharded group axis, so under GSPMD the
MoE layer partitions with *no token-stream gathers* — measured in
EXPERIMENTS.md §Perf (hillclimb 1: jamba train collective bytes).
Capacity is per-group (C = cf·Ng·k/E), so expected drop rates match the
global sort when tokens are shuffled across groups, which data-parallel
batching guarantees.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.kernels import ops
from repro.models.layers import init_mlp, apply_mlp
from repro.models.param import ParamBuilder
from repro.sharding.ctx import moe_dispatch_plan


def init_moe(b: ParamBuilder, cfg: ModelConfig) -> None:
    m = cfg.moe
    d, E, F = cfg.d_model, m.num_experts, m.expert_d_ff
    eb = b.child("moe")
    eb.make("router", (d, E), ("embed", "expert"))
    # expert weights use "embed_ep", NOT "embed": FSDP-sharding their
    # d_model dim makes every expert matmul contract over a sharded axis
    # — XLA then emits (E, C, F)-sized partial-sum all-reduces per MoE
    # layer plus token-stream permutes (measured: the dominant collective
    # in every MoE train/prefill cell; EXPERIMENTS.md §Perf hillclimb 1).
    # Experts shard on "model" (EP); their d_model dim stays unsharded.
    eb.make("wg", (E, d, F), ("expert", "embed_ep", "ff"), fan_in=d)
    eb.make("wi", (E, d, F), ("expert", "embed_ep", "ff"), fan_in=d)
    eb.make("wo", (E, F, d), ("expert", "ff", "embed_ep"), fan_in=F)
    if m.num_shared_experts:
        init_mlp(eb.child("shared"), cfg,
                 d_ff=m.num_shared_experts * m.shared_ff(), mlp_type="swiglu")


def _capacity(m, n_tokens: int) -> int:
    c = int(m.capacity_factor * n_tokens * m.top_k / m.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8


def _dispatch(xf, ids, E: int, k: int, C: int):
    """(Ng, D) tokens + (Ng, k) expert ids -> (E, C, D) capacity buffers
    plus the metadata `_combine` needs.  Pure per-group function: vmaps
    over a leading (sharded) group axis with zero cross-group traffic."""
    Ng, D = xf.shape
    flat_ids = ids.reshape(Ng * k)
    order = jnp.argsort(flat_ids, stable=True)
    sorted_ids = flat_ids[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat_ids].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(Ng * k, dtype=jnp.int32) - starts[sorted_ids]
    keep = rank < C
    buf_idx = jnp.where(keep, sorted_ids * C + rank, E * C)  # OOB -> dropped
    token_idx = order // k
    buffers = jnp.zeros((E * C, D), xf.dtype).at[buf_idx].set(
        xf[token_idx], mode="drop").reshape(E, C, D)
    return buffers, (keep, buf_idx, order)


def _combine(y_buf, md, gates, k: int):
    """Inverse of `_dispatch`: (E, C, D) expert outputs -> (Ng, D)."""
    keep, buf_idx, order = md
    E, C, D = y_buf.shape
    flat = y_buf.reshape(E * C, D)
    y_sorted = jnp.where(keep[:, None],
                         flat.at[buf_idx].get(mode="fill", fill_value=0), 0)
    inv = jnp.argsort(order)
    Ng = gates.shape[0]
    y_k = y_sorted[inv].reshape(Ng, k, D)
    return jnp.sum(y_k * gates[..., None].astype(y_k.dtype), axis=1)


def _expert_ffn(p, buffers, impl):
    """SwiGLU through the per-expert grouped matmul.  Accepts (E, C, D) or
    (G, E, C, D); the Pallas path folds G into C (one kernel launch)."""
    def gmm(x, w):
        if x.ndim == 3:
            return ops.gmm(x, w, impl=impl)
        G, E, C, D = x.shape
        if impl == "pallas":
            x2 = x.transpose(1, 0, 2, 3).reshape(E, G * C, D)
            out = ops.gmm(x2, w, impl=impl)
            return out.reshape(E, G, C, -1).transpose(1, 0, 2, 3)
        return jnp.einsum("gecd,edf->gecf", x, w)

    h = jax.nn.silu(gmm(buffers, p["wg"])) * gmm(buffers, p["wi"])
    return gmm(h, p["wo"])


def apply_moe(p, cfg: ModelConfig, x, *, impl: str = "auto"):
    """x: (B, S, D) -> (y, aux_loss)."""
    m = cfg.moe
    B, S, D = x.shape
    E, k = m.num_experts, m.top_k
    N = B * S
    # Under a distributed residual constraint, reshard tokens batch-only
    # and group the dispatch by batch shard (ctx.moe_dispatch_plan); the
    # config's dispatch_groups is the single-host/test override.
    x, auto_groups = moe_dispatch_plan(x, E)
    G = auto_groups or m.dispatch_groups
    if G <= 0 or N % G:
        G = 1
    xf = x.reshape(N, D)

    logits = (xf @ p["router"]).astype(jnp.float32)  # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, k)  # (N, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style, global)
    me = probs.mean(axis=0)  # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(1.0) / (N * k)
    aux = m.aux_loss_weight * E * jnp.sum(me * ce)

    Ng = N // G
    C = _capacity(m, Ng)

    if G == 1:
        buffers, md = _dispatch(xf, ids, E, k, C)
        y_buf = _expert_ffn(p, buffers, impl)
        y = _combine(y_buf, md, gates, k)
    else:
        buffers, md = jax.vmap(lambda a, b: _dispatch(a, b, E, k, C))(
            xf.reshape(G, Ng, D), ids.reshape(G, Ng, k))
        y_buf = _expert_ffn(p, buffers, impl)  # (G, E, C, D) in one call
        y = jax.vmap(lambda yb, m_, g: _combine(yb, m_, g, k))(
            y_buf, md, gates.reshape(G, Ng, k)).reshape(N, D)

    if m.num_shared_experts:
        y = y + apply_mlp(p["shared"]["mlp"], cfg, xf, mlp_type="swiglu")
    # the residual stream must stay in the model dtype: a float32 leak
    # here upcasts every downstream activation (2× memory + collective
    # bytes on all MoE archs — caught in the §Perf autopsy)
    return y.reshape(B, S, D).astype(x.dtype), aux
