"""GQA attention block: train / prefill / decode / cross / MemCom-prefix.

RoPE positions and mask order are deliberately decoupled: masking always
follows sequential text order (``mask_offset + arange``) while RoPE may use
M-RoPE 3-D position streams (Qwen2-VL).

MemCom integration: ``prefix`` carries the layer's compressed memory
representations, either as hidden states ``{"h": (B, m, D)}`` (training —
K/V derived through this layer's frozen projections, differentiable into
the compressor) or as a precomputed compressed KV cache
``{"k": (B, m, Hkv, hd), "v": ...}`` (serving).  Target tokens sit at
positions ``m..m+S`` and see every memory slot (positions ``0..m-1``).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.kernels import ops
from repro.models.layers import apply_rope
from repro.models.param import ParamBuilder
from repro.sharding.ctx import head_sharded


def init_attention(b: ParamBuilder, cfg: ModelConfig, name: str = "attn",
                   num_heads: int | None = None) -> None:
    d, hd = cfg.d_model, cfg.hd
    nh = num_heads or cfg.num_heads
    nkv = num_heads or cfg.num_kv_heads
    ab = b.child(name)
    ab.make("wq", (d, nh * hd), ("embed", "heads"))
    ab.make("wk", (d, nkv * hd), ("embed", "kv_heads"))
    ab.make("wv", (d, nkv * hd), ("embed", "kv_heads"))
    ab.make("wo", (nh * hd, d), ("heads", "embed"), fan_in=nh * hd)
    if cfg.attn_qkv_bias:
        ab.make("bq", (nh * hd,), ("heads",), init="zeros")
        ab.make("bk", (nkv * hd,), ("kv_heads",), init="zeros")
        ab.make("bv", (nkv * hd,), ("kv_heads",), init="zeros")


def _proj(x, w, b, n, hd):
    y = x @ w
    if b is not None:
        y = y + b
    return y.reshape(*x.shape[:-1], n, hd)


def project_q(p, cfg: ModelConfig, x, positions):
    q = _proj(x, p["wq"], p.get("bq"), -1, cfg.hd)
    if cfg.pos_embed == "rope":
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
    return q


def project_kv(p, cfg: ModelConfig, x, positions):
    """Roped K and V from hidden states — also used to build the MemCom
    compressed cache from memory representations (positions 0..m-1)."""
    k = _proj(x, p["wk"], p.get("bk"), -1, cfg.hd)
    v = _proj(x, p["wv"], p.get("bv"), -1, cfg.hd)
    if cfg.pos_embed == "rope":
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    return k, v


def scatter_rows(cache, new, starts, valid=None):
    """Write ``new[b]`` into ``cache[b]`` at per-slot offsets ``starts[b]``
    along the sequence axis — the continuous-batching cache write, where
    every slot sits at its own ``base_len + tokens_consumed`` position.

    ``valid`` (B,) int32 (optional) is the fused-step ragged-lane mask:
    only lanes ``s < valid[b]`` are written; the rest scatter to the
    out-of-bounds sentinel row ``max_len`` and are dropped.  The masked
    path must NOT use ``dynamic_update_slice`` — its clamp semantics
    would shift a window whose garbage tail crosses ``max_len`` *back*
    over valid cache rows."""
    if valid is None:
        def one(c, u, s):
            return jax.lax.dynamic_update_slice_in_dim(
                c, u.astype(c.dtype), s, axis=0)
        return jax.vmap(one)(cache, new, starts)
    L = cache.shape[1]
    S = new.shape[1]
    pos = starts[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]  # (B,S)
    lane = jnp.arange(S, dtype=jnp.int32)[None, :]
    dest = jnp.where(lane < valid[:, None], pos, L)  # L = OOB -> dropped

    def one(c, u, d):
        return c.at[d].set(u.astype(c.dtype), mode="drop")

    return jax.vmap(one)(cache, new, dest)


def _prefix_kv(p, cfg: ModelConfig, prefix: dict):
    if "k" in prefix:
        return prefix["k"], prefix["v"]
    h = prefix["h"]
    B, m = h.shape[0], h.shape[1]
    pos = jnp.broadcast_to(jnp.arange(m, dtype=jnp.int32), (B, m))
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(pos, (3, B, m))
    return project_kv(p, cfg, h, pos)


def apply_attention(
    p,
    cfg: ModelConfig,
    x,
    *,
    positions,
    mask_offset=0,
    prefix: Optional[dict] = None,
    cache: Optional[dict] = None,
    cache_index=None,
    kv_source=None,
    decode: bool = False,
    block_tables=None,
    lane_valid=None,
    mesh=None,
    impl: str = "auto",
):
    """Returns (out (B,S,D), new_cache_or_None).  ``mesh`` (tensor-parallel
    serving) reaches the decode kernels, which split Q/K/V by head over
    its "model" axis while per-slot lengths and block tables stay
    replicated — see :mod:`repro.sharding.serving`.

    ``lane_valid`` (B,) int32 (fused serving step, per-slot decode only)
    marks how many of the S lanes carry real tokens per slot: invalid
    lanes' KV writes are dropped (dense) or routed to the trash block
    (paged).  The attention *read* needs no masking — ``lengths =
    cache_index + S`` puts lane ``s`` at query position ``cache_index +
    s``, and causality already hides every cache row an invalid lane
    could have written.

    With ``block_tables`` (B, nb) the cache entries are *paged*: ``k``/``v``
    are shared ``(num_blocks, block_size, Hkv, hd)`` pools and slot ``b``'s
    cache position ``p`` lives at ``(block_tables[b, p // bs], p % bs)``.
    Decode requires the per-slot length vector; prefill continues behind
    the seated blocks (static ``cache_index`` base, as in the dense path).
    """
    B, S, _ = x.shape
    softcap = cfg.attn_logit_softcap
    scale = cfg.hd**-0.5

    # ---------------- cross-attention (enc-dec) ----------------
    if kv_source is not None or (cache is not None and "ck" in cache):
        q = _proj(x, p["wq"], p.get("bq"), -1, cfg.hd)  # no rope (whisper)
        if cache is not None and "ck" in cache:
            if kv_source is not None:  # prefill: project and store
                k = _proj(kv_source, p["wk"], p.get("bk"), -1, cfg.hd)
                v = _proj(kv_source, p["wv"], p.get("bv"), -1, cfg.hd)
                cache = {"ck": k.astype(cache["ck"].dtype), "cv": v.astype(cache["cv"].dtype)}
            k, v = cache["ck"], cache["cv"]
        else:
            k = _proj(kv_source, p["wk"], p.get("bk"), -1, cfg.hd)
            v = _proj(kv_source, p["wv"], p.get("bv"), -1, cfg.hd)
        F = k.shape[1]
        q_pos = jnp.zeros((B, S), jnp.int32)
        kv_pos = jnp.zeros((B, F), jnp.int32)
        out = ops.attention(q, k.astype(q.dtype), v.astype(q.dtype), q_pos=q_pos,
                            kv_pos=kv_pos, causal=False, softcap=softcap,
                            scale=scale, impl=impl)
        return out.reshape(B, S, -1) @ p["wo"], cache

    q = project_q(p, cfg, x, positions)

    # ---------------- decode: read/write KV cache ----------------
    if decode:
        assert cache is not None and cache_index is not None
        k_new, v_new = project_kv(p, cfg, x, positions)
        if block_tables is not None:
            # paged: scatter the new tokens into each slot's tail block,
            # then walk the block tables (shared prefix blocks are read by
            # every slot seated on the task but stored once)
            assert jnp.ndim(cache_index) == 1, "paged decode needs (slots,) lengths"
            k_pool = ops.paged_scatter(cache["k"], k_new, block_tables,
                                       cache_index, valid=lane_valid)
            v_pool = ops.paged_scatter(cache["v"], v_new, block_tables,
                                       cache_index, valid=lane_valid)
            out = ops.paged_decode_attention(
                q, k_pool, v_pool, block_tables=block_tables,
                lengths=cache_index + S, softcap=softcap, scale=scale,
                impl=impl, mesh=mesh)
            return out.reshape(B, S, -1) @ p["wo"], {"k": k_pool, "v": v_pool}
        if jnp.ndim(cache_index) == 1:
            # per-slot lengths (continuous batching): each slot writes at its
            # own offset and is masked to its own seated region only
            k_cache = scatter_rows(cache["k"], k_new, cache_index,
                                   valid=lane_valid)
            v_cache = scatter_rows(cache["v"], v_new, cache_index,
                                   valid=lane_valid)
            out = ops.decode_attention(
                q, k_cache.astype(q.dtype), v_cache.astype(q.dtype),
                lengths=cache_index + S, softcap=softcap, scale=scale,
                impl=impl, mesh=mesh)
            return out.reshape(B, S, -1) @ p["wo"], {"k": k_cache, "v": v_cache}
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k_new.astype(cache["k"].dtype), cache_index, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v_new.astype(cache["v"].dtype), cache_index, axis=1)
        max_len = k_cache.shape[1]
        slot = jnp.arange(max_len, dtype=jnp.int32)
        kv_pos = jnp.where(slot < cache_index + S, slot, -1)
        kv_pos = jnp.broadcast_to(kv_pos, (B, max_len))
        q_pos = cache_index + jnp.arange(S, dtype=jnp.int32)
        q_pos = jnp.broadcast_to(q_pos, (B, S))
        out = ops.attention(q, k_cache.astype(q.dtype), v_cache.astype(q.dtype),
                            q_pos=q_pos, kv_pos=kv_pos, causal=True,
                            softcap=softcap, scale=scale, impl=impl)
        return out.reshape(B, S, -1) @ p["wo"], {"k": k_cache, "v": v_cache}

    # ---------------- train / prefill: full self-attention ----------------
    k, v = project_kv(p, cfg, x, positions)
    # TP-attention layout — one seq gather per layer instead of one per
    # q-chunk/kv-chunk inside the streaming kernels.  Applied only when
    # the KV heads divide the model axis: otherwise the GQA fold reshape
    # (Hq → Hkv×G) cannot preserve the shard and XLA falls back to
    # "involuntary full rematerialization" (measured: +3 % on jamba,
    # whose kv=8 < 16 — EXPERIMENTS.md §Perf H4).
    k_sh = head_sharded(k)
    if k_sh is not k:
        q, k, v = head_sharded(q), k_sh, head_sharded(v)
    if (prefix is None and cache is not None
            and isinstance(cache_index, int) and cache_index > 0):
        # prefill continuation: slots [0, cache_index) are already seated
        # (compressed memory or an earlier prefill segment) — attend to
        # them as a fully-visible prefix.  Static start only.
        if block_tables is not None:
            bs = cache["k"].shape[1]
            nbt = -(-cache_index // bs)  # ceil: blocks covering the base
            blk = block_tables[:, :nbt]
            prefix = {
                "k": ops.paged_gather(cache["k"], blk)[:, :cache_index]
                .astype(x.dtype),
                "v": ops.paged_gather(cache["v"], blk)[:, :cache_index]
                .astype(x.dtype),
            }
        else:
            prefix = {"k": cache["k"][:, :cache_index].astype(x.dtype),
                      "v": cache["v"][:, :cache_index].astype(x.dtype)}
    if prefix is not None:
        k_pre, v_pre = _prefix_kv(p, cfg, prefix)
        m = k_pre.shape[1]
        out = ops.attention_with_prefix(
            q, k, v, k_pre.astype(q.dtype), v_pre.astype(q.dtype),
            offset=mask_offset if mask_offset else m,
            softcap=softcap, scale=scale, impl=impl)
    else:
        out = ops.self_attention_causal(q, k, v, offset=mask_offset,
                                        softcap=softcap, scale=scale, impl=impl)
    new_cache = None
    if cache is not None:  # prefill writes the cache
        start = cache_index if cache_index is not None else 0
        if block_tables is not None:
            starts = jnp.full((B,), start, jnp.int32)
            new_cache = {
                "k": ops.paged_scatter(cache["k"], k, block_tables, starts),
                "v": ops.paged_scatter(cache["v"], v, block_tables, starts),
            }
        else:
            new_cache = {
                "k": jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k.astype(cache["k"].dtype), start, axis=1),
                "v": jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v.astype(cache["v"].dtype), start, axis=1),
            }
    return out.reshape(B, S, -1) @ p["wo"], new_cache


def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    nkv, hd = cfg.num_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((batch, max_len, nkv, hd), dtype),
        "v": jnp.zeros((batch, max_len, nkv, hd), dtype),
    }


def init_paged_attn_cache(cfg: ModelConfig, num_blocks: int, block_size: int,
                          dtype) -> dict:
    nkv, hd = cfg.num_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((num_blocks, block_size, nkv, hd), dtype),
        "v": jnp.zeros((num_blocks, block_size, nkv, hd), dtype),
    }


def init_cross_cache(cfg: ModelConfig, batch: int, num_frames: int, dtype) -> dict:
    nh, hd = cfg.num_heads, cfg.hd
    return {
        "ck": jnp.zeros((batch, num_frames, nh, hd), dtype),
        "cv": jnp.zeros((batch, num_frames, nh, hd), dtype),
    }
