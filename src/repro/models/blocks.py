"""Transformer block: sequence mixer + channel MLP, all families.

``memcom`` (when given) injects the paper's compression cross-attention
between the self-attention and MLP residual branches and captures
``omega`` — the per-layer compressed representation handed to the target.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.config import LayerDesc, ModelConfig
from repro.models.attention import (
    apply_attention,
    init_attention,
    init_attn_cache,
    init_cross_cache,
    init_paged_attn_cache,
)
from repro.models.layers import apply_mlp, apply_norm, init_mlp, init_norm
from repro.models.mamba2 import apply_mamba, init_mamba, init_mamba_cache
from repro.models.mla import apply_mla, init_mla, init_mla_cache, init_paged_mla_cache
from repro.models.moe import apply_moe, init_moe
from repro.models.param import ParamBuilder
from repro.models.xattn import apply_memcom_xattn


def init_block(b: ParamBuilder, cfg: ModelConfig, desc: LayerDesc) -> None:
    init_norm(b, cfg, "norm1")
    if desc.mixer == "attn":
        init_attention(b, cfg)
    elif desc.mixer == "mla":
        init_mla(b, cfg)
    elif desc.mixer == "mamba":
        init_mamba(b, cfg)
    else:
        raise ValueError(desc.mixer)
    if desc.cross_attn:
        init_norm(b, cfg, "norm_x")
        init_attention(b, cfg, name="xattn_enc")
    if desc.mlp != "none":
        init_norm(b, cfg, "norm2")
        if desc.mlp == "moe":
            init_moe(b, cfg)
        else:
            init_mlp(b, cfg)


def apply_block(
    p,
    cfg: ModelConfig,
    desc: LayerDesc,
    h,
    *,
    positions,
    mask_offset=0,
    prefix: Optional[dict] = None,
    cache: Optional[dict] = None,
    cache_index=None,
    decode: bool = False,
    block_tables=None,
    lane_valid=None,
    mesh=None,
    encoder_out=None,
    memcom: Optional[dict] = None,
    impl: str = "auto",
):
    """Returns (h, new_cache_or_None, aux{moe_loss, omega}).

    ``block_tables`` routes the attention/MLA cache entries through the
    paged block-pool layout; recurrent (conv/ssm) and cross-attention
    entries stay per-slot dense either way.

    ``lane_valid`` (fused serving step) masks ragged decode lanes in the
    attention/MLA cache writes.  Recurrent mixers cannot honour it (the
    SSM state would advance over garbage lanes regardless), which is why
    the engine gates the fused path to attention/MLA-only layouts.
    """
    aux = {"moe_loss": jnp.float32(0.0), "omega": None}
    new_cache = {} if cache is not None else None

    # ---- sequence mixer ----
    hn = apply_norm(p["norm1"], cfg, h)
    if desc.mixer == "attn":
        self_cache = None
        if cache is not None and "k" in cache:
            self_cache = {"k": cache["k"], "v": cache["v"]}
        o, c = apply_attention(
            p["attn"], cfg, hn, positions=positions, mask_offset=mask_offset,
            prefix=prefix, cache=self_cache, cache_index=cache_index,
            decode=decode, block_tables=block_tables, lane_valid=lane_valid,
            mesh=mesh, impl=impl)
        if c is not None:
            new_cache.update(c)
    elif desc.mixer == "mla":
        self_cache = None
        if cache is not None and "ckv" in cache:
            self_cache = {"ckv": cache["ckv"], "kr": cache["kr"]}
        o, c = apply_mla(
            p["attn"], cfg, hn, positions=positions, mask_offset=mask_offset,
            prefix=prefix, cache=self_cache, cache_index=cache_index,
            decode=decode, block_tables=block_tables, lane_valid=lane_valid,
            mesh=mesh, impl=impl)
        if c is not None:
            new_cache.update(c)
    else:  # mamba
        self_cache = None
        if cache is not None and "conv" in cache:
            self_cache = {"conv": cache["conv"], "ssm": cache["ssm"]}
        init_state = None
        if prefix is not None and "ssm" in prefix:
            init_state = prefix["ssm"]  # hybrid MemCom state handoff
        o, c = apply_mamba(p["mamba"], cfg, hn, cache=self_cache,
                           decode=decode, init_state=init_state, impl=impl)
        if c is not None:
            new_cache.update(c)
    h = h + o

    # ---- enc-dec cross-attention (whisper decoder) ----
    if desc.cross_attn:
        hx = apply_norm(p["norm_x"], cfg, h)
        cross_cache = None
        if cache is not None and "ck" in cache:
            cross_cache = {"ck": cache["ck"], "cv": cache["cv"]}
        o, c = apply_attention(p["xattn_enc"], cfg, hx, positions=positions,
                               kv_source=encoder_out, cache=cross_cache,
                               impl=impl)
        if c is not None:
            new_cache.update(c)
        h = h + o

    # ---- MemCom compression cross-attention (Memory-LLM only) ----
    if memcom is not None:
        h = h + apply_memcom_xattn(memcom["params"]["memx"], cfg, h,
                                   memcom["src"], impl=impl)
        aux["omega"] = h  # O^i — the layer's compressed representation

    # ---- channel MLP ----
    if desc.mlp != "none":
        hn = apply_norm(p["norm2"], cfg, h)
        if desc.mlp == "moe":
            o, moe_loss = apply_moe(p["moe"], cfg, hn, impl=impl)
            aux["moe_loss"] = moe_loss
        else:
            o = apply_mlp(p["mlp"], cfg, hn)
        h = h + o
    return h, new_cache, aux


def init_block_cache(cfg: ModelConfig, desc: LayerDesc, batch: int,
                     max_len: int, dtype) -> dict:
    if desc.mixer == "attn":
        c = init_attn_cache(cfg, batch, max_len, dtype)
    elif desc.mixer == "mla":
        c = init_mla_cache(cfg, batch, max_len, dtype)
    else:
        c = init_mamba_cache(cfg, batch, dtype)
    if desc.cross_attn:
        assert cfg.encoder is not None
        c.update(init_cross_cache(cfg, batch, cfg.encoder.num_frames, dtype))
    return c


def init_block_paged_cache(cfg: ModelConfig, desc: LayerDesc, num_blocks: int,
                           block_size: int, slots: int, dtype) -> dict:
    """Paged layout: attention/MLA KV pooled over ``num_blocks`` physical
    blocks (shared across slots via block tables); recurrent state and
    cross-attention KV stay per-slot (they are O(1) resp. fixed-size per
    slot — paging them buys nothing)."""
    if desc.mixer == "attn":
        c = init_paged_attn_cache(cfg, num_blocks, block_size, dtype)
    elif desc.mixer == "mla":
        c = init_paged_mla_cache(cfg, num_blocks, block_size, dtype)
    else:
        c = init_mamba_cache(cfg, slots, dtype)
    if desc.cross_attn:
        assert cfg.encoder is not None
        c.update(init_cross_cache(cfg, slots, cfg.encoder.num_frames, dtype))
    return c
