"""The model: embedding → (prefix blocks, scanned period blocks) → head.

One ``forward`` serves all three MemCom stacks:

* Source-LLM   — ``capture_hiddens=True`` → per-layer input reps H^i
* Memory-LLM   — ``memcom={"params": …, "src": …}`` → per-layer O^i
* Target-LLM   — ``prefix=…`` → attends to compressed per-layer context

Layer-wise quantities (params, caches, captured hiddens, prefixes, omegas)
all share the *Layerwise* layout::

    {"prefix": [per-layer, ...], "period": {"l0": stacked(repeats, ...), ...}}

so the three stacks (which are copies of the same architecture) can
exchange them directly, and the period part rides through ``jax.lax.scan``
as xs/ys with a leading ``repeats`` dim.

See docs/ARCHITECTURE.md for the layout's batch-axis conventions and the
per-layer O^i prefix formats each mixer family exchanges.
"""

from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.blocks import (
    apply_block,
    init_block,
    init_block_cache,
    init_block_paged_cache,
)
from repro.models.layers import (
    apply_mlp,
    apply_norm,
    init_mlp,
    init_norm,
    sinusoidal_pos_embed,
    softcap,
)
from repro.models.attention import apply_attention, init_attention
from repro.models.param import ParamBuilder
from repro.sharding.ctx import constrain
from repro.utils.rng import Keys


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, seed: int | Keys = 0, abstract: bool = False):
    params, _ = _build(cfg, seed, abstract)
    return params


def param_specs(cfg: ModelConfig):
    """Logical-axis tree matching init_params structure (abstract build)."""
    _, axes = _build(cfg, 0, abstract=True)
    return axes


def abstract_params(cfg: ModelConfig):
    params, _ = _build(cfg, 0, abstract=True)
    return params


def _build(cfg: ModelConfig, seed, abstract: bool):
    cfg.validate()
    keys = seed if isinstance(seed, Keys) else Keys(seed)
    dtype = jnp.dtype(cfg.dtype)
    b = ParamBuilder(keys, dtype, abstract)

    eb = b.child("embed")
    eb.make("tokens", (cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
            init="normal", scale=cfg.d_model**-0.5)
    if cfg.pos_embed == "learned":
        eb.make("pos", (cfg.max_seq, cfg.d_model), (None, "embed"),
                init="normal", scale=0.02)

    if cfg.encoder is not None:
        enc = b.child("encoder")
        pb = enc.child("period", stack=cfg.encoder.num_layers)
        lb = pb.child("l0")
        init_norm(lb, cfg, "norm1")
        init_attention(lb, cfg)
        init_norm(lb, cfg, "norm2")
        init_mlp(lb, cfg, d_ff=cfg.encoder.d_ff, mlp_type="gelu_mlp")
        init_norm(enc, cfg, "final_norm")

    for i, desc in enumerate(cfg.layout.prefix):
        init_block(b.child(f"prefix_{i}"), cfg, desc)
    if cfg.layout.repeats:
        pb = b.child("period", stack=cfg.layout.repeats)
        for j, desc in enumerate(cfg.layout.period):
            init_block(pb.child(f"l{j}"), cfg, desc)

    init_norm(b, cfg, "final_norm")
    if not cfg.tie_embeddings:
        b.make("lm_head", (cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    return b.build()


# ---------------------------------------------------------------------------
# Layerwise helpers
# ---------------------------------------------------------------------------


def _lw_prefix(lw, i):
    if lw is None:
        return None
    entry = lw.get("prefix")
    if entry is None:
        return None
    return entry[i]


def _lw_period(lw):
    if lw is None:
        return {}
    return lw.get("period") or {}


def layerwise(prefix_list, period_dict):
    out = {}
    if prefix_list:
        out["prefix"] = prefix_list
    if period_dict:
        out["period"] = period_dict
    return out


# ---------------------------------------------------------------------------
# Encoder (whisper stub frontend: precomputed frame embeddings)
# ---------------------------------------------------------------------------


def encode(enc_params, cfg: ModelConfig, frames, *, impl: str = "auto",
           unroll: bool = False):
    B, F, D = frames.shape
    h = frames + sinusoidal_pos_embed(F, D).astype(frames.dtype)[None]

    def body(h, lp):
        p = lp["l0"]
        hn = apply_norm(p["norm1"], cfg, h)
        o, _ = apply_attention(p["attn"], cfg, hn, positions=None,
                               kv_source=hn, impl=impl)
        h = h + o
        hn = apply_norm(p["norm2"], cfg, h)
        h = h + apply_mlp(p["mlp"], cfg, hn, mlp_type="gelu_mlp")
        return h, None

    h, _ = jax.lax.scan(body, h, enc_params["period"], unroll=True if unroll else 1)
    return apply_norm(enc_params["final_norm"], cfg, h)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def forward(
    params,
    cfg: ModelConfig,
    *,
    tokens=None,
    embeds=None,
    positions=None,
    mask_offset=0,
    prefix: Optional[dict] = None,  # Layerwise compressed context (MemCom)
    cache: Optional[dict] = None,  # Layerwise KV/state cache
    cache_index=None,
    decode: bool = False,
    block_tables=None,  # (B, nb) int32: paged-cache block tables
    lane_valid=None,  # (B,) int32: fused-step ragged-lane mask (decode)
    mesh=None,  # tensor-parallel serving mesh (reaches the decode kernels)

    capture_hiddens: bool = False,
    memcom: Optional[dict] = None,  # {"params": Layerwise, "src": Layerwise}
    encoder_frames=None,
    encoder_out=None,
    remat: bool = False,
    remat_policy: Optional[Any] = None,
    logits: bool = True,
    unroll: bool = False,  # unroll layer scans (dry-run cost extraction)
    impl: str = "auto",
):
    """Returns (logits_or_hidden, aux).

    aux keys: "cache" (Layerwise), "hiddens" (Layerwise, layer inputs H^i),
    "omega" (Layerwise, Memory-LLM compressed reps O^i), "moe_loss",
    "encoder_out".
    """
    if embeds is None:
        h = jnp.take(params["embed"]["tokens"], tokens, axis=0)
    else:
        h = embeds
    h = constrain(h)  # residual-stream sharding (repro.sharding.ctx)
    B, S = h.shape[0], h.shape[1]
    if cfg.embed_scale:
        h = h * jnp.asarray(math.sqrt(cfg.d_model), h.dtype)
    start = cache_index if (decode and cache_index is not None) else mask_offset
    per_slot = decode and cache_index is not None and jnp.ndim(cache_index) == 1
    if per_slot:
        # continuous batching: each slot decodes at its own length
        pos2d = cache_index[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
        if cfg.pos_embed == "learned":
            h = h + jnp.take(params["embed"]["pos"], pos2d, axis=0).astype(h.dtype)
        if positions is None:
            positions = pos2d
            if cfg.mrope_sections:
                positions = jnp.broadcast_to(positions, (3, B, S))
    else:
        if cfg.pos_embed == "learned":
            pe = jax.lax.dynamic_slice_in_dim(params["embed"]["pos"], start, S, axis=0)
            h = h + pe[None].astype(h.dtype)
        if positions is None:
            positions = jnp.broadcast_to(start + jnp.arange(S, dtype=jnp.int32), (B, S))
            if cfg.mrope_sections:
                positions = jnp.broadcast_to(positions, (3, B, S))

    if cfg.encoder is not None and encoder_frames is not None and encoder_out is None:
        encoder_out = encode(params["encoder"], cfg, encoder_frames, impl=impl,
                             unroll=unroll)

    aux_loss = jnp.float32(0.0)
    n_prefix = len(cfg.layout.prefix)
    caps_p, omegas_p, caches_p = [], [], []

    memx_params = memcom["params"] if memcom is not None else None
    memx_src = memcom["src"] if memcom is not None else None

    def one_block(p, desc, h, *, lpre, lcache, lmemx, lsrc):
        mem = None
        if lmemx is not None and desc.mixer in ("attn", "mla"):
            mem = {"params": lmemx, "src": lsrc}
        return apply_block(
            p, cfg, desc, h, positions=positions, mask_offset=mask_offset,
            prefix=lpre, cache=lcache, cache_index=cache_index, decode=decode,
            block_tables=block_tables, lane_valid=lane_valid, mesh=mesh,
            encoder_out=encoder_out, memcom=mem, impl=impl)

    for i, desc in enumerate(cfg.layout.prefix):
        if capture_hiddens:
            caps_p.append(h)
        fn = one_block
        if remat:
            fn = jax.checkpoint(one_block, policy=remat_policy,
                                static_argnums=(1,))
        h, c, a = fn(params[f"prefix_{i}"], desc, h,
                     lpre=_lw_prefix(prefix, i), lcache=_lw_prefix(cache, i),
                     lmemx=_lw_prefix(memx_params, i),
                     lsrc=_lw_prefix(memx_src, i))
        h = constrain(h)
        aux_loss = aux_loss + a["moe_loss"]
        if c is not None:
            caches_p.append(c)
        if a["omega"] is not None:
            omegas_p.append(a["omega"])

    period_caches, period_caps, period_omegas = {}, {}, {}
    if cfg.layout.repeats:
        xs = (
            params["period"],
            _lw_period(prefix),
            _lw_period(cache),
            _lw_period(memx_params),
            _lw_period(memx_src),
        )

        def body(carry, xs):
            h, aux = carry
            lp, lpre, lcache, lmemx, lsrc = xs
            new_caches, caps, omegas = {}, {}, {}
            for j, desc in enumerate(cfg.layout.period):
                key = f"l{j}"
                if capture_hiddens:
                    caps[key] = h
                h, c, a = one_block(
                    lp[key], desc, h,
                    lpre=lpre.get(key) if lpre else None,
                    lcache=lcache.get(key) if lcache else None,
                    lmemx=lmemx.get(key) if lmemx else None,
                    lsrc=lsrc.get(key) if lsrc else None)
                h = constrain(h)
                aux = aux + a["moe_loss"]
                if c is not None:
                    new_caches[key] = c
                if a["omega"] is not None:
                    omegas[key] = a["omega"]
            return (h, aux), (new_caches, caps, omegas)

        scan_body = jax.checkpoint(body, policy=remat_policy) if remat else body
        (h, aux_loss), (period_caches, period_caps, period_omegas) = jax.lax.scan(
            scan_body, (h, aux_loss), xs, unroll=True if unroll else 1)

    hn = apply_norm(params["final_norm"], cfg, h)
    out = hn
    if logits:
        if cfg.tie_embeddings:
            out = hn @ params["embed"]["tokens"].T
        else:
            out = hn @ params["lm_head"]
        out = softcap(out, cfg.final_logit_softcap)

    aux = {
        "moe_loss": aux_loss,
        "cache": layerwise(caches_p, period_caches) if cache is not None else None,
        "hiddens": layerwise(caps_p, period_caps) if capture_hiddens else None,
        "omega": layerwise(omegas_p, period_omegas) if memcom is not None else None,
        "encoder_out": encoder_out,
    }
    return out, aux


# ---------------------------------------------------------------------------
# Cache init
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    prefix = [
        init_block_cache(cfg, desc, batch, max_len, dtype)
        for desc in cfg.layout.prefix
    ]
    period = {}
    if cfg.layout.repeats:
        for j, desc in enumerate(cfg.layout.period):
            one = init_block_cache(cfg, desc, batch, max_len, dtype)
            period[f"l{j}"] = jax.tree.map(
                lambda x: jnp.zeros((cfg.layout.repeats,) + x.shape, x.dtype), one)
    return layerwise(prefix, period)


def init_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int,
                     slots: int, dtype=None):
    """Block-pool KV cache: attention/MLA leaves are a single
    ``(num_blocks, block_size, ...)`` physical pool per layer (period
    section stacks a pool per repeat on the leading axis, as always),
    addressed through per-slot block tables; recurrent conv/ssm and
    cross-attention leaves keep the per-slot ``(slots, ...)`` layout."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    prefix = [
        init_block_paged_cache(cfg, desc, num_blocks, block_size, slots, dtype)
        for desc in cfg.layout.prefix
    ]
    period = {}
    if cfg.layout.repeats:
        for j, desc in enumerate(cfg.layout.period):
            one = init_block_paged_cache(cfg, desc, num_blocks, block_size,
                                         slots, dtype)
            period[f"l{j}"] = jax.tree.map(
                lambda x: jnp.zeros((cfg.layout.repeats,) + x.shape, x.dtype), one)
    return layerwise(prefix, period)
