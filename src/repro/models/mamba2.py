"""Mamba2 (SSD — state-space duality) mixer.

Layer: in_proj -> [z | xBC | dt]; causal depthwise conv over xBC; SSD over
heads; gated RMSNorm; out_proj.  Prefill returns (conv_state, ssm_state)
for the serving cache; decode performs the O(1) recurrent update.

The SSM state is also what the hybrid (Jamba) MemCom adaptation hands off:
a fixed-size, exact summary of the source context (DESIGN.md §4).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.kernels import ops
from repro.models.param import ParamBuilder


def _dims(cfg: ModelConfig):
    mb = cfg.mamba
    d = cfg.d_model
    di = mb.d_inner(d)
    nh = mb.nheads(d)
    conv_dim = di + 2 * mb.ngroups * mb.d_state
    return mb, d, di, nh, conv_dim


def init_mamba(b: ParamBuilder, cfg: ModelConfig) -> None:
    mb, d, di, nh, conv_dim = _dims(cfg)
    m = b.child("mamba")
    m.make("in_proj", (d, 2 * di + 2 * mb.ngroups * mb.d_state + nh),
           ("embed", "mamba_inner"))
    m.make("conv_w", (mb.conv_width, conv_dim), (None, "mamba_inner"),
           init="normal", scale=mb.conv_width**-0.5)
    m.make("conv_b", (conv_dim,), ("mamba_inner",), init="zeros")
    m.make("A_log", (nh,), ("mamba_heads",), init="uniform", dtype=jnp.float32)
    m.make("dt_bias", (nh,), ("mamba_heads",), init="zeros", dtype=jnp.float32)
    m.make("D", (nh,), ("mamba_heads",), init="ones", dtype=jnp.float32)
    m.make("norm", (di,), ("mamba_inner",), init="ones")
    m.make("out_proj", (di, d), ("mamba_inner", "embed"))


def _split_proj(cfg: ModelConfig, proj):
    mb, _, di, nh, _ = _dims(cfg)
    gn = mb.ngroups * mb.d_state
    return jnp.split(proj, [di, 2 * di, 2 * di + gn, 2 * di + 2 * gn], axis=-1)


def _gated_norm(y, z, scale, eps):
    g = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    out = g * jax.lax.rsqrt((g**2).mean(-1, keepdims=True) + eps)
    return (out * scale.astype(jnp.float32)).astype(y.dtype)


def apply_mamba(
    p,
    cfg: ModelConfig,
    x,
    *,
    cache: Optional[dict] = None,
    decode: bool = False,
    init_state=None,
    impl: str = "auto",
):
    """Returns (out (B,S,D), new_cache_or_None).

    cache = {"conv": (B, W-1, conv_dim), "ssm": (B, H, P, N) fp32}.
    ``init_state`` lets the hybrid MemCom adaptation seed the recurrence
    with the source context's final state.
    """
    mb, d, di, nh, conv_dim = _dims(cfg)
    B, S, _ = x.shape
    W = mb.conv_width

    proj = x @ p["in_proj"]
    z, xr, Bm_r, Cm_r, dt_r = _split_proj(cfg, proj)
    xbc = jnp.concatenate([xr, Bm_r, Cm_r], axis=-1)  # (B,S,conv_dim)

    if decode:
        assert cache is not None and S == 1
        window = jnp.concatenate([cache["conv"], xbc], axis=1)  # (B,W,conv)
        conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                              p["conv_w"].astype(jnp.float32))
        conv_out = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32))[:, None, :]
        new_conv = window[:, 1:, :]
    else:
        if cache is not None:
            # chained prefill: the conv window continues from the cached
            # last W-1 raw inputs (zeros on the first segment)
            padded = jnp.concatenate(
                [cache["conv"].astype(xbc.dtype), xbc], axis=1)
        else:
            padded = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
        # causal depthwise conv as a sum of W shifted copies (cheap, fused)
        conv_out = sum(
            padded[:, i : i + S, :].astype(jnp.float32) * p["conv_w"][i].astype(jnp.float32)
            for i in range(W)
        )
        conv_out = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32))
        new_conv = padded[:, S : S + W - 1, :]  # last W-1 raw inputs

    conv_out = conv_out.astype(x.dtype)
    xs, Bm, Cm = jnp.split(conv_out, [di, di + mb.ngroups * mb.d_state], axis=-1)
    dt = jax.nn.softplus(dt_r.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])  # (H,)

    xh = xs.reshape(B, S, nh, mb.headdim)
    Bg = Bm.reshape(B, S, mb.ngroups, mb.d_state)
    Cg = Cm.reshape(B, S, mb.ngroups, mb.d_state)

    state0 = init_state
    if state0 is None and cache is not None:
        state0 = cache["ssm"]  # decode step or chained prefill
    if decode:
        y1, new_ssm = ops.ssd_decode_step(
            state0, xh[:, 0], dt[:, 0], A, Bg[:, 0], Cg[:, 0])
        y = y1[:, None]
    else:
        y, new_ssm = ops.ssd(xh, dt, A, Bg, Cg, init_state=state0,
                             chunk=mb.chunk_size, impl=impl)
    y = y + xh * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B, S, di)
    y = _gated_norm(y, z, p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"]

    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "ssm": new_ssm.astype(cache["ssm"].dtype)}
    return out, new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    mb, d, di, nh, conv_dim = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, mb.conv_width - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, nh, mb.headdim, mb.d_state), jnp.float32),
    }
