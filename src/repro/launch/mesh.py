"""Production mesh factory.

Single pod: 16×16 = 256 chips (data, model).
Multi-pod:  2×16×16 = 512 chips (pod, data, model) — the "pod" axis is
data-parallel by default and becomes the pipeline axis when pipeline
parallelism is enabled.

A FUNCTION, not a module constant: importing this module never touches
jax device state (device count is locked at first jax init, so the
dry-run driver must set XLA_FLAGS before any jax import — see dryrun.py).
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def _make_mesh(shape, axes):
    """jax.make_mesh across jax versions: ``axis_types`` (and
    ``jax.sharding.AxisType`` itself) only exist on newer releases."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(model: int = 1, data: int | None = None):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    data = data or (n // model)
    return _make_mesh((data, model), ("data", "model"))


def make_serving_mesh(model: int = 1, data: int = 1):
    """A (data, model) mesh over the *first* ``data * model`` devices —
    unlike :func:`make_host_mesh` it does not insist on consuming every
    device, so a serving engine can run a 2-way model mesh on an 8-device
    CI host (the spare devices stay idle).  ``model == data == 1`` still
    returns a real one-device mesh so the mesh-aware code path is
    exercised uniformly."""
    need = data * model
    devices = jax.devices()
    if need > len(devices):
        raise ValueError(
            f"serving mesh {data}x{model} needs {need} devices, have "
            f"{len(devices)} — set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need} before the "
            "first jax import (launch/serve.py --mesh does this for you)")
    return Mesh(np.asarray(devices[:need]).reshape(data, model),
                ("data", "model"))
