"""Production mesh factory.

Single pod: 16×16 = 256 chips (data, model).
Multi-pod:  2×16×16 = 512 chips (pod, data, model) — the "pod" axis is
data-parallel by default and becomes the pipeline axis when pipeline
parallelism is enabled.

A FUNCTION, not a module constant: importing this module never touches
jax device state (device count is locked at first jax init, so the
dry-run driver must set XLA_FLAGS before any jax import — see dryrun.py).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1, data: int | None = None):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    data = data or (n // model)
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
