import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import (see dryrun.py).
"""Perf hillclimb driver (§Perf): lower+compile named variants of a cell
and record the three roofline terms per variant, so each
hypothesis → change → measure → validate cycle is one CLI invocation.

    python -m repro.launch.perf --cell jamba_train --variant baseline
    python -m repro.launch.perf --cell jamba_train --variant moe_grouped

Variants are explicit, named configurations (not flags scattered over
runs) so EXPERIMENTS.md §Perf can point at exactly what changed.
"""

import argparse
import dataclasses
import gc
import json
import pathlib
import time  # reprolint: ignore-file[wall-clock] -- a perf harness times the real host clock by design

import jax

from repro.config import LayerLayout
from repro.launch import costs
from repro.launch.dryrun import _mem_dict, _reduced, lower_and_compile
from repro.launch.hlo_stats import collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.sharding.rules import BASELINE_RULES, FSDP_RULES

CHIPS = 256
PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


# --- the three hillclimb cells and their variants --------------------------
# each variant: kwargs for lower_and_compile (+ optional cost override)

CELLS = {
    # 1. most collective-bound + worst-fitting: MoE-hybrid 398B training
    "jamba_train": dict(
        arch="jamba-1.5-large-398b", shape="train_4k",
        variants={
            # pre-fix posture (what the baseline sweep measured):
            # expert weights FSDP-sharded on d_model, global-sort dispatch
            "baseline": dict(rules_name="fsdp_ep_embed"),
            # H1 (REFUTED): group-local sort alone — the token stream was
            # already scrambled across (data, model) by the residual
            # sharding, so grouping didn't localize anything
            "moe_grouped": dict(rules_name="fsdp_ep_embed", moe_groups=16),
            # H2 (REFUTED): batch-only residual sharding — −6 % only;
            # the dominant term was the expert-matmul partial sums
            "moe_grouped_bs": dict(rules_name="fsdp_ep_embed",
                                   moe_groups=16, act_seq=False),
            # H3: EP-only expert weights + explicit batch-local token
            # reshard inside the MoE layer (ctx.moe_dispatch_plan) —
            # the shipped default
            "moe_ep_local": {},
        }),
    # 2. the paper's own workload: compression (prefill) at 32k
    "deepseek_compress": dict(
        arch="deepseek-v2-236b", shape="prefill_32k",
        variants={
            "baseline": dict(rules_name="fsdp_ep_embed"),
            "moe_ep_local": {},
        }),
    # 3. memory-bound serving: 32k decode — the cost MemCom removes
    "nemo_decode": dict(
        arch="mistral-nemo-12b", shape="decode_32k",
        variants={
            "baseline": {},
            # the paper's technique as deployed: m-slot compressed cache
            "compressed_cache": dict(objective="decode_compressed",
                                     decode_window=256),
            # H: after the cache shrink the collective term (weight
            # all-gathers from ZeRO-3) dominates — serve TP-resident
            # (BASELINE_RULES keeps weights sharded only on "model",
            # resident across steps: 24 GB/16 = 1.5 GB/chip fits)
            "baseline_tp": dict(rules_name="baseline"),
            "compressed_tp": dict(objective="decode_compressed",
                                  decode_window=256,
                                  rules_name="baseline"),
        }),
}

from repro.sharding.rules import FSDP_EP_EMBED_RULES  # noqa: E402

RULES = {"fsdp": FSDP_RULES, "baseline": BASELINE_RULES,
         "fsdp_ep_embed": FSDP_EP_EMBED_RULES}


def measure(arch, shape_name, *, extrapolate=True, **kw):
    if "rules_name" in kw:
        kw["rules"] = RULES[kw.pop("rules_name")]
    mesh = make_production_mesh(multi_pod=False)
    t0 = time.monotonic()
    cell, lowered, compiled, timing = lower_and_compile(
        arch, shape_name, mesh, **kw)
    rec = {
        "memory": _mem_dict(compiled),
        "collectives_full": collective_bytes(compiled.as_text()),
        "xla_cost": {k: float(v)
                     for k, v in (compiled.cost_analysis() or {}).items()
                     if isinstance(v, (int, float))
                     and k in ("flops", "bytes accessed")},
        "compile_s": round(time.monotonic() - t0, 1),
    }
    cfg = cell["cfg"]
    if extrapolate and cfg.layout.repeats > 2:
        per_r = {}
        for r in (1, 2):
            kw2 = dict(kw)
            kw2["cfg_override"] = _reduced(cfg, r)
            _, _, comp_r, _ = lower_and_compile(arch, shape_name, mesh, **kw2)
            per_r[r] = collective_bytes(comp_r.as_text())["total"]
            del comp_r
            gc.collect()
        slope = per_r[2] - per_r[1]
        total = (max(per_r[1] - slope, 0.0)
                 + max(slope, 0.0) * cfg.layout.repeats)
        rec["collectives"] = {
            "total": max(total, rec["collectives_full"]["total"]),
            "per_layer_period": slope,
            "method": "repeats-1/2 extrapolation",
        }
    else:
        rec["collectives"] = {"total": rec["collectives_full"]["total"],
                              "method": "direct"}

    obj = cell["objective"]
    cost_kind = {"memcom_train": "memcom_train", "lm_train": "lm_train",
                 "compress": "prefill", "prefill": "prefill",
                 "decode": "decode", "decode_compressed": "decode"}[obj]
    shape = cell["shape"]
    if obj == "decode_compressed":
        # analytic decode cost with the compressed cache length
        L = cfg.memcom.num_memory_tokens + kw.get("decode_window", 256)
        shape = dataclasses.replace(shape, seq_len=L)
    cc = costs.cell_cost(cfg, shape, cost_kind)
    rec["analytic"] = {"flops": cc.flops, "hbm_bytes": cc.hbm_bytes,
                       "model_flops": cc.model_flops}
    rec["terms"] = {
        "compute_s": cc.flops / (CHIPS * PEAK_FLOPS),
        "memory_s": cc.hbm_bytes / (CHIPS * HBM_BW),
        "collective_s": rec["collectives"]["total"] / LINK_BW,
    }
    rec["objective"] = obj
    del compiled, lowered
    gc.collect()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=sorted(CELLS), required=True)
    ap.add_argument("--variant", default=None)
    ap.add_argument("--no-extrapolate", action="store_true")
    ap.add_argument("--out", default="artifacts/perf")
    args = ap.parse_args()

    spec = CELLS[args.cell]
    variants = ([args.variant] if args.variant
                else list(spec["variants"]))
    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    for name in variants:
        kw = dict(spec["variants"][name])
        path = out_dir / f"{args.cell}__{name}.json"
        if path.exists():
            print(f"[skip existing] {path.name}")
            continue
        print(f"== {args.cell} / {name} …", flush=True)
        try:
            rec = measure(spec["arch"], spec["shape"],
                          extrapolate=not args.no_extrapolate, **kw)
            rec.update(cell=args.cell, variant=name, arch=spec["arch"],
                       shape=spec["shape"])
            path.write_text(json.dumps(rec, indent=1))
            t = rec["terms"]
            print(f"   compute {t['compute_s']*1e3:.1f}ms | "
                  f"memory {t['memory_s']*1e3:.1f}ms | "
                  f"collective {t['collective_s']*1e3:.1f}ms | "
                  f"temp/dev {rec['memory'].get('temp_size_in_bytes', 0)/1e9:.1f}GB")
        except Exception as e:  # noqa: BLE001
            print(f"   ERROR: {type(e).__name__}: {e}")
            path.write_text(json.dumps(
                {"cell": args.cell, "variant": name, "status": "error",
                 "error": str(e)}, indent=1))


if __name__ == "__main__":
    main()
