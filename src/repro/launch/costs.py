"""Analytic FLOP / HBM-byte model for the roofline (EXPERIMENTS.md §Roofline).

Why analytic: XLA's ``compiled.cost_analysis()`` counts each scan (while
loop) body ONCE, not × trip count (verified empirically), so any scanned-
layer model is undercounted by ~num_layers.  Every matmul in this
framework is known in closed form, so we account FLOPs/bytes analytically
and keep the XLA numbers in the artifacts as a secondary reference.

Conventions
-----------
* FLOPs are GLOBAL (whole step, all chips); the roofline divides by chips.
* A matmul (m×k)·(k×n) costs 2mkn.
* Backward-pass multipliers: trainable stack ×3 (fwd + dL/dx + dL/dW),
  frozen-but-backpropagated stack ×2 (fwd + dL/dx — the Target-LLM in
  MemCom training: activations carry gradients to the compressed prefix
  but no weight grads are formed), frozen forward-only ×1.
* HBM bytes are a structural estimate: weight traffic × passes, optimizer
  traffic for trainable params, activation traffic ~ C·tokens·d per layer,
  KV-cache traffic for decode.  Coarser than FLOPs but the decode cells it
  classifies as memory-bound are unambiguous (arith intensity < 10).
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.config import LayerDesc, ModelConfig, ShapeSpec

BF16 = 2


@dataclass
class CellCost:
    flops: float  # global
    hbm_bytes: float  # global
    model_flops: float  # 6·N_active·tokens (the "useful" reference)
    detail: dict


# ---------------------------------------------------------------------------
# per-block FLOPs for processing n_q tokens attending to avg ctx tokens
# ---------------------------------------------------------------------------


def _attn_flops(cfg: ModelConfig, n_q: float, ctx: float, cross: bool = False) -> float:
    d, nh, nkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    proj = 2 * d * nh * hd + 2 * 2 * d * nkv * hd + 2 * nh * hd * d
    attn = 4 * ctx * nh * hd  # scores + AV
    total = n_q * (proj + attn)
    if cross:
        total *= 2  # whisper decoder has self + cross modules
    return total


def _mla_flops(cfg: ModelConfig, n_q: float, ctx: float, decode: bool) -> float:
    m = cfg.mla
    d, nh = cfg.d_model, cfg.num_heads
    q_proj = 2 * d * m.q_lora_rank + 2 * m.q_lora_rank * nh * m.qk_head_dim
    latent = 2 * d * (m.kv_lora_rank + m.qk_rope_head_dim)
    if decode:  # absorbed: attention runs in latent space
        absorb = 2 * nh * m.qk_nope_head_dim * m.kv_lora_rank * 2  # q fold + out
        attn = 2 * ctx * nh * (m.kv_lora_rank + m.qk_rope_head_dim) \
            + 2 * ctx * nh * m.kv_lora_rank
        out = 2 * nh * m.v_head_dim * d
        return n_q * (q_proj + latent + absorb + attn + out)
    expand = 2 * m.kv_lora_rank * nh * (m.qk_nope_head_dim + m.v_head_dim)
    attn = 2 * ctx * nh * m.qk_head_dim + 2 * ctx * nh * m.v_head_dim
    out = 2 * nh * m.v_head_dim * d
    return n_q * (q_proj + latent + expand + attn + out)


def _mamba_flops(cfg: ModelConfig, n_q: float, decode: bool) -> float:
    mb = cfg.mamba
    d = cfg.d_model
    di, N, P = mb.d_inner(d), mb.d_state, mb.headdim
    nh, g = mb.nheads(d), mb.ngroups
    proj = 2 * d * (2 * di + 2 * g * N + nh) + 2 * di * d
    conv = 2 * mb.conv_width * (di + 2 * g * N)
    if decode:
        ssd = nh * 4 * N * P
    else:
        Q = mb.chunk_size
        ssd = nh * (2 * Q * N + 2 * Q * P + 4 * N * P)
    return n_q * (proj + conv + ssd)


def _mlp_flops(cfg: ModelConfig, desc: LayerDesc, n_q: float) -> float:
    d = cfg.d_model
    if desc.mlp == "none":
        return 0.0
    if desc.mlp == "moe":
        m = cfg.moe
        router = 2 * d * m.num_experts
        experts = 6 * m.capacity_factor * m.top_k * d * m.expert_d_ff
        shared = 6 * d * m.num_shared_experts * m.shared_ff()
        return n_q * (router + experts + shared)
    per = 4 * d * cfg.d_ff if cfg.mlp_type == "gelu_mlp" else 6 * d * cfg.d_ff
    return n_q * per


def _block_flops(cfg, desc, n_q, ctx, decode=False) -> float:
    if desc.mixer == "attn":
        f = _attn_flops(cfg, n_q, ctx, cross=desc.cross_attn)
    elif desc.mixer == "mla":
        f = _mla_flops(cfg, n_q, ctx, decode)
    else:
        f = _mamba_flops(cfg, n_q, decode)
    return f + _mlp_flops(cfg, desc, n_q)


def _stack_flops(cfg: ModelConfig, n_q: float, ctx_self: float,
                 extra_ctx: float = 0.0, decode: bool = False) -> float:
    """All blocks; ctx per attn layer = ctx_self + extra_ctx (prefix)."""
    total = 0.0
    for desc in cfg.layout.descriptors():
        ctx = (ctx_self + extra_ctx) if desc.mixer in ("attn", "mla") else 0.0
        total += _block_flops(cfg, desc, n_q, ctx, decode)
    return total


def _encoder_flops(cfg: ModelConfig, batch: float) -> float:
    if cfg.encoder is None:
        return 0.0
    e = cfg.encoder
    n = batch * e.num_frames
    per = (2 * 4 * cfg.d_model * cfg.d_model  # qkvo
           + 4 * e.num_frames * e.num_heads * (cfg.d_model // e.num_heads)
           + 4 * cfg.d_model * e.d_ff)
    return n * per * e.num_layers


def _xattn_flops(cfg: ModelConfig, n_mem: float, n_src: float) -> float:
    """MemCom compression cross-attention, per layer with a module."""
    d = cfg.d_model
    n_layers = sum(1 for de in cfg.layout.descriptors()
                   if de.mixer in ("attn", "mla"))
    per_layer = (2 * n_mem * d * d  # wq
                 + 2 * 2 * n_src * d * d  # wk, wv over source reps
                 + 2 * n_mem * n_src * d * 2  # scores + AV
                 + 2 * n_mem * d * d)  # wo
    return n_layers * per_layer


def _logits_flops(cfg: ModelConfig, n_q: float) -> float:
    return 2 * n_q * cfg.d_model * cfg.vocab_size


# ---------------------------------------------------------------------------
# HBM byte estimates (global)
# ---------------------------------------------------------------------------


def _param_bytes(cfg: ModelConfig) -> float:
    return cfg.param_count() * BF16


def _active_param_bytes(cfg: ModelConfig) -> float:
    return cfg.active_param_count() * BF16


def _act_bytes(cfg: ModelConfig, tokens: float, passes: float) -> float:
    # residual stream + a few intermediates per layer, read+write
    C = 6.0
    return tokens * cfg.d_model * cfg.num_layers * BF16 * C * passes


def _kv_bytes_per_token(cfg: ModelConfig) -> float:
    per = 0.0
    for desc in cfg.layout.descriptors():
        if desc.mixer == "attn":
            per += 2 * cfg.num_kv_heads * cfg.hd * BF16
        elif desc.mixer == "mla":
            per += (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim) * BF16
    return per


def _state_bytes(cfg: ModelConfig, batch: float) -> float:
    if cfg.mamba is None:
        return 0.0
    mb = cfg.mamba
    n_mamba = sum(1 for d in cfg.layout.descriptors() if d.mixer == "mamba")
    per = mb.nheads(cfg.d_model) * mb.headdim * mb.d_state * 4
    return batch * n_mamba * per


# ---------------------------------------------------------------------------
# Cell-level costs
# ---------------------------------------------------------------------------


def train_split(shape: ShapeSpec) -> tuple[int, int]:
    """source/target split for MemCom training (paper: ~75/25)."""
    t = int(shape.seq_len * 0.75)
    return t, shape.seq_len - t


def memcom_train_cost(cfg: ModelConfig, shape: ShapeSpec, phase: int = 2) -> CellCost:
    B = shape.global_batch
    T, S = train_split(shape)
    mtok = cfg.memcom.num_memory_tokens

    src_mult = 3.0 if phase == 2 else 1.0  # phase-1: forward-only source
    memstack_mult = 3.0 if phase == 2 else 2.0  # phase-1: grads to mem_tokens
    f_src = src_mult * (B * _stack_flops(cfg, T, T / 2) + _encoder_flops(cfg, B))
    f_mem = memstack_mult * B * _stack_flops(cfg, mtok, mtok / 2)
    f_x = 3.0 * B * _xattn_flops(cfg, mtok, T)
    f_tgt = 2.0 * B * (_stack_flops(cfg, S, S / 2, extra_ctx=mtok)
                       + _logits_flops(cfg, S))
    flops = f_src + f_mem + f_x + f_tgt

    tokens = B * (T + S + mtok)
    trainable = (2 * cfg.param_count() if phase == 2
                 else cfg.memcom.num_memory_tokens * cfg.d_model
                 + 4 * cfg.d_model**2 * cfg.num_layers)
    weights = 3 * _param_bytes(cfg)  # three stacks read (fwd)
    weights += 2 * _param_bytes(cfg)  # bwd re-reads (source+memory or target)
    opt = trainable * (BF16 + 4 * 3 * 2)  # grads + adam mu/nu/master r+w
    hbm = weights + opt + _act_bytes(cfg, tokens, passes=2.0) \
        + 2 * B * S * cfg.vocab_size * BF16
    model_flops = 6 * cfg.active_param_count() * B * shape.seq_len
    return CellCost(flops, hbm, model_flops, {
        "source": f_src, "memory": f_mem, "xattn": f_x, "target": f_tgt,
        "split": (T, S), "phase": phase})


def lm_train_cost(cfg: ModelConfig, shape: ShapeSpec) -> CellCost:
    B, S = shape.global_batch, shape.seq_len
    flops = 3.0 * B * (_stack_flops(cfg, S, S / 2) + _logits_flops(cfg, S)
                       ) + 3.0 * _encoder_flops(cfg, B)
    hbm = (3 * _param_bytes(cfg)
           + cfg.param_count() * (BF16 + 4 * 3 * 2)
           + _act_bytes(cfg, B * S, passes=2.0)
           + 2 * B * S * cfg.vocab_size * BF16)
    model_flops = 6 * cfg.active_param_count() * B * S
    return CellCost(flops, hbm, model_flops, {})


def prefill_cost(cfg: ModelConfig, shape: ShapeSpec) -> CellCost:
    B, S = shape.global_batch, shape.seq_len
    flops = B * (_stack_flops(cfg, S, S / 2) + _logits_flops(cfg, 1)
                 ) + _encoder_flops(cfg, B)
    hbm = (_param_bytes(cfg) + _act_bytes(cfg, B * S, passes=1.0)
           + B * S * _kv_bytes_per_token(cfg))  # cache write
    model_flops = 2 * cfg.active_param_count() * B * S
    return CellCost(flops, hbm, model_flops, {})


def decode_cost(cfg: ModelConfig, shape: ShapeSpec) -> CellCost:
    B, L = shape.global_batch, shape.seq_len
    flops = B * (_stack_flops(cfg, 1, L, decode=True) + _logits_flops(cfg, 1))
    hbm = (_active_param_bytes(cfg)  # every weight read once per step
           + B * L * _kv_bytes_per_token(cfg)  # cache read
           + _state_bytes(cfg, B)
           + B * cfg.vocab_size * BF16)
    model_flops = 2 * cfg.active_param_count() * B
    return CellCost(flops, hbm, model_flops, {})


def cell_cost(cfg: ModelConfig, shape: ShapeSpec, objective: str) -> CellCost:
    if objective == "memcom_train":
        return memcom_train_cost(cfg, shape)
    if objective == "lm_train":
        return lm_train_cost(cfg, shape)
    if objective == "prefill":
        return prefill_cost(cfg, shape)
    if objective == "decode":
        return decode_cost(cfg, shape)
    raise ValueError(objective)
