"""Production serving launcher: offline compression + compressed-cache
serving behind one CLI (the paper's cloud-edge deployment, §1).

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
        --requests 4 --max-new 8

Stages:
  1. "cloud": load/initialize the compressor, compress the many-shot
     context once, materialize the per-layer compressed KV through the
     frozen target projections.
  2. "edge": a ServingEngine seats the compressed cache and serves
     batched generate/classify requests against m slots per layer.

On a fleet the same entry point runs with the production mesh and
sharded weights (launch/steps.py `compress` + `decode` objectives are
the dry-run-proven lowerings of stages 1 and 2).
"""

from __future__ import annotations

import argparse
import json
import time

import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core import memcom
from repro.data import (ICLTaskSpec, SyntheticVocab, build_manyshot_prompt,
                        make_episode, make_query)
from repro.models import transformer as tfm
from repro.serving.engine import ServingEngine, materialize_prefix
from repro.utils.pytree import tree_bytes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--context-tokens", type=int, default=96)
    ap.add_argument("--classify", action="store_true",
                    help="serve ICL label queries instead of generation")
    ap.add_argument("--metrics", default=None)
    args = ap.parse_args()

    vocab = SyntheticVocab()
    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch)).replace(vocab_size=vocab.size)
    if cfg.memcom is None:
        raise SystemExit(f"{args.arch}: attention-free — serve with the "
                         "native SSM state snapshot (see DESIGN.md §4)")
    m = cfg.memcom.num_memory_tokens

    print(f"[cloud] target {cfg.name} ({cfg.param_count()/1e6:.1f}M), "
          f"m={m} memory tokens")
    target = tfm.init_params(cfg, 0)
    compressor = memcom.init_memcom(cfg, target, 1)

    rng = np.random.default_rng(0)
    task = ICLTaskSpec(vocab, num_labels=8, keys_per_label=4)
    episode = make_episode(task, rng)
    prompt = build_manyshot_prompt(task, episode, rng,
                                   budget=args.context_tokens)
    t0 = time.perf_counter()
    prefix, _ = memcom.compress(compressor, cfg, jnp.asarray(prompt[None]))
    kv = materialize_prefix(target, cfg, prefix)
    t_compress = time.perf_counter() - t0
    print(f"[cloud] compressed {len(prompt)} tokens -> {m} slots/layer "
          f"in {t_compress:.2f}s; payload {tree_bytes(kv)/1e3:.1f} KB")

    engine = ServingEngine(cfg, target, slots=args.requests,
                           max_len=m + args.max_new + 16)
    engine.seat_compressed(kv)
    metrics = {"arch": cfg.name, "m": m, "context_tokens": len(prompt),
               "compress_s": t_compress, "payload_bytes": tree_bytes(kv)}

    if args.classify:
        hits = 0
        t0 = time.perf_counter()
        for _ in range(args.requests):
            q, label = make_query(task, episode, prompt, rng)
            pred = engine.score_labels(np.empty((0,), np.int32), q,
                                       vocab.label_ids())
            hits += int(pred - vocab.label_base == label)
        dt = time.perf_counter() - t0
        print(f"[edge] {args.requests} label queries in {dt:.2f}s "
              f"({hits}/{args.requests} correct — untrained compressor "
              f"unless loaded from a checkpoint)")
        metrics.update(queries=args.requests, correct=hits,
                       serve_s=dt)
    else:
        prompts = rng.integers(4, vocab.size, (args.requests, 8)).astype(
            np.int32)
        t0 = time.perf_counter()
        out = engine.generate(prompts, max_new=args.max_new)
        dt = time.perf_counter() - t0
        tok_s = args.requests * out.shape[1] / dt
        print(f"[edge] generated {out.shape} in {dt:.2f}s "
              f"({tok_s:.1f} tok/s, attending to {m} slots/layer)")
        metrics.update(generated=int(out.size), serve_s=dt,
                       tokens_per_s=tok_s)

    if args.metrics:
        with open(args.metrics, "w") as f:
            json.dump(metrics, f, indent=1)
        print(f"metrics -> {args.metrics}")


if __name__ == "__main__":
    main()
