"""Production serving launcher: offline compression + continuous-batching
compressed-cache serving behind one CLI (the paper's cloud-edge
deployment, §1).

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
        --requests 6 --tasks 2 --slots 4 --max-new 8

Stages:
  1. "cloud": load/initialize the compressor, compress each ICL task's
     many-shot context once, materialize the per-layer compressed KV
     through the frozen target projections, and register it in the
     engine's PrefixStore.
  2. "edge": a continuous-batching ServingEngine seats each request's
     compressed task memory in its own slot and serves ragged
     generate/classify traffic — more requests than slots is fine,
     finished slots refill mid-decode.

``--raw-shots`` removes stage 1 from the critical path: requests carry
their raw many-shot context and the engine's online PrefixCompiler
compresses each unseen task *inside* the serving loop — in
``--compile-budget``-token chunks interleaved with decode steps, so
already-seated slots keep emitting tokens while a cold task compiles
(single-flight: concurrent requests for one task share one compile).
``--stats`` prints the engine's cache/compile counters either way.

``--host-capacity``/``--disk-dir`` put a memory hierarchy behind the
HBM prefix store (``--prefix-capacity`` bounds HBM residency): evicted
compressed prefixes demote to pinned host RAM, spill to
codec-compressed disk shards under host pressure, and promote back
host→HBM in ``--promote-budget``-chunk steps interleaved with decode
when a request names them again.  Combined with ``--raw-shots``
(content-addressed prefix names) a restart pointing ``--disk-dir`` at a
previous run's directory promotes the spilled shards instead of
recompiling those tasks; in offline-compress mode stage 1 always
re-registers fresh prefixes, superseding any old shards.

``--kv-layout paged`` swaps the per-slot dense cache for the block-pool
paged cache: every slot seated on the same task points its block table
at one shared physical copy of the compressed prefix (copy-on-write on
the partial tail block), so prefix memory is O(tasks) instead of
O(slots).  ``--block-size``/``--num-blocks`` size the pool; admission is
gated on free blocks.  See docs/ARCHITECTURE.md.

``--traffic zipf`` (Poisson arrivals) / ``--traffic onoff`` (bursty
ON-OFF) replaces the fixed request batch with a seeded production-shaped
workload: a Zipf-popularity catalog of ``--traffic-tasks`` ICL tasks
(requests carry raw shots, so unseen tasks compile online and evicted
ones churn through the tiers) served at ``--traffic-rate`` requests per
*simulated* second against the engine's virtual clock —
``--priority-classes N`` splits requests into preemptible priority
classes (``--priority-aging`` bounds starvation), ``--slo-ttft`` sets
the TTFT SLO the goodput line reports against, and
``--autotune-budgets`` lets the engine trade compile/promote budgets
against the observed decode gap.  Same seed, same numbers, any host.

``--fused-step`` folds admission prefills (in ``--fused-chunk-tokens``
pieces) and online compile chunks into the batched decode dispatch, so
churn never opens a decode gap; ``--spec-draft smollm-135m --spec-k 2``
adds speculative decoding on the same fused lanes (a small drafter — or
``self`` — proposes k tokens per slot, verified in one step; greedy
output is token-identical to the non-speculative engine).

``--mesh M`` (or ``--mesh DxM``) runs the whole edge stage
tensor-parallel: target params placed from their logical axes, KV
caches/pools split by head over the mesh "model" axis, block tables and
per-slot lengths replicated (see docs/ARCHITECTURE.md §"Sharded
serving").  On a CPU host with too few devices the launcher forces
``--xla_force_host_platform_device_count`` *before the first jax
import* — so ``--mesh 2`` works on single-CPU CI out of the box;
``--rules {baseline,fsdp}`` picks the weight-sharding rule set.

On a fleet the same entry point runs with the production mesh and
sharded weights (launch/steps.py `compress` + `decode` objectives are
the dry-run-proven lowerings of stages 1 and 2).
"""

from __future__ import annotations

import os
import sys


def _parse_mesh(spec: str):
    """"M" -> (1, M) model-parallel; "DxM" -> (data, model)."""
    parts = spec.lower().split("x")
    if len(parts) == 1:
        data, model = 1, int(parts[0])
    elif len(parts) == 2:
        data, model = int(parts[0]), int(parts[1])
    else:
        raise ValueError(f"bad mesh spec {spec!r}: use M or DxM")
    if data < 1 or model < 1:
        raise ValueError(f"bad mesh spec {spec!r}: axes must be >= 1")
    return data, model


def _mesh_device_fallback() -> None:
    """``--mesh N`` needs N devices, and the host-platform device count
    locks at the first jax import — so peek at argv *before* any jax
    import and force the placeholder topology when the operator has not
    set XLA_FLAGS themselves.  Inert on real TPU backends (the flag only
    affects the host platform)."""
    spec = None
    for i, arg in enumerate(sys.argv):
        if arg.startswith("--mesh="):
            spec = arg.split("=", 1)[1]
        elif arg == "--mesh" and i + 1 < len(sys.argv):
            spec = sys.argv[i + 1]
    if not spec:
        return
    try:
        data, model = _parse_mesh(spec)
    except ValueError:
        return  # let argparse report the malformed spec with context
    existing = os.environ.get("XLA_FLAGS", "")
    if data * model > 1 and \
            "--xla_force_host_platform_device_count" not in existing:
        # append rather than replace: unrelated XLA_FLAGS (fast-math etc.)
        # must survive; an operator-forced device count always wins
        os.environ["XLA_FLAGS"] = (existing + " " if existing else "") + \
            f"--xla_force_host_platform_device_count={data * model}"


_mesh_device_fallback()

import argparse  # noqa: E402  (the device fallback must precede jax)
import json
import time  # reprolint: ignore-file[wall-clock] -- the live server stamps real arrival/finish times; tests use VirtualClock

import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core import memcom
from repro.data import (ICLTaskSpec, SyntheticVocab, build_manyshot_prompt,
                        make_episode, make_query)
from repro.models import transformer as tfm
from repro.serving import Request, ServingEngine, materialize_prefix
from repro.utils.pytree import tree_bytes


def main():
    # no prefix abbreviations: the pre-jax-import device-count fallback
    # scans argv for the literal --mesh, so an abbreviated --mes must be
    # rejected here rather than silently skip the forced topology
    ap = argparse.ArgumentParser(allow_abbrev=False)
    ap.add_argument("--arch", choices=ARCH_IDS, default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--tasks", type=int, default=2,
                    help="distinct compressed ICL tasks to serve in one batch")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--context-tokens", type=int, default=96)
    ap.add_argument("--classify", action="store_true",
                    help="serve ICL label queries instead of generation")
    ap.add_argument("--kv-layout", choices=("dense", "paged"), default="dense",
                    help="dense: per-slot cache stripes; paged: block-pool "
                         "cache where slots seated on the same compressed "
                         "task share its prefix blocks (copy-on-write)")
    ap.add_argument("--block-size", type=int, default=8,
                    help="tokens per physical KV block (paged layout only)")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="physical blocks in the paged pool (default: "
                         "slots+4 worst-case windows)")
    ap.add_argument("--prefix-capacity", type=int, default=None,
                    help="max HBM-resident compressed prefixes (LRU past "
                         "it; default unbounded)")
    ap.add_argument("--host-capacity", type=int, default=None,
                    help="enable the tiered prefix cache: HBM evictions "
                         "demote to a pinned-host tier holding up to N "
                         "prefixes (0 = demote straight to disk)")
    ap.add_argument("--disk-dir", default=None,
                    help="disk tier directory: host pressure spills "
                         "codec-compressed prefix shards here, and shards "
                         "from a previous run are promoted instead of "
                         "recompiled")
    ap.add_argument("--promote-budget", type=int, default=None,
                    help="max per-layer host->HBM chunks copied per "
                         "serve-loop iteration during a promotion "
                         "(default: whole prefix at once — decode stalls "
                         "for the full copy)")
    ap.add_argument("--raw-shots", action="store_true",
                    help="skip the offline compress stage: requests carry "
                         "their raw many-shot context and the engine "
                         "compiles each unseen task online, interleaved "
                         "with decode")
    ap.add_argument("--compile-budget", type=int, default=None,
                    help="max source tokens compiled per serve-loop "
                         "iteration (default: a whole task at once — "
                         "decode stalls for the full compile)")
    ap.add_argument("--stats", action="store_true",
                    help="print engine cache/compile counters after serving")
    ap.add_argument("--traffic", choices=("zipf", "onoff"), default=None,
                    help="serve a seeded synthetic workload instead of the "
                         "fixed batch: Zipf-popularity task catalog under "
                         "Poisson (zipf) or bursty ON-OFF (onoff) arrivals "
                         "on the engine's virtual clock")
    ap.add_argument("--priority-classes", type=int, default=1,
                    help="traffic mode: priority classes to draw requests "
                         "from (class 0 most urgent; >1 enables preemption "
                         "pressure)")
    ap.add_argument("--traffic-requests", type=int, default=32)
    ap.add_argument("--traffic-tasks", type=int, default=8,
                    help="catalog size; set above --prefix-capacity/"
                         "--host-capacity to make the tiers churn")
    ap.add_argument("--traffic-rate", type=float, default=200.0,
                    help="arrival rate in requests per simulated second")
    ap.add_argument("--zipf-alpha", type=float, default=1.1)
    ap.add_argument("--priority-aging", type=float, default=None,
                    help="seconds of queue wait per one-class priority "
                         "boost (anti-starvation; default off)")
    ap.add_argument("--slo-ttft", type=float, default=0.02,
                    help="traffic mode: TTFT SLO in simulated seconds")
    ap.add_argument("--autotune-budgets", action="store_true",
                    help="halve/double --compile-budget/--promote-budget "
                         "against the observed decode gap")
    ap.add_argument("--target-gap", type=float, default=2e-3,
                    help="decode-gap target (simulated s) for "
                         "--autotune-budgets")
    ap.add_argument("--seed", type=int, default=0,
                    help="traffic trace seed (same seed -> same workload "
                         "and, on the virtual clock, same metrics)")
    ap.add_argument("--fused-step", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="fuse admission prefill chunks / compile chunks "
                         "into the batched decode dispatch (pure "
                         "attention/MLA archs): new requests join by "
                         "streaming their prompt through the decode step "
                         "instead of opening a prefill-sized decode gap")
    ap.add_argument("--fused-chunk-tokens", type=int, default=16,
                    help="prompt tokens a joining slot streams per fused "
                         "step (--fused-step)")
    ap.add_argument("--spec-draft", default=None, metavar="ARCH|self",
                    help="speculative decoding drafter: an arch id (its "
                         "smoke config drafts for the target) or 'self' "
                         "(the target drafts for itself — the acceptance "
                         "upper bound).  Needs --fused-step and --spec-k")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="draft tokens proposed and verified per fused "
                         "step and slot (0 = speculative decoding off)")
    ap.add_argument("--mesh", default=None,
                    help="serve tensor-parallel: M (model-parallel ways) or "
                         "DxM (data x model); forces the host device count "
                         "on CPU so it runs anywhere")
    ap.add_argument("--rules", choices=("baseline", "fsdp"),
                    default="baseline",
                    help="weight-sharding rule set for --mesh (baseline: "
                         "tensor/expert parallel; fsdp: +embed over data)")
    ap.add_argument("--http-port", type=int, default=None, metavar="PORT",
                    help="serve the telemetry plane over HTTP while the "
                         "engine runs: GET /metrics (Prometheus text), "
                         "/healthz, /debug/state, /debug/trace on "
                         "127.0.0.1:PORT (0 = pick an ephemeral port, "
                         "printed at startup)")
    ap.add_argument("--http-linger", type=float, default=0.0, metavar="S",
                    help="keep the process (and --http-port server) alive "
                         "S seconds after serving finishes, so external "
                         "scrapers/smoke tests can curl the final state")
    ap.add_argument("--metrics", default=None)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record a full request-lifecycle trace and write "
                         "it as Chrome-trace/Perfetto JSON (open at "
                         "ui.perfetto.dev); on the virtual clock the file "
                         "is byte-identical for one (scenario, seed)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the engine's MetricsRegistry in Prometheus "
                         "text exposition format after serving")
    ap.add_argument("--flight-recorder", type=int, default=None,
                    metavar="N",
                    help="bound the tracer's ring buffer to the last N "
                         "events (the flight recorder: dumped to "
                         "--trace-out on a crash); default keeps all")
    args = ap.parse_args()
    if args.tasks < 1 or args.slots < 1 or args.requests < 1:
        ap.error("--tasks, --slots and --requests must all be >= 1")
    if args.block_size < 1:
        ap.error("--block-size must be >= 1")
    if args.compile_budget is not None and args.compile_budget < 1:
        ap.error("--compile-budget must be >= 1")
    if args.promote_budget is not None and args.promote_budget < 1:
        ap.error("--promote-budget must be >= 1")
    if args.host_capacity is not None and args.host_capacity < 0:
        ap.error("--host-capacity must be >= 0")
    if args.flight_recorder is not None and args.flight_recorder < 1:
        ap.error("--flight-recorder must be >= 1")
    if args.raw_shots and args.classify:
        ap.error("--raw-shots serves generation traffic (classify goes "
                 "through the offline seat path)")
    if args.traffic and (args.classify or args.raw_shots):
        ap.error("--traffic generates its own raw-shot requests (drop "
                 "--classify/--raw-shots)")
    if args.autotune_budgets and \
            args.compile_budget is None and args.promote_budget is None:
        ap.error("--autotune-budgets needs --compile-budget and/or "
                 "--promote-budget to tune")
    if (args.spec_k > 0) != (args.spec_draft is not None):
        ap.error("--spec-draft and --spec-k come together (both or neither)")
    if args.spec_k and not args.fused_step:
        ap.error("--spec-k rides the fused step: add --fused-step")
    if args.fused_chunk_tokens < 1:
        ap.error("--fused-chunk-tokens must be >= 1")
    if args.spec_draft is not None and args.spec_draft != "self" \
            and args.spec_draft not in ARCH_IDS:
        ap.error(f"--spec-draft must be 'self' or one of {ARCH_IDS}")
    if args.http_port is not None and args.http_port < 0:
        ap.error("--http-port must be >= 0 (0 picks an ephemeral port)")
    if args.http_linger < 0:
        ap.error("--http-linger must be >= 0")
    if args.http_linger and args.http_port is None:
        ap.error("--http-linger needs --http-port")

    vocab = SyntheticVocab()
    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch)).replace(vocab_size=vocab.size)
    if cfg.memcom is None:
        raise SystemExit(f"{args.arch}: attention-free — serve with the "
                         "native SSM state snapshot (see DESIGN.md §4)")
    m = cfg.memcom.num_memory_tokens

    print(f"[cloud] target {cfg.name} ({cfg.param_count()/1e6:.1f}M), "
          f"m={m} memory tokens, {args.tasks} task(s)")
    target = tfm.init_params(cfg, 0)
    compressor = memcom.init_memcom(cfg, target, 1)

    rng = np.random.default_rng(0)
    paged_kw = {}
    if args.kv_layout == "paged":
        paged_kw = dict(block_size=args.block_size,
                        num_blocks=args.num_blocks)
    mesh = rules = None
    if args.mesh:
        from repro.launch.mesh import make_serving_mesh
        from repro.sharding.rules import BASELINE_RULES, FSDP_RULES

        data, model = _parse_mesh(args.mesh)
        mesh = make_serving_mesh(model=model, data=data)
        rules = {"baseline": BASELINE_RULES, "fsdp": FSDP_RULES}[args.rules]
        print(f"[edge] tensor-parallel mesh {data}x{model} "
              f"(data x model), rules={args.rules}")
    spec_draft = None
    if args.spec_k:
        if args.spec_draft == "self":
            spec_draft = "self"
            print(f"[edge] self-speculative decoding, k={args.spec_k}")
        else:
            dcfg = (get_smoke_config(args.spec_draft) if args.smoke
                    else get_config(args.spec_draft)).replace(
                        vocab_size=vocab.size)
            spec_draft = (dcfg, tfm.init_params(dcfg, 1))
            print(f"[edge] speculative decoding: drafter {dcfg.name} "
                  f"({dcfg.param_count()/1e6:.1f}M), k={args.spec_k}")
    clock = None
    if args.traffic:
        # traffic replays timed arrivals against a virtual clock: time
        # advances through the engine's work-cost model, so the SLO
        # numbers are simulated seconds, reproducible for one seed
        from repro.serving import VirtualClock

        clock = VirtualClock()
    tracer = None
    if args.trace_out or args.flight_recorder or args.http_port is not None:
        from repro.serving import Tracer

        # the tracer binds to the engine's clock at construction, so on
        # a --traffic run the spans sit on simulated time; --http-port
        # implies one so GET /debug/trace has a flight recorder to dump
        tracer = Tracer(capacity=args.flight_recorder,
                        dump_path=args.trace_out)
        print(f"[edge] tracing: flight recorder "
              f"{'unbounded' if args.flight_recorder is None else args.flight_recorder}"
              f" event(s)"
              + (f", dump -> {args.trace_out}" if args.trace_out else ""))
    registry = watchdog = None
    if args.traffic or args.http_port is not None:
        from repro.serving import MetricsRegistry

        registry = MetricsRegistry()
    if args.traffic:
        # SLO burn-rate watchdog over the virtual clock: alerts land as
        # tracer instants + serving_alerts_total counters (scrapeable
        # via --http-port /metrics), and the page-severity degradation
        # hook sheds lowest-priority admissions while active
        from repro.serving import ShedDegrade, SLOWatchdog, default_rules

        watchdog = SLOWatchdog(default_rules(slo_ttft_s=args.slo_ttft),
                               metrics=registry, tracer=tracer,
                               degrade_hook=ShedDegrade())
    engine = ServingEngine(cfg, target, slots=args.slots,
                           max_len=m + 24 + args.max_new + 16,
                           kv_layout=args.kv_layout,
                           compressor=(compressor
                                       if args.raw_shots or args.traffic
                                       else None),
                           compile_token_budget=args.compile_budget,
                           prefix_capacity=args.prefix_capacity,
                           host_capacity=args.host_capacity,
                           disk_dir=args.disk_dir,
                           promote_layer_budget=args.promote_budget,
                           mesh=mesh, rules=rules,
                           clock=clock,
                           priority_aging_s=args.priority_aging,
                           autotune_budgets=args.autotune_budgets,
                           target_decode_gap_s=(args.target_gap
                                                if args.autotune_budgets
                                                else None),
                           fused_step=args.fused_step,
                           fused_chunk_tokens=args.fused_chunk_tokens,
                           spec_draft=spec_draft, spec_k=args.spec_k,
                           tracer=tracer, metrics=registry,
                           watchdog=watchdog,
                           **paged_kw)
    http_server = None
    if args.http_port is not None:
        from repro.serving import TelemetryServer

        http_server = TelemetryServer(engine, port=args.http_port)
        port = http_server.start()
        print(f"[edge] http telemetry on 127.0.0.1:{port} "
              "(/metrics /healthz /debug/state /debug/trace)")
    if engine.tiers is not None:
        preloaded = engine.tiers.disk_names()
        print(f"[edge] tiered prefix cache: host capacity "
              f"{'unbounded' if args.host_capacity is None else args.host_capacity}"
              f", disk {args.disk_dir or '(none)'}"
              + (f", {len(preloaded)} shard(s) indexed from a previous run"
                 if preloaded else ""))

    tasks, payload = [], 0
    t0 = time.perf_counter()
    for t in range(0 if args.traffic else args.tasks):
        task = ICLTaskSpec(vocab, num_labels=8, keys_per_label=4)
        episode = make_episode(task, rng)
        prompt = build_manyshot_prompt(task, episode, rng,
                                       budget=args.context_tokens)
        if not args.raw_shots:  # stage 1: compress offline, register
            prefix, _ = memcom.compress(compressor, cfg,
                                        jnp.asarray(prompt[None]))
            kv = materialize_prefix(target, cfg, prefix)
            engine.add_prefix(f"task{t}", kv)
            payload += tree_bytes(kv)
        tasks.append((f"task{t}", task, episode, prompt))
    t_compress = time.perf_counter() - t0
    if args.traffic:
        pass  # the trace carries its own raw shots; no offline stage
    elif args.raw_shots:
        print(f"[edge] no offline stage: {args.tasks} task(s) will compile "
              f"online, {'whole-task' if args.compile_budget is None else str(args.compile_budget) + '-token'} "
              "chunks interleaved with decode")
    else:
        print(f"[cloud] compressed {args.tasks}x{args.context_tokens} tokens "
              f"-> {m} slots/layer each in {t_compress:.2f}s; "
              f"payload {payload/1e3:.1f} KB total")
    metrics = {"arch": cfg.name, "m": m, "tasks": args.tasks,
               "slots": args.slots, "context_tokens": args.context_tokens,
               "compress_s": t_compress, "payload_bytes": payload,
               "kv_layout": args.kv_layout, "raw_shots": args.raw_shots,
               "compile_budget": args.compile_budget,
               "prefix_capacity": args.prefix_capacity,
               "host_capacity": args.host_capacity,
               "disk_dir": args.disk_dir,
               "promote_budget": args.promote_budget,
               "mesh": args.mesh, "rules": args.rules if args.mesh else None,
               "fused_step": args.fused_step,
               "spec_draft": args.spec_draft, "spec_k": args.spec_k}
    if args.kv_layout == "paged":
        print(f"[edge] paged pool: {engine.alloc.num_blocks} blocks x "
              f"{engine.block_size} tokens, "
              f"{engine.alloc.used_count} resident after task registration")
        metrics.update(block_size=engine.block_size,
                       num_blocks=engine.alloc.num_blocks,
                       blocks_resident=engine.alloc.used_count)

    if args.traffic:
        from repro.serving import TrafficConfig, generate_trace, slo_metrics

        tcfg = TrafficConfig(
            num_tasks=args.traffic_tasks, zipf_alpha=args.zipf_alpha,
            context_tokens=args.context_tokens,
            num_requests=args.traffic_requests,
            process="poisson" if args.traffic == "zipf" else "onoff",
            rate_rps=args.traffic_rate,
            priority_classes=args.priority_classes)
        trace = generate_trace(tcfg, args.seed, vocab=vocab)
        print(f"[edge] traffic: {tcfg.num_requests} requests over "
              f"{tcfg.num_tasks} task(s), zipf {tcfg.zipf_alpha}, "
              f"{tcfg.process} arrivals @ {tcfg.rate_rps:.0f} r/s "
              f"(simulated), {tcfg.priority_classes} priority class(es), "
              f"seed {args.seed}")
        t0 = time.perf_counter()
        out = engine.serve(list(trace.requests))
        wall = time.perf_counter() - t0
        devices = 1
        if args.mesh:
            d_, m_ = _parse_mesh(args.mesh)
            devices = d_ * m_
        slo = slo_metrics(engine.request_log, slo_ttft_s=args.slo_ttft,
                          devices=devices, gap_samples=engine.gap_samples)
        generated = int(sum(len(v) for v in out.values()))
        print(f"[edge] {slo['completed']}/{slo['requests']} completed, "
              f"{generated} tokens in {slo['duration_s']*1e3:.1f} ms "
              f"simulated ({wall:.2f}s wall): TTFT p50 "
              f"{slo['ttft_p50_s']*1e3:.2f} / p99 "
              f"{slo['ttft_p99_s']*1e3:.2f} ms, goodput "
              f"{slo['goodput_rps']:.1f} r/s @ SLO "
              f"{args.slo_ttft*1e3:.0f} ms, "
              f"{slo['tokens_per_s_per_device']:.0f} tok/s/device, "
              f"decode-gap p99 {slo['decode_gap_p99_s']*1e3:.2f} ms, "
              f"{slo['preemptions']} preemption(s)")
        for cls, row in sorted(slo["per_class"].items()):
            print(f"[edge]   class {cls}: "
                  f"{row['completed']}/{row['requests']} done, TTFT p50 "
                  f"{row['ttft_p50_s']*1e3:.2f} ms, {row['slo_attained']} "
                  f"in SLO, {row['preemptions']} preempted")
        fires = sum(1 for e in watchdog.alert_log if e["kind"] == "fire")
        print(f"[edge] watchdog: {fires} alert fire(s), "
              f"{len(watchdog.alert_log) - fires} clear(s) over "
              f"{len(watchdog.rules)} burn-rate rule(s)")
        metrics["traffic"] = {
            "process": tcfg.process, "seed": args.seed,
            "traffic_tasks": tcfg.num_tasks, "rate_rps": tcfg.rate_rps,
            "zipf_alpha": tcfg.zipf_alpha,
            "priority_classes": tcfg.priority_classes,
            "wall_s": wall, "generated": generated,
            "alerts": watchdog.report(), **slo}
    elif args.classify:
        hits = 0
        t0 = time.perf_counter()
        for i in range(args.requests):
            name, task, episode, prompt = tasks[i % len(tasks)]
            engine.seat_prefix(0, name)
            q, label = make_query(task, episode, prompt, rng)
            pred = engine.score_labels(np.empty((0,), np.int32), q,
                                       vocab.label_ids())
            hits += int(pred - vocab.label_base == label)
        dt = time.perf_counter() - t0
        print(f"[edge] {args.requests} label queries in {dt:.2f}s "
              f"({hits}/{args.requests} correct — untrained compressor "
              f"unless loaded from a checkpoint)")
        metrics.update(queries=args.requests, correct=hits, serve_s=dt)
    else:
        # ragged prompts, round-robin over tasks, per-request stop budget;
        # with --raw-shots each request carries its task's many-shot
        # context and the first request per task triggers the (deduped)
        # online compile
        reqs = [
            Request(tokens=rng.integers(4, vocab.size,
                                        int(rng.integers(4, 12))),
                    max_new=args.max_new, prefix=tasks[i % len(tasks)][0],
                    raw_shots=(tasks[i % len(tasks)][3]
                               if args.raw_shots else None),
                    stop_token=None)
            for i in range(args.requests)
        ]
        t0 = time.perf_counter()
        out = engine.serve(reqs)
        dt = time.perf_counter() - t0
        generated = int(sum(len(v) for v in out.values()))
        tok_s = generated / dt
        print(f"[edge] served {args.requests} ragged requests "
              f"({args.tasks} compressed tasks, {args.slots} slots) in "
              f"{dt:.2f}s: {generated} tokens, {tok_s:.1f} tok/s, "
              f"attending to <= {m}+prompt slots/layer per request")
        metrics.update(requests=args.requests, generated=generated,
                       serve_s=dt, tokens_per_s=tok_s)
        if args.raw_shots:
            cs = engine.stats()["compiler"]
            print(f"[edge] online compile: {cs['jobs']} job(s), "
                  f"{cs['deduped']} deduped submit(s), {cs['chunks']} "
                  f"chunk(s) / {cs['tokens']} source tokens")
        if args.fused_step:
            es = engine.stats()["engine"]
            line = (f"[edge] fused: {es['fused_steps']} fused step(s), "
                    f"{es['fused_prefill_tokens']} prompt tokens streamed "
                    f"in {es['fused_prefill_chunks']} chunk(s), "
                    f"{es['fused_compile_chunks']} compile chunk(s) fused")
            if args.spec_k:
                line += (f"; speculative: {es['draft_accepted']}/"
                         f"{es['draft_proposed']} drafts accepted "
                         f"({es['accept_rate']:.0%})")
            print(line)

    if args.stats:
        stats = engine.stats()
        print("[stats]", json.dumps(stats, indent=1))
        metrics["stats"] = stats

    if args.trace_out:
        path = tracer.dump(args.trace_out)
        n = len(tracer.events())
        print(f"[edge] trace -> {path} ({n} event(s)"
              + (f", {tracer.dropped} dropped by the flight recorder"
                 if tracer.dropped else "") + ")")

    if args.metrics_out:
        parent = os.path.dirname(args.metrics_out)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(args.metrics_out, "w") as f:
            f.write(engine.metrics.render_prometheus())
        print(f"[edge] prometheus metrics -> {args.metrics_out}")

    if args.metrics:
        with open(args.metrics, "w") as f:
            json.dump(metrics, f, indent=1)
        print(f"metrics -> {args.metrics}")

    if http_server is not None:
        if args.http_linger:
            print(f"[edge] http telemetry lingering {args.http_linger:g}s "
                  f"on 127.0.0.1:{http_server.bound_port}", flush=True)
            time.sleep(args.http_linger)
        http_server.stop()


if __name__ == "__main__":
    main()
