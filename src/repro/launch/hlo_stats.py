"""Collective-traffic extraction from partitioned HLO text.

``collective_bytes`` parses ``compiled.as_text()`` (the per-partition SPMD
module) and estimates per-device link traffic per op type:

    all-gather         ≈ result_bytes          (receive everyone's shards)
    all-reduce         ≈ 2 × result_bytes      (ring: reduce-scatter + gather)
    reduce-scatter     ≈ result_bytes × group  (operand volume streamed)
    all-to-all         ≈ result_bytes
    collective-permute ≈ result_bytes

Collectives inside while loops appear once in the text; the dry-run
therefore measures two *unrolled* reduced-depth variants (repeats = 1, 2)
and extrapolates linearly to the real depth (launch/dryrun.py).
"""

from __future__ import annotations

import re
from collections import defaultdict

import numpy as np

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_OP_RE = re.compile(
    r"=\s+(?:\()?([a-z0-9]+)\[([\d,]*)\][^=]*?"
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,]+)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _bytes_of(dtype: str, dims: str) -> float:
    n = int(np.prod([int(d) for d in dims.split(",") if d])) if dims else 1
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Per-device link traffic estimate, keyed by op type (+ 'total')."""
    out: dict = defaultdict(float)
    counts: dict = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # async pair: count the -start only
        dtype, dims, op = m.groups()
        size = _bytes_of(dtype, dims)
        group = 1
        g = _GROUPS_RE.search(line)
        if g:
            group = len(g.group(1).split(","))
        else:
            g2 = _GROUPS_V2_RE.search(line)
            if g2:
                group = int(g2.group(2))
        if op == "all-gather":
            traffic = size
        elif op == "all-reduce":
            traffic = 2.0 * size
        elif op == "reduce-scatter":
            traffic = size * group
        else:  # all-to-all, collective-permute
            traffic = size
        out[op] += traffic
        counts[op] += 1
    out["total"] = float(sum(v for k, v in out.items() if k != "total"))
    out["counts"] = dict(counts)
    return dict(out)
