import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: device count locks at first jax init.
"""Multi-pod dry-run driver (deliverable e).

For every (architecture × input shape) cell, builds the production mesh
(single-pod 16×16 = 256 chips, or multi-pod 2×16×16 = 512), assembles the
cell's step function and fully-abstract sharded inputs
(:mod:`repro.launch.steps`), then::

    lowered  = jax.jit(step, donate_argnums=…).lower(*args)
    compiled = lowered.compile()
    print(compiled.memory_analysis())   # proves it fits
    print(compiled.cost_analysis())     # FLOPs/bytes for §Roofline

and records per-cell JSON artifacts (memory, XLA cost, collective-traffic
estimates) that §Roofline (launch/roofline.py) consumes.

Collective accounting: collectives inside a scanned layer stack appear
ONCE in the HLO text. The driver therefore also compiles reduced-depth
variants (repeats = 1, 2) and extrapolates per-layer traffic linearly to
the real depth — slope × repeats + intercept (hlo_stats docstring).

Usage::

    python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out artifacts/dryrun]
"""

import argparse
import dataclasses
import gc
import json
import pathlib
import time  # reprolint: ignore-file[wall-clock] -- measuring real host wall time for compile/dispatch latency is the point
import traceback

import jax

from repro.config import SHAPES, EncoderConfig, LayerLayout
from repro.configs import ARCH_IDS, get_config
from repro.launch import costs
from repro.launch.hlo_stats import collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell, cell_is_skipped, default_objective
from repro.sharding.ctx import act_sharding
from repro.sharding.rules import FSDP_RULES

ASSIGNED = tuple(a for a in ARCH_IDS if a not in ("gemma2-2b", "mistral-7b"))


def _mesh_tag(multi_pod: bool) -> str:
    return "pod2x16x16" if multi_pod else "pod16x16"


def _reduced(cfg, repeats: int):
    """Same layer *period*, fewer scan trips (collective extrapolation)."""
    lay = cfg.layout
    kw = {}
    if cfg.encoder is not None:
        kw["encoder"] = dataclasses.replace(cfg.encoder, num_layers=repeats)
    return cfg.replace(
        layout=LayerLayout(period=lay.period, repeats=repeats,
                           prefix=lay.prefix), **kw)


def lower_and_compile(arch: str, shape_name: str, mesh, *, objective=None,
                      phase: int = 1, rules=None, impl: str = "auto",
                      cfg_override=None, decode_window: int = 0,
                      moe_groups: int = 0, act_seq: bool = True):
    cell = build_cell(arch, shape_name, mesh, objective=objective,
                      phase=phase, rules=rules, impl=impl,
                      cfg_override=cfg_override, decode_window=decode_window,
                      moe_groups=moe_groups)
    act = cell["act_sharding"]
    if not act_seq and act is not None:
        # batch-only residual sharding (perf variant: no seq resharding)
        from jax.sharding import NamedSharding, PartitionSpec as P

        spec = act.spec
        act = NamedSharding(act.mesh, P(spec[0], *([None] * (len(spec) - 1))))
    jitted = jax.jit(cell["step"], donate_argnums=cell["donate"])
    with act_sharding(act):
        t0 = time.monotonic()
        lowered = jitted.lower(*cell["args"])
        t1 = time.monotonic()
        compiled = lowered.compile()
        t2 = time.monotonic()
    return cell, lowered, compiled, {"lower_s": round(t1 - t0, 2),
                                     "compile_s": round(t2 - t1, 2)}


def _mem_dict(compiled) -> dict:
    m = compiled.memory_analysis()
    if m is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes", "peak_memory_in_bytes")
    out = {}
    for k in keys:
        v = getattr(m, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, phase: int,
             extrapolate: bool, out_dir: pathlib.Path, force: bool,
             objective=None) -> dict:
    tag = f"{arch}__{shape_name}__{_mesh_tag(multi_pod)}"
    if objective:
        tag += f"__{objective}"
    path = out_dir / f"{tag}.json"
    if path.exists() and not force:
        rec = json.loads(path.read_text())
        print(f"[skip existing] {tag}: {rec.get('status')}")
        return rec

    skip = cell_is_skipped(arch, shape_name)
    rec: dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "multi_pod": multi_pod, "phase": phase,
    }
    if skip:
        rec.update(status="skipped", reason=skip)
        path.write_text(json.dumps(rec, indent=1))
        print(f"[SKIP] {tag}: {skip}")
        return rec

    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        cell, lowered, compiled, timing = lower_and_compile(
            arch, shape_name, mesh, phase=phase, objective=objective)
        rec["objective"] = cell["objective"]
        rec["timing"] = timing
        rec["memory"] = _mem_dict(compiled)
        ca = compiled.cost_analysis() or {}
        rec["xla_cost"] = {k: float(v) for k, v in ca.items()
                           if isinstance(v, (int, float)) and
                           k in ("flops", "bytes accessed", "optimal_seconds")}
        text = compiled.as_text()
        rec["collectives_full"] = collective_bytes(text)
        rec["hlo_chars"] = len(text)
        del compiled, lowered
        gc.collect()

        cfg = cell["cfg"]
        if extrapolate and cfg.layout.repeats > 2:
            per_r = {}
            for r in (1, 2):
                _, _, comp_r, _ = lower_and_compile(
                    arch, shape_name, mesh, phase=phase,
                    objective=objective, cfg_override=_reduced(cfg, r))
                per_r[r] = collective_bytes(comp_r.as_text())
                del comp_r
                gc.collect()
            slope = per_r[2]["total"] - per_r[1]["total"]
            intercept = per_r[1]["total"] - slope
            total = max(intercept, 0.0) + max(slope, 0.0) * cfg.layout.repeats
            # extrapolation can only add to what the full text shows
            total = max(total, rec["collectives_full"]["total"])
            rec["collectives"] = {
                "per_layer_period": slope,
                "outside_scan": max(intercept, 0.0),
                "total": total,
                "method": "repeats-1/2 linear extrapolation",
                "r1": per_r[1]["total"], "r2": per_r[2]["total"],
            }
        else:
            rec["collectives"] = {
                "total": rec["collectives_full"]["total"],
                "method": "direct (unscanned or shallow)",
            }

        # analytic FLOP/byte model (primary for §Roofline; scan-aware)
        shape = cell["shape"]
        obj = cell["objective"]
        cost_kind = {"memcom_train": "memcom_train", "lm_train": "lm_train",
                     "compress": "prefill", "prefill": "prefill",
                     "decode": "decode", "decode_compressed": "decode"}[obj]
        cc = costs.cell_cost(cfg, shape, cost_kind)
        rec["analytic"] = {
            "flops": cc.flops, "hbm_bytes": cc.hbm_bytes,
            "model_flops": cc.model_flops,
        }
        rec["status"] = "ok"
        print(f"[OK]  {tag} obj={rec['objective']} "
              f"compile={timing['compile_s']}s "
              f"coll={rec['collectives']['total']/1e9:.3f} GB/dev")
    except Exception as e:  # noqa: BLE001 — sweep must survive cell failures
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[ERR] {tag}: {rec['error']}")
    path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=[s.name for s in SHAPES])
    ap.add_argument("--objective", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--phase", type=int, default=1)
    ap.add_argument("--no-extrapolate", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    cells = []
    if args.all:
        for arch in ASSIGNED:
            for s in SHAPES:
                cells.append((arch, s.name))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells.append((args.arch, args.shape))

    n_ok = n_err = n_skip = 0
    for multi_pod in meshes:
        for arch, shape_name in cells:
            rec = run_cell(
                arch, shape_name, multi_pod=multi_pod, phase=args.phase,
                extrapolate=not args.no_extrapolate and not multi_pod,
                out_dir=out_dir, force=args.force, objective=args.objective)
            s = rec.get("status")
            n_ok += s == "ok"
            n_err += s == "error"
            n_skip += s == "skipped"
    print(f"\ndone: {n_ok} ok, {n_skip} skipped (spec), {n_err} errors")
    raise SystemExit(1 if n_err else 0)


if __name__ == "__main__":
    main()
