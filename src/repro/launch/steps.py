"""Dry-run / production cell builders: (arch × shape × mesh) → jittable
step + fully-abstract, sharding-annotated inputs.

Objectives per shape kind (EXPERIMENTS.md §Dry-run records the mapping):

* ``train``   → MemCom training step (the paper's workload): compressor
  fwd/bwd + frozen-target fwd/bwd-to-activations + AdamW on the trainable
  subtree (Phase-1 by default — the paper's headline setting).  Archs the
  technique doesn't apply to (attention-free mamba2) lower a plain LM
  train step instead (DESIGN.md §Arch-applicability).
* ``prefill`` → the system's offline compression pass: Source-LLM +
  Memory-LLM over the many-shot tokens → per-layer compressed KV cache
  materialized through the frozen target projections.  (mamba2: vanilla
  prefill — its post-prompt SSM state *is* the compressed cache.)
* ``decode``  → vanilla serve step: one new token per sequence against a
  seq_len KV cache (the paper's *baseline* inference cost — what MemCom
  removes).  ``decode_compressed`` lowers the MemCom-served counterpart
  (m memory slots + a small generation window) for the §Perf comparison.

Everything is abstract: ``jax.eval_shape`` builds the state trees,
shardings are attached to ``ShapeDtypeStruct``s, nothing is allocated.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, SHAPES, ShapeSpec
from repro.configs import get_config
from repro.core import icae as icae_lib
from repro.core import memcom
from repro.launch import costs
from repro.models import transformer as tfm
from repro.optim import AdamW, clip_by_global_norm, warmup_cosine
from repro.serving.engine import materialize_prefix
from repro.sharding.rules import (
    FSDP_RULES, Rules, batch_sharding, logical_to_shardings, replicated,
    spec_for,
)
from repro.utils.pytree import tree_map_with_path

# Archs whose family makes MemCom inapplicable (train falls back to LM).
ATTENTION_FREE = ("mamba2-370m",)
# Sub-quadratic archs that run long_500k.
SUBQUADRATIC = ("mamba2-370m", "jamba-1.5-large-398b")


def shape_by_name(name: str) -> ShapeSpec:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def cell_is_skipped(arch: str, shape_name: str) -> Optional[str]:
    """Return a skip reason or None (spec: long_500k is sub-quadratic-only)."""
    shape = shape_by_name(shape_name)
    if shape.subquadratic_only and arch not in SUBQUADRATIC:
        return ("full-attention arch: 500k decode needs sub-quadratic "
                "attention (DESIGN.md §4)")
    return None


# ---------------------------------------------------------------------------
# Sharding helpers
# ---------------------------------------------------------------------------


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _with_shardings(abstract_tree, sharding_tree):
    return jax.tree.map(
        lambda a, s: _sds(a.shape, a.dtype, s), abstract_tree, sharding_tree)


def _data_axes(mesh: Mesh):
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _batch_spec(mesh: Mesh, batch: int, ndim: int):
    axes = _data_axes(mesh)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    lead = axes if batch % n == 0 else None
    return NamedSharding(mesh, P(lead, *([None] * (ndim - 1))))


def act_sharding_for(mesh: Mesh, cfg: ModelConfig, batch: int, seq: int):
    """Residual-stream constraint: batch→data axes, seq→model."""
    axes = _data_axes(mesh)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    b = axes if batch % n == 0 else None
    s = "model" if seq % mesh.shape["model"] == 0 and seq > 1 else None
    return NamedSharding(mesh, P(b, s, None))


def param_shardings(cfg: ModelConfig, mesh: Mesh, rules: Rules = FSDP_RULES):
    abstract = tfm.abstract_params(cfg)
    axes = tfm.param_specs(cfg)
    return logical_to_shardings(abstract, axes, mesh, rules), abstract


def memcom_shardings(cfg: ModelConfig, mesh: Mesh, rules: Rules = FSDP_RULES):
    tgt_abs = tfm.abstract_params(cfg)
    mc_abs = memcom.init_memcom(cfg, tgt_abs, abstract=True)
    mc_axes = memcom.memcom_axes(cfg)
    return logical_to_shardings(mc_abs, mc_axes, mesh, rules), mc_abs


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_abstract):
    """KV/state cache shardings: batch→data axes, cache-seq→model."""
    daxes = _data_axes(mesh)
    n_data = 1
    for a in daxes:
        n_data *= mesh.shape[a]
    n_model = mesh.shape["model"]

    def leaf_spec(path: str, leaf):
        name = path.rsplit("/", 1)[-1]
        shape = leaf.shape
        stacked = path.startswith("period")
        off = 1 if stacked else 0
        entries = [None] * len(shape)
        # batch dim
        bdim = off
        if shape[bdim] % n_data == 0:
            entries[bdim] = daxes
        if name in ("k", "v", "ck", "cv", "ckv", "kr"):
            sdim = off + 1  # cache sequence
            if shape[sdim] % n_model == 0:
                entries[sdim] = "model"
        elif name == "ssm":  # (B, H, P, N): heads → model
            hdim = off + 1
            if shape[hdim] % n_model == 0:
                entries[hdim] = "model"
        elif name == "conv":  # (B, W-1, conv_dim): channels → model
            cdim = off + 2
            if shape[cdim] % n_model == 0:
                entries[cdim] = "model"
        return NamedSharding(mesh, P(*entries))

    return tree_map_with_path(leaf_spec, cache_abstract)


def opt_shardings(state_abstract, p_shardings, mesh: Mesh):
    from repro.sharding.rules import opt_state_shardings

    return opt_state_shardings(state_abstract, p_shardings, mesh)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins for every model input)
# ---------------------------------------------------------------------------


def input_specs(arch: str, shape_name: str, mesh: Mesh,
                objective: Optional[str] = None) -> dict:
    """Abstract, sharded batch inputs for one cell."""
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    objective = objective or default_objective(arch, shape)
    B = shape.global_batch
    i32 = jnp.int32

    def tok(n, b=B):
        return _sds((b, n), i32, _batch_spec(mesh, b, 2))

    out: dict = {}
    if objective == "memcom_train":
        T, S = costs.train_split(shape)
        out["source"] = tok(T)
        out["target"] = tok(S)
        out["target_mask"] = _sds((B, S), i32, _batch_spec(mesh, B, 2))
    elif objective == "lm_train":
        out["tokens"] = tok(shape.seq_len)
    elif objective in ("compress", "prefill"):
        out["source"] = tok(shape.seq_len)
    elif objective.startswith("decode"):
        out["tokens"] = tok(1)
        out["cache_index"] = _sds((), i32)
    else:
        raise ValueError(objective)
    if cfg.encoder is not None and objective in (
            "memcom_train", "lm_train", "compress", "prefill"):
        e = cfg.encoder
        out["frames"] = _sds((B, e.num_frames, cfg.d_model),
                             jnp.dtype(cfg.dtype), _batch_spec(mesh, B, 3))
    return out


def default_objective(arch: str, shape: ShapeSpec) -> str:
    if shape.kind == "train":
        return "lm_train" if arch in ATTENTION_FREE else "memcom_train"
    if shape.kind == "prefill":
        return "prefill" if arch in ATTENTION_FREE else "compress"
    return "decode"


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------


def _stop_frozen(tree, mask):
    return jax.tree.map(
        lambda x, m: x if m else jax.lax.stop_gradient(x), tree, mask)


def build_memcom_train_step(cfg: ModelConfig, *, phase: int = 1,
                            impl: str = "auto", remat: bool = True,
                            clip: float = 1.0):
    """(mc_params, opt_state, target_params, batch) → (mc, opt, metrics).

    Weight grads exist only for the phase's trainable subtree
    (``stop_gradient`` on frozen leaves ⇒ XLA never forms their dL/dW);
    activation grads still flow through every stack, faithful to the
    paper's training scheme.
    """
    sched = warmup_cosine(2e-4 if phase == 1 else 2e-6,
                          warmup_steps=500, total_steps=20_000)

    def loss_fn(mc, target_params, batch):
        mask = memcom.trainable_mask(mc, phase)
        mc = _stop_frozen(mc, mask)
        return memcom.memcom_loss(mc, target_params, cfg, batch,
                                  remat=remat, impl=impl)

    def step(mc, opt_state, target_params, batch):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            mc, target_params, batch)
        grads, gnorm = clip_by_global_norm(grads, clip)
        opt = AdamW(lr=sched, mask=memcom.trainable_mask(mc, phase))
        mc, opt_state = opt.step(mc, grads, opt_state)
        return mc, opt_state, {"loss": loss, "grad_norm": gnorm, **aux}

    return step, None


def build_lm_train_step(cfg: ModelConfig, *, impl: str = "auto",
                        remat: bool = True, clip: float = 1.0):
    opt = AdamW(lr=warmup_cosine(1e-4, warmup_steps=500, total_steps=20_000))

    def loss_fn(params, batch):
        logits, aux = tfm.forward(
            params, cfg, tokens=batch["tokens"],
            encoder_frames=batch.get("frames"), remat=remat, impl=impl)
        loss = memcom.next_token_loss(logits, batch["tokens"])
        return loss + aux["moe_loss"], {"ce": loss}

    def step(params, opt_state, batch):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        grads, gnorm = clip_by_global_norm(grads, clip)
        params, opt_state = opt.step(params, grads, opt_state)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm, **aux}

    return step, opt


def build_compress_step(cfg: ModelConfig, *, impl: str = "auto",
                        remat: bool = False):
    """(mc_params, target_params, batch) → materialized compressed cache."""

    def step(mc, target_params, batch):
        prefix, info = memcom.compress(
            mc, cfg, batch.get("source"),
            encoder_frames=batch.get("frames"), remat=remat, impl=impl)
        cache = materialize_prefix(target_params, cfg, prefix)
        return cache, info.get("encoder_out")

    return step


def build_prefill_step(cfg: ModelConfig, max_len: int, *, impl: str = "auto"):
    """Vanilla prefill: run the prompt, write the KV/state cache."""

    def step(params, batch):
        B = batch["source"].shape[0]
        cache = tfm.init_cache(cfg, B, max_len)
        logits, aux = tfm.forward(
            params, cfg, tokens=batch["source"], cache=cache, cache_index=0,
            encoder_frames=batch.get("frames"), impl=impl)
        return logits[:, -1:], aux["cache"]

    return step


def build_decode_step(cfg: ModelConfig, *, impl: str = "dense"):
    """(params, cache, batch) → (logits, new cache). One-token serve step."""

    def step(params, cache, batch):
        logits, aux = tfm.forward(
            params, cfg, tokens=batch["tokens"], cache=cache,
            cache_index=batch["cache_index"], decode=True, impl=impl)
        return logits, aux["cache"]

    return step


# ---------------------------------------------------------------------------
# Cell assembly: everything jax.jit needs for one (arch × shape × mesh)
# ---------------------------------------------------------------------------


def default_rules_for(cfg: ModelConfig, mesh: Mesh) -> Rules:
    """Shipped posture: FSDP + EP-only expert weights — unless the arch's
    expert count does not divide the model axis, in which case EP cannot
    shard the experts and the pre-fix posture (expert d_model FSDP) is
    the measured-better fallback (EXPERIMENTS.md §Perf, granite)."""
    from repro.sharding.rules import FSDP_EP_EMBED_RULES

    if cfg.moe is not None and cfg.moe.num_experts % mesh.shape["model"]:
        return FSDP_EP_EMBED_RULES
    return FSDP_RULES


def build_cell(arch: str, shape_name: str, mesh: Mesh, *,
               objective: Optional[str] = None, phase: int = 1,
               rules: Optional[Rules] = None, impl: str = "auto",
               decode_window: int = 0, moe_groups: int = 0,
               cfg_override: Optional[ModelConfig] = None) -> dict:
    """Returns {step, args (abstract+sharded), donate, act_sharding, meta}.

    ``moe_groups`` > 0 switches the MoE dispatch to group-local sort with
    that many groups (hillclimb 1; 0 keeps the config's default)."""
    import dataclasses as _dc

    cfg = cfg_override or get_config(arch)
    if rules is None:
        rules = default_rules_for(cfg, mesh)
    if moe_groups and cfg.moe is not None:
        cfg = cfg.replace(moe=_dc.replace(cfg.moe,
                                          dispatch_groups=moe_groups))
    shape = shape_by_name(shape_name)
    objective = objective or default_objective(arch, shape)
    batch = input_specs(arch, shape_name, mesh, objective)
    B = shape.global_batch

    if objective == "memcom_train":
        step, opt = build_memcom_train_step(cfg, phase=phase, impl=impl)
        mc_sh, mc_abs = memcom_shardings(cfg, mesh, rules)
        tgt_sh, tgt_abs = param_shardings(cfg, mesh, rules)
        mask = memcom.trainable_mask(mc_abs, phase)
        opt_abs = jax.eval_shape(
            AdamW(lr=0.0, mask=mask).init, mc_abs)
        opt_sh = opt_shardings(opt_abs, mc_sh, mesh)
        args = (
            _with_shardings(mc_abs, mc_sh),
            _with_shardings(opt_abs, opt_sh),
            _with_shardings(tgt_abs, tgt_sh),
            batch,
        )
        T, S = costs.train_split(shape)
        act = act_sharding_for(mesh, cfg, B, T)
        return dict(step=step, args=args, donate=(0, 1), act_sharding=act,
                    objective=objective, cfg=cfg, shape=shape, phase=phase)

    if objective == "lm_train":
        step, opt = build_lm_train_step(cfg, impl=impl)
        p_sh, p_abs = param_shardings(cfg, mesh, rules)
        opt_abs = jax.eval_shape(AdamW(lr=0.0).init, p_abs)
        opt_sh = opt_shardings(opt_abs, p_sh, mesh)
        args = (
            _with_shardings(p_abs, p_sh),
            _with_shardings(opt_abs, opt_sh),
            batch,
        )
        act = act_sharding_for(mesh, cfg, B, shape.seq_len)
        return dict(step=step, args=args, donate=(0, 1), act_sharding=act,
                    objective=objective, cfg=cfg, shape=shape, phase=None)

    if objective == "compress":
        step = build_compress_step(cfg, impl=impl)
        mc_sh, mc_abs = memcom_shardings(cfg, mesh, rules)
        tgt_sh, tgt_abs = param_shardings(cfg, mesh, rules)
        args = (
            _with_shardings(mc_abs, mc_sh),
            _with_shardings(tgt_abs, tgt_sh),
            batch,
        )
        act = act_sharding_for(mesh, cfg, B, shape.seq_len)
        return dict(step=step, args=args, donate=(), act_sharding=act,
                    objective=objective, cfg=cfg, shape=shape, phase=None)

    if objective == "prefill":
        step = build_prefill_step(cfg, max_len=shape.seq_len, impl=impl)
        p_sh, p_abs = param_shardings(cfg, mesh, rules)
        args = (_with_shardings(p_abs, p_sh), batch)
        act = act_sharding_for(mesh, cfg, B, shape.seq_len)
        return dict(step=step, args=args, donate=(), act_sharding=act,
                    objective=objective, cfg=cfg, shape=shape, phase=None)

    if objective.startswith("decode"):
        # decode: 1 new token against a cache of seq_len (vanilla baseline)
        # decode_compressed: cache = m memory slots + a generation window
        if objective == "decode_compressed":
            assert cfg.memcom is not None
            L = cfg.memcom.num_memory_tokens + (decode_window or 256)
        else:
            L = shape.seq_len
        step = build_decode_step(cfg, impl=impl if impl != "auto" else "dense")
        p_sh, p_abs = param_shardings(cfg, mesh, rules)
        cache_abs = jax.eval_shape(
            functools.partial(tfm.init_cache, cfg, B, L))
        cache_sh = cache_shardings(cfg, mesh, cache_abs)
        args = (
            _with_shardings(p_abs, p_sh),
            _with_shardings(cache_abs, cache_sh),
            batch,
        )
        return dict(step=step, args=args, donate=(1,), act_sharding=None,
                    objective=objective, cfg=cfg, shape=shape, phase=None)

    raise ValueError(objective)
