"""Roofline analysis (deliverable g): three-term roofline per
(arch × shape) from the dry-run artifacts.

    compute    = FLOPs / (chips × 197 TFLOP/s bf16)
    memory     = HBM bytes / (chips × 819 GB/s)
    collective = per-device link bytes / 50 GB/s  (ICI, per link)

FLOPs/HBM come from the analytic cost model (launch/costs.py — XLA's
``cost_analysis`` counts a scanned layer body once, so the compiled
number undercounts by ~num_layers; both are recorded).  Collective bytes
come from the compiled SPMD module text with repeats-1/2 linear
extrapolation through the scan (launch/hlo_stats.py).

Emits the §Roofline markdown table:

    python -m repro.launch.roofline [--dir artifacts/dryrun] [--md out.md]
"""

from __future__ import annotations

import argparse
import json
import pathlib

CHIPS = 256  # single-pod roofline (16×16), per the assignment
PEAK_FLOPS = 197e12  # bf16 per chip (TPU v5e)
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per ICI link

NOTES = {
    "compute": ("compute-bound: raise per-chip math utilization "
                "(larger per-chip tiles, fewer pad/replica FLOPs)"),
    "memory": ("HBM-bound: cut bytes/step (compressed/smaller KV cache, "
               "fused reads, lower-precision cache)"),
    "collective": ("collective-bound: reshard to remove per-layer "
                   "gathers (group-local MoE dispatch, head-sharded "
                   "attention, batch-only activations)"),
}


def analyze(rec: dict) -> dict:
    a = rec["analytic"]
    coll = rec.get("collectives", {}).get("total",
                                          rec["collectives_full"]["total"])
    t_comp = a["flops"] / (CHIPS * PEAK_FLOPS)
    t_mem = a["hbm_bytes"] / (CHIPS * HBM_BW)
    t_coll = coll / LINK_BW  # already per-device traffic
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "objective": rec.get("objective"),
        "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
        "dominant": dom,
        "roofline_fraction": t_comp / bound if bound else 0.0,
        "model_flops": a["model_flops"],
        "useful_ratio": a["model_flops"] / a["flops"] if a["flops"] else 0.0,
        "xla_flops": rec.get("xla_cost", {}).get("flops"),
        "note": NOTES[dom],
        "peak_bytes_per_dev": rec.get("memory", {}).get(
            "peak_memory_in_bytes"),
        "temp_bytes_per_dev": rec.get("memory", {}).get(
            "temp_size_in_bytes"),
    }


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--md", default=None)
    ap.add_argument("--mesh", default="pod16x16")
    args = ap.parse_args()

    rows, skips, errs = [], [], []
    for p in sorted(pathlib.Path(args.dir).glob(f"*__{args.mesh}.json")):
        rec = json.loads(p.read_text())
        if rec.get("status") == "skipped":
            skips.append((rec["arch"], rec["shape"], rec["reason"]))
        elif rec.get("status") == "error":
            errs.append((rec["arch"], rec["shape"], rec.get("error")))
        else:
            rows.append(analyze(rec))

    lines = [
        "| arch | shape | objective | compute | memory | collective |"
        " dominant | MODEL/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['objective']} "
            f"| {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
            f"| {fmt_s(r['collective_s'])} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.2%} |")
    if skips:
        lines.append("")
        lines.append("Skipped (per spec):")
        for a, s, why in skips:
            lines.append(f"* {a} × {s} — {why}")
    if errs:
        lines.append("")
        for a, s, e in errs:
            lines.append(f"* ERROR {a} × {s}: {e}")

    out = "\n".join(lines)
    print(out)
    if args.md:
        pathlib.Path(args.md).write_text(out + "\n")

    # hillclimb candidates
    if rows:
        worst = min(rows, key=lambda r: r["roofline_fraction"])
        collb = max(rows, key=lambda r: r["collective_s"])
        print(f"\nworst roofline fraction: {worst['arch']} × {worst['shape']}"
              f" ({worst['roofline_fraction']:.1%})")
        print(f"most collective-bound:   {collb['arch']} × {collb['shape']}"
              f" ({fmt_s(collb['collective_s'])})")


if __name__ == "__main__":
    main()
