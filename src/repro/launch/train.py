"""Production training launcher: mesh + sharded MemCom step + fault-
tolerant Trainer.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 50 --data 2 --model 1

On this container the mesh is host-device-sized (use --data/--model to
shape it); on a real fleet the same entry point runs under
``jax.distributed.initialize`` with the production 16×16 (or 2×16×16)
mesh from launch/mesh.py — the step function, shardings, checkpointing
and data pipeline are identical (the dry-run proves the production-mesh
lowering; see launch/dryrun.py).
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core import memcom
from repro.data import PretrainStream, SyntheticVocab
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import (
    act_sharding_for, build_memcom_train_step, memcom_shardings,
    opt_shardings, param_shardings, _with_shardings,
)
from repro.models import transformer as tfm
from repro.optim import AdamW
from repro.sharding.ctx import act_sharding
from repro.sharding.rules import batch_sharding
from repro.train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="smollm-135m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--phase", type=int, default=1, choices=(1, 2))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--data", type=int, default=None)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--ckpt", default="artifacts/launch_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    vocab = SyntheticVocab()
    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch)).replace(vocab_size=vocab.size)
    if cfg.memcom is None:
        raise SystemExit(f"{args.arch}: MemCom inapplicable "
                         "(attention-free) — use examples/train_memcom.py "
                         "for plain LM training")

    mesh = make_host_mesh(model=args.model, data=args.data)
    print(f"mesh: {dict(mesh.shape)}, arch: {cfg.name} "
          f"({cfg.param_count()/1e6:.1f}M params), phase {args.phase}")

    step, _ = build_memcom_train_step(cfg, phase=args.phase, remat=False)
    mc_sh, mc_abs = memcom_shardings(cfg, mesh)
    tgt_sh, _ = param_shardings(cfg, mesh)
    mask = memcom.trainable_mask(mc_abs, args.phase)
    opt = AdamW(lr=0.0, mask=mask)
    opt_abs = jax.eval_shape(opt.init, mc_abs)
    opt_sh = opt_shardings(opt_abs, mc_sh, mesh)

    # real (sharded) state
    target = jax.device_put(tfm.init_params(cfg, 0), tgt_sh)
    mc = jax.device_put(memcom.init_memcom(cfg, target, 1), mc_sh)
    opt_state = jax.device_put(
        AdamW(lr=0.0, mask=mask).init(mc), opt_sh)

    bsh = batch_sharding(mesh, ndim=2)
    act = act_sharding_for(mesh, cfg, args.batch, args.seq)
    split = int(args.seq * 0.75)
    stream = PretrainStream(vocab, batch=args.batch, seq_len=args.seq,
                            split_choices=(split,), seed=0)

    with act_sharding(act):
        jitted = jax.jit(step, donate_argnums=(0, 1))

        def train_step(mc, opt_state, batch):
            with act_sharding(act):
                return jitted(mc, opt_state, target, batch)

        def batch_at(i):
            b = stream.batch_at(i)
            return {k: jax.device_put(jnp.asarray(b[k]), bsh)
                    for k in ("source", "target", "target_mask")}

        trainer = Trainer(
            train_step, mc, opt_state, batch_at, args.ckpt,
            TrainerConfig(num_steps=args.steps, ckpt_every=args.ckpt_every,
                          log_every=10,
                          metrics_path=os.path.join(args.ckpt,
                                                    "metrics.jsonl")))
        resumed = trainer.restore_if_available()
        if resumed:
            print(f"resumed from step {resumed}")
        last = trainer.run()
    print(f"done: {last}")


if __name__ == "__main__":
    main()
