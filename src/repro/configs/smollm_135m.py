"""smollm-135m [dense] — llama-arch small [hf:HuggingFaceTB/SmolLM-135M; hf].

30L, d_model=576, 9 heads (GQA kv=3), d_ff=1536, vocab=49152.
"""

from repro.config import LayerDesc, LayerLayout, MemComConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m",
        family="dense",
        layout=LayerLayout.uniform(LayerDesc("attn", "dense"), 30),
        d_model=576,
        num_heads=9,
        num_kv_heads=3,
        d_ff=1536,
        vocab_size=49152,
        rope_theta=10_000.0,
        tie_embeddings=True,
        max_seq=40_960,
        memcom=MemComConfig(num_memory_tokens=512),
        source="[hf:HuggingFaceTB/SmolLM-135M; hf]",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="smollm-135m-smoke",
        layout=LayerLayout.uniform(LayerDesc("attn", "dense"), 3),
        d_model=96, num_heads=3, num_kv_heads=3, d_ff=192, vocab_size=512,
        max_seq=256, memcom=MemComConfig(num_memory_tokens=8), dtype="float32",
        source="reduced smoke",
    )
