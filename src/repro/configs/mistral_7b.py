"""mistral-7b (v0.3) — the paper's larger target model [arXiv:2310.06825].

32L, d_model=4096, 32 heads (GQA kv=8, head_dim=128), d_ff=14336,
vocab=32768 (v0.3).  Paper setting: 6k-token many-shots,
m ∈ {2048, 1024, 768}.
"""

from repro.config import LayerDesc, LayerLayout, MemComConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-7b",
        family="dense",
        layout=LayerLayout.uniform(LayerDesc("attn", "dense"), 32),
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=32768,
        rope_theta=1_000_000.0,
        tie_embeddings=False,
        max_seq=40_960,
        memcom=MemComConfig(num_memory_tokens=768),
        source="[arXiv:2310.06825; hf] (paper's model)",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="mistral-7b-smoke",
        layout=LayerLayout.uniform(LayerDesc("attn", "dense"), 3),
        d_model=128, num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256,
        vocab_size=512, max_seq=256,
        memcom=MemComConfig(num_memory_tokens=8), dtype="float32",
        source="reduced smoke",
    )
