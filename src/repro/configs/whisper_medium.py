"""whisper-medium [audio] — enc-dec, conv frontend stubbed
[arXiv:2212.04356; unverified].

24 decoder layers (cross-attending to a 24-layer encoder over 1500
precomputed frame embeddings — the conv frontend is the assignment's
modality stub), d_model=1024, 16 heads (kv=16), d_ff=4096, vocab=51865.
Whisper uses learned absolute decoder positions and LayerNorm+GELU MLPs.
MemCom applies to the decoder's many-shot prefix (DESIGN.md §4).
"""

from repro.config import (
    EncoderConfig, LayerDesc, LayerLayout, MemComConfig, ModelConfig,
)


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium",
        family="audio",
        layout=LayerLayout.uniform(LayerDesc("attn", "dense", cross_attn=True), 24),
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=4096,
        vocab_size=51865,
        encoder=EncoderConfig(num_layers=24, num_frames=1500, num_heads=16,
                              d_ff=4096),
        pos_embed="learned",
        norm_type="layernorm",
        mlp_type="gelu_mlp",
        tie_embeddings=True,
        max_seq=40_960,  # covers decode_32k; long_500k skipped (full attention)
        memcom=MemComConfig(num_memory_tokens=512),
        source="[arXiv:2212.04356; unverified]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium-smoke",
        family="audio",
        layout=LayerLayout.uniform(LayerDesc("attn", "dense", cross_attn=True), 2),
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        encoder=EncoderConfig(num_layers=2, num_frames=24, num_heads=4, d_ff=128),
        pos_embed="learned",
        norm_type="layernorm",
        mlp_type="gelu_mlp",
        tie_embeddings=True,
        max_seq=256,
        memcom=MemComConfig(num_memory_tokens=8),
        dtype="float32",
        source="reduced smoke",
    )
