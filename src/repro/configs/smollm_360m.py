"""smollm-360m [dense] — llama-arch small [hf:HuggingFaceTB/SmolLM-135M; hf].

32L, d_model=960, 15 heads (GQA kv=5), d_ff=2560, vocab=49152.
"""

from repro.config import LayerDesc, LayerLayout, MemComConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m",
        family="dense",
        layout=LayerLayout.uniform(LayerDesc("attn", "dense"), 32),
        d_model=960,
        num_heads=15,
        num_kv_heads=5,
        d_ff=2560,
        vocab_size=49152,
        rope_theta=10_000.0,
        tie_embeddings=True,
        max_seq=40_960,
        memcom=MemComConfig(num_memory_tokens=512),
        source="[hf:HuggingFaceTB/SmolLM-135M; hf]",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="smollm-360m-smoke",
        layout=LayerLayout.uniform(LayerDesc("attn", "dense"), 3),
        d_model=96, num_heads=3, num_kv_heads=1, d_ff=192, vocab_size=512,
        max_seq=256, memcom=MemComConfig(num_memory_tokens=8), dtype="float32",
        source="reduced smoke",
    )
