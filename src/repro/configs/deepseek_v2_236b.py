"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434; hf].

60L, d_model=5120, 128 heads (MLA: qk 128 nope + 64 rope, v 128,
kv_lora_rank=512, q_lora_rank=1536), expert d_ff=1536, vocab=102400.
First layer is a dense-FFN MLA block (d_ff=12288), layers 2..60 are MoE
— expressed as layout prefix + 59-repeat period.
"""

from repro.config import (
    LayerDesc, LayerLayout, MLAConfig, MemComConfig, MoEConfig, ModelConfig,
)


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        layout=LayerLayout(
            prefix=(LayerDesc("mla", "dense"),),
            period=(LayerDesc("mla", "moe"),),
            repeats=59,
        ),
        d_model=5120,
        num_heads=128,
        num_kv_heads=128,
        d_ff=12288,  # dense first-layer FFN
        vocab_size=102400,
        mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128),
        moe=MoEConfig(num_experts=160, top_k=6, expert_d_ff=1536,
                      num_shared_experts=2, shared_d_ff=1536),
        rope_theta=10_000.0,
        tie_embeddings=False,
        max_seq=131_072,
        memcom=MemComConfig(num_memory_tokens=1024),
        source="[arXiv:2405.04434; hf]",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="deepseek-v2-smoke",
        layout=LayerLayout(
            prefix=(LayerDesc("mla", "dense"),),
            period=(LayerDesc("mla", "moe"),),
            repeats=2,
        ),
        d_model=96, num_heads=4, num_kv_heads=4, d_ff=192, vocab_size=512,
        mla=MLAConfig(kv_lora_rank=32, q_lora_rank=48, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
        moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=48,
                      num_shared_experts=2, shared_d_ff=48),
        max_seq=256, memcom=MemComConfig(num_memory_tokens=8), dtype="float32",
        source="reduced smoke",
    )
