"""gemma2-2b — the paper's smaller target model [arXiv:2408.00118].

26L, d_model=2304, 8 heads (GQA kv=4, head_dim=256), d_ff=9216 (GeGLU),
vocab=256128, attn/final logit softcaps 50/30, embeddings scaled by
sqrt(d).  (Alternating sliding-window attention simplified to global —
noted deviation.)  Paper setting: 3k-token many-shots,
m ∈ {1024, 512, 384}.
"""

from repro.config import LayerDesc, LayerLayout, MemComConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b",
        family="dense",
        layout=LayerLayout.uniform(LayerDesc("attn", "dense"), 26),
        d_model=2304,
        num_heads=8,
        num_kv_heads=4,
        head_dim=256,
        d_ff=9216,
        vocab_size=256128,
        mlp_type="geglu",
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        embed_scale=True,
        rope_theta=10_000.0,
        tie_embeddings=True,
        max_seq=40_960,
        memcom=MemComConfig(num_memory_tokens=512),
        source="[arXiv:2408.00118; hf] (paper's model)",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="gemma2-2b-smoke",
        layout=LayerLayout.uniform(LayerDesc("attn", "dense"), 3),
        d_model=96, num_heads=4, num_kv_heads=2, head_dim=24, d_ff=192,
        vocab_size=512, max_seq=256,
        memcom=MemComConfig(num_memory_tokens=8), dtype="float32",
        source="reduced smoke",
    )
