"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE
[arXiv:2403.19887; hf].

72L = 9 periods of 8 (attention at in-period index 4, Mamba elsewhere —
the Jamba paper's placement), MoE every other layer (16 experts top-2,
expert d_ff = dense d_ff = 24576), d_model=8192, 64 heads (GQA kv=8,
head_dim=128), vocab=65536.  Analytic total ≈ 398B params.

MemCom hybrid adaptation: attention layers take per-layer compressed KV;
Mamba layers hand off the source's exact SSM state (DESIGN.md §4).
"""

from repro.config import (
    LayerDesc, LayerLayout, MambaConfig, MemComConfig, MoEConfig, ModelConfig,
)

_M, _A = "mamba", "attn"


def _period():
    descs = []
    for i in range(8):
        mixer = _A if i == 4 else _M
        mlp = "moe" if i % 2 == 1 else "dense"
        descs.append(LayerDesc(mixer, mlp))
    return tuple(descs)


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        layout=LayerLayout(period=_period(), repeats=9),
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab_size=65536,
        mamba=MambaConfig(d_state=128, headdim=64, expand=2, chunk_size=256),
        moe=MoEConfig(num_experts=16, top_k=2, expert_d_ff=24576),
        rope_theta=10_000.0,
        tie_embeddings=False,
        max_seq=1_048_576,
        memcom=MemComConfig(num_memory_tokens=1024),
        source="[arXiv:2403.19887; hf]",
    )


def smoke_config() -> ModelConfig:
    period = tuple(
        LayerDesc(_A if i == 2 else _M, "moe" if i % 2 == 1 else "dense")
        for i in range(4)
    )
    return config().replace(
        name="jamba-smoke",
        layout=LayerLayout(period=period, repeats=2),
        d_model=64, num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=512,
        mamba=MambaConfig(d_state=16, headdim=16, expand=2, chunk_size=16),
        moe=MoEConfig(num_experts=4, top_k=2, expert_d_ff=128),
        max_seq=256, memcom=MemComConfig(num_memory_tokens=8), dtype="float32",
        source="reduced smoke",
    )
