"""granite-moe-3b-a800m [moe] — 40 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

32L, d_model=1536, 24 heads (GQA kv=8), expert d_ff=512, vocab=49155,
MoE 40e top-8, no shared experts.
NB: 40 experts and the 49155-row vocab do not divide the 16-way model
axis — the sharding rules engine drops those dims to replication
(DESIGN.md; revisited in §Perf).
"""

from repro.config import LayerDesc, LayerLayout, MemComConfig, MoEConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        layout=LayerLayout.uniform(LayerDesc("attn", "moe"), 32),
        d_model=1536,
        num_heads=24,
        num_kv_heads=8,
        d_ff=512,
        vocab_size=49155,
        moe=MoEConfig(num_experts=40, top_k=8, expert_d_ff=512),
        rope_theta=10_000.0,
        tie_embeddings=True,
        max_seq=40_960,
        memcom=MemComConfig(num_memory_tokens=512),
        source="[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="granite-moe-smoke",
        layout=LayerLayout.uniform(LayerDesc("attn", "moe"), 3),
        d_model=96, num_heads=6, num_kv_heads=2, d_ff=64, vocab_size=515,
        moe=MoEConfig(num_experts=5, top_k=2, expert_d_ff=64),
        max_seq=256, memcom=MemComConfig(num_memory_tokens=8), dtype="float32",
        source="reduced smoke",
    )
