"""mistral-nemo-12b [dense] — 128k ctx [hf:mistralai/Mistral-Nemo-Base-2407; hf].

40L, d_model=5120, 32 heads (GQA kv=8), head_dim=128 (model card),
d_ff=14336, vocab=131072, rope theta=1e6.
"""

from repro.config import LayerDesc, LayerLayout, MemComConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b",
        family="dense",
        layout=LayerLayout.uniform(LayerDesc("attn", "dense"), 40),
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=131072,
        rope_theta=1_000_000.0,
        tie_embeddings=False,
        max_seq=131_072,
        memcom=MemComConfig(num_memory_tokens=1024),
        source="[hf:mistralai/Mistral-Nemo-Base-2407; hf]",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="mistral-nemo-12b-smoke",
        layout=LayerLayout.uniform(LayerDesc("attn", "dense"), 3),
        d_model=128, num_heads=4, num_kv_heads=1, head_dim=32, d_ff=256,
        vocab_size=512, max_seq=256,
        memcom=MemComConfig(num_memory_tokens=8), dtype="float32",
        source="reduced smoke",
    )
