"""Architecture registry: ``get_config(name)`` / ``--arch <id>``.

Ten assigned architectures + the paper's own two (Gemma2-2B, Mistral-7B).
Each module exposes ``config()`` (full published config) and
``smoke_config()`` (reduced same-family config for CPU smoke tests).
"""

from __future__ import annotations

import importlib

from repro.config import ModelConfig

ARCH_IDS = (
    "whisper-medium",
    "smollm-360m",
    "mistral-nemo-12b",
    "smollm-135m",
    "stablelm-1.6b",
    "granite-moe-3b-a800m",
    "deepseek-v2-236b",
    "mamba2-370m",
    "qwen2-vl-2b",
    "jamba-1.5-large-398b",
    # the paper's own models
    "gemma2-2b",
    "mistral-7b",
)

_MODULES = {name: "repro.configs." + name.replace("-", "_").replace(".", "_")
            for name in ARCH_IDS}


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_IDS}")
    return importlib.import_module(_MODULES[name]).config()


def get_smoke_config(name: str) -> ModelConfig:
    return importlib.import_module(_MODULES[name]).smoke_config()
