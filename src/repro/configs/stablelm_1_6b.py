"""stablelm-1.6b [dense] — [hf:stabilityai/stablelm-2-1_6b; unverified].

24L, d_model=2048, 32 heads (MHA: kv=32), d_ff=5632, vocab=100352.
StableLM-2 uses LayerNorm; its 25%-partial rotary embedding is simplified
to full RoPE here (noted deviation; unverified-tier source).
"""

from repro.config import LayerDesc, LayerLayout, MemComConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b",
        family="dense",
        layout=LayerLayout.uniform(LayerDesc("attn", "dense"), 24),
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        d_ff=5632,
        vocab_size=100352,
        norm_type="layernorm",
        rope_theta=10_000.0,
        tie_embeddings=False,
        max_seq=40_960,
        memcom=MemComConfig(num_memory_tokens=512),
        source="[hf:stabilityai/stablelm-2-1_6b; unverified]",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="stablelm-1.6b-smoke",
        layout=LayerLayout.uniform(LayerDesc("attn", "dense"), 3),
        d_model=128, num_heads=4, num_kv_heads=4, d_ff=256, vocab_size=512,
        max_seq=256, memcom=MemComConfig(num_memory_tokens=8), dtype="float32",
        source="reduced smoke",
    )
