"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

28L backbone, d_model=1536, 12 heads (GQA kv=2), head_dim=128,
d_ff=8960, vocab=151936, qkv bias, M-RoPE sections (t,h,w)=(16,24,24).
The vision frontend is the assignment's stub: ``input_specs`` provides
precomputed patch embeddings merged into the token stream, with 3-D
position ids.
"""

from repro.config import LayerDesc, LayerLayout, MemComConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b",
        family="vlm",
        layout=LayerLayout.uniform(LayerDesc("attn", "dense"), 28),
        d_model=1536,
        num_heads=12,
        num_kv_heads=2,
        head_dim=128,
        d_ff=8960,
        vocab_size=151936,
        mrope_sections=(16, 24, 24),
        attn_qkv_bias=True,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        max_seq=40_960,
        memcom=MemComConfig(num_memory_tokens=512),
        source="[arXiv:2409.12191; hf]",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="qwen2-vl-smoke",
        layout=LayerLayout.uniform(LayerDesc("attn", "dense"), 3),
        d_model=96, num_heads=4, num_kv_heads=2, head_dim=32, d_ff=192,
        vocab_size=512, mrope_sections=(4, 6, 6),
        max_seq=256, memcom=MemComConfig(num_memory_tokens=8), dtype="float32",
        source="reduced smoke",
    )
