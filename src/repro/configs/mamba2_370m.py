"""mamba2-370m [ssm] — SSD (state-space duality) [arXiv:2405.21060; unverified].

48L, d_model=1024, attention-free mixer-only blocks (d_ff=0),
vocab=50280, ssm_state=128, headdim=64 (d_inner=2048 → 32 heads).

MemCom is inapplicable (no KV / cross-attention target — DESIGN.md
§Arch-applicability); the arch is implemented without the technique and
the serving engine snapshots the post-prompt SSM state, which natively
achieves O(1) prompt memory.
"""

from repro.config import LayerDesc, LayerLayout, MambaConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        family="ssm",
        layout=LayerLayout.uniform(LayerDesc("mamba", "none"), 48),
        d_model=1024,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        mamba=MambaConfig(d_state=128, headdim=64, expand=2, chunk_size=256),
        pos_embed="none",
        tie_embeddings=True,
        max_seq=1_048_576,
        memcom=None,  # inapplicable — see module docstring
        source="[arXiv:2405.21060; unverified]",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="mamba2-370m-smoke",
        layout=LayerLayout.uniform(LayerDesc("mamba", "none"), 3),
        d_model=64, vocab_size=512,
        mamba=MambaConfig(d_state=16, headdim=16, expand=2, chunk_size=16),
        max_seq=256, dtype="float32",
        source="reduced smoke",
    )
