"""Synthetic pretraining corpus (CPU-scale stand-in for FineWebEdu+SlimPajama).

Two mixed stream kinds, deterministic in (seed, step) so the pipeline is
seekable — a restarted trainer resumes at the exact batch it crashed on:

* markov  — order-1 Markov "text" over the word-token range (a fixed random
  transition table per seed); teaches generic next-token structure.
* icl     — many-shot episodes: a fresh random key→label mapping per
  episode rendered as ``[SEP key ARROW label]`` shots.  Next-token training
  on these teaches induction (predict the label of a key seen earlier) —
  the structural core of the paper's large-label-set classification tasks.

Batches come pre-split into (source, target) at a split point drawn from
``split_choices`` (the paper's random split band, quantized to a few
values to bound recompilation).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple

import numpy as np


@dataclass(frozen=True)
class SyntheticVocab:
    num_keys: int = 64
    num_labels: int = 64
    num_words: int = 256

    PAD: int = 0
    BOS: int = 1
    SEP: int = 2
    ARROW: int = 3

    @property
    def key_base(self) -> int:
        return 4

    @property
    def label_base(self) -> int:
        return self.key_base + self.num_keys

    @property
    def word_base(self) -> int:
        return self.label_base + self.num_labels

    @property
    def size(self) -> int:
        return self.word_base + self.num_words

    def key(self, i) -> int:
        return self.key_base + i

    def label(self, i) -> int:
        return self.label_base + i

    def label_ids(self) -> np.ndarray:
        return np.arange(self.label_base, self.label_base + self.num_labels)


class PretrainStream:
    def __init__(self, vocab: SyntheticVocab, batch: int, seq_len: int,
                 split_choices: Tuple[int, ...], seed: int = 0,
                 icl_fraction: float = 0.7):
        assert all(s < seq_len for s in split_choices)
        self.vocab = vocab
        self.batch = batch
        self.seq_len = seq_len
        self.split_choices = split_choices
        self.seed = seed
        self.icl_fraction = icl_fraction
        base = np.random.default_rng(seed)
        # fixed markov transition table (sparse-ish: each word has 8 likely successors)
        W = vocab.num_words
        self._succ = base.integers(0, W, size=(W, 8))

    def _episode(self, rng: np.random.Generator, length: int) -> np.ndarray:
        v = self.vocab
        mapping = rng.integers(0, v.num_labels, size=v.num_keys)
        n_shots = length // 4
        keys = rng.integers(0, v.num_keys, size=n_shots)
        toks = np.empty((n_shots, 4), np.int32)
        toks[:, 0] = v.SEP
        toks[:, 1] = v.key_base + keys
        toks[:, 2] = v.ARROW
        toks[:, 3] = v.label_base + mapping[keys]
        flat = toks.reshape(-1)
        out = np.full((length,), v.PAD, np.int32)
        out[: flat.size] = flat
        return out

    def _markov(self, rng: np.random.Generator, length: int) -> np.ndarray:
        v = self.vocab
        W = v.num_words
        out = np.empty((length,), np.int32)
        cur = int(rng.integers(0, W))
        for i in range(length):
            out[i] = v.word_base + cur
            if rng.random() < 0.1:
                cur = int(rng.integers(0, W))
            else:
                cur = int(self._succ[cur, int(rng.integers(0, 8))])
        return out

    def batch_at(self, step: int) -> dict:
        """Deterministic batch for a step (seekable restart)."""
        rng = np.random.default_rng((self.seed, step))
        split = int(rng.choice(self.split_choices))
        toks = np.empty((self.batch, self.seq_len), np.int32)
        for b in range(self.batch):
            if rng.random() < self.icl_fraction:
                toks[b] = self._episode(rng, self.seq_len)
            else:
                toks[b] = self._markov(rng, self.seq_len)
        source = toks[:, :split]
        target = toks[:, split:]
        mask = (target != self.vocab.PAD).astype(np.float32)
        return {"source": source, "target": target, "target_mask": mask,
                "split": split}
