from repro.data.synthetic import SyntheticVocab, PretrainStream
from repro.data.icl_tasks import (ICLTaskSpec, make_episode, make_query,
                                  build_manyshot_prompt, eval_accuracy)
from repro.data.pipeline import Prefetcher

__all__ = [
    "SyntheticVocab",
    "PretrainStream",
    "ICLTaskSpec",
    "make_episode",
    "make_query",
    "build_manyshot_prompt",
    "eval_accuracy",
    "Prefetcher",
]
