"""Host data pipeline: background prefetch + host sharding + seekability.

On a real multi-host deployment each process constructs the stream with
its ``(host_id, num_hosts)`` slice and reads only its sub-batch; the
global step drives ``batch_at`` so every host stays in lockstep without a
data service.  Restart = seek to the checkpointed step (no replay/skip).
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Optional


class Prefetcher:
    """Runs ``producer(step)`` one step ahead on a background thread."""

    def __init__(self, producer: Callable[[int], dict], start_step: int = 0,
                 depth: int = 2):
        self.producer = producer
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._next = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._next
        while not self._stop.is_set():
            try:
                item = (step, self.producer(step))
            except Exception as e:  # surface producer errors to the consumer
                self.q.put((step, e))
                return
            while not self._stop.is_set():
                try:
                    self.q.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def get(self) -> tuple[int, dict]:
        step, item = self.q.get()
        if isinstance(item, Exception):
            raise item
        return step, item

    def stop(self):
        self._stop.set()


def host_slice(batch_size: int, host_id: int, num_hosts: int) -> slice:
    assert batch_size % num_hosts == 0
    per = batch_size // num_hosts
    return slice(host_id * per, (host_id + 1) * per)
