"""Downstream ICL evaluation tasks (paper §3, Table 1, App. A.3).

A task instance is a random key→label mapping with a large label set;
prompts are built with the paper's class-balanced round-robin procedure:
iterate over labels, append one random shot of that label, repeat until
the token budget is (nearly) filled, drop the overflowing shot.

The *fewer-shots baseline* at compression ratio r is simply
``build_manyshot_prompt(..., budget=t // r)`` — identical construction,
smaller budget — exactly the paper's strongest simple baseline.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from repro.data.synthetic import SyntheticVocab


@dataclass(frozen=True)
class ICLTaskSpec:
    vocab: SyntheticVocab
    num_labels: int  # labels used by this task (<= vocab.num_labels)
    keys_per_label: int = 2
    shot_tokens: int = 4  # [SEP key ARROW label]


def make_episode(task: ICLTaskSpec, rng: np.random.Generator) -> dict:
    """Sample a task instance: an injective-ish key→label mapping."""
    v = task.vocab
    n_keys = task.num_labels * task.keys_per_label
    assert n_keys <= v.num_keys, (n_keys, v.num_keys)
    keys = rng.permutation(v.num_keys)[:n_keys]
    labels = np.repeat(np.arange(task.num_labels), task.keys_per_label)
    return {"keys": keys, "labels": labels}


def build_manyshot_prompt(task: ICLTaskSpec, episode: dict,
                          rng: np.random.Generator, budget: int) -> np.ndarray:
    """Class-balanced round-robin shots within a token budget (App. A.3)."""
    v = task.vocab
    by_label = [episode["keys"][episode["labels"] == c]
                for c in range(task.num_labels)]
    toks: list[int] = []
    while True:
        added = False
        order = rng.permutation(task.num_labels)
        for c in order:
            if len(toks) + task.shot_tokens > budget:
                return np.asarray(toks, np.int32)
            k = int(rng.choice(by_label[c]))
            toks.extend([v.SEP, v.key(k), v.ARROW, v.label(c)])
            added = True
        if not added:
            return np.asarray(toks, np.int32)


def make_query(task: ICLTaskSpec, episode: dict, prompt: np.ndarray,
               rng: np.random.Generator) -> tuple[np.ndarray, int]:
    """A query over a key that appears in the *full* prompt; answer label."""
    v = task.vocab
    seen_keys = prompt.reshape(-1, task.shot_tokens)[:, 1] - v.key_base
    k = int(rng.choice(seen_keys))
    label = int(episode["labels"][np.where(episode["keys"] == k)[0][0]])
    return np.asarray([v.SEP, v.key(k), v.ARROW], np.int32), label


def eval_accuracy(predict_label: Callable[[np.ndarray, np.ndarray], int],
                  task: ICLTaskSpec, *, budget: int, n_episodes: int = 20,
                  queries_per_episode: int = 20, seed: int = 0,
                  query_budget: Optional[int] = None) -> float:
    """predict_label(context_tokens, query_tokens) -> label index.

    ``query_budget`` (when given) builds queries against the FULL-budget
    prompt but evaluates the model on a truncated ``budget`` context —
    the fewer-shots-baseline protocol (queries may be unanswerable from
    the truncated context, which is exactly the failure mode measured).
    """
    rng = np.random.default_rng(seed)
    full_budget = query_budget or budget
    correct = total = 0
    for _ in range(n_episodes):
        episode = make_episode(task, rng)
        full_prompt = build_manyshot_prompt(task, episode, rng, full_budget)
        context = full_prompt[:budget] if budget < full_budget else full_prompt
        # drop a trailing partial shot
        context = context[: (len(context) // task.shot_tokens) * task.shot_tokens]
        for _ in range(queries_per_episode):
            q, label = make_query(task, episode, full_prompt, rng)
            pred = predict_label(context, q)
            correct += int(pred == label)
            total += 1
    return correct / max(total, 1)
