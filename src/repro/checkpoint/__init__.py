from repro.checkpoint.store import save_tree, load_tree
from repro.checkpoint.manager import CheckpointManager

__all__ = ["save_tree", "load_tree", "CheckpointManager"]
