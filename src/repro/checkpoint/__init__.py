from repro.checkpoint.store import (compress_bytes, decompress_bytes,
                                    default_codec, load_tree, save_tree)
from repro.checkpoint.manager import CheckpointManager

__all__ = ["save_tree", "load_tree", "CheckpointManager",
           "compress_bytes", "decompress_bytes", "default_codec"]
