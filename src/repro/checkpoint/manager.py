"""Checkpoint lifecycle: rotation, latest-pointer, preemption safety,
elastic restore.

Directory layout::

    <root>/step_00001200/   # one store.save_tree dir per retained step
    <root>/step_00001500/
    <root>/PREEMPTED        # flag file a cluster agent drops before kill

``restore_latest`` returns numpy trees; the trainer ``device_put``s them
with the current mesh's shardings, so a checkpoint written on any mesh
restores onto any other (elastic re-shard — tested in
tests/test_fault_tolerance.py).
"""

from __future__ import annotations

import os
import re
from typing import Any, Dict, Optional

from repro.checkpoint.store import load_tree, save_tree

_STEP_RE = re.compile(r"^step_(\d{8})$")


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)

    def _step_dirs(self):
        out = []
        for name in os.listdir(self.root):
            m = _STEP_RE.match(name)
            if m and not name.endswith(".tmp"):
                out.append((int(m.group(1)), os.path.join(self.root, name)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        dirs = self._step_dirs()
        return dirs[-1][0] if dirs else None

    def available_steps(self) -> list:
        return [s for s, _ in self._step_dirs()]

    def save(self, step: int, tree: Any, meta: Optional[Dict] = None) -> str:
        meta = dict(meta or {}, step=step)
        path = os.path.join(self.root, f"step_{step:08d}")
        save_tree(path, tree, meta)
        self._rotate()
        return path

    def restore(self, step: int, template: Any = None):
        path = os.path.join(self.root, f"step_{step:08d}")
        return load_tree(path, template)

    def restore_latest(self, template: Any = None):
        step = self.latest_step()
        if step is None:
            return None, None, None
        tree, meta = self.restore(step, template)
        return step, tree, meta

    def _rotate(self):
        import shutil

        dirs = self._step_dirs()
        while len(dirs) > self.keep:
            _, path = dirs.pop(0)
            shutil.rmtree(path)

    # ---- preemption protocol ----

    def preempted(self) -> bool:
        return os.path.exists(os.path.join(self.root, "PREEMPTED"))

    def flag_preemption(self) -> None:
        """What the cluster agent does before SIGKILL (tests simulate it)."""
        with open(os.path.join(self.root, "PREEMPTED"), "w") as f:
            f.write("1")

    def clear_preemption(self) -> None:
        flag = os.path.join(self.root, "PREEMPTED")
        if os.path.exists(flag):
            os.remove(flag)
