"""Sharded, compressed, atomic checkpoint store.

Layout (one directory per checkpoint)::

    <dir>/manifest.msgpack       # treedef paths, shapes, dtypes, shard map, codec, user meta
    <dir>/shard_00000.bin.zst    # concatenated raw leaf bytes, compressed

Leaves are grouped into ~``shard_bytes`` shards so very large trees write
many independently-compressible files (on a real cluster each host writes
its own shards; here one process writes all).  Writes go to ``<dir>.tmp``
and are committed with an atomic rename, so a preempted save can never be
mistaken for a valid checkpoint.  Loading returns numpy arrays — callers
``device_put`` with whatever shardings the *current* mesh wants, which is
what makes restore elastic (any checkpoint loads onto any mesh size).

Compression codec: ``zstd`` when the optional :mod:`zstandard` package is
installed, otherwise ``zlib`` (stdlib).  The codec used at save time is
recorded in the manifest header, so any build can load any checkpoint
whose codec it has available (``raw`` always works).
"""

from __future__ import annotations

import os
import shutil
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import msgpack
import numpy as np

try:  # optional dependency — zlib fallback keeps the store importable
    import zstandard
except ImportError:
    zstandard = None

from repro.utils.pytree import tree_flatten_with_names

_DTYPE_FIX = {"bfloat16": "bfloat16"}  # ml_dtypes name passthrough

_SHARD_EXT = {"zstd": ".bin.zst", "zlib": ".bin.zz", "raw": ".bin"}


def default_codec() -> str:
    return "zstd" if zstandard is not None else "zlib"


def _shard_ext(codec: str) -> str:
    if codec not in _SHARD_EXT:
        raise ValueError(f"unknown checkpoint codec {codec!r}; "
                         f"choose from {sorted(_SHARD_EXT)}")
    return _SHARD_EXT[codec]


def compress_bytes(data: bytes, codec: Optional[str] = None,
                   level: int = 3) -> Tuple[str, bytes]:
    """Compress a byte string, returning ``(codec, payload)``.

    The returned codec tag is what the caller must record next to the
    payload (checkpoint manifests put it in their header, the serving
    disk tier in each prefix shard's header) and hand back to
    :func:`decompress_bytes` — payloads themselves are untagged streams,
    so any build can read any artifact whose codec it has available
    (``raw`` always works).  ``codec=None`` picks :func:`default_codec`.
    """
    codec = codec or default_codec()
    if codec == "zstd":
        if zstandard is None:
            raise ImportError("codec 'zstd' requires the zstandard package "
                              "(pip install zstandard)")
        return codec, zstandard.ZstdCompressor(level=level).compress(data)
    if codec == "zlib":
        return codec, zlib.compress(data, level)
    if codec == "raw":
        return codec, data
    raise ValueError(f"unknown checkpoint codec {codec!r}; "
                     f"choose from {sorted(_SHARD_EXT)}")


def decompress_bytes(data: bytes, codec: str) -> bytes:
    """Invert :func:`compress_bytes` given the recorded codec tag."""
    if codec == "zstd":
        if zstandard is None:
            raise ImportError("artifact was written with codec 'zstd' but "
                              "zstandard is not installed (pip install "
                              "zstandard, or re-save with codec='zlib')")
        return zstandard.ZstdDecompressor().decompress(data)
    if codec == "zlib":
        return zlib.decompress(data)
    if codec == "raw":
        return data
    raise ValueError(f"unknown checkpoint codec {codec!r}")


def _to_numpy(x):
    return np.asarray(x)


def save_tree(path: str, tree: Any, meta: Optional[Dict] = None,
              shard_bytes: int = 64 * 1024 * 1024, level: int = 3,
              codec: Optional[str] = None) -> None:
    codec = codec or default_codec()
    ext = _shard_ext(codec)
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    flat = tree_flatten_with_names(tree)
    entries = []
    shard_id, shard_buf, shard_size = 0, [], 0

    def flush():
        nonlocal shard_id, shard_buf, shard_size
        if not shard_buf:
            return
        data = b"".join(shard_buf)
        with open(os.path.join(tmp, f"shard_{shard_id:05d}{ext}"), "wb") as f:
            f.write(compress_bytes(data, codec, level)[1])
        shard_id += 1
        shard_buf, shard_size = [], 0

    for name, leaf in flat:
        arr = _to_numpy(leaf)
        raw = arr.tobytes()
        entries.append({
            "name": name,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "shard": shard_id,
            "offset": shard_size,
            "nbytes": len(raw),
        })
        shard_buf.append(raw)
        shard_size += len(raw)
        if shard_size >= shard_bytes:
            flush()
    flush()

    manifest = {"entries": entries, "meta": meta or {}, "num_shards": shard_id,
                "codec": codec}
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)  # atomic commit


def load_tree(path: str, template: Any = None):
    """Returns ({name: np.ndarray}, meta) or (tree, meta) if a template
    pytree (with matching names) is given."""
    import ml_dtypes  # noqa: F401  (registers bfloat16 with numpy)

    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    # pre-codec checkpoints carry no header entry and are always zstd
    codec = manifest.get("codec", "zstd")
    ext = _shard_ext(codec)
    shards = {}
    arrays = {}
    for e in manifest["entries"]:
        sid = e["shard"]
        if sid not in shards:
            with open(os.path.join(path, f"shard_{sid:05d}{ext}"), "rb") as f:
                shards[sid] = decompress_bytes(f.read(), codec)
        raw = shards[sid][e["offset"] : e["offset"] + e["nbytes"]]
        arr = np.frombuffer(raw, dtype=np.dtype(e["dtype"])).reshape(e["shape"])
        arrays[e["name"]] = arr
    if template is None:
        return arrays, manifest["meta"]
    names = [n for n, _ in tree_flatten_with_names(template)]
    leaves, treedef = jax.tree.flatten(template)
    out = [arrays[n] for n in names]
    return jax.tree.unflatten(treedef, out), manifest["meta"]
