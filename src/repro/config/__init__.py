from repro.config.base import (
    LayerDesc,
    LayerLayout,
    MoEConfig,
    MambaConfig,
    MLAConfig,
    EncoderConfig,
    MemComConfig,
    ModelConfig,
    ShapeSpec,
    SHAPES,
)

__all__ = [
    "LayerDesc",
    "LayerLayout",
    "MoEConfig",
    "MambaConfig",
    "MLAConfig",
    "EncoderConfig",
    "MemComConfig",
    "ModelConfig",
    "ShapeSpec",
    "SHAPES",
]
