"""Model / technique configuration dataclasses.

Every architecture in ``repro/configs`` instantiates a :class:`ModelConfig`.
The layer stack is described explicitly by a :class:`LayerLayout` —
an irregular ``prefix`` (unrolled) followed by a ``period`` of layer
descriptors scanned ``repeats`` times.  This keeps HLO size O(period)
regardless of depth and is how hybrid patterns (Jamba's 1-attn-per-8 with
MoE every other layer) are expressed without per-layer Python loops.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Layer descriptors
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LayerDesc:
    """One transformer block: a sequence mixer + a channel MLP."""

    mixer: str = "attn"  # "attn" | "mla" | "mamba"
    mlp: str = "dense"  # "dense" | "moe"
    cross_attn: bool = False  # enc-dec decoder blocks

    def tag(self) -> str:
        c = "+x" if self.cross_attn else ""
        return f"{self.mixer}/{self.mlp}{c}"


@dataclass(frozen=True)
class LayerLayout:
    """prefix (unrolled) + period × repeats (scanned)."""

    period: Tuple[LayerDesc, ...]
    repeats: int
    prefix: Tuple[LayerDesc, ...] = ()

    @property
    def num_layers(self) -> int:
        return len(self.prefix) + len(self.period) * self.repeats

    def descriptors(self) -> Tuple[LayerDesc, ...]:
        return self.prefix + self.period * self.repeats

    @staticmethod
    def uniform(desc: LayerDesc, num_layers: int) -> "LayerLayout":
        return LayerLayout(period=(desc,), repeats=num_layers)


# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_d_ff: int
    num_shared_experts: int = 0
    shared_d_ff: int = 0  # d_ff of the shared expert(s); defaults to expert_d_ff
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01
    router_dtype: str = "float32"
    # Dispatch locality: tokens are argsorted/capacitied within G
    # independent groups instead of one global sort.  With G = number of
    # data shards the whole dispatch (sort, cumsum, scatter) carries a
    # leading sharded group axis — no cross-shard gathers.  G=1 is the
    # single-group (global-sort) baseline; the launcher sets G to the
    # data-shard count (see EXPERIMENTS.md §Perf hillclimb 1).
    dispatch_groups: int = 1

    def shared_ff(self) -> int:
        return self.shared_d_ff or self.expert_d_ff


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 128
    headdim: int = 64
    expand: int = 2
    chunk_size: int = 256
    conv_width: int = 4
    ngroups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def nheads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.headdim


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_head_dim + self.qk_rope_head_dim


@dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style audio encoder operating on precomputed frame embeddings
    (the conv frontend is a stub per the assignment)."""

    num_layers: int = 24
    num_frames: int = 1500
    num_heads: int = 16
    d_ff: int = 4096


@dataclass(frozen=True)
class MemComConfig:
    """The paper's technique, as a first-class model feature."""

    num_memory_tokens: int = 512
    xattn_kind: str = "1head"  # "1head" | "mha" | "mqa"
    xattn_heads: int = 1  # used when kind != 1head
    # Hybrid archs: attention layers get MemCom xattn; mamba layers hand
    # off the exact post-source SSM state (beyond-paper adaptation).
    ssm_state_handoff: bool = True


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm | audio
    layout: LayerLayout
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    mla: Optional[MLAConfig] = None
    encoder: Optional[EncoderConfig] = None
    memcom: Optional[MemComConfig] = None

    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = ()  # Qwen2-VL M-RoPE (t, h, w)
    pos_embed: str = "rope"  # "rope" | "learned" | "none"
    norm_type: str = "rmsnorm"  # "rmsnorm" | "layernorm"
    norm_eps: float = 1e-6
    mlp_type: str = "swiglu"  # "swiglu" | "gelu_mlp" | "geglu"
    attn_qkv_bias: bool = False
    attn_logit_softcap: float = 0.0  # gemma2: 50.0
    final_logit_softcap: float = 0.0  # gemma2: 30.0
    embed_scale: bool = False  # gemma: h *= sqrt(d_model)
    tie_embeddings: bool = True
    max_seq: int = 8192
    dtype: str = "bfloat16"
    source: str = ""  # provenance note [source; verified-tier]

    # ---- derived -----------------------------------------------------

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def num_layers(self) -> int:
        return self.layout.num_layers

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), used for
        MODEL_FLOPS = 6*N*D roofline accounting."""
        n = self.vocab_size * self.d_model  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        for desc in self.layout.descriptors():
            n += self._mixer_params(desc) + self._mlp_params(desc)
            n += (2 if desc.mlp != "none" else 1) * self.d_model  # norms
        n += self.d_model  # final norm
        if self.encoder is not None:
            e = self.encoder
            per = 4 * self.d_model * self.d_model + 2 * self.d_model * e.d_ff + 2 * self.d_model
            n += e.num_layers * per + self.d_model
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        n = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        for desc in self.layout.descriptors():
            n += self._mixer_params(desc)
            if desc.mlp == "moe":
                m = self.moe
                per_expert = 3 * self.d_model * m.expert_d_ff
                n += m.top_k * per_expert + m.num_shared_experts * 3 * self.d_model * m.shared_ff()
                n += self.d_model * m.num_experts  # router
            else:
                n += self._mlp_params(desc)
            n += 2 * self.d_model
        n += self.d_model
        return n

    def _mixer_params(self, desc: LayerDesc) -> int:
        d = self.d_model
        if desc.mixer == "attn":
            n = d * self.num_heads * self.hd  # q
            n += 2 * d * self.num_kv_heads * self.hd  # k, v
            n += self.num_heads * self.hd * d  # o
            if desc.cross_attn:
                n *= 2
            return n
        if desc.mixer == "mla":
            m = self.mla
            n = d * m.q_lora_rank + m.q_lora_rank * self.num_heads * m.qk_head_dim
            n += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            n += m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
            n += self.num_heads * m.v_head_dim * d
            return n
        if desc.mixer == "mamba":
            mb = self.mamba
            di, ns, hd = mb.d_inner(d), mb.d_state, mb.headdim
            nh, ng = mb.nheads(d), mb.ngroups
            n = d * (2 * di + 2 * ng * ns + nh)  # in_proj (z, x, B, C, dt)
            n += mb.conv_width * (di + 2 * ng * ns)  # conv
            n += nh * 2 + di  # A_log, dt_bias? (nh each) + D (di? per-head) -> keep nh*3
            n += di * d  # out_proj
            return n
        raise ValueError(desc.mixer)

    def _mlp_params(self, desc: LayerDesc) -> int:
        d = self.d_model
        if desc.mlp == "none":
            return 0
        if desc.mlp == "moe":
            m = self.moe
            n = m.num_experts * 3 * d * m.expert_d_ff
            n += m.num_shared_experts * 3 * d * m.shared_ff()
            n += d * m.num_experts
            return n
        if self.mlp_type == "gelu_mlp":
            return 2 * d * self.d_ff
        return 3 * d * self.d_ff  # swiglu / geglu

    # ---- validation / (de)serialization ------------------------------

    def validate(self) -> None:
        assert self.layout.num_layers > 0
        if self.num_heads:
            assert self.num_heads % max(self.num_kv_heads, 1) == 0 or self.mla is not None
        for desc in self.layout.descriptors():
            if desc.mixer == "mamba":
                assert self.mamba is not None, f"{self.name}: mamba desc needs MambaConfig"
            if desc.mixer == "mla":
                assert self.mla is not None
            if desc.mlp == "moe":
                assert self.moe is not None
        if self.mrope_sections:
            assert sum(self.mrope_sections) == self.hd // 2, (
                f"mrope sections {self.mrope_sections} must sum to head_dim/2={self.hd // 2}"
            )

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2, default=str)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Assigned input shapes (same four for every LM-family arch)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"
    subquadratic_only: bool = False


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode", subquadratic_only=True),
)
