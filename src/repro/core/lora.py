"""LoRA adapters for the ICAE compressor family (paper §5.1, Fig. 3a).

The adapter tree sparsely mirrors the model parameter tree; each adapted
kernel ``w`` gets ``{"a": (in, r), "b": (r, out)}`` and the effective
weight is ``w + (alpha/r) * a @ b``, materialized in-graph before the
forward pass (one rank-r matmul per adapted kernel — negligible next to
the model itself, and it keeps the model code adapter-agnostic).
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.utils.rng import Keys


def init_lora(params, targets: Sequence[str], rank: int = 32,
              seed: int | Keys = 0, abstract: bool = False):
    """Build an adapter tree for every leaf whose name is in ``targets``
    (e.g. ("wq", "wk")) under an ``attn`` scope."""
    keys = seed if isinstance(seed, Keys) else Keys(seed)

    def walk(node, path):
        if not isinstance(node, dict):
            return None
        out = {}
        for name, child in node.items():
            if isinstance(child, dict):
                sub = walk(child, path + (name,))
                if sub:
                    out[name] = sub
            elif name in targets and "attn" in path and child.ndim >= 2:
                d_in, d_out = child.shape[-2], child.shape[-1]
                stack = child.shape[:-2]
                if abstract:
                    a = jax.ShapeDtypeStruct(stack + (d_in, rank), child.dtype)
                    bm = jax.ShapeDtypeStruct(stack + (rank, d_out), child.dtype)
                else:
                    k = keys("/".join(path + (name,)))
                    a = (d_in**-0.5 * jax.random.normal(
                        k, stack + (d_in, rank), jnp.float32)).astype(child.dtype)
                    bm = jnp.zeros(stack + (rank, d_out), child.dtype)
                out[name] = {"a": a, "b": bm}
        return out

    return walk(params, ()) or {}


def merge_lora(params, lora, alpha: float = 16.0, rank: int = 32):
    """Return params with LoRA deltas folded in (non-destructive)."""
    scale = alpha / rank

    def walk(p, l):
        if l is None:
            return p
        out = dict(p)
        for name, entry in l.items():
            if set(entry.keys()) == {"a", "b"}:
                out[name] = p[name] + scale * (entry["a"] @ entry["b"]).astype(p[name].dtype)
            else:
                out[name] = walk(p[name], entry)
        return out

    return walk(params, lora)
