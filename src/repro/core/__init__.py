# The paper's primary contribution: MemCom layer-wise many-shot compression.
from repro.core.memcom import (
    init_memcom,
    init_memx,
    compress,
    memcom_loss,
    next_token_loss,
    trainable_mask,
    build_prefix,
)
from repro.core.icae import (
    init_icae,
    icae_compress,
    icae_loss,
    icae_trainable_mask,
)
from repro.core.lora import merge_lora, init_lora

__all__ = [
    "init_memcom",
    "init_memx",
    "compress",
    "memcom_loss",
    "next_token_loss",
    "trainable_mask",
    "build_prefix",
    "init_icae",
    "icae_compress",
    "icae_loss",
    "icae_trainable_mask",
    "merge_lora",
    "init_lora",
]
