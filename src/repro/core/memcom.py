"""MemCom — the paper's contribution (§4), as a composable JAX module.

Parameter tree::

    {"source":     <transformer params, init = copy of target>,
     "memory_llm": <transformer params, init = copy of target>,
     "memx":       Layerwise cross-attention params (attn/mla layers only),
     "mem_tokens": (m, d) learnable memory token embeddings}

``compress`` runs the Source-LLM with per-layer capture, then the
Memory-LLM over the memory tokens with the compression cross-attention,
and packages the per-layer O^i as a *prefix* the frozen Target-LLM
consumes.  For hybrid (Jamba-style) architectures, Mamba layers hand off
the Source-LLM's exact final SSM state instead (DESIGN.md §4).

Training: Phase-1 trains only {memx, mem_tokens}; Phase-2 additionally
unfreezes {source, memory_llm}.  The target is frozen in both phases.

docs/ARCHITECTURE.md documents this parameter tree, the per-layer O^i
prefix formats, and the serving-time handoff in one place.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import transformer as tfm
from repro.models.param import ParamBuilder
from repro.models.xattn import init_memcom_xattn
from repro.utils.rng import Keys
from repro.utils.pytree import tree_map_with_path


def _needs_state_handoff(cfg: ModelConfig) -> bool:
    if cfg.memcom is None or not cfg.memcom.ssm_state_handoff:
        return False
    return any(d.mixer == "mamba" for d in cfg.layout.descriptors())


def init_memx(cfg: ModelConfig, seed: int | Keys = 0, abstract: bool = False):
    """Layerwise cross-attention params — only attn/mla layers get one."""
    keys = seed if isinstance(seed, Keys) else Keys(seed)
    b = ParamBuilder(keys, jnp.dtype(cfg.dtype), abstract)
    for i, desc in enumerate(cfg.layout.prefix):
        if desc.mixer in ("attn", "mla"):
            init_memcom_xattn(b.child("prefix").child(str(i)), cfg)
    if cfg.layout.repeats:
        pb = b.child("period", stack=cfg.layout.repeats)
        for j, desc in enumerate(cfg.layout.period):
            if desc.mixer in ("attn", "mla"):
                init_memcom_xattn(pb.child(f"l{j}"), cfg)
    params, _ = b.build()
    # repackage: {"prefix": [... or None], "period": {...}}
    out = {}
    if cfg.layout.prefix:
        out["prefix"] = [
            params.get("prefix", {}).get(str(i))
            for i in range(len(cfg.layout.prefix))
        ]
    if cfg.layout.repeats and params.get("period"):
        out["period"] = params["period"]
    return out


def memcom_axes(cfg: ModelConfig):
    """Logical-axis tree matching init_memcom structure (for sharding rules)."""
    keys = Keys(0)
    b = ParamBuilder(keys, jnp.dtype(cfg.dtype), abstract=True)
    for i, desc in enumerate(cfg.layout.prefix):
        if desc.mixer in ("attn", "mla"):
            init_memcom_xattn(b.child("prefix").child(str(i)), cfg)
    if cfg.layout.repeats:
        pb = b.child("period", stack=cfg.layout.repeats)
        for j, desc in enumerate(cfg.layout.period):
            if desc.mixer in ("attn", "mla"):
                init_memcom_xattn(pb.child(f"l{j}"), cfg)
    _, axes = b.build()
    memx_axes = {}
    if cfg.layout.prefix:
        memx_axes["prefix"] = [
            axes.get("prefix", {}).get(str(i))
            for i in range(len(cfg.layout.prefix))
        ]
    if cfg.layout.repeats and axes.get("period"):
        memx_axes["period"] = axes["period"]
    from repro.models.transformer import param_specs

    tgt_axes = param_specs(cfg)
    return {
        "source": tgt_axes,
        "memory_llm": tgt_axes,
        "memx": memx_axes,
        "mem_tokens": (None, "embed"),
    }


def init_memcom(cfg: ModelConfig, target_params, seed: int | Keys = 0,
                abstract: bool = False):
    assert cfg.memcom is not None, f"{cfg.name}: set ModelConfig.memcom"
    keys = seed if isinstance(seed, Keys) else Keys(seed)
    m = cfg.memcom.num_memory_tokens
    if abstract:
        copy = lambda t: jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
        mem_tokens = jax.ShapeDtypeStruct((m, cfg.d_model), jnp.dtype(cfg.dtype))
    else:
        copy = lambda t: jax.tree.map(jnp.array, t)
        mem_tokens = (cfg.d_model**-0.5 * jax.random.normal(
            keys("mem_tokens"), (m, cfg.d_model), jnp.float32)
        ).astype(jnp.dtype(cfg.dtype))
    return {
        "source": copy(target_params),
        "memory_llm": copy(target_params),
        "memx": init_memx(cfg, keys.child("memx"), abstract),
        "mem_tokens": mem_tokens,
    }


def compress(mc_params, cfg: ModelConfig, source_tokens=None, *,
             source_embeds=None, encoder_frames=None, remat: bool = False,
             unroll: bool = False, impl: str = "auto"):
    """Many-shot tokens (B, T) -> Layerwise compressed prefix for the target.

    Returns (prefix, info).  prefix entries: attn/mla -> {"h": O^i (B,m,D)};
    mamba -> {"ssm": final source state (B,H,P,N)}.
    """
    B = (source_tokens if source_tokens is not None else source_embeds).shape[0]
    mem = cfg.memcom.num_memory_tokens

    state_cache = None
    if _needs_state_handoff(cfg):
        state_cache = _mamba_only_cache(cfg, B)

    _, aux_s = tfm.forward(
        mc_params["source"], cfg, tokens=source_tokens, embeds=source_embeds,
        capture_hiddens=True,
        cache=state_cache, cache_index=0 if state_cache is not None else None,
        encoder_frames=encoder_frames, logits=False, remat=remat,
        unroll=unroll, impl=impl)

    mem_embeds = jnp.broadcast_to(
        mc_params["mem_tokens"][None], (B, mem, cfg.d_model)
    ).astype(mc_params["mem_tokens"].dtype)
    _, aux_m = tfm.forward(
        mc_params["memory_llm"], cfg, embeds=mem_embeds,
        memcom={"params": _memx_wrap(mc_params["memx"]), "src": aux_s["hiddens"]},
        encoder_out=aux_s["encoder_out"], logits=False, remat=remat,
        unroll=unroll, impl=impl)

    prefix = build_prefix(cfg, aux_m["omega"], aux_s["cache"])
    info = {"encoder_out": aux_s["encoder_out"]}
    return prefix, info


# ---------------------------------------------------------------------------
# Chunked (stateful) compression — the online-serving variant
# ---------------------------------------------------------------------------


@dataclass
class CompressionState:
    """Carry-over between :func:`compress_chunk` calls: the Source-LLM's
    cache (KV for attention/MLA continuation, conv/ssm recurrence for
    mamba) plus the per-layer hiddens H^i captured so far.

    The state lets a t-token shot set compile in fixed-budget slices —
    chunk k prefills positions [offset, offset+w) behind the cached
    [0, offset) context, exactly the engine's prefill-continuation path —
    so a serving loop can interleave compression with decode steps
    (:mod:`repro.serving.compiler`).
    """

    cache: dict                      # Layerwise source cache (functional)
    offset: int = 0                  # source tokens consumed so far
    hiddens: List[dict] = field(default_factory=list)  # per-chunk H^i
    encoder_out: Optional[jax.Array] = None


def begin_compress(cfg: ModelConfig, batch: int, total_len: int, *,
                   mc_params=None, encoder_frames=None,
                   impl: str = "auto") -> CompressionState:
    """Open a chunked compression over ``total_len`` source tokens.

    Allocates a full Source-LLM cache (attention KV *and* recurrent
    state — unlike the one-shot :func:`compress`, every family needs its
    running context carried across chunk boundaries).
    """
    encoder_out = None
    if cfg.encoder is not None and encoder_frames is not None:
        assert mc_params is not None, "encoder configs need mc_params"
        encoder_out = tfm.encode(mc_params["source"]["encoder"], cfg,
                                 encoder_frames, impl=impl)
    return CompressionState(cache=tfm.init_cache(cfg, batch, total_len),
                            encoder_out=encoder_out)


def compress_chunk(mc_params, cfg: ModelConfig, state: CompressionState,
                   tokens, *, impl: str = "auto") -> CompressionState:
    """Run the Source-LLM over one chunk of the shot set and fold the
    result into ``state``.  ``tokens`` is (B, w); ``state.offset`` must be
    a python int (the continuation slice is static, as in engine prefill —
    one trace per (width, offset) pair).  Returns the advanced state."""
    offset = state.offset
    assert isinstance(offset, int)
    _, aux = tfm.forward(
        mc_params["source"], cfg, tokens=tokens, capture_hiddens=True,
        cache=state.cache, cache_index=offset, mask_offset=offset,
        encoder_out=state.encoder_out, logits=False, impl=impl)
    return replace(state, cache=aux["cache"], offset=offset + tokens.shape[1],
                   hiddens=state.hiddens + [aux["hiddens"]])


def finish_compress(mc_params, cfg: ModelConfig, state: CompressionState, *,
                    impl: str = "auto"):
    """Close a chunked compression: concatenate the captured H^i along the
    source-time axis, run the Memory-LLM once over the m memory tokens,
    and package the per-layer prefix.  Same return shape as
    :func:`compress`."""
    assert state.hiddens, "no chunks were compressed"
    if len(state.hiddens) == 1:
        hiddens = state.hiddens[0]
    else:  # time is axis -2 in both sections ((B,T,D) / (repeats,B,T,D))
        hiddens = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=-2), *state.hiddens)
    B = jax.tree.leaves(hiddens)[0].shape[-3]
    mem = cfg.memcom.num_memory_tokens
    mem_embeds = jnp.broadcast_to(
        mc_params["mem_tokens"][None], (B, mem, cfg.d_model)
    ).astype(mc_params["mem_tokens"].dtype)
    _, aux_m = tfm.forward(
        mc_params["memory_llm"], cfg, embeds=mem_embeds,
        memcom={"params": _memx_wrap(mc_params["memx"]), "src": hiddens},
        encoder_out=state.encoder_out, logits=False, impl=impl)
    prefix = build_prefix(cfg, aux_m["omega"], state.cache)
    return prefix, {"encoder_out": state.encoder_out}


def compress_chunked(mc_params, cfg: ModelConfig, source_tokens, *,
                     chunk_size: int, encoder_frames=None,
                     impl: str = "auto"):
    """Chunked :func:`compress`: identical output, computed in
    ``chunk_size``-token slices with the Source-LLM cache carried across
    slices (parity asserted in ``tests/test_compiler.py``)."""
    T = source_tokens.shape[1]
    state = begin_compress(cfg, source_tokens.shape[0], T,
                           mc_params=mc_params,
                           encoder_frames=encoder_frames, impl=impl)
    for lo in range(0, T, chunk_size):
        state = compress_chunk(mc_params, cfg, state,
                               source_tokens[:, lo:lo + chunk_size],
                               impl=impl)
    return finish_compress(mc_params, cfg, state, impl=impl)


def _memx_wrap(memx):
    """Wrap each layer's xattn params under the key blocks expect."""
    out = {}
    if "prefix" in memx:
        out["prefix"] = [
            None if p is None else {"memx": p["memx"]} for p in memx["prefix"]
        ]
    if "period" in memx:
        out["period"] = {k: {"memx": v["memx"]} for k, v in memx["period"].items()}
    return out


def _mamba_only_cache(cfg: ModelConfig, batch: int):
    """A cache holding only mamba conv/ssm states (no KV allocation)."""
    from repro.models.mamba2 import init_mamba_cache

    prefix = [
        init_mamba_cache(cfg, batch, jnp.dtype(cfg.dtype))
        if desc.mixer == "mamba" else {}
        for desc in cfg.layout.prefix
    ]
    period = {}
    for j, desc in enumerate(cfg.layout.period):
        if desc.mixer != "mamba":
            continue
        one = init_mamba_cache(cfg, batch, jnp.dtype(cfg.dtype))
        period[f"l{j}"] = jax.tree.map(
            lambda x: jnp.zeros((cfg.layout.repeats,) + x.shape, x.dtype), one)
    return tfm.layerwise(prefix, period)


def build_prefix(cfg: ModelConfig, omega, source_cache):
    """Assemble the target's per-layer compressed context."""
    out = {}
    if cfg.layout.prefix:
        entries = []
        oi = 0
        omega_prefix = (omega or {}).get("prefix", [])
        for i, desc in enumerate(cfg.layout.prefix):
            if desc.mixer in ("attn", "mla"):
                entries.append({"h": omega_prefix[oi]})
                oi += 1
            else:
                entries.append({"ssm": source_cache["prefix"][i]["ssm"]})
        out["prefix"] = entries
    period = {}
    oi = 0
    omega_period_keys = sorted((omega or {}).get("period", {}).keys())
    for j, desc in enumerate(cfg.layout.period):
        key = f"l{j}"
        if desc.mixer in ("attn", "mla"):
            # omega period dict keys follow layer order among attn layers
            period[key] = {"h": omega["period"][key]}
        else:
            period[key] = {"ssm": source_cache["period"][key]["ssm"]}
    if period:
        out["period"] = period
    del oi, omega_period_keys
    return out


def memcom_loss(mc_params, target_params, cfg: ModelConfig, batch, *,
                remat: bool = False, unroll: bool = False, impl: str = "auto"):
    """Next-token CE on target-segment tokens (paper's training objective).

    batch: {"source": (B,T), "target": (B,S), "target_mask": (B,S)}.
    Labels are target shifted by one; the last position predicts nothing.
    """
    prefix, info = compress(
        mc_params, cfg, batch.get("source"),
        source_embeds=batch.get("source_embeds"),
        encoder_frames=batch.get("frames"), remat=remat, unroll=unroll,
        impl=impl)
    m = cfg.memcom.num_memory_tokens
    logits, aux = tfm.forward(
        target_params, cfg, tokens=batch["target"], prefix=prefix,
        mask_offset=m, encoder_out=info["encoder_out"], remat=remat,
        unroll=unroll, impl=impl)
    loss = next_token_loss(logits, batch["target"], batch.get("target_mask"))
    return loss + aux["moe_loss"], {"ce": loss, "moe": aux["moe_loss"]}


def next_token_loss(logits, tokens, mask=None):
    lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    tgt = tokens[:, 1:]
    ll = jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    if mask is not None:
        w = mask[:, 1:].astype(jnp.float32)
    else:
        w = jnp.ones_like(ll)
    return -(ll * w).sum() / jnp.maximum(w.sum(), 1.0)


def trainable_mask(mc_params, phase: int):
    """Bool pytree: which compressor params receive gradients."""
    if phase == 2:
        return jax.tree.map(lambda _: True, mc_params)

    def mark(path, _):
        return path.startswith("memx") or path.startswith("mem_tokens")

    return tree_map_with_path(mark, mc_params)
