"""ICAE / ICAE+ / ICAE++ baselines (paper §5.1, Fig. 3, Table 4).

One compressor LLM (a copy of the target): the source sequence is appended
with m learnable memory embeddings, one full forward pass is taken, and the
final-layer memory outputs become m soft tokens *prepended to the target's
input* — i.e. coarse final-layer compression, against which MemCom's
layer-wise compression is compared.

Variants (increasing compressor capacity):
  icae    — LoRA(r=32) on W_q, W_k            (original paper setup)
  icae+   — LoRA(r=32) on W_q, W_k, W_v, W_o
  icae++  — full attention modules trainable

Trained with next-token loss only (the AE loss destabilizes training —
paper App. A.2), matching MemCom's objective.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core.lora import init_lora, merge_lora
from repro.core.memcom import next_token_loss
from repro.models import transformer as tfm
from repro.utils.pytree import tree_map_with_path
from repro.utils.rng import Keys

VARIANTS = {
    "icae": ("wq", "wk"),
    "icae+": ("wq", "wk", "wv", "wo"),
    "icae++": (),  # full attention trainable, no LoRA
}


def init_icae(cfg: ModelConfig, target_params, variant: str = "icae++",
              seed: int | Keys = 0, abstract: bool = False):
    assert variant in VARIANTS, variant
    assert cfg.memcom is not None, "memcom config carries num_memory_tokens"
    keys = seed if isinstance(seed, Keys) else Keys(seed)
    m = cfg.memcom.num_memory_tokens
    if abstract:
        copy = lambda t: jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
        mem = jax.ShapeDtypeStruct((m, cfg.d_model), jnp.dtype(cfg.dtype))
    else:
        copy = lambda t: jax.tree.map(jnp.array, t)
        mem = (cfg.d_model**-0.5 * jax.random.normal(
            keys("mem_embed"), (m, cfg.d_model), jnp.float32)
        ).astype(jnp.dtype(cfg.dtype))
    targets = VARIANTS[variant]
    lora = (init_lora(target_params, targets, rank=32, seed=keys.child("lora"),
                      abstract=abstract) if targets else {})
    # NB: the variant is *not* stored in the tree (strings aren't jit-able
    # leaves); callers thread it explicitly.
    return {"compressor": copy(target_params), "lora": lora, "mem_embed": mem}


def icae_compress(ic_params, cfg: ModelConfig, source_tokens, *,
                  remat: bool = False, impl: str = "auto"):
    """(B, T) source tokens -> (B, m, D) soft memory tokens."""
    B, T = source_tokens.shape
    m = cfg.memcom.num_memory_tokens
    comp = ic_params["compressor"]
    if ic_params["lora"]:
        comp = merge_lora(comp, ic_params["lora"])
    src_emb = jnp.take(comp["embed"]["tokens"], source_tokens, axis=0)
    mem_emb = jnp.broadcast_to(ic_params["mem_embed"][None],
                               (B, m, cfg.d_model)).astype(src_emb.dtype)
    embeds = jnp.concatenate([src_emb, mem_emb], axis=1)
    hidden, _ = tfm.forward(comp, cfg, embeds=embeds, logits=False,
                            remat=remat, impl=impl)
    return hidden[:, T:, :]


def icae_loss(ic_params, target_params, cfg: ModelConfig, batch, *,
              remat: bool = False, impl: str = "auto"):
    """Soft memory prepended to target input; CE on target tokens."""
    soft = icae_compress(ic_params, cfg, batch["source"], remat=remat, impl=impl)
    tgt = batch["target"]
    m = soft.shape[1]
    tgt_emb = jnp.take(target_params["embed"]["tokens"], tgt, axis=0)
    embeds = jnp.concatenate([soft.astype(tgt_emb.dtype), tgt_emb], axis=1)
    logits, aux = tfm.forward(target_params, cfg, embeds=embeds,
                              remat=remat, impl=impl)
    loss = next_token_loss(logits[:, m:], tgt, batch.get("target_mask"))
    return loss + aux["moe_loss"], {"ce": loss, "moe": aux["moe_loss"]}


def icae_trainable_mask(ic_params, variant: str):
    def mark(path, _):
        if path.startswith("lora") or path.startswith("mem_embed"):
            return True
        if variant == "icae++" and path.startswith("compressor") and "/attn/" in path:
            return True
        return False

    return tree_map_with_path(mark, ic_params)
