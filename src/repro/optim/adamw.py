"""AdamW with trainable-parameter masking and fp32 master weights.

Optimizer state is a *flat name-keyed dict* holding entries only for
trainable leaves — frozen params (the Target-LLM in both MemCom phases,
~99% of the compressor in Phase-1) cost zero optimizer memory.  Flat
naming also makes the state trivially checkpointable and shardable (a
state entry inherits its param's sharding spec by name).

``{"mu": {name: f32}, "nu": {...}, "master": {...}, "count": i32}``
Master fp32 copies exist only for trainable params stored in lower
precision.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.utils.pytree import tree_flatten_with_names


class AdamW:
    def __init__(self, lr: Callable | float, b1: float = 0.9, b2: float = 0.999,
                 eps: float = 1e-8, weight_decay: float = 0.0,
                 mask: Optional[object] = None):
        self.lr = lr if callable(lr) else (lambda _: jnp.float32(lr))
        self.b1, self.b2, self.eps = b1, b2, eps
        self.weight_decay = weight_decay
        self.mask = mask

    def _trainable(self, params):
        names = [n for n, _ in tree_flatten_with_names(params)]
        if self.mask is None:
            return {n: True for n in names}
        mleaves = [bool(m) for _, m in tree_flatten_with_names(self.mask)]
        return dict(zip(names, mleaves))

    def init(self, params):
        flat = dict(tree_flatten_with_names(params))
        tr = self._trainable(params)
        mu = {n: jnp.zeros(p.shape, jnp.float32) for n, p in flat.items() if tr[n]}
        nu = {n: jnp.zeros(p.shape, jnp.float32) for n, p in flat.items() if tr[n]}
        master = {n: p.astype(jnp.float32) for n, p in flat.items()
                  if tr[n] and p.dtype != jnp.float32}
        return {"mu": mu, "nu": nu, "master": master,
                "count": jnp.zeros((), jnp.int32)}

    def step(self, params, grads, state):
        count = state["count"] + 1
        lr = self.lr(count)
        b1, b2, eps, wd = self.b1, self.b2, self.eps, self.weight_decay
        cf = count.astype(jnp.float32)
        bc1 = 1 - b1**cf
        bc2 = 1 - b2**cf

        leaves, treedef = jax.tree.flatten(params)
        names = [n for n, _ in tree_flatten_with_names(params)]
        gflat = dict(tree_flatten_with_names(grads))
        tr = self._trainable(params)

        new_leaves = []
        mu, nu, master = dict(state["mu"]), dict(state["nu"]), dict(state["master"])
        for n, p in zip(names, leaves):
            if not tr.get(n, False):
                new_leaves.append(p)
                continue
            g32 = gflat[n].astype(jnp.float32)
            mu[n] = b1 * mu[n] + (1 - b1) * g32
            nu[n] = b2 * nu[n] + (1 - b2) * (g32 * g32)
            p32 = master.get(n, p.astype(jnp.float32))
            step = (mu[n] / bc1) / (jnp.sqrt(nu[n] / bc2) + eps)
            p32 = p32 - lr * (step + wd * p32)
            if n in master:
                master[n] = p32
            new_leaves.append(p32.astype(p.dtype))
        new_params = jax.tree.unflatten(treedef, new_leaves)
        return new_params, {"mu": mu, "nu": nu, "master": master, "count": count}
