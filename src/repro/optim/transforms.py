"""Gradient transforms: clipping and communication compression.

``compress_grads_bf16`` emulates bf16 gradient all-reduce (half the DP
collective bytes); ``ErrorFeedbackInt8`` implements 1-byte quantized
gradient exchange with an error-feedback accumulator so the quantization
noise is unbiased over time (used by the shard_map DP path in the trainer;
convergence is test-asserted in tests/test_optim.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def compress_grads_bf16(grads):
    """Round-trip grads through bf16 — the cast that halves all-reduce bytes."""
    return jax.tree.map(
        lambda g: g.astype(jnp.bfloat16).astype(g.dtype), grads)


class ErrorFeedbackInt8:
    """Stateful int8 quantization with error feedback.

    q = round(g / s) clipped to [-127, 127] with per-leaf scale
    s = max|g| / 127; the residual (g - q*s) is carried into the next
    step's gradient, so the compressed sequence is asymptotically unbiased.
    """

    def init(self, grads):
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def compress(self, grads, err):
        gl, td = jax.tree.flatten(grads)
        el = jax.tree.leaves(err)
        qs, ss, es = [], [], []
        for g, e in zip(gl, el):
            gf = g.astype(jnp.float32) + e
            s = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
            q = jnp.clip(jnp.round(gf / s), -127, 127).astype(jnp.int8)
            qs.append(q)
            ss.append(s)
            es.append(gf - q.astype(jnp.float32) * s)
        return (jax.tree.unflatten(td, qs), jax.tree.unflatten(td, ss)), \
            jax.tree.unflatten(td, es)

    def decompress(self, compressed):
        q_tree, s_tree = compressed
        return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s,
                            q_tree, s_tree)
