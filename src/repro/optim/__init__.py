from repro.optim.adamw import AdamW
from repro.optim.schedule import warmup_cosine, warmup_constant
from repro.optim.transforms import (
    global_norm,
    clip_by_global_norm,
    compress_grads_bf16,
    ErrorFeedbackInt8,
)

__all__ = [
    "AdamW",
    "warmup_cosine",
    "warmup_constant",
    "global_norm",
    "clip_by_global_norm",
    "compress_grads_bf16",
    "ErrorFeedbackInt8",
]
