"""MemCom layer-wise 1-head cross-attention Pallas TPU kernel.

The paper's compression hot spot: at every transformer layer, m memory
queries attend over t source-token representations with a *single* head
of width d_model — ``O = softmax(Q K^T / sqrt(D)) V`` with
Q (B, m, D), K = V (B, t, D), m ≤ 2k, t ≤ 6k+, D up to 8192.

TPU adaptation (DESIGN.md §3): a 1-head attention offers no head axis to
batch over, so a generic attention kernel would issue one (m × t) matmul
with a D-wide contraction per layer — fine for the MXU only if the tiles
are staged right. We tile it as a blocked matmul pipeline in VMEM:

* grid ``(B, nm, nt)``, the t-axis innermost/sequential (online softmax
  state in scratch), m and batch parallel;
* Q tile (bm, D) stays resident across the whole t sweep (it is the
  reused operand: every K tile contracts against it);
* K/V tiles (bt, D) stream through; logits (bm, bt) never touch HBM;
* the D-wide contraction is the MXU-friendly axis — D is a multiple of
  128 for every assigned arch (576, 960, 1024, …, 8192), so the
  (bm × D)·(D × bt) product runs at full systolic occupancy without the
  head-dim padding waste a 64/80-wide head would suffer.

VMEM: Q + K + V tiles (bf16) + acc (bm, D, f32). At D = 8192 the acc
dominates: bm=128 → 4 MB acc + 2 MB Q + 2·(bt=256)·16 KB = 12 MB, under
budget; at the paper's own scales (D ≤ 4096) bm=256, bt=512 fits.
``_pick_blocks`` auto-sizes to the VMEM budget.

No mask: every memory token sees every source token (the paper's
compressor is bidirectional over the source), so padding of t is handled
with an explicit validity test on the block's global column index.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.pltpu_compat import CompilerParams as _CompilerParams

NEG_INF = -1e30
_VMEM_BUDGET = 12 * 1024 * 1024


def _xattn_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_scr, l_scr,
                  *, scale: float, t_total: int, block_t: int):
    it = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(it == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    q = q_ref[0]  # (bm, D)
    k = k_ref[0]  # (bt, D)
    v = v_ref[0]  # (bt, D)
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (bm, bt)
    col = it * block_t + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    logits = jnp.where(col < t_total, logits, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=-1, keepdims=True))
    p = jnp.exp(logits - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
    m_scr[...] = m_new
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    acc[...] = acc[...] * corr + pv

    @pl.when(it == nt - 1)
    def _finish():
        o_ref[0] = (acc[...] / jnp.maximum(l_scr[...], 1e-37)).astype(o_ref.dtype)


def _pick_blocks(D: int, itemsize: int) -> tuple[int, int]:
    """Largest (bm, bt) with acc + q + 2 kv tiles under the VMEM budget."""
    for bm, bt in ((512, 512), (256, 512), (256, 256), (128, 256),
                   (128, 128), (64, 128), (32, 128)):
        vmem = bm * D * 4 + bm * D * itemsize + 2 * bt * D * itemsize
        if vmem <= _VMEM_BUDGET:
            return bm, bt
    return 16, 128


@functools.partial(
    jax.jit, static_argnames=("scale", "block_m", "block_t", "interpret"))
def memcom_xattn(q, k, v, *, scale=None, block_m=None, block_t=None,
                 interpret=False):
    """(B,M,D) x (B,T,D) -> (B,M,D) 1-head cross attention, no mask."""
    B, M, D = q.shape
    T = k.shape[1]
    if scale is None:
        scale = D**-0.5
    auto_m, auto_t = _pick_blocks(D, q.dtype.itemsize)
    bm = min(block_m or auto_m, max(M, 8))
    bt = min(block_t or auto_t, max(T, 8))

    pad_m = (-M) % bm
    pad_t = (-T) % bt
    qp = jnp.pad(q, ((0, 0), (0, pad_m), (0, 0))) if pad_m else q
    kp = jnp.pad(k, ((0, 0), (0, pad_t), (0, 0))) if pad_t else k
    vp = jnp.pad(v, ((0, 0), (0, pad_t), (0, 0))) if pad_t else v
    nm, nt = (M + pad_m) // bm, (T + pad_t) // bt

    kernel = functools.partial(
        _xattn_kernel, scale=scale, t_total=T, block_t=bt)
    out = pl.pallas_call(
        kernel,
        grid=(B, nm, nt),
        in_specs=[
            pl.BlockSpec((1, bm, D), lambda b, im, it: (b, im, 0)),
            pl.BlockSpec((1, bt, D), lambda b, im, it: (b, it, 0)),
            pl.BlockSpec((1, bt, D), lambda b, im, it: (b, it, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, D), lambda b, im, it: (b, im, 0)),
        out_shape=jax.ShapeDtypeStruct((B, M + pad_m, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, D), jnp.float32),
            pltpu.VMEM((bm, 1), jnp.float32),
            pltpu.VMEM((bm, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.PARALLEL, pltpu.PARALLEL,
                                 pltpu.ARBITRARY)),
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :M]
