"""Grouped (per-expert) matmul Pallas TPU kernel for MoE expert compute.

``(E, C, D) x (E, D, F) -> (E, C, F)`` — the inner loop of the sort-based
capacity MoE (repro/models/moe.py): tokens are already bucketed into
per-expert capacity buffers, so expert compute is a batch of E
independent matmuls.

TPU mapping: grid ``(E, nc, nf, nd)`` with the contraction (D) axis
innermost/sequential accumulating into an f32 VMEM scratch tile, and the
expert / row / column axes parallel. Blocks are MXU-shaped
(bc × bd)·(bd × bf) with 128-aligned defaults; weights tiles are the
streamed operand (a fresh (bd, bf) slab per step), activation tiles are
reused across the f-sweep.

This layout is deliberately *not* a megablocks port (DESIGN.md §3): on
TPU the capacity-buffer formulation keeps every matmul dense and
identical in shape, which the MXU pipeline rewards far more than the
variable-size group handling megablocks does for CUDA warps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.pltpu_compat import CompilerParams as _CompilerParams


def _gmm_kernel(x_ref, w_ref, o_ref, acc):
    idd = pl.program_id(3)

    @pl.when(idd == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jax.lax.dot_general(
        x_ref[0], w_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(idd == pl.num_programs(3) - 1)
    def _finish():
        o_ref[0] = acc[...].astype(o_ref.dtype)


def _pad(x, mult, axis):
    p = (-x.shape[axis]) % mult
    if not p:
        return x
    w = [(0, 0)] * x.ndim
    w[axis] = (0, p)
    return jnp.pad(x, w)


@functools.partial(
    jax.jit,
    static_argnames=("block_c", "block_d", "block_f", "interpret"))
def gmm(x, w, *, block_c=128, block_d=512, block_f=512, interpret=False):
    """Per-expert matmul: (E,C,D) x (E,D,F) -> (E,C,F)."""
    E, C, D = x.shape
    _, _, F = w.shape
    bc = min(block_c, max(C, 8))
    bd = min(block_d, max(D, 8))
    bf = min(block_f, max(F, 8))
    xp = _pad(_pad(x, bc, 1), bd, 2)
    wp = _pad(_pad(w, bd, 1), bf, 2)
    Cp, Dp = xp.shape[1], xp.shape[2]
    Fp = wp.shape[2]

    out = pl.pallas_call(
        _gmm_kernel,
        grid=(E, Cp // bc, Fp // bf, Dp // bd),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, ic, jf, kd: (e, ic, kd)),
            pl.BlockSpec((1, bd, bf), lambda e, ic, jf, kd: (e, kd, jf)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, ic, jf, kd: (e, ic, jf)),
        out_shape=jax.ShapeDtypeStruct((E, Cp, Fp), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.PARALLEL, pltpu.PARALLEL,
                                 pltpu.PARALLEL, pltpu.ARBITRARY)),
        interpret=interpret,
    )(xp, wp)
    return out[:, :C, :F]
