"""Version shim for the Pallas TPU compiler-params class.

jax renamed ``TPUCompilerParams`` -> ``CompilerParams`` across 0.4.x
releases; every kernel in this package imports the resolved class from
here so the compatibility logic lives in exactly one place.
"""

from __future__ import annotations

import jax.experimental.pallas.tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams
