"""Public kernel entry points with backend dispatch.

Every op has three implementations:

* ``dense``  — :mod:`repro.kernels.ref` oracle (tiny shapes, tests)
* ``jnp``    — streaming :mod:`repro.kernels.jnp_impl` (CPU, dry-run lowering)
* ``pallas`` — TPU kernels in this package (``interpret=True`` on CPU tests)

``impl="auto"`` picks ``pallas`` on TPU backends and ``jnp`` elsewhere,
falling back to ``dense`` for very small problems where blocking overhead
dominates.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import jnp_impl, ref

_FORCED_IMPL: Optional[str] = None


def set_default_impl(impl: Optional[str]) -> None:
    """Force an implementation globally (None restores auto)."""
    global _FORCED_IMPL
    _FORCED_IMPL = impl


def _resolve(impl: str, small: bool) -> str:
    if impl != "auto":
        return impl
    if _FORCED_IMPL is not None:
        return _FORCED_IMPL
    if small:
        return "dense"
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def attention(q, k, v, *, q_pos, kv_pos, causal=True, softcap=0.0, scale=None,
              impl="auto", kv_chunk=1024, return_lse=False):
    """General position-masked GQA attention (prefix / decode / cross)."""
    small = q.shape[1] * k.shape[1] <= 256 * 256
    impl = _resolve(impl, small)
    if impl == "dense":
        out = ref.attention_ref(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                                causal=causal, softcap=softcap, scale=scale)
        if return_lse:
            # dense path recomputes lse explicitly (tests only)
            _, lse = jnp_impl.attention_chunked(
                q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=causal,
                softcap=softcap, scale=scale, kv_chunk=max(k.shape[1], 1),
                return_lse=True)
            return out, lse
        return out
    if impl == "pallas":
        from repro.kernels import flash_attention  # lazy: TPU-targeted

        return flash_attention.flash_attention(
            q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=causal,
            softcap=softcap, scale=scale, return_lse=return_lse,
            interpret=jax.default_backend() != "tpu")
    return jnp_impl.attention_chunked(
        q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=causal, softcap=softcap,
        scale=scale, kv_chunk=kv_chunk, return_lse=return_lse)


def self_attention_causal(q, k, v, *, offset=0, softcap=0.0, scale=None,
                          impl="auto", q_chunk=512, kv_chunk=512,
                          return_lse=False):
    """Pure causal self-attention (q_pos = kv_pos = offset + arange(S))."""
    S = q.shape[1]
    small = S * S <= 512 * 512
    impl = _resolve(impl, small)
    if impl == "dense":
        B = q.shape[0]
        pos = jnp.broadcast_to(offset + jnp.arange(S, dtype=jnp.int32), (B, S))
        out = ref.attention_ref(q, k, v, q_pos=pos, kv_pos=pos, causal=True,
                                softcap=softcap, scale=scale)
        if return_lse:
            _, lse = jnp_impl.attention_causal_blocked(
                q, k, v, offset=offset, softcap=softcap, scale=scale,
                q_chunk=min(q_chunk, S), kv_chunk=min(kv_chunk, S),
                return_lse=True)
            return out, lse
        return out
    if impl == "pallas":
        from repro.kernels import flash_attention

        B = q.shape[0]
        pos = jnp.broadcast_to(offset + jnp.arange(S, dtype=jnp.int32), (B, S))
        return flash_attention.flash_attention(
            q, k, v, q_pos=pos, kv_pos=pos, causal=True, softcap=softcap,
            scale=scale, return_lse=return_lse,
            interpret=jax.default_backend() != "tpu")
    return jnp_impl.attention_causal_blocked(
        q, k, v, offset=offset, softcap=softcap, scale=scale,
        q_chunk=q_chunk, kv_chunk=kv_chunk, return_lse=return_lse)


def _head_parallel(mesh, *operands, head_axis=2):
    """True when a mesh with a >1 "model" axis is installed and every
    head-carrying operand's head dim divides it — the condition for
    splitting a decode kernel by head (GQA: Hq and Hkv must both split)."""
    from repro.sharding.serving import model_axis_size

    n = model_axis_size(mesh)
    return n > 1 and all(x.shape[head_axis] % n == 0 for x in operands)


def decode_attention(q, k, v, *, lengths, softcap=0.0, scale=None,
                     impl="auto", kv_chunk=256, mesh=None):
    """Per-slot length-aware decode attention (continuous batching).

    ``q`` (B, S, Hq, D) holds each slot's last S tokens; ``k``/``v``
    (B, L, Hkv, D) are the full fixed-size caches; ``lengths`` (B,) int32 is
    each slot's total valid length *including* the S new tokens.  Slot ``b``
    attends causally within cache positions ``[0, lengths[b])`` — nothing
    beyond its own seated prefix + written tokens is visible, so slots with
    different compressed prefixes and ragged prompts share one batched step.

    The jnp path skips KV chunks beyond ``max(lengths)`` at runtime; the
    pallas path reuses the flash kernel with per-slot position masks.

    ``mesh``: tensor-parallel serving.  Q/K/V split on the head axis over
    the mesh's "model" axis while ``lengths`` stays replicated — the jnp
    path is pinned head-parallel via a sharding constraint (GSPMD handles
    the rest), the pallas path runs per-shard under ``shard_map`` (pallas
    has no GSPMD partitioning rule).  Heads that don't divide the axis
    fall back to the unsharded call.
    """
    B, S = q.shape[:2]
    small = S * k.shape[1] <= 256 * 256
    impl = _resolve(impl, small)
    if impl in ("dense", "pallas"):
        if impl == "pallas" and _head_parallel(mesh, q, k, v):
            from repro.sharding.serving import shard_map_heads

            def per_shard(qs, ks, vs, lens):
                return decode_attention(qs, ks, vs, lengths=lens,
                                        softcap=softcap, scale=scale,
                                        impl="pallas", mesh=None)

            return shard_map_heads(per_shard, mesh, head_args=3,
                                   replicated_args=1)(q, k, v, lengths)
        L = k.shape[1]
        slot = jnp.arange(L, dtype=jnp.int32)
        kv_pos = jnp.broadcast_to(slot[None, :], (B, L))
        q_pos = lengths[:, None] - S + jnp.arange(S, dtype=jnp.int32)[None, :]
        if impl == "dense":
            return ref.attention_ref(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                                     causal=True, softcap=softcap, scale=scale)
        from repro.kernels import flash_attention

        return flash_attention.flash_attention(
            q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=True,
            softcap=softcap, scale=scale,
            interpret=jax.default_backend() != "tpu")
    if _head_parallel(mesh, q, k, v):
        from repro.sharding.serving import constrain_heads

        q = constrain_heads(q, mesh)
        k = constrain_heads(k, mesh)
        v = constrain_heads(v, mesh)
    return jnp_impl.decode_attention_lengths(
        q, k, v, lengths=lengths, softcap=softcap, scale=scale,
        kv_chunk=kv_chunk)


def paged_decode_attention(q, k_pool, v_pool, *, block_tables, lengths,
                           softcap=0.0, scale=None, impl="auto", mesh=None):
    """Per-slot decode attention over a paged (block-pool) KV cache.

    ``q`` (B, S, Hq, D) holds each slot's last S tokens; ``k_pool`` /
    ``v_pool`` (num_blocks, block_size, Hkv, D) are the shared physical
    pools; ``block_tables`` (B, nb) int32 maps slot ``b``'s logical block
    ``j`` to a pool block; ``lengths`` (B,) is each slot's total valid
    length *including* the S new tokens.  Slot ``b`` attends causally
    within logical positions ``[0, lengths[b])`` — identical semantics to
    :func:`decode_attention` on the materialized view, but prefix blocks
    shared between slots are stored (and streamed) once.

    The jnp path gathers one ``(B, block_size, ...)`` chunk per table
    column and skips columns past ``max(lengths)``; the pallas path walks
    the tables with scalar-prefetched indices (one grid program per slot
    reusing the flash-decode inner loop); the dense path materializes each
    slot's view and defers to :func:`decode_attention`'s oracle.

    ``mesh``: tensor-parallel serving.  Q and the physical pools split on
    their head axis over the "model" mesh axis; ``block_tables`` and
    ``lengths`` are replicated on every shard (the table resolves block
    *indices*, identical per head shard — the control plane never shards).
    The jnp path is pinned head-parallel with a sharding constraint; the
    pallas path runs per-shard under ``shard_map``.
    """
    B, S = q.shape[:2]
    bs = k_pool.shape[1]
    L = block_tables.shape[1] * bs
    small = S * L <= 256 * 256
    impl = _resolve(impl, small)
    if impl == "dense":
        k = jnp_impl.paged_gather(k_pool, block_tables).astype(q.dtype)
        v = jnp_impl.paged_gather(v_pool, block_tables).astype(q.dtype)
        slot = jnp.arange(L, dtype=jnp.int32)
        kv_pos = jnp.broadcast_to(slot[None, :], (B, L))
        q_pos = lengths[:, None] - S + jnp.arange(S, dtype=jnp.int32)[None, :]
        return ref.attention_ref(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                                 causal=True, softcap=softcap, scale=scale)
    if impl == "pallas":
        if _head_parallel(mesh, q, k_pool, v_pool):
            from repro.sharding.serving import shard_map_heads

            def per_shard(qs, ks, vs, tbl, lens):
                return paged_decode_attention(
                    qs, ks, vs, block_tables=tbl, lengths=lens,
                    softcap=softcap, scale=scale, impl="pallas", mesh=None)

            return shard_map_heads(per_shard, mesh, head_args=3,
                                   replicated_args=2)(
                q, k_pool, v_pool, block_tables, lengths)
        from repro.kernels import paged_attention  # lazy: TPU-targeted

        return paged_attention.paged_flash_decode(
            q, k_pool, v_pool, block_tables=block_tables, lengths=lengths,
            softcap=softcap, scale=scale,
            interpret=jax.default_backend() != "tpu")
    if _head_parallel(mesh, q, k_pool, v_pool):
        from repro.sharding.serving import constrain_heads

        q = constrain_heads(q, mesh)
        k_pool = constrain_heads(k_pool, mesh)
        v_pool = constrain_heads(v_pool, mesh)
    return jnp_impl.paged_decode_attention_lengths(
        q, k_pool, v_pool, block_tables=block_tables, lengths=lengths,
        softcap=softcap, scale=scale)


def attention_with_prefix(q, k_self, v_self, k_pre, v_pre, *, pre_pos=None,
                          offset=None, softcap=0.0, scale=None, impl="auto"):
    """Causal self-attention plus a fully-visible KV prefix (MemCom memory).

    Computed as two FLOP-optimal partials merged exactly via log-sum-exp —
    the flash-decoding decomposition.  ``offset`` defaults to the prefix
    length (target tokens sit after the memory slots in RoPE space).
    """
    m = k_pre.shape[1]
    B = q.shape[0]
    if offset is None:
        offset = m
    if pre_pos is None:
        pre_pos = jnp.broadcast_to(jnp.arange(m, dtype=jnp.int32), (B, m))
    o_self, l_self = self_attention_causal(
        q, k_self, v_self, offset=offset, softcap=softcap, scale=scale,
        impl=impl, return_lse=True)
    q_pos = jnp.broadcast_to(
        offset + jnp.arange(q.shape[1], dtype=jnp.int32), (B, q.shape[1]))
    o_pre, l_pre = attention(
        q, k_pre, v_pre, q_pos=q_pos, kv_pos=pre_pos, causal=False,
        softcap=softcap, scale=scale, impl=impl, return_lse=True)
    return jnp_impl.combine_attention_partials([(o_self, l_self), (o_pre, l_pre)])


# ---------------------------------------------------------------------------
# MemCom layer-wise cross-attention (the paper's compressor hot spot)
# ---------------------------------------------------------------------------


def memcom_xattn(q, k, v, *, scale=None, impl="auto"):
    """1-head cross-attention, head width = d_model: (B,M,D)x(B,T,D)->(B,M,D)."""
    small = q.shape[1] * k.shape[1] <= 256 * 256
    impl = _resolve(impl, small)
    if impl == "dense":
        return ref.memcom_xattn_ref(q, k, v, scale=scale)
    if impl == "pallas":
        from repro.kernels import memcom_xattn as kx

        return kx.memcom_xattn(q, k, v, scale=scale,
                               interpret=jax.default_backend() != "tpu")
    # jnp streaming: reuse chunked attention with a single head
    B, M, D = q.shape
    T = k.shape[1]
    qh = q[:, :, None, :]
    kh = k[:, :, None, :]
    vh = v[:, :, None, :]
    q_pos = jnp.zeros((B, M), jnp.int32)
    kv_pos = jnp.zeros((B, T), jnp.int32)
    out = jnp_impl.attention_chunked(
        qh, kh, vh, q_pos=q_pos, kv_pos=kv_pos, causal=False, scale=scale,
        kv_chunk=1024)
    return out[:, :, 0, :]


# ---------------------------------------------------------------------------
# Grouped matmul (MoE expert compute)
# ---------------------------------------------------------------------------


def gmm(x, w, *, impl="auto"):
    """(E,C,D) x (E,D,F) -> (E,C,F) per-expert matmul."""
    small = x.shape[0] * x.shape[1] * x.shape[2] <= 64 * 64 * 64
    impl = _resolve(impl, small)
    if impl == "pallas":
        from repro.kernels import moe_gmm

        return moe_gmm.gmm(x, w, interpret=jax.default_backend() != "tpu")
    return ref.gmm_ref(x, w)


# ---------------------------------------------------------------------------
# Mamba2 SSD
# ---------------------------------------------------------------------------


def ssd(x, dt, A, Bm, Cm, *, init_state=None, chunk=256, impl="auto"):
    small = x.shape[1] <= 64
    impl = _resolve(impl, small)
    if impl == "dense":
        return ref.ssd_ref(x, dt, A, Bm, Cm, init_state=init_state)
    if impl == "pallas":
        from repro.kernels import ssd_scan

        return ssd_scan.ssd(x, dt, A, Bm, Cm, init_state=init_state,
                            chunk=chunk, interpret=jax.default_backend() != "tpu")
    return jnp_impl.ssd_chunked(x, dt, A, Bm, Cm, init_state=init_state, chunk=chunk)


ssd_decode_step = jnp_impl.ssd_decode_step

# paged-cache primitives (pure jnp, re-exported so model code depends on
# ops alone and the pallas kernel module stays a lazy import).
# paged_scatter(valid=) is the fused serving step's ragged-lane contract:
# lanes >= valid[b] are geometry padding and land in the trash block.
paged_scatter = jnp_impl.paged_scatter
paged_gather = jnp_impl.paged_gather
