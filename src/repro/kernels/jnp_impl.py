"""Streaming (flash-style) pure-jnp implementations.

These are the production paths on CPU and the dry-run lowering; the Pallas
kernels in this package implement the same contracts for the TPU target.
All return values match :mod:`repro.kernels.ref` oracles to float tolerance.

Design notes
------------
* ``attention_chunked`` — rectangular KV streaming with online softmax.
  O(Sq * kv_chunk) live memory instead of O(Sq * Skv).  Used for
  cross-/prefix-attention and decode.
* ``attention_causal_blocked`` — q-chunked with per-chunk KV scans that stop
  at the diagonal, so compiled FLOPs are causal-optimal (~2x less than a
  rectangular mask).  Requires q_pos = kv_pos = offset + arange(S) (pure
  self-attention), which the model guarantees by construction.
* Partial results carry (out, lse) so prefix attention and self attention
  can be combined exactly (flash-decoding style) via
  ``combine_attention_partials``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_fold(q, num_kv):
    B, Sq, Hq, Dk = q.shape
    return q.reshape(B, Sq, num_kv, Hq // num_kv, Dk)


def _apply_softcap(logits, softcap):
    if softcap:
        return softcap * jnp.tanh(logits / softcap)
    return logits


def attention_chunked(
    q, k, v, *, q_pos, kv_pos, causal=True, softcap=0.0, scale=None,
    kv_chunk=1024, return_lse=False,
):
    """Rectangular streaming attention with online softmax."""
    B, Sq, Hq, Dk = q.shape
    _, Skv, Hkv, _ = k.shape
    Dv = v.shape[-1]
    if scale is None:
        scale = Dk**-0.5
    kv_chunk = min(kv_chunk, Skv)
    pad = (-Skv) % kv_chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
    n_chunks = (Skv + pad) // kv_chunk

    qh = _gqa_fold(q, Hkv)  # (B,Sq,Hkv,G,Dk)
    G = Hq // Hkv

    def body(carry, xs):
        acc, m, l = carry
        kc, vc, pc = xs  # (B,C,Hkv,Dk) (B,C,Hkv,Dv) (B,C)
        logits = jnp.einsum("bqhgd,bchd->bqhgc", qh, kc).astype(jnp.float32) * scale
        logits = _apply_softcap(logits, softcap)
        valid = pc[:, None, :] >= 0
        if causal:
            valid = valid & (pc[:, None, :] <= q_pos[:, :, None])
        else:
            valid = jnp.broadcast_to(valid, (B, Sq, kv_chunk))
        logits = jnp.where(valid[:, :, None, None, :], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        # guard: keep m_new finite so exp() never sees (-inf) - (-inf)
        m_safe = jnp.maximum(m_new, NEG_INF)
        p = jnp.exp(logits - m_safe[..., None])
        corr = jnp.exp(m - m_safe)
        l = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bqhgc,bchd->bqhgd", p.astype(v.dtype), vc).astype(jnp.float32)
        acc = acc * corr[..., None] + pv
        return (acc, m_safe, l), None

    acc0 = jnp.zeros((B, Sq, Hkv, G, Dv), jnp.float32)
    m0 = jnp.full((B, Sq, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, G), jnp.float32)
    xs = (
        k.reshape(B, n_chunks, kv_chunk, Hkv, Dk).swapaxes(0, 1),
        v.reshape(B, n_chunks, kv_chunk, Hkv, Dv).swapaxes(0, 1),
        kv_pos.reshape(B, n_chunks, kv_chunk).swapaxes(0, 1),
    )
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), xs)
    out = (acc / jnp.maximum(l, 1e-37)[..., None]).astype(q.dtype)
    out = jnp.where((l > 0)[..., None], out, 0).reshape(B, Sq, Hq, Dv).astype(q.dtype)
    if return_lse:
        lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-37)), NEG_INF)
        return out, lse.reshape(B, Sq, Hq)
    return out


def attention_causal_blocked(
    q, k, v, *, offset=0, softcap=0.0, scale=None, q_chunk=512, kv_chunk=512,
    return_lse=False,
):
    """Causal self-attention, FLOP-optimal blocking.

    Assumes q_pos = kv_pos = offset + arange(S): blocks strictly above the
    diagonal are skipped *statically* so they never enter the HLO.
    """
    B, S, Hq, Dk = q.shape
    Hkv = k.shape[2]
    Dv = v.shape[-1]
    G = Hq // Hkv
    if scale is None:
        scale = Dk**-0.5
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, S)
    if S % q_chunk or S % kv_chunk or q_chunk % kv_chunk:
        # fall back to rectangular streaming with explicit positions
        pos = offset + jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
        return attention_chunked(
            q, k, v, q_pos=pos, kv_pos=pos, causal=True, softcap=softcap,
            scale=scale, kv_chunk=kv_chunk, return_lse=return_lse,
        )

    nq = S // q_chunk
    outs, lses = [], []
    tri = jnp.tril(jnp.ones((q_chunk, q_chunk), bool))

    for i in range(nq):
        qi = _gqa_fold(q[:, i * q_chunk : (i + 1) * q_chunk], Hkv)
        # ---- strictly-below-diagonal blocks: rectangular scan ----
        n_full = (i * q_chunk) // kv_chunk
        acc = jnp.zeros((B, q_chunk, Hkv, G, Dv), jnp.float32)
        m = jnp.full((B, q_chunk, Hkv, G), NEG_INF, jnp.float32)
        l = jnp.zeros((B, q_chunk, Hkv, G), jnp.float32)

        if n_full:
            def body(carry, xs, qi=qi):
                acc, m, l = carry
                kc, vc = xs
                logits = jnp.einsum("bqhgd,bchd->bqhgc", qi, kc).astype(jnp.float32) * scale
                logits = _apply_softcap(logits, softcap)
                m_new = jnp.maximum(m, logits.max(axis=-1))
                p = jnp.exp(logits - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l = l * corr + p.sum(axis=-1)
                pv = jnp.einsum("bqhgc,bchd->bqhgd", p.astype(v.dtype), vc).astype(jnp.float32)
                acc = acc * corr[..., None] + pv
                return (acc, m_new, l), None

            xs = (
                k[:, : n_full * kv_chunk].reshape(B, n_full, kv_chunk, Hkv, Dk).swapaxes(0, 1),
                v[:, : n_full * kv_chunk].reshape(B, n_full, kv_chunk, Hkv, Dv).swapaxes(0, 1),
            )
            (acc, m, l), _ = jax.lax.scan(body, (acc, m, l), xs)

        # ---- diagonal block: triangular mask ----
        kd = k[:, i * q_chunk : (i + 1) * q_chunk]
        vd = v[:, i * q_chunk : (i + 1) * q_chunk]
        logits = jnp.einsum("bqhgd,bchd->bqhgc", qi, kd).astype(jnp.float32) * scale
        logits = _apply_softcap(logits, softcap)
        logits = jnp.where(tri[None, :, None, None, :], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bqhgc,bchd->bqhgd", p.astype(v.dtype), vd).astype(jnp.float32)
        acc = acc * corr[..., None] + pv

        outs.append((acc / jnp.maximum(l, 1e-37)[..., None]).astype(q.dtype))
        lses.append(m_new + jnp.log(jnp.maximum(l, 1e-37)))

    out = jnp.concatenate(outs, axis=1).reshape(B, S, Hq, Dv)
    if return_lse:
        lse = jnp.concatenate(lses, axis=1).reshape(B, S, Hq)
        return out, lse
    return out


def decode_attention_lengths(
    q, k, v, *, lengths, softcap=0.0, scale=None, kv_chunk=256,
):
    """Per-slot length-masked decode attention with unseated-tail skipping.

    ``q`` holds each slot's last ``Sq`` tokens (cache positions
    ``lengths[b]-Sq .. lengths[b]-1``); ``k``/``v`` are the full fixed-size
    caches.  Slot ``b`` attends to cache positions ``< lengths[b]`` only, so
    ragged continuous-batching slots never see each other's unseated tail or
    stale KV from a previous occupant of the slot.

    KV chunks that start at or beyond ``max(lengths)`` are skipped at
    runtime via ``lax.cond`` — the cache is allocated at ``max_len`` but a
    young batch only pays for the chunks it has actually filled.
    """
    B, Sq, Hq, Dk = q.shape
    _, Skv, Hkv, _ = k.shape
    Dv = v.shape[-1]
    G = Hq // Hkv
    if scale is None:
        scale = Dk**-0.5
    kv_chunk = min(kv_chunk, Skv)
    pad = (-Skv) % kv_chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = (Skv + pad) // kv_chunk

    qh = _gqa_fold(q, Hkv)
    q_pos = lengths[:, None] - Sq + jnp.arange(Sq, dtype=jnp.int32)[None, :]
    live_end = jnp.max(lengths)  # chunks past this hold no seated KV at all

    def attend(carry, start):
        acc, m, l = carry
        kc = jax.lax.dynamic_slice_in_dim(k, start, kv_chunk, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(v, start, kv_chunk, axis=1)
        pos = start + jnp.arange(kv_chunk, dtype=jnp.int32)
        logits = jnp.einsum("bqhgd,bchd->bqhgc", qh, kc).astype(jnp.float32) * scale
        logits = _apply_softcap(logits, softcap)
        # pos <= q_pos already bounds pos < lengths[b] (q_pos max = lengths-1)
        valid = pos[None, None, :] <= q_pos[:, :, None]
        logits = jnp.where(valid[:, :, None, None, :], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        # exp(NEG_INF - NEG_INF) = 1: re-zero masked slots so a row with no
        # valid KV yet (lengths[b] < Sq) accumulates l = 0, not kv_chunk
        p = jnp.where(valid[:, :, None, None, :], p, 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bqhgc,bchd->bqhgd", p.astype(v.dtype), vc).astype(jnp.float32)
        acc = acc * corr[..., None] + pv
        return acc, m_new, l

    def body(carry, c):
        start = c * kv_chunk
        carry = jax.lax.cond(start < live_end, attend,
                             lambda carry, _start: carry, carry, start)
        return carry, None

    acc0 = jnp.zeros((B, Sq, Hkv, G, Dv), jnp.float32)
    m0 = jnp.full((B, Sq, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, G), jnp.float32)
    (acc, _, l), _ = jax.lax.scan(
        body, (acc0, m0, l0), jnp.arange(n_chunks, dtype=jnp.int32))
    out = (acc / jnp.maximum(l, 1e-37)[..., None]).astype(q.dtype)
    return jnp.where((l > 0)[..., None], out, 0).reshape(B, Sq, Hq, Dv)


# ---------------------------------------------------------------------------
# Paged KV cache primitives (vLLM-style block pool + per-slot block tables)
# ---------------------------------------------------------------------------


def paged_scatter(pool, new, block_tables, starts, valid=None):
    """Write ``new[b, s]`` into the block pool at logical cache position
    ``starts[b] + s`` of slot ``b``.

    ``pool`` is ``(num_blocks, block_size, ...)``; ``new`` is ``(B, S, ...)``
    with matching trailing dims; ``block_tables`` ``(B, num_table_cols)``
    int32 maps each slot's logical block ``j`` to a physical pool block;
    ``starts`` ``(B,)`` int32.  Positions are translated token-wise
    (``block = table[b, pos // bs]``, ``offset = pos % bs``) so a write may
    straddle physical blocks that are not adjacent in the pool.

    ``valid`` (B,) int32 (optional) is the ragged-lane mask for the fused
    serving step: only lanes ``s < valid[b]`` carry real tokens, the rest
    are geometry padding (speculative lanes past a slot's budget, chunk
    lanes of other slots).  Invalid lanes are routed to physical block 0 —
    the allocator's reserved trash block — so they can never corrupt an
    allocated block.  The table column is also clamped: an invalid lane's
    ``pos // bs`` may exceed the table width, and take_along_axis's clamp
    semantics would otherwise read the *last* column (a real block for a
    full slot)."""
    bs = pool.shape[1]
    B, S = new.shape[:2]
    pos = starts[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]  # (B,S)
    col = pos // bs
    if valid is not None:
        col = jnp.clip(col, 0, block_tables.shape[1] - 1)
    blk = jnp.take_along_axis(block_tables, col, axis=1)  # (B,S)
    if valid is not None:
        lane = jnp.arange(S, dtype=jnp.int32)[None, :]
        blk = jnp.where(lane < valid[:, None], blk, 0)  # 0 == trash block
    return pool.at[blk, pos % bs].set(new.astype(pool.dtype))


def paged_gather(pool, block_tables):
    """Materialize each slot's logical cache view from the pool:
    ``(num_blocks, bs, ...) x (B, nb) -> (B, nb*bs, ...)``."""
    B, nb = block_tables.shape
    view = pool[block_tables]  # (B, nb, bs, ...)
    return view.reshape(B, nb * pool.shape[1], *pool.shape[2:])


def paged_decode_attention_lengths(
    q, k_pool, v_pool, *, block_tables, lengths, softcap=0.0, scale=None,
):
    """Streaming paged decode attention: walk each slot's block table.

    Same contract as :func:`decode_attention_lengths` except K/V live in a
    shared ``(num_blocks, block_size, Hkv, D)`` pool and slot ``b``'s cache
    positions ``[j*bs, (j+1)*bs)`` resolve to pool block
    ``block_tables[b, j]``.  One gather of ``(B, bs, ...)`` per table column
    — never the materialized ``(B, nb*bs, ...)`` view — and columns at or
    beyond ``max(lengths)`` are skipped at runtime via ``lax.cond``.
    """
    B, Sq, Hq, Dk = q.shape
    bs, Hkv = k_pool.shape[1], k_pool.shape[2]
    Dv = v_pool.shape[-1]
    G = Hq // Hkv
    nb = block_tables.shape[1]
    if scale is None:
        scale = Dk**-0.5

    qh = _gqa_fold(q, Hkv)
    q_pos = lengths[:, None] - Sq + jnp.arange(Sq, dtype=jnp.int32)[None, :]
    live_end = jnp.max(lengths)

    def attend(carry, j):
        acc, m, l = carry
        blk = jax.lax.dynamic_slice_in_dim(block_tables, j, 1, axis=1)[:, 0]
        kc = k_pool[blk]  # (B, bs, Hkv, Dk)
        vc = v_pool[blk]
        pos = j * bs + jnp.arange(bs, dtype=jnp.int32)
        logits = jnp.einsum("bqhgd,bchd->bqhgc", qh, kc.astype(qh.dtype))
        logits = logits.astype(jnp.float32) * scale
        logits = _apply_softcap(logits, softcap)
        valid = pos[None, None, :] <= q_pos[:, :, None]
        logits = jnp.where(valid[:, :, None, None, :], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        p = jnp.where(valid[:, :, None, None, :], p, 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bqhgc,bchd->bqhgd", p.astype(vc.dtype), vc)
        acc = acc * corr[..., None] + pv.astype(jnp.float32)
        return acc, m_new, l

    def body(carry, j):
        carry = jax.lax.cond(j * bs < live_end, attend,
                             lambda carry, _j: carry, carry, j)
        return carry, None

    acc0 = jnp.zeros((B, Sq, Hkv, G, Dv), jnp.float32)
    m0 = jnp.full((B, Sq, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, G), jnp.float32)
    (acc, _, l), _ = jax.lax.scan(
        body, (acc0, m0, l0), jnp.arange(nb, dtype=jnp.int32))
    out = (acc / jnp.maximum(l, 1e-37)[..., None]).astype(q.dtype)
    return jnp.where((l > 0)[..., None], out, 0).reshape(B, Sq, Hq, Dv)


def combine_attention_partials(parts):
    """Exact combination of attention computed over disjoint KV sets.

    parts: list of (out (B,S,H,Dv), lse (B,S,H)).
    """
    lses = jnp.stack([p[1] for p in parts])  # (P,B,S,H)
    outs = jnp.stack([p[0] for p in parts])  # (P,B,S,H,Dv)
    m = lses.max(axis=0)
    w = jnp.exp(lses - m[None])  # (P,B,S,H)
    denom = w.sum(axis=0)
    w = w / jnp.maximum(denom, 1e-37)
    out = (outs.astype(jnp.float32) * w[..., None]).sum(axis=0)
    return out.astype(parts[0][0].dtype)


# ---------------------------------------------------------------------------
# Mamba2 SSD — chunked (state-space duality) implementation
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_chunked(x, dt, A, Bm, Cm, *, init_state=None, chunk=256):
    """Chunk-parallel SSD.  Same contract as :func:`repro.kernels.ref.ssd_ref`.

    Per chunk: quadratic intra-chunk term (attention-like, in matmul form,
    MXU-friendly) + inter-chunk state recurrence carried by a scan.
    """
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    if init_state is None:
        init_state = jnp.zeros((B, H, P, N), jnp.float32)
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk

    xf = x.astype(jnp.float32).reshape(B, nc, chunk, H, P).swapaxes(0, 1)
    dtf = dt.astype(jnp.float32).reshape(B, nc, chunk, H).swapaxes(0, 1)
    Bf = jnp.repeat(Bm.astype(jnp.float32), rep, axis=2).reshape(B, nc, chunk, H, N).swapaxes(0, 1)
    Cf = jnp.repeat(Cm.astype(jnp.float32), rep, axis=2).reshape(B, nc, chunk, H, N).swapaxes(0, 1)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def body(h, xs):
        xc, dtc, bc, cc = xs  # (B,Q,H,P) (B,Q,H) (B,Q,H,N) (B,Q,H,N)
        a = dtc * A[None, None, :]  # (B,Q,H) log-decay per step
        cum = jnp.cumsum(a, axis=1)  # inclusive
        # intra-chunk: y_i += sum_{j<=i} (C_i.B_j) exp(cum_i - cum_j) dt_j x_j
        decay = cum[:, :, None, :] - cum[:, None, :, :]  # (B,Q_i,Q_j,H)
        L = jnp.where(tri[None, :, :, None], jnp.exp(decay), 0.0)
        gcb = jnp.einsum("bihn,bjhn->bijh", cc, bc)
        w = gcb * L * dtc[:, None, :, :]  # (B,Qi,Qj,H)
        y = jnp.einsum("bijh,bjhp->bihp", w, xc)
        # inter-chunk: y_i += C_i . (h_prev * exp(cum_i))
        y = y + jnp.einsum("bihn,bhpn->bihp", cc * jnp.exp(cum)[..., None], h)
        # chunk state: h = h*exp(cum_last) + sum_j exp(cum_last - cum_j) dt_j B_j x_j^T
        seg = jnp.exp(cum[:, -1:, :] - cum)  # (B,Q,H)
        h = h * jnp.exp(cum[:, -1])[..., None, None] + jnp.einsum(
            "bjh,bjhp,bjhn->bhpn", seg * dtc, xc, bc
        )
        return h, y

    final, ys = jax.lax.scan(body, init_state, (xf, dtf, Bf, Cf))
    y = ys.swapaxes(0, 1).reshape(B, Sp, H, P)[:, :S]
    return y.astype(x.dtype), final


def ssd_decode_step(state, x, dt, A, Bm, Cm):
    """Single-token recurrent SSD update.

    state: (B,H,P,N); x: (B,H,P); dt: (B,H); Bm/Cm: (B,G,N).
    Returns (y (B,H,P), new_state).
    """
    H = x.shape[1]
    G = Bm.shape[1]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    dA = jnp.exp(dtf * A[None, :])
    state = state * dA[..., None, None] + (dtf[..., None] * x.astype(jnp.float32))[..., None] * Bh[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
    return y.astype(x.dtype), state
