"""Pure-jnp oracles for every kernel in this package.

These are the ground truth used by tests (``assert_allclose`` against both
the streaming jnp implementations and the Pallas kernels in interpret mode)
and by tiny-shape paths where blocking overhead is not worth it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,  # (B, Sq, Hq, Dk)
    k: jax.Array,  # (B, Skv, Hkv, Dk)
    v: jax.Array,  # (B, Skv, Hkv, Dv)
    *,
    q_pos: jax.Array,  # (B, Sq) int32
    kv_pos: jax.Array,  # (B, Skv) int32; -1 marks invalid slots
    causal: bool = True,
    softcap: float = 0.0,
    scale: float | None = None,
) -> jax.Array:
    """Dense reference attention with GQA and position-derived masking."""
    B, Sq, Hq, Dk = q.shape
    _, Skv, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    if scale is None:
        scale = Dk**-0.5
    qh = q.reshape(B, Sq, Hkv, G, Dk)
    logits = jnp.einsum("bqhgd,bshd->bhgqs", qh, k).astype(jnp.float32) * scale
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    valid = kv_pos[:, None, :] >= 0  # (B, 1, Skv)
    if causal:
        valid = valid & (kv_pos[:, None, :] <= q_pos[:, :, None])  # (B, Sq, Skv)
    else:
        valid = jnp.broadcast_to(valid, (B, Sq, Skv))
    logits = jnp.where(valid[:, None, None, :, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    # fully-masked rows -> zeros (softmax of -1e30 rows is uniform; re-mask)
    any_valid = jnp.any(valid, axis=-1)[:, None, None, :, None]
    p = jnp.where(any_valid, p, 0.0)
    out = jnp.einsum("bhgqs,bshd->bqhgd", p.astype(v.dtype), v)
    return out.reshape(B, Sq, Hq, v.shape[-1])


def memcom_xattn_ref(
    q: jax.Array,  # (B, M, D)   memory-token queries (single head of width D)
    k: jax.Array,  # (B, T, D)   source reps
    v: jax.Array,  # (B, T, D)
    *,
    scale: float | None = None,
) -> jax.Array:
    """The paper's 1-head cross-attention: m memory queries over t source
    tokens, head width = d_model, no mask."""
    D = q.shape[-1]
    if scale is None:
        scale = D**-0.5
    logits = jnp.einsum("bmd,btd->bmt", q, k).astype(jnp.float32) * scale
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bmt,btd->bmd", p.astype(v.dtype), v)


def gmm_ref(
    x: jax.Array,  # (E, C, D) expert input buffers
    w: jax.Array,  # (E, D, F)
) -> jax.Array:
    """Grouped (per-expert) matmul oracle: (E, C, F)."""
    return jnp.einsum("ecd,edf->ecf", x, w)


def ssd_ref(
    x: jax.Array,  # (B, S, H, P)   inputs per head
    dt: jax.Array,  # (B, S, H)     discretization steps (post-softplus)
    A: jax.Array,  # (H,)           negative decay rates
    Bm: jax.Array,  # (B, S, G, N)  input matrices (groups broadcast to heads)
    Cm: jax.Array,  # (B, S, G, N)
    *,
    init_state: jax.Array | None = None,  # (B, H, P, N)
) -> tuple[jax.Array, jax.Array]:
    """Sequential state-space-duality oracle.

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t x_t^T ;  y_t = C_t . h_t
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    B_, S, H, P = x.shape
    G = Bm.shape[2]
    N = Bm.shape[3]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2)  # (B,S,H,N)
    Ch = jnp.repeat(Cm, rep, axis=2)
    if init_state is None:
        init_state = jnp.zeros((B_, H, P, N), jnp.float32)

    def step(h, inputs):
        xt, dtt, bt, ct = inputs  # (B,H,P) (B,H) (B,H,N) (B,H,N)
        dA = jnp.exp(dtt * A[None, :])  # (B,H)
        h = h * dA[..., None, None] + (dtt[..., None] * xt)[..., None] * bt[:, :, None, :]
        y = jnp.einsum("bhpn,bhn->bhp", h, ct)
        return h, y

    xs = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
        jnp.moveaxis(Bh.astype(jnp.float32), 1, 0),
        jnp.moveaxis(Ch.astype(jnp.float32), 1, 0),
    )
    final, ys = jax.lax.scan(step, init_state, xs)
    y = jnp.moveaxis(ys, 0, 1)  # (B,S,H,P)
    return y.astype(x.dtype), final
