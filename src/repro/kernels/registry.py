"""Reference-twin registry for the pallas kernels.

Every public pallas kernel entry point must name a pure-jnp oracle here
— the function the parity tests (and the `impl="jnp"` dispatch path in
``ops.py``) compare it against.  reprolint's ``ref-twin`` rule fails the
build when a new kernel lands without an entry, or an entry points at a
function that no longer exists.

Keys are ``"<kernel module>:<public function>"``; values are
``"jnp_impl:<fn>"`` or ``"ref:<fn>"``.  The dict must stay a pure
literal — the linter reads it with ``ast.literal_eval`` without
importing jax.
"""

from __future__ import annotations

REFERENCE_TWINS = {
    # flash prefill/decode attention <-> O(S^2) masked reference
    "flash_attention:flash_attention": "ref:attention_ref",
    # MemCom compressor cross-attention (queries = memory slots)
    "memcom_xattn:memcom_xattn": "ref:memcom_xattn_ref",
    # grouped matmul behind the MoE dispatch
    "moe_gmm:gmm": "ref:gmm_ref",
    # paged decode attention <-> streaming jnp block-table walk
    "paged_attention:paged_flash_decode": "jnp_impl:paged_decode_attention_lengths",
    # mamba2 state-space chunked scan
    "ssd_scan:ssd": "ref:ssd_ref",
}


def resolve(key: str):
    """Import and return the twin callable for ``key`` (test helper —
    the linter never calls this; it parses the literal above)."""
    target = REFERENCE_TWINS[key]
    modname, fn = target.split(":")
    if modname == "jnp_impl":
        from repro.kernels import jnp_impl as mod
    elif modname == "ref":
        from repro.kernels import ref as mod
    else:  # pragma: no cover - registry validated by reprolint
        raise ValueError(f"unknown twin module {modname!r}")
    return getattr(mod, fn)
