"""Mamba2 SSD (state-space duality) chunked-scan Pallas TPU kernel.

Computes, per head, the SSD recurrence

    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t^T;   y_t = C_t . h_t

in chunk-parallel form: within a chunk of Q tokens the contribution is a
masked quadratic form (three MXU matmuls), between chunks a (P × N)
state carries the recurrence. This is the TPU-native phrasing of the
paper's SSD duality — the quadratic intra-chunk term *is* an attention-
like matmul and keeps the MXU busy, while the O(S) sequential part runs
once per chunk instead of once per token.

Grid ``(B, H, nc)`` — the chunk axis innermost/sequential with the
(P, N) state in f32 VMEM scratch; batch and head axes parallel. Blocks:
x (Q, P), dt (Q,), B/C (Q, N) (GQA-style groups resolved by ``h // rep``
in the index map), y (Q, P); A enters as a per-head scalar block.

Per chunk (all f32 in-kernel):
    a     = dt * A                     (Q,)   log-decay steps
    cum   = cumsum(a)                  (Q,)   inclusive
    L     = tril(exp(cum_i - cum_j))   (Q, Q) decay kernel
    w     = (C B^T) * L * dt_j         (Q, Q)
    y     = w @ x + (C * exp(cum)) @ state^T          intra + carry-in
    state = state * exp(cum_Q) + x^T @ (exp(cum_Q - cum) * dt * B)

VMEM: state P×N f32 (64×128 → 32 KB) + chunk tiles; Q=256, P=64, N=128
→ ~0.6 MB. The (Q, Q) decay kernel lives in registers/VMEM transiently.

Padding: S is padded to a chunk multiple with dt = 0 — exp(0·A) = 1 and
the input term carries dt as a factor, so padded steps are exact no-ops
on the state and the padded y rows are sliced off.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.pltpu_compat import CompilerParams as _CompilerParams


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref,
                y_ref, hf_ref, state, *, chunk: int):
    ic = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ic == 0)
    def _init():
        state[...] = h0_ref[0, 0].astype(jnp.float32)  # (P, N)

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (Q,)
    A = a_ref[0].astype(jnp.float32)  # scalar
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)  # (Q, N)
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)  # (Q, N)

    a = dt * A  # (Q,)
    cum = jnp.cumsum(a)  # (Q,)
    # intra-chunk decay kernel: L_ij = exp(cum_i - cum_j) for j <= i
    decay = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(jj <= ii, jnp.exp(decay), 0.0)

    gcb = jax.lax.dot_general(  # (Q, Q) = C . B^T
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    w = gcb * L * dt[None, :]
    y = jax.lax.dot_general(  # (Q, P) intra-chunk
        w, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    # carry-in: y += (C * exp(cum)) @ state^T  — (Q,N)·(N,P)
    y = y + jax.lax.dot_general(
        Cm * jnp.exp(cum)[:, None], state[...],
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # state update: state * exp(cum_Q) + x^T @ (exp(cum_Q - cum) * dt * B)
    seg = jnp.exp(cum[-1] - cum) * dt  # (Q,)
    state[...] = state[...] * jnp.exp(cum[-1]) + jax.lax.dot_general(
        x, seg[:, None] * Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ic == nc - 1)
    def _finish():
        hf_ref[0, 0] = state[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, A, Bm, Cm, *, init_state=None, chunk=256, interpret=False):
    """Same contract as :func:`repro.kernels.ref.ssd_ref`.

    x (B,S,H,P); dt (B,S,H); A (H,); Bm/Cm (B,S,G,N) →
    (y (B,S,H,P), final_state (B,H,P,N) f32).
    """
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Q = min(chunk, max(S, 8))
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))  # dt=0: exact no-op
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nc = Sp // Q
    if init_state is None:
        init_state = jnp.zeros((B, H, P, N), jnp.float32)

    kernel = functools.partial(_ssd_kernel, chunk=Q)
    y, hf = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, ic: (b, ic, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda b, h, ic: (b, ic, h)),
            pl.BlockSpec((1,), lambda b, h, ic: (h,)),
            pl.BlockSpec((1, Q, 1, N), lambda b, h, ic, rep=rep: (b, ic, h // rep, 0)),
            pl.BlockSpec((1, Q, 1, N), lambda b, h, ic, rep=rep: (b, ic, h // rep, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, ic: (b, ic, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Sp, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.PARALLEL, pltpu.PARALLEL,
                                 pltpu.ARBITRARY)),
        interpret=interpret,
    )(x, dt, A, Bm, Cm, init_state)
    return y[:, :S], hf
