"""Flash attention Pallas TPU kernel (GQA, position-masked, online softmax).

The one kernel behind every attention call in the framework: causal
self-attention (train/prefill), prefix attention (MemCom memory slots),
decode (1 query row against a long cache), and enc-dec cross attention —
all expressed through the (q_pos, kv_pos) contract of
:func:`repro.kernels.ref.attention_ref`.

TPU mapping
-----------
Grid ``(B, Hq, nq, nk)`` — the KV-block axis is innermost and
``ARBITRARY`` (sequential) so the online-softmax state for one (batch,
head, q-block) lives in VMEM scratch across its KV sweep; batch/head/
q-block axes are ``PARALLEL``. Blocks:

* q     (1, bq, 1, D)  — one head's q tile; D kept whole (128-aligned
  head dims: 64/80/128 pad to lane width once, not per block).
* k/v   (1, bk, 1, D)  — indexed by ``h // G`` (GQA: G q-heads share one
  KV head, so consecutive q-heads reuse the same KV tile; with the head
  axis PARALLEL adjacent programs hit VMEM-resident tiles).
* positions (1, bq)/(1, bk) int32 — drive masking inside the kernel; the
  causal test is ``kv_pos <= q_pos`` so decode, sliding windows, and
  MemCom's "memory slots visible to everyone" all reduce to position
  vectors, no mask tensors in HBM.

Scratch: acc (bq, D) f32, running max m and sum l (bq, 1) f32
=> VMEM footprint ≈ bq*D*4 + 2*(bq+bk)*D*2 bytes; defaults bq=bk=512,
D=128 ≈ 1.3 MB — triple-buffered comfortably under the 16 MB/core budget.

Block-level skip: a KV block whose minimum kv_pos exceeds the block's
maximum q_pos contributes nothing under the causal mask — `pl.when`
skips its matmuls (the flash causal ~2× FLOP saving, decided from the
loaded position tiles, so it also fires for decode where q_pos is a
cache offset, not a diagonal).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.pltpu_compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _attn_kernel(
    q_pos_ref, kv_pos_ref, q_ref, k_ref, v_ref,  # inputs
    o_ref, lse_ref,  # outputs
    acc, m_scr, l_scr,  # scratch
    *, scale: float, causal: bool, softcap: float, block_k: int,
):
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    q_pos = q_pos_ref[0]  # (bq,) int32
    kv_pos = kv_pos_ref[0]  # (bk,) int32

    def compute():
        q = q_ref[0, :, 0, :]  # (bq, D)
        k = k_ref[0, :, 0, :]  # (bk, D)
        v = v_ref[0, :, 0, :]  # (bk, D)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if softcap:
            logits = softcap * jnp.tanh(logits / softcap)
        valid = (kv_pos >= 0)[None, :]
        if causal:
            valid = valid & (kv_pos[None, :] <= q_pos[:, None])
        logits = jnp.where(valid, logits, NEG_INF)

        m_prev = m_scr[...]  # (bq, 1)
        m_new = jnp.maximum(m_prev, logits.max(axis=-1, keepdims=True))
        p = jnp.exp(logits - m_new)
        corr = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
        m_scr[...] = m_new
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc[...] = acc[...] * corr + pv

    if causal:
        # skip blocks strictly above the causal frontier (padding slots
        # carry kv_pos == -1 and never raise the block minimum)
        kv_lo = jnp.where(kv_pos >= 0, kv_pos, jnp.int32(2**30)).min()
        pl.when(kv_lo <= q_pos.max())(compute)
    else:
        compute()

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_scr[...]
        out = acc[...] / jnp.maximum(l, 1e-37)
        out = jnp.where(l > 0, out, 0.0)
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)
        lse = jnp.where(
            l > 0, m_scr[...] + jnp.log(jnp.maximum(l, 1e-37)), NEG_INF)
        lse_ref[0, :, 0] = lse[:, 0]


def _pad_to(x, mult, axis, value=0):
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "softcap", "scale", "block_q", "block_k",
                     "return_lse", "interpret"),
)
def flash_attention(
    q, k, v, *, q_pos, kv_pos, causal=True, softcap=0.0, scale=None,
    block_q=512, block_k=512, return_lse=False, interpret=False,
):
    """(B,Sq,Hq,D) x (B,Skv,Hkv,D) -> (B,Sq,Hq,Dv) [, lse (B,Sq,Hq)]."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, Dv = v.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    if scale is None:
        scale = D**-0.5

    bq = min(block_q, max(Sq, 8))
    bk = min(block_k, max(Skv, 8))
    qp = _pad_to(q, bq, axis=1)
    kp = _pad_to(k, bk, axis=1)
    vp = _pad_to(v, bk, axis=1)
    # padded q rows: positions below every valid kv so causal masks all;
    # padded kv slots: -1 marks invalid under both mask kinds
    q_pos_p = _pad_to(q_pos.astype(jnp.int32), bq, axis=1, value=-(2**30))
    kv_pos_p = _pad_to(kv_pos.astype(jnp.int32), bk, axis=1, value=-1)
    Sqp, Skvp = qp.shape[1], kp.shape[1]
    nq, nk = Sqp // bq, Skvp // bk

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, softcap=softcap,
        block_k=bk)
    out, lse = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq), lambda b, h, iq, ik: (b, iq)),
            pl.BlockSpec((1, bk), lambda b, h, iq, ik: (b, ik)),
            pl.BlockSpec((1, bq, 1, D), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, iq, ik: (b, ik, h // G, 0)),
            pl.BlockSpec((1, bk, 1, Dv), lambda b, h, iq, ik: (b, ik, h // G, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, 1, Dv), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, bq, 1), lambda b, h, iq, ik: (b, iq, h)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Sqp, Hq, Dv), q.dtype),
            jax.ShapeDtypeStruct((B, Sqp, Hq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, Dv), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.PARALLEL, pltpu.PARALLEL,
                                 pltpu.PARALLEL, pltpu.ARBITRARY)),
        interpret=interpret,
    )(q_pos_p, kv_pos_p, qp, kp, vp)

    out = out[:, :Sq]
    if return_lse:
        return out, lse[:, :Sq]
    return out
