"""Paged decode-attention Pallas TPU kernel (block-pool KV cache).

Serving counterpart of :mod:`repro.kernels.flash_attention`: K/V live in a
single ``(num_blocks, block_size, Hkv, D)`` pool per layer and each batch
slot owns a *block table* — a row of physical block ids — instead of a
contiguous cache stripe.  N slots seated on the same compressed ICL task
point at the same prefix blocks, so the pool holds each distinct task's
memory once (O(tasks), not O(slots)).

TPU mapping
-----------
Grid ``(B, Hq, nb)`` — one program per (slot, head) *walking that slot's
block table*; the block axis is innermost and ``ARBITRARY`` (sequential)
so the online-softmax state lives in VMEM scratch across the walk, exactly
the flash-decode inner loop.

The physical block to stream is data-dependent (``table[b, j]``), which a
plain ``BlockSpec`` index map cannot express — block tables and per-slot
lengths ride in as **scalar-prefetch** operands
(``pltpu.PrefetchScalarGridSpec``), available to the index maps before the
kernel body runs, so the pipeline DMAs pool block ``table[b, j]`` while
program ``j-1`` computes:

* q        (1, Sp, 1, D)   — the slot's last S query rows (padded to 8).
* k/v pool (1, bs, 1, D)   — block ``table[b*nb + j]``, KV head ``h // G``
  (GQA fold as in flash_attention).
* tables   (B*nb,) int32 SMEM — flattened so the index map stays 1-D.
* lengths  (B,)    int32 SMEM — drives masking *and* the per-slot early
  skip: a block whose start position is at or past ``lengths[b]`` is
  skipped via ``pl.when`` (idle slots cost ~nothing; young slots pay only
  for blocks they filled).

Unused table entries must still hold a *valid* pool index (the engine
keeps them at 0, a reserved scratch block) — they are never read into the
softmax because the length mask precedes them, but the DMA engine does
fetch whatever the index map names.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.pltpu_compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _paged_kernel(
    tbl_ref, len_ref,  # scalar prefetch (SMEM)
    q_ref, k_ref, v_ref,  # inputs
    o_ref,  # output
    acc, m_scr, l_scr,  # scratch
    *, scale: float, softcap: float, block_size: int, s_valid: int,
):
    b = pl.program_id(0)
    j = pl.program_id(2)
    nb = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    length = len_ref[b]
    start = j * block_size

    @pl.when(start < length)
    def _compute():
        q = q_ref[0, :, 0, :]  # (Sp, D)
        k = k_ref[0, :, 0, :]  # (bs, D)
        v = v_ref[0, :, 0, :]  # (bs, D)
        logits = jax.lax.dot_general(
            q, k.astype(q.dtype), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if softcap:
            logits = softcap * jnp.tanh(logits / softcap)
        Sp = q.shape[0]
        row = jax.lax.broadcasted_iota(jnp.int32, (Sp, block_size), 0)
        pos = start + jax.lax.broadcasted_iota(
            jnp.int32, (Sp, block_size), 1)
        # query row r sits at cache position length - s_valid + r; padded
        # rows (r >= s_valid) are masked out entirely
        q_pos = length - s_valid + row
        valid = (row < s_valid) & (pos <= q_pos)
        logits = jnp.where(valid, logits, NEG_INF)

        m_prev = m_scr[...]  # (Sp, 1)
        m_new = jnp.maximum(m_prev, logits.max(axis=-1, keepdims=True))
        p = jnp.exp(logits - m_new)
        p = jnp.where(valid, p, 0.0)  # exp(NEG_INF - NEG_INF) = 1 guard
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
        m_scr[...] = m_new
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc[...] = acc[...] * corr + pv

    @pl.when(j == nb - 1)
    def _finish():
        l = l_scr[...]
        out = acc[...] / jnp.maximum(l, 1e-37)
        out = jnp.where(l > 0, out, 0.0)
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("softcap", "scale", "interpret"),
)
def paged_flash_decode(
    q, k_pool, v_pool, *, block_tables, lengths, softcap=0.0, scale=None,
    interpret=False,
):
    """(B,S,Hq,D) x pool (N,bs,Hkv,D) x tables (B,nb) -> (B,S,Hq,Dv).

    Slot ``b`` attends causally within its logical cache positions
    ``[0, lengths[b])``; logical block ``j`` resolves to pool block
    ``block_tables[b, j]``.
    """
    B, S, Hq, D = q.shape
    _, bs, Hkv, Dv = v_pool.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    nb = block_tables.shape[1]
    if scale is None:
        scale = D**-0.5

    # pad query rows to the 8-sublane floor; padded rows are masked via
    # the in-kernel row < s_valid test and sliced off below
    Sp = max(S, 8)
    if Sp != S:
        q = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))

    tables_flat = block_tables.astype(jnp.int32).reshape(-1)  # (B*nb,)
    lengths = lengths.astype(jnp.int32)

    kernel = functools.partial(
        _paged_kernel, scale=scale, softcap=softcap, block_size=bs,
        s_valid=S)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hq, nb),
        in_specs=[
            pl.BlockSpec((1, Sp, 1, D), lambda b, h, j, tbl, lens: (b, 0, h, 0)),
            pl.BlockSpec((1, bs, 1, D),
                         lambda b, h, j, tbl, lens: (tbl[b * nb + j], 0, h // G, 0)),
            pl.BlockSpec((1, bs, 1, Dv),
                         lambda b, h, j, tbl, lens: (tbl[b * nb + j], 0, h // G, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, Sp, 1, Dv), lambda b, h, j, tbl, lens: (b, 0, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((Sp, Dv), jnp.float32),
            pltpu.VMEM((Sp, 1), jnp.float32),
            pltpu.VMEM((Sp, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Sp, Hq, Dv), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.PARALLEL, pltpu.PARALLEL,
                                 pltpu.ARBITRARY)),
        interpret=interpret,
    )(tables_flat, lengths, q, k_pool, v_pool)
    return out[:, :S]
