"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel ships three implementations with one contract:
``ref.py`` (pure-jnp oracle, ground truth for tests), ``jnp_impl.py``
(streaming CPU/production-fallback paths), and the Pallas kernel module
(``pl.pallas_call`` + explicit BlockSpec VMEM tiling, validated in
interpret mode on CPU).  ``ops.py`` is the dispatch layer
(``impl="auto"`` → pallas on TPU, jnp elsewhere, dense for tiny shapes).

Kernels: flash_attention (GQA, position-masked, causal block-skip),
memcom_xattn (the paper's 1-head m×t compression cross-attention),
moe_gmm (per-expert grouped matmul), ssd_scan (Mamba2 chunked SSD).
"""
