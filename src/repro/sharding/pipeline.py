"""Pipeline parallelism over the scanned layer stack (DESIGN.md §8).

The period-stacked parameters (leading ``repeats`` dim) are split across
the ``pod`` mesh axis: stage p holds layers [p·R/P, (p+1)·R/P).  The
batch is split into M microbatches and a GPipe-style schedule runs
T = M + P − 1 ticks; between ticks every stage hands its activations to
the next stage with a single ``ppermute`` ring hop — the jax-native
phrasing of the paper-scale P2P pipeline (no NCCL send/recv emulation).

The whole schedule is one ``jax.lax.scan`` over ticks inside
``shard_map``, so it is differentiable end-to-end (``ppermute``'s
transpose is the reverse-ring ``ppermute``; XLA overlaps the hop with
the next tick's stage compute — the standard TPU pipeline overlap).

Bubble fraction = (P−1)/(M+P−1); callers pick M ≥ 4·P to keep it < 20%.

Used by the multi-pod mesh when the ``pod`` axis is designated the
pipeline axis; validated against the sequential scan in
tests/test_pipeline.py (forward and gradients, 4-device host mesh).
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_apply(
    stage_fn: Callable,  # (layer_params_stack, h) -> h   (one stage's layers)
    period_params,  # pytree, leaves (R, ...) — layer-stacked
    h,  # (B, S, D) input activations (embedded tokens)
    *,
    mesh: Mesh,
    axis: str = "pod",
    microbatches: int = 4,
):
    """Run the layer stack as a P-stage pipeline over ``axis``.

    Semantically identical to ``scan(stage_fn)`` over all R layers;
    physically each device computes only its R/P layers and activations
    ride a ppermute ring.  B must divide by ``microbatches``.
    """
    Pn = mesh.shape[axis]
    B = h.shape[0]
    M = microbatches
    assert B % M == 0, (B, M)
    mb = B // M

    # params: shard the layer-stack dim; activations enter replicated
    # along the pipeline axis (each stage uses only its own microbatch
    # slice at tick 0) and leave gathered from the last stage.
    pspecs = jax.tree.map(lambda _: P(axis), period_params)
    T = M + Pn - 1

    def staged(params, h_all):
        idx = jax.lax.axis_index(axis)
        # (M, mb, S, D) microbatch queue, resident on every stage
        q = h_all.reshape(M, mb, *h_all.shape[1:])
        carry = jnp.zeros_like(q[0])  # in-flight activations on this stage
        outs = jnp.zeros_like(q)  # completed microbatches (last stage)

        def tick(state, t):
            carry, outs = state
            # stage 0 injects microbatch t; others use the handed-off carry
            inject = jnp.where(t < M, t, 0)
            h_in = jnp.where(idx == 0, q[inject], carry)
            active = (t - idx >= 0) & (t - idx < M)
            h_out = stage_fn(params, h_in)
            h_out = jnp.where(active, h_out, h_in)
            # last stage banks its finished microbatch m = t - (P-1)
            bank = jnp.where((idx == Pn - 1) & active, t - (Pn - 1), 0)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs,
                jnp.where((idx == Pn - 1) & active, h_out, outs[bank]),
                bank, axis=0)
            # ring hop: stage i -> i+1 (last stage's output drops off)
            nxt = jax.lax.ppermute(
                h_out, axis, [(i, (i + 1) % Pn) for i in range(Pn)])
            return (nxt, outs), None

        (carry, outs), _ = jax.lax.scan(tick, (carry, outs),
                                        jnp.arange(T, dtype=jnp.int32))
        # only the last stage's banked outputs are real; psum a masked
        # copy so every stage leaves with the full result (replicated out)
        outs = jnp.where(idx == Pn - 1, outs, 0)
        outs = jax.lax.psum(outs, axis)
        return outs.reshape(B, *h_all.shape[1:])

    other = tuple(a for a in mesh.axis_names if a != axis)
    return shard_map(
        staged, mesh=mesh,
        in_specs=(pspecs, P()),
        out_specs=P(),
        check_rep=False,
    )(period_params, h)


def stage_scan(apply_layer: Callable):
    """Lift a per-layer body into a stage function: scans this stage's
    (R/P, ...) parameter slice — same body the sequential model scans."""

    def stage_fn(params_slice, h):
        def body(h, lp):
            return apply_layer(lp, h), None

        h, _ = jax.lax.scan(body, h, params_slice)
        return h

    return stage_fn
