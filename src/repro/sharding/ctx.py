"""Activation-sharding context (sequence parallelism for GSPMD).

The model code stays sharding-agnostic; the launcher installs a residual-
stream constraint (batch → data axes, seq → model axis) and
:func:`repro.models.transformer.forward` calls :func:`constrain` at every
block boundary.  GSPMD then keeps the saved/captured per-layer hidden
states (the dominant live tensors in MemCom training — the Source-LLM
captures H^i for all layers) sharded 2-D instead of replicating the
sequence across the model axis; attention internals re-shard transiently
as the partitioner dictates.

Use as a context manager so dry-run cells can't leak constraints:

    with act_sharding(NamedSharding(mesh, P("data", "model", None))):
        lowered = jax.jit(step).lower(...)
"""

from __future__ import annotations

import contextlib
from typing import Optional

import jax

_ACT: Optional[jax.sharding.NamedSharding] = None


def set_act_sharding(sharding: Optional[jax.sharding.NamedSharding]) -> None:
    global _ACT
    _ACT = sharding


@contextlib.contextmanager
def act_sharding(sharding: Optional[jax.sharding.NamedSharding]):
    global _ACT
    prev = _ACT
    _ACT = sharding
    try:
        yield
    finally:
        _ACT = prev


def constrain(h):
    """Apply the installed (B, S, D) residual-stream constraint, if any."""
    if _ACT is None or h.ndim != len(_ACT.spec):
        return h
    return jax.lax.with_sharding_constraint(h, _ACT)


def head_sharded(x):
    """Constrain a (B, S, H, hd) attention operand to
    (batch→data, seq unsharded, heads→model): the classic TP-attention
    layout.  Without this, every q-chunk slice / kv-chunk reshape of the
    seq-sharded stream re-gathers the tensor — measured as the dominant
    all-gather source after the MoE fix (EXPERIMENTS.md §Perf H4).
    Returns x unchanged when no constraint is installed or heads don't
    divide the model axis."""
    if _ACT is None or x.ndim != 4:
        return x
    spec = _ACT.spec
    b = spec[0]
    model = _ACT.mesh.shape.get("model", 1)
    if model <= 1 or x.shape[2] % model:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P

    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_ACT.mesh, P(b, None, "model", None)))


_MOE_PLAN = True


@contextlib.contextmanager
def moe_plan_disabled():
    """Ablation switch: EP-only expert weights *without* the explicit
    batch-local token reshard (EXPERIMENTS.md §Perf H3 attribution)."""
    global _MOE_PLAN
    prev = _MOE_PLAN
    _MOE_PLAN = False
    try:
        yield
    finally:
        _MOE_PLAN = prev


def moe_dispatch_plan(x, num_experts: int = 0):
    """(x re-constrained batch-only, dispatch group count) for the MoE
    token stream, derived from the installed residual sharding.

    The sort/scatter dispatch cannot run over a sequence-sharded token
    stream without GSPMD scrambling it into partial-sum all-reduces over
    the (E, C, F) expert buffers (measured — EXPERIMENTS.md §Perf).  One
    explicit reshard to (batch→data, seq unsharded) per MoE layer makes
    the grouped dispatch exactly data-local; the block-boundary
    :func:`constrain` re-shards the output back.  Returns (x, None) when
    no constraint is installed (single-host tests, CPU benches) or when
    the expert count does not divide the model axis — the plan only pays
    off with shardable experts (granite's E=40 on a 16-way axis measured
    9× *worse* with it; EXPERIMENTS.md §Perf)."""
    if not _MOE_PLAN or _ACT is None or x.ndim != len(_ACT.spec):
        return x, None
    if num_experts and num_experts % _ACT.mesh.shape.get("model", 1):
        return x, None
    spec = _ACT.spec
    b = spec[0]
    if b is None:
        return x, None
    axes = (b,) if isinstance(b, str) else tuple(b)
    n = 1
    for a in axes:
        n *= _ACT.mesh.shape[a]
    if n <= 1 or x.shape[0] % n:
        return x, None
    from jax.sharding import NamedSharding, PartitionSpec as P

    batch_only = NamedSharding(_ACT.mesh, P(b, *([None] * (x.ndim - 1))))
    return jax.lax.with_sharding_constraint(x, batch_only), n
