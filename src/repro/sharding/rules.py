"""Logical-axis → mesh-axis sharding rules (MaxText-style).

Params record logical axes at init (:mod:`repro.models.param`); this module
turns the logical tree + a rules table + a mesh into NamedShardings.
Axes whose dimension does not divide the assigned mesh-axis extent are
dropped to replication (e.g. granite's 40 experts or its 49155-row vocab
on a 16-way model axis) — dimension-safe by construction.

Two built-in rule sets:

* BASELINE_RULES — pure tensor/expert parallel weights ("model" axis),
  replicated across data: the paper's own 512-chip DP posture.
* FSDP_RULES     — additionally shards every kernel's "embed" dim over
  the data axes (ZeRO-3-style fully-sharded weights; XLA all-gathers a
  layer at a time inside the scan).  Required to fit the 236B/398B
  configs.  LAYERS_FSDP_RULES shards the stacked-layer dim instead
  (only useful when repeats % data_axes == 0).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.utils.pytree import tree_flatten_with_names

AxisAssignment = Union[None, str, Tuple[str, ...]]
Rules = Dict[str, AxisAssignment]

# "data_axes" is resolved per-mesh: ("pod", "data") when a pod axis exists.
BASELINE_RULES: Rules = {
    "vocab": "model",
    "embed": None,
    "embed_ep": None,  # expert-weight d_model: never FSDP-sharded (the
    # expert matmul contracts it; sharding it trades a cheap weight
    # gather for per-layer partial-sum all-reduces — §Perf hillclimb 1)
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "expert": "model",
    "mamba_inner": "model",
    "mamba_heads": "model",
    "mla_lora": None,
    "layers": None,
}

FSDP_RULES: Rules = dict(BASELINE_RULES, embed="data_axes")
LAYERS_FSDP_RULES: Rules = dict(BASELINE_RULES, layers="data_axes")
# pre-fix posture (expert weights FSDP-sharded on d_model) — kept for the
# §Perf before/after measurement
FSDP_EP_EMBED_RULES: Rules = dict(FSDP_RULES, embed_ep="data_axes")


def _data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _resolve(assign: AxisAssignment, mesh: Mesh) -> Tuple[str, ...]:
    if assign is None:
        return ()
    if assign == "data_axes":
        return _data_axes(mesh)
    if isinstance(assign, str):
        return (assign,)
    return tuple(assign)


def _axes_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def spec_for(shape: Tuple[int, ...], logical: Tuple[Optional[str], ...],
             mesh: Mesh, rules: Rules) -> P:
    entries = []
    used = set()
    for dim, name in zip(shape, logical):
        assign = _resolve(rules.get(name), mesh) if name else ()
        # an axis may be consumed only once per spec; drop non-divisible
        assign = tuple(a for a in assign if a not in used)
        if assign and dim % _axes_size(mesh, assign) == 0:
            entries.append(assign if len(assign) > 1 else assign[0])
            used.update(assign)
        else:
            entries.append(None)
    return P(*entries)


def _flatten_axes(axes_tree):
    """Flatten the logical-axes tree keeping each axis *tuple* as one leaf
    (tuples are pytree nodes, so the default flatten would explode them)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(
        axes_tree, is_leaf=lambda x: isinstance(x, tuple))
    out = {}
    for path, leaf in flat:
        parts = []
        for p in path:
            parts.append(str(getattr(p, "key", getattr(p, "idx", p))))
        out["/".join(parts)] = leaf
    return out


def logical_to_shardings(abstract_params, axes_tree, mesh: Mesh, rules: Rules):
    """Pytree of NamedSharding matching params structure."""
    flat_p = tree_flatten_with_names(abstract_params)
    flat_a = _flatten_axes(axes_tree)
    leaves, treedef = jax.tree.flatten(abstract_params)
    out = []
    for (name, leaf) in flat_p:
        logical = flat_a[name]
        out.append(NamedSharding(mesh, spec_for(leaf.shape, logical, mesh, rules)))
    return jax.tree.unflatten(treedef, out)


def batch_sharding(mesh: Mesh, ndim: int = 2, batch_dim: int = 0):
    """Shard the batch dim over (pod, data); replicate the rest."""
    entries = [None] * ndim
    entries[batch_dim] = _data_axes(mesh)
    return NamedSharding(mesh, P(*entries))


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def opt_state_shardings(state_abstract, param_shardings, mesh: Mesh):
    """Optimizer state entries inherit their param's sharding by name.

    AdamW state is flat-dict-keyed by the param path with '/'-separators;
    mu/nu/master have the same shape as the param.
    """
    flat_ps = dict(tree_flatten_with_names(param_shardings))

    def lookup(kind_tree):
        out = {}
        for name, leaf in kind_tree.items():
            sh = flat_ps.get(name)
            out[name] = sh if sh is not None else replicated(mesh)
        return out

    return {
        "mu": lookup(state_abstract["mu"]),
        "nu": lookup(state_abstract["nu"]),
        "master": lookup(state_abstract["master"]),
        "count": replicated(mesh),
    }
