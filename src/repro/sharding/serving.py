"""Mesh placement for the serving stack (engine caches, prefixes, kernels).

Training shards *parameters* from their recorded logical axes
(:mod:`repro.sharding.rules`); serving additionally has to place the
engine-owned state — dense per-slot KV stripes, paged block pools, block
tables, materialized compressed prefixes — none of which carries logical
axes.  This module derives those placements from the one invariant the
whole serving design preserves: **attention splits by head**.

* ``k``/``v`` (dense ``(slots, L, Hkv, hd)``, paged ``(N, bs, Hkv, hd)``,
  cross ``ck``/``cv``) shard the head axis on the mesh "model" axis and
  replicate everything else — slots, positions and block structure are
  identical on every shard, so the host-side block tables and per-slot
  length vectors stay plain replicated numpy and the control plane never
  becomes mesh-aware.
* MLA ``ckv``/``kr`` latents have *no* head axis (that is the point of
  the absorbed decode) and stay replicated — at kv_lora_rank floats per
  token they are the cheap leaf.
* mamba ``conv``/``ssm`` recurrent state shards its channel/head dims
  like the corresponding weights (``mamba_inner`` / ``mamba_heads``).

Non-divisible dims drop to replication via :func:`repro.sharding.rules
.spec_for`, so a 3-head smoke config on a 2-way model mesh still runs —
it just replicates that leaf.

See docs/ARCHITECTURE.md §"Sharded serving".
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding.rules import BASELINE_RULES, Rules, spec_for

__all__ = [
    "BASELINE_RULES", "cache_shardings", "constrain_cache",
    "constrain_heads", "leaf_sharding", "leaf_spec", "model_axis_size",
    "shard_cache", "shard_map_heads",
]

#: trailing logical dims per cache/prefix leaf key; leading dims (layer
#: stack, batch/pool, positions) are always replicated.  The same table
#: covers every layout the key appears in — dense cache, paged pool,
#: stacked period section, materialized prefix, batch-free store row —
#: because the head/channel axes are always the *trailing* ones.
_TRAILING = {
    "k": ("kv_heads", None),
    "v": ("kv_heads", None),
    "ck": ("heads", None),
    "cv": ("heads", None),
    "ckv": (),
    "kr": (),
    "h": (),            # compressor output O^i: (B, m, d_model), replicated
    "conv": ("mamba_inner",),
    "ssm": ("mamba_heads", None, None),
}


def model_axis_size(mesh: Optional[Mesh]) -> int:
    """Extent of the tensor-parallel axis (1 when no mesh / no axis)."""
    if mesh is None:
        return 1
    return int(mesh.shape.get("model", 1))


def _leaf_key(path) -> Optional[str]:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
    return None


def leaf_spec(key: Optional[str], ndim: int, shape: Tuple[int, ...],
              mesh: Mesh, rules: Rules) -> P:
    trailing = _TRAILING.get(key, ())
    if ndim < len(trailing):
        return P()
    logical = (None,) * (ndim - len(trailing)) + trailing
    return spec_for(shape, logical, mesh, rules)


def leaf_sharding(key: Optional[str], arr, mesh: Mesh,
                  rules: Rules = BASELINE_RULES) -> NamedSharding:
    """NamedSharding for one cache/prefix leaf by its dict key — the
    per-leaf form of :func:`cache_shardings`, used by the tiered store's
    promotion path to ``device_put`` each host chunk directly into the
    pool layout (no replicated detour, no second host round-trip)."""
    return NamedSharding(
        mesh, leaf_spec(key, arr.ndim, tuple(arr.shape), mesh, rules))


def cache_shardings(tree, mesh: Mesh, rules: Rules = BASELINE_RULES):
    """NamedSharding pytree for any Layerwise cache / prefix / store-row
    tree, keyed by leaf name (``k``/``v``/``ckv``/…).  Works for dense and
    paged layouts alike — the head axis is trailing in both."""

    def one(path, x):
        return NamedSharding(
            mesh, leaf_spec(_leaf_key(path), x.ndim, x.shape, mesh, rules))

    return jax.tree_util.tree_map_with_path(one, tree)


def shard_cache(tree, mesh: Optional[Mesh], rules: Rules = BASELINE_RULES):
    """Place a cache/prefix tree on the mesh (no-op without a mesh)."""
    if mesh is None:
        return tree
    return jax.device_put(tree, cache_shardings(tree, mesh, rules))


def constrain_cache(tree, mesh: Optional[Mesh],
                    rules: Rules = BASELINE_RULES):
    """``with_sharding_constraint`` a cache/prefix tree inside jit — pins
    freshly materialized prefixes to the pool layout so the compile →
    store.put handoff never round-trips through a replicated gather."""
    if mesh is None or model_axis_size(mesh) <= 1:
        return tree

    def one(path, x):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, leaf_spec(_leaf_key(path), x.ndim,
                                             x.shape, mesh, rules)))

    return jax.tree_util.tree_map_with_path(one, tree)


def constrain_heads(x, mesh: Optional[Mesh], axis: int = 2):
    """Pin a (..., heads, hd) attention operand's head axis to the model
    mesh axis (replicating the rest) so GSPMD keeps decode head-parallel
    instead of gathering the cache.  No-op when no mesh / heads don't
    divide."""
    n = model_axis_size(mesh)
    if n <= 1 or x.ndim <= axis or x.shape[axis] % n:
        return x
    entries = [None] * x.ndim
    entries[axis] = "model"
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))


def _shard_map(f, mesh: Mesh, in_specs, out_specs):
    """shard_map across jax versions (experimental → jax.shard_map)."""
    try:
        from jax.experimental.shard_map import shard_map

        # pallas_call has no replication rule — checking is pointless here
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)
    except ImportError:
        try:  # newer jax renamed the replication-check opt-out
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
        except TypeError:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs)


def shard_map_heads(f, mesh: Mesh, head_args, replicated_args: int,
                    head_axis: int = 2):
    """Wrap a head-parallel kernel in shard_map: the first ``head_args``
    operands split their ``head_axis`` over "model" (batch, positions and
    block structure replicated), the remaining ``replicated_args``
    operands (lengths, block tables) are replicated on every shard, and
    the output is head-split like the inputs.

    This is what makes the *pallas* decode kernels mesh-runnable: unlike
    jnp ops they have no GSPMD partitioning rule, so each shard must run
    the kernel on its own head slice explicitly.
    """
    def head_spec(ndim):
        entries = [None] * ndim
        entries[head_axis] = "model"
        return P(*entries)

    def wrapped(*args):
        assert len(args) == head_args + replicated_args
        in_specs = tuple(head_spec(a.ndim) for a in args[:head_args]) + \
            tuple(P(*([None] * a.ndim)) for a in args[head_args:])
        out_specs = head_spec(4)  # attention output: (B, S, Hq, Dv)
        return _shard_map(f, mesh, in_specs, out_specs)(*args)

    return wrapped
