from repro.sharding.rules import (
    Rules,
    BASELINE_RULES,
    FSDP_RULES,
    LAYERS_FSDP_RULES,
    logical_to_shardings,
    batch_sharding,
    replicated,
    opt_state_shardings,
)

__all__ = [
    "Rules",
    "BASELINE_RULES",
    "FSDP_RULES",
    "LAYERS_FSDP_RULES",
    "logical_to_shardings",
    "batch_sharding",
    "replicated",
    "opt_state_shardings",
]
