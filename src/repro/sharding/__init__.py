from repro.sharding.rules import (
    Rules,
    BASELINE_RULES,
    FSDP_RULES,
    LAYERS_FSDP_RULES,
    logical_to_shardings,
    batch_sharding,
    replicated,
    opt_state_shardings,
)
from repro.sharding.serving import (
    cache_shardings,
    constrain_cache,
    constrain_heads,
    model_axis_size,
    shard_cache,
    shard_map_heads,
)

__all__ = [
    "Rules",
    "BASELINE_RULES",
    "FSDP_RULES",
    "LAYERS_FSDP_RULES",
    "logical_to_shardings",
    "batch_sharding",
    "replicated",
    "opt_state_shardings",
    "cache_shardings",
    "constrain_cache",
    "constrain_heads",
    "model_axis_size",
    "shard_cache",
    "shard_map_heads",
]
