"""Quickstart: the MemCom pipeline end to end in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

1. Build a (reduced) model config and a frozen Target-LLM.
2. Wrap it with a MemCom compressor (Source-LLM + Memory-LLM + per-layer
   1-head cross-attention + learnable memory tokens).
3. Compress a many-shot prompt into m per-layer memory representations.
4. Serve: the target attends to m compressed slots instead of t tokens.
"""

import numpy as np
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import memcom
from repro.models import transformer as tfm
from repro.serving.engine import ServingEngine, materialize_prefix
from repro.utils.pytree import tree_bytes

# 1. a frozen target model (reduced config of the smollm-135m family)
cfg = get_smoke_config("smollm-135m")
target = tfm.init_params(cfg, seed=0)
print(f"target: {cfg.name}, {cfg.num_layers} layers, d={cfg.d_model}, "
      f"m={cfg.memcom.num_memory_tokens} memory tokens")

# 2. the compressor (untrained here — see examples/train_memcom.py)
compressor = memcom.init_memcom(cfg, target, seed=1)

# 3. offline compression: t=64 many-shot tokens -> m per-layer slots
rng = np.random.default_rng(0)
t = 64
source = jnp.asarray(rng.integers(4, cfg.vocab_size, (1, t)), jnp.int32)
prefix, _ = memcom.compress(compressor, cfg, source)
reps = prefix["period"]["l0"]["h"]
print(f"compressed: {t} tokens -> per-layer {tuple(reps.shape[1:])} "
      f"(layers stacked: {reps.shape[0]})")

# 4. serve against the compressed cache
kv = materialize_prefix(target, cfg, prefix)
m = cfg.memcom.num_memory_tokens
full_kv_bytes = tree_bytes(tfm.init_cache(cfg, 1, t))
comp_kv_bytes = tree_bytes(kv)
print(f"KV cache: {full_kv_bytes/1e3:.1f} KB -> {comp_kv_bytes/1e3:.1f} KB "
      f"({full_kv_bytes/comp_kv_bytes:.1f}x smaller)")

engine = ServingEngine(cfg, target, slots=1, max_len=m + 32)
engine.seat_compressed(kv)
prompt = rng.integers(4, cfg.vocab_size, (1, 8)).astype(np.int32)
out = engine.generate(prompt, max_new=8)
print(f"generated (attending to {m} compressed slots): {out[0].tolist()}")
