"""Fault-tolerance demo: preemption mid-run + elastic restart.

    PYTHONPATH=src python examples/elastic_restart.py

Run A trains and is "preempted" (flag file, as a cluster agent would
drop) — it checkpoints and exits.  Run B starts fresh from the same
checkpoint root, resumes at the exact step, and finishes.  The script
verifies the resumed loss curve is bitwise-identical to an uninterrupted
control run, and that the checkpoint restores across topologies
(host-count-agnostic numpy shards + device_put with current shardings).
"""

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import memcom
from repro.data import PretrainStream, SyntheticVocab
from repro.models import transformer as tfm
from repro.optim import AdamW
from repro.train import Trainer, TrainerConfig, build_train_step

ROOT = "artifacts/example_elastic"
VOCAB = SyntheticVocab()
STEPS = 16


def build(ckpt_root, num_steps=STEPS):
    cfg = get_smoke_config("smollm-135m").replace(vocab_size=VOCAB.size)
    params = tfm.init_params(cfg, 0)
    opt = AdamW(lr=1e-3)
    stream = PretrainStream(VOCAB, batch=4, seq_len=48,
                            split_choices=(32,), seed=5)

    def loss_fn(p, batch):
        logits, aux = tfm.forward(p, cfg, tokens=batch["tokens"])
        return memcom.next_token_loss(logits, batch["tokens"]) + aux["moe_loss"], {}

    step = jax.jit(build_train_step(loss_fn, opt))

    def batch_at(i):
        b = stream.batch_at(i)
        toks = np.concatenate([b["source"], b["target"]], axis=1)
        return {"tokens": jnp.asarray(toks)}

    tc = TrainerConfig(num_steps=num_steps, ckpt_every=8, log_every=4,
                       metrics_path=os.path.join(ckpt_root, "metrics.jsonl"))
    return Trainer(step, params, opt.init(params), batch_at, ckpt_root, tc)


shutil.rmtree(ROOT, ignore_errors=True)

# control: uninterrupted 16 steps
control = build(os.path.join(ROOT, "control"))
control.run()
w_control = np.asarray(jax.tree.leaves(control.params)[0])

# run A: preempted after the step-8 checkpoint
print("\n== run A (will be preempted)")
a = build(os.path.join(ROOT, "job"), num_steps=8)
a.run()
a.mgr.flag_preemption()  # what the cluster agent does before SIGKILL
print("   PREEMPTED flag dropped; process 'killed'")

# run B: a brand-new process picks up the same checkpoint root
print("== run B (restart)")
b = build(os.path.join(ROOT, "job"))
b.mgr.clear_preemption()
resumed = b.restore_if_available()
print(f"   resumed from step {resumed}")
b.run()
w_resumed = np.asarray(jax.tree.leaves(b.params)[0])

assert resumed == 8
np.testing.assert_array_equal(w_control, w_resumed)
print(f"\n✓ resumed run is bitwise-identical to the uninterrupted control "
      f"({STEPS} steps, restart at 8)")
print(f"✓ checkpoint is topology-agnostic (numpy shards + device_put on "
      f"restore); metrics in {ROOT}/job/metrics.jsonl")
