"""Cloud-edge serving example (paper §1's deployment story).

"Cloud" side: compress a many-shot classification prompt offline into m
per-layer memory slots.  "Edge" side: a ServingEngine that never sees the
raw shots — it seats the compressed cache once and answers every query
against m slots instead of t tokens.

Part two drops the cloud step entirely: requests carry their raw shots
and the engine's *online prefix compiler* compresses the task on the
serving path — the public API is just "submit requests"; nothing here
calls compress/materialize_prefix for those tasks.

    PYTHONPATH=src python examples/serve_compressed.py
"""

import numpy as np
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import memcom
from repro.data import ICLTaskSpec, SyntheticVocab, build_manyshot_prompt, \
    make_episode, make_query
from repro.models import transformer as tfm
from repro.serving import Request
from repro.serving.engine import ServingEngine, materialize_prefix
from repro.utils.pytree import tree_bytes

VOCAB = SyntheticVocab()

cfg = get_smoke_config("smollm-135m").replace(vocab_size=VOCAB.size)
target = tfm.init_params(cfg, 0)
compressor = memcom.init_memcom(cfg, target, 1)
m = cfg.memcom.num_memory_tokens

# ---- cloud: build the many-shot prompt and compress it offline --------
rng = np.random.default_rng(0)
task = ICLTaskSpec(VOCAB, num_labels=8, keys_per_label=4)
episode = make_episode(task, rng)
prompt = build_manyshot_prompt(task, episode, rng, budget=96)
print(f"[cloud] many-shot prompt: {len(prompt)} tokens "
      f"({len(prompt)//task.shot_tokens} shots, 8 labels)")

prefix, _ = memcom.compress(compressor, cfg, jnp.asarray(prompt[None]))
kv = materialize_prefix(target, cfg, prefix)
print(f"[cloud] compressed to {m} slots/layer "
      f"({len(prompt)/m:.1f}x); payload {tree_bytes(kv)/1e3:.1f} KB")

# ---- edge: seat once, answer queries against the compressed cache -----
engine = ServingEngine(cfg, target, slots=1, max_len=m + 16)
engine.seat_compressed(kv)
print(f"[edge] engine ready: {engine.slots} slot(s), base_len={engine.base_len}")

for i in range(3):
    q, label = make_query(task, episode, prompt, rng)
    pred = engine.score_labels(np.empty((0,), np.int32), q,
                               VOCAB.label_ids())
    print(f"[edge] query {q.tolist()} -> predicted label "
          f"{pred - VOCAB.label_base} (true {label}) "
          f"{'✓' if pred - VOCAB.label_base == label else '✗ (untrained compressor)'}")

# ---- edge, online: unseen tasks served straight from raw shots --------
# No cloud step: the engine owns the compressor and compiles each unseen
# task inside the serving loop — at most 32 source tokens per iteration
# while other slots decode (idle engines, as here, finish the job in one
# chunk).  The two requests for task B carry byte-identical shots, so
# they share one compilation (single-flight, content-addressed).
online = ServingEngine(cfg, target, slots=2, max_len=m + 16,
                       compressor=compressor, compile_token_budget=32)
task_b = ICLTaskSpec(VOCAB, num_labels=8, keys_per_label=4)
episode_b = make_episode(task_b, rng)
shots_b = build_manyshot_prompt(task_b, episode_b, rng, budget=96)
queries = [make_query(task_b, episode_b, shots_b, rng)[0] for _ in range(2)]
reqs = [Request(tokens=q, max_new=1, raw_shots=shots_b) for q in queries]
out = online.serve(reqs)
cs = online.stats()["compiler"]
print(f"\n[edge/online] served {len(reqs)} raw-shot requests for an unseen "
      f"task: {cs['jobs']} compile ({cs['chunks']} chunks, "
      f"{cs['tokens']} source tokens), {cs['deduped']} deduped submit(s)")
for r, q in zip(reqs, queries):
    print(f"[edge/online] query {q.tolist()} -> next token "
          f"{out[r.uid].tolist()}")

print("\nNote: the compressor here is untrained — run benchmarks/run.py "
      "to see trained-compressor accuracy vs the fewer-shots baseline.")
