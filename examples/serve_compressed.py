"""Cloud-edge serving example (paper §1's deployment story).

"Cloud" side: compress a many-shot classification prompt offline into m
per-layer memory slots.  "Edge" side: a ServingEngine that never sees the
raw shots — it seats the compressed cache once and answers every query
against m slots instead of t tokens.

    PYTHONPATH=src python examples/serve_compressed.py
"""

import numpy as np
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import memcom
from repro.data import ICLTaskSpec, SyntheticVocab, build_manyshot_prompt, \
    make_episode, make_query
from repro.models import transformer as tfm
from repro.serving.engine import ServingEngine, materialize_prefix
from repro.utils.pytree import tree_bytes

VOCAB = SyntheticVocab()

cfg = get_smoke_config("smollm-135m").replace(vocab_size=VOCAB.size)
target = tfm.init_params(cfg, 0)
compressor = memcom.init_memcom(cfg, target, 1)
m = cfg.memcom.num_memory_tokens

# ---- cloud: build the many-shot prompt and compress it offline --------
rng = np.random.default_rng(0)
task = ICLTaskSpec(VOCAB, num_labels=8, keys_per_label=4)
episode = make_episode(task, rng)
prompt = build_manyshot_prompt(task, episode, rng, budget=96)
print(f"[cloud] many-shot prompt: {len(prompt)} tokens "
      f"({len(prompt)//task.shot_tokens} shots, 8 labels)")

prefix, _ = memcom.compress(compressor, cfg, jnp.asarray(prompt[None]))
kv = materialize_prefix(target, cfg, prefix)
print(f"[cloud] compressed to {m} slots/layer "
      f"({len(prompt)/m:.1f}x); payload {tree_bytes(kv)/1e3:.1f} KB")

# ---- edge: seat once, answer queries against the compressed cache -----
engine = ServingEngine(cfg, target, slots=1, max_len=m + 16)
engine.seat_compressed(kv)
print(f"[edge] engine ready: {engine.slots} slot(s), base_len={engine.base_len}")

for i in range(3):
    q, label = make_query(task, episode, prompt, rng)
    pred = engine.score_labels(np.empty((0,), np.int32), q,
                               VOCAB.label_ids())
    print(f"[edge] query {q.tolist()} -> predicted label "
          f"{pred - VOCAB.label_base} (true {label}) "
          f"{'✓' if pred - VOCAB.label_base == label else '✗ (untrained compressor)'}")

print("\nNote: the compressor here is untrained — run benchmarks/run.py "
      "to see trained-compressor accuracy vs the fewer-shots baseline.")
