"""End-to-end training driver: pretrain a target LM, then train a MemCom
compressor against it (Phase-1, optionally Phase-2) with the
fault-tolerant Trainer — checkpoints, restart, metrics, preemption.

    PYTHONPATH=src python examples/train_memcom.py                # CPU-sized
    PYTHONPATH=src python examples/train_memcom.py --preset 100m  # spec-sized

The 100m preset is the "train a ~100M model for a few hundred steps"
configuration (smollm-135m family at full width); the default preset is
CPU-sized so the example finishes in minutes in this container.  Both run
the same code path as the production launcher (repro.launch.train) minus
the mesh.
"""

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import LayerDesc, LayerLayout, MemComConfig, ModelConfig
from repro.configs import get_config
from repro.core import memcom
from repro.data import PretrainStream, SyntheticVocab
from repro.models import transformer as tfm
from repro.optim import AdamW, warmup_constant
from repro.train import Trainer, TrainerConfig, build_train_step

VOCAB = SyntheticVocab()


def make_cfg(preset: str) -> ModelConfig:
    if preset == "100m":
        # smollm-135m backbone on the synthetic vocab (~100M params)
        return get_config("smollm-135m").replace(
            vocab_size=VOCAB.size, dtype="float32",
            memcom=MemComConfig(num_memory_tokens=32))
    return ModelConfig(
        name="example-lm", family="dense",
        layout=LayerLayout.uniform(LayerDesc("attn", "dense"), 4),
        d_model=128, num_heads=4, num_kv_heads=4, d_ff=256,
        vocab_size=VOCAB.size, max_seq=512, dtype="float32",
        memcom=MemComConfig(num_memory_tokens=24), source="example")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["cpu", "100m"], default="cpu")
    ap.add_argument("--pretrain-steps", type=int, default=200)
    ap.add_argument("--memcom-steps", type=int, default=200)
    ap.add_argument("--phase2", action="store_true")
    ap.add_argument("--ckpt", default="artifacts/example_train")
    args = ap.parse_args()

    cfg = make_cfg(args.preset)
    stream = PretrainStream(VOCAB, batch=8, seq_len=96,
                            split_choices=(64, 72), seed=0)

    # ---- stage 1: pretrain the target --------------------------------
    print(f"== stage 1: pretraining target ({cfg.param_count()/1e6:.1f}M "
          f"params) for {args.pretrain_steps} steps")
    params = tfm.init_params(cfg, 0)
    opt = AdamW(lr=warmup_constant(3e-3, 20))

    def lm_loss(p, batch):
        logits, aux = tfm.forward(p, cfg, tokens=batch["tokens"])
        return (memcom.next_token_loss(logits, batch["tokens"],
                                       batch.get("mask"))
                + aux["moe_loss"], {})

    step = jax.jit(build_train_step(lm_loss, opt))

    def lm_batch_at(i):
        b = stream.batch_at(i)
        toks = np.concatenate([b["source"], b["target"]], axis=1)
        return {"tokens": jnp.asarray(toks),
                "mask": jnp.asarray((toks != VOCAB.PAD).astype(np.float32))}

    trainer = Trainer(step, params, opt.init(params), lm_batch_at,
                      os.path.join(args.ckpt, "target"),
                      TrainerConfig(num_steps=args.pretrain_steps,
                                    ckpt_every=100, log_every=25,
                                    metrics_path=os.path.join(
                                        args.ckpt, "target_metrics.jsonl")))
    trainer.restore_if_available()
    last = trainer.run()
    print(f"   target loss: {last.get('loss', float('nan')):.4f}")
    target = trainer.params

    # ---- stage 2: MemCom Phase-1 (frozen target) ---------------------
    phase = 2 if args.phase2 else 1
    print(f"== stage 2: MemCom Phase-{phase} compressor "
          f"({args.memcom_steps} steps, target frozen)")
    mc = memcom.init_memcom(cfg, target, 1)
    mask = memcom.trainable_mask(mc, phase)
    mopt = AdamW(lr=warmup_constant(2e-3 if phase == 1 else 2e-4, 20),
                 mask=mask)

    def mc_loss(c, batch):
        c = jax.tree.map(
            lambda x, m: x if m else jax.lax.stop_gradient(x), c, mask)
        return memcom.memcom_loss(c, target, cfg, batch)

    mc_step = jax.jit(build_train_step(mc_loss, mopt))

    def mc_batch_at(i):
        b = stream.batch_at(1000 + i)
        return {k: jnp.asarray(b[k]) for k in
                ("source", "target", "target_mask")}

    mtrainer = Trainer(mc_step, mc, mopt.init(mc), mc_batch_at,
                       os.path.join(args.ckpt, f"memcom_p{phase}"),
                       TrainerConfig(num_steps=args.memcom_steps,
                                     ckpt_every=100, log_every=25,
                                     metrics_path=os.path.join(
                                         args.ckpt, "memcom_metrics.jsonl")))
    mtrainer.restore_if_available()
    last = mtrainer.run()
    print(f"   memcom loss: {last.get('loss', float('nan')):.4f}")
    print(f"checkpoints + metrics under {args.ckpt}/")


if __name__ == "__main__":
    main()
