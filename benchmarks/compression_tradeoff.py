"""Paper Table 2/3 + Figure 2 analog: accuracy vs compression ratio.

Methods: full-context upper bound, fewer-shots baseline, ICAE++, MemCom
(Phase-1), MemCom-P2 — each evaluated at 3×/6×/8× compression of the
many-shot budget.  Claims reproduced: C1 (baseline collapses at high
ratio, MemCom degrades gently) and C4 (Phase-2 adds small gains).
"""

from __future__ import annotations

import argparse
import dataclasses

from benchmarks import common as C


def run(steps: int = 300, ratios=(3, 6, 8), with_p2: bool = True,
        eval_episodes: int = 12):
    cfg0, target = C.get_or_pretrain_target()
    results = {"source_len": C.SOURCE_LEN, "rows": []}

    # upper bound: full many-shot context, no compression
    upper = C.evaluate(
        C.make_full_context_predictor(cfg0, target, C.SOURCE_LEN),
        budget=C.SOURCE_LEN, n_episodes=eval_episodes)
    results["rows"].append(("full-context", C.SOURCE_LEN, "-", upper))
    C.log(f"upper bound: {upper}")

    for ratio in ratios:
        m = C.RATIOS[ratio]
        cfg = cfg0.replace(
            memcom=dataclasses.replace(cfg0.memcom, num_memory_tokens=m))

        # fewer-shots baseline: same construction, budget = t / ratio
        base = C.evaluate(
            C.make_full_context_predictor(cfg, target, m),
            budget=m, query_budget=C.SOURCE_LEN, n_episodes=eval_episodes)
        results["rows"].append((f"baseline", m, f"{ratio}x", base))
        C.log(f"baseline @{ratio}x (m={m}): {base}")

        icae_pp, _ = C.train_compressor("icae", target, cfg, steps=steps,
                                        variant="icae++")
        acc = C.evaluate(
            C.make_icae_predictor(cfg, target, icae_pp, C.SOURCE_LEN),
            budget=C.SOURCE_LEN, n_episodes=eval_episodes)
        results["rows"].append((f"icae++", m, f"{ratio}x", acc))
        C.log(f"icae++ @{ratio}x: {acc}")

        mc, _ = C.train_compressor("memcom", target, cfg, steps=steps,
                                   phase=1)
        acc = C.evaluate(
            C.make_memcom_predictor(cfg, target, mc, C.SOURCE_LEN),
            budget=C.SOURCE_LEN, n_episodes=eval_episodes)
        results["rows"].append((f"memcom", m, f"{ratio}x", acc))
        C.log(f"memcom @{ratio}x: {acc}")

        if with_p2:
            mc2, _ = C.train_compressor(
                "memcom", target, cfg, steps=steps // 2, lr=2e-4, phase=2,
                init_from=mc)
            acc = C.evaluate(
                C.make_memcom_predictor(cfg, target, mc2, C.SOURCE_LEN),
                budget=C.SOURCE_LEN, n_episodes=eval_episodes)
            results["rows"].append((f"memcom-p2", m, f"{ratio}x", acc))
            C.log(f"memcom-p2 @{ratio}x: {acc}")

    rows = [(meth, m, r, round(acc["mean"], 3),
             *(round(acc[t], 3) for t in C.TASKS))
            for meth, m, r, acc in results["rows"]]
    print("\n" + C.fmt_table(
        rows, ("method", "m", "ratio", "mean", *C.TASKS)) + "\n")
    C.write_result("compression_tradeoff", {
        "rows": [dict(method=meth, m=m, ratio=r, acc=acc)
                 for meth, m, r, acc in results["rows"]],
        "source_len": C.SOURCE_LEN, "steps": steps})
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    if args.quick:
        run(steps=120, ratios=(8,), with_p2=False, eval_episodes=6)
    else:
        run(steps=args.steps)
