"""Deep-trained headline comparison at the hardest ratio (8×).

The 150-step suite (compression_tradeoff) is 4 orders of magnitude below
the paper's 80 B-token compressor budget; this benchmark concentrates
the remaining budget on the single headline cell — MemCom vs ICAE++ vs
fewer-shots baseline at 8× — with one continuous training run per
compressor and periodic accuracy probes, so the *trajectory* (does
compressed-context accuracy climb with compressor training?) is recorded
even where the endpoint is compute-limited.

    PYTHONPATH=src python -m benchmarks.deep_tradeoff --steps 600
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from benchmarks import common as C
from repro.core import icae as icae_lib
from repro.core import memcom
from repro.optim import AdamW, clip_by_global_norm, warmup_constant


def _train_with_probes(kind, target, cfg, *, steps, probe_every, lr,
                       eval_episodes, variant="icae++"):
    if kind == "memcom":
        comp = memcom.init_memcom(cfg, target, 1)
        mask = memcom.trainable_mask(comp, 1)

        def loss_fn(c, batch):
            c = jax.tree.map(
                lambda x, mk: x if mk else jax.lax.stop_gradient(x), c, mask)
            return memcom.memcom_loss(c, target, cfg, batch)

        make = C.make_memcom_predictor
    else:
        comp = icae_lib.init_icae(cfg, target, variant=variant, seed=1)
        mask = icae_lib.icae_trainable_mask(comp, variant)

        def loss_fn(c, batch):
            c = jax.tree.map(
                lambda x, mk: x if mk else jax.lax.stop_gradient(x), c, mask)
            return icae_lib.icae_loss(c, target, cfg, batch)

        make = C.make_icae_predictor

    opt = AdamW(lr=warmup_constant(lr, 30), mask=mask)
    state = opt.init(comp)

    @jax.jit
    def step_fn(comp, state, batch):
        (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(comp, batch)
        g, _ = clip_by_global_norm(g, 1.0)
        comp, state = opt.step(comp, g, state)
        return comp, state, l

    stream = C._stream(seed=123)
    traj = []
    for i in range(steps):
        b = stream.batch_at(i)
        batch = {k: jnp.asarray(b[k]) for k in
                 ("source", "target", "target_mask")}
        comp, state, l = step_fn(comp, state, batch)
        if (i + 1) % probe_every == 0:
            acc = C.evaluate(make(cfg, target, comp, C.SOURCE_LEN),
                             budget=C.SOURCE_LEN, n_episodes=eval_episodes)
            traj.append(dict(steps=i + 1, loss=float(l), acc=acc))
            C.log(f"  {kind} step {i+1}: loss {float(l):.3f} "
                  f"mean-acc {acc['mean']:.3f}")
    return comp, traj


def run(steps: int = 600, ratio: int = 8, probe_every: int = 200,
        eval_episodes: int = 12, kinds=("memcom", "icae")):
    cfg0, target = C.get_or_pretrain_target()
    m = C.RATIOS[ratio]
    cfg = cfg0.replace(
        memcom=dataclasses.replace(cfg0.memcom, num_memory_tokens=m))

    rows = []
    full = C.evaluate(C.make_full_context_predictor(cfg, target, C.SOURCE_LEN),
                      budget=C.SOURCE_LEN, n_episodes=eval_episodes)
    base = C.evaluate(C.make_full_context_predictor(cfg, target, m),
                      budget=m, query_budget=C.SOURCE_LEN,
                      n_episodes=eval_episodes)
    rows.append((f"full-context-{C.SOURCE_LEN}", full))
    rows.append((f"baseline-{m}", base))
    C.log(f"full-context {full['mean']:.3f} | baseline@{ratio}x "
          f"{base['mean']:.3f}")

    trajectories = {}
    for kind in kinds:
        C.log(f"deep-training {kind} for {steps} steps …")
        _, traj = _train_with_probes(
            kind, target, cfg, steps=steps, probe_every=probe_every,
            lr=2e-3, eval_episodes=eval_episodes)
        trajectories[kind] = traj
        rows.append((f"{kind}-{steps}", traj[-1]["acc"]))

    table = [(n, round(a["mean"], 3), *(round(a[t], 3) for t in C.TASKS))
             for n, a in rows]
    print("\n" + C.fmt_table(table, ("method", "mean", *C.TASKS)))
    C.write_result("deep_tradeoff", {
        "ratio": ratio, "m": m, "steps": steps,
        "rows": [dict(method=n, acc=a) for n, a in rows],
        "trajectories": trajectories})
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--probe-every", type=int, default=200)
    ap.add_argument("--kinds", default="memcom,icae")
    args = ap.parse_args()
    run(steps=args.steps, probe_every=args.probe_every,
        kinds=tuple(args.kinds.split(",")))
