"""Schema-validate serving observability artifacts (one CLI, three kinds).

CI runs this against the smoke bench's dumps so a malformed artifact
fails the build instead of shipping something Perfetto / the perf gate
cannot load.  Three artifact kinds share the CLI, each validated by the
same helper its producer exposes to tests:

* ``trace`` — a Chrome-trace JSON from the flight-recorder
  :class:`~repro.serving.telemetry.Tracer`
  (``repro.serving.validate_chrome_trace``): every event carries
  ``ph``/``pid``/``tid``/``name``, non-metadata events carry ``ts``,
  complete spans carry ``dur``, async begin/end carry ``id``, and every
  span in ``--require`` appears at least once.
* ``profile`` — a ``repro/profile-report/v1`` from
  :func:`repro.serving.profile_spans`
  (``repro.serving.validate_profile_report``): per-phase span counts and
  non-negative total/self times with self ≤ total.
* ``alerts`` — a ``repro/alert-log/v1`` from
  :class:`repro.serving.SLOWatchdog`
  (``repro.serving.validate_alert_log``): monotonic timestamps, legal
  fire/clear sequencing per rule, known severities.

``--kind auto`` (the default) sniffs the document: an explicit
``schema`` field selects profile/alerts, anything else is a trace.

Usage::

    python -m benchmarks.validate_trace artifacts/bench/traffic_trace.json
    python -m benchmarks.validate_trace trace.json --require admission,finish
    python -m benchmarks.validate_trace artifacts/bench/traffic_profile.json
    python -m benchmarks.validate_trace artifacts/bench/traffic_alerts.json

Exits 0 when the artifact is well-formed, 1 with one error per line on
stderr otherwise (2 for an unreadable file or unknown kind).
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.serving.profiler import (PROFILE_REPORT_SCHEMA,
                                    validate_profile_report)
from repro.serving.slo_watchdog import ALERT_LOG_SCHEMA, validate_alert_log
from repro.serving.telemetry import REQUIRED_SPANS, validate_chrome_trace


def sniff_kind(doc: dict) -> str:
    schema = doc.get("schema")
    if schema == PROFILE_REPORT_SCHEMA:
        return "profile"
    if schema == ALERT_LOG_SCHEMA:
        return "alerts"
    return "trace"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("trace", help="path to an artifact JSON dump")
    ap.add_argument("--kind", default="auto",
                    choices=("auto", "trace", "profile", "alerts"),
                    help="artifact kind (default: sniff the 'schema' "
                         "field; no field = Chrome trace)")
    ap.add_argument("--require", default=",".join(REQUIRED_SPANS),
                    help="trace kind only: comma-separated span names "
                         "that must appear (default: the tracer's "
                         "REQUIRED_SPANS; pass '' to check structure "
                         "only)")
    args = ap.parse_args(argv)

    try:
        with open(args.trace) as fh:
            doc = json.load(fh)
    except (OSError, ValueError) as e:
        print(f"validate_trace: cannot read {args.trace!r}: {e}",
              file=sys.stderr)
        return 2
    kind = sniff_kind(doc) if args.kind == "auto" else args.kind

    if kind == "trace":
        require = tuple(s for s in args.require.split(",") if s)
        errors = validate_chrome_trace(doc, require_spans=require)
        detail = (f"{sum(1 for e in doc.get('traceEvents', ()) if e.get('ph') != 'M')} "
                  f"events, {len(require)} required span(s) present")
    elif kind == "profile":
        errors = validate_profile_report(doc)
        phases = doc.get("phases", {}) if isinstance(doc, dict) else {}
        detail = (f"{sum(st.get('spans', 0) for st in phases.values() if isinstance(st, dict))} "
                  f"spans over {len(phases)} phases, "
                  f"wall {doc.get('wall_s', 0.0)}s")
    else:  # alerts
        errors = validate_alert_log(doc)
        events = doc.get("events", []) if isinstance(doc, dict) else []
        detail = (f"{len(events)} alert events over "
                  f"{len(doc.get('rules', []))} rules")

    if errors:
        for err in errors:
            print(f"validate_trace[{kind}]: {err}", file=sys.stderr)
        return 1
    print(f"validate_trace[{kind}]: OK — {detail}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
