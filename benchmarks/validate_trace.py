"""Schema-validate a Chrome-trace JSON dumped by the serving Tracer.

CI runs this against the smoke bench's ``traffic_trace.json`` artifact
so a malformed dump (missing ``ph``/``ts``/``dur`` fields, broken async
pairing metadata, or a lifecycle span that silently stopped being
emitted) fails the build instead of shipping an artifact Perfetto cannot
load.  The checks are the same ones ``repro.serving.validate_chrome_trace``
exposes to tests:

* every event carries ``ph``, ``pid``, ``tid`` and ``name``;
* non-metadata events carry ``ts``; complete events (``ph == "X"``)
  carry ``dur``; async begin/end events carry ``id``;
* every span name in ``--require`` (default: the tracer's
  ``REQUIRED_SPANS`` — the full request lifecycle from admission through
  preempt/resume) appears at least once.

Usage::

    python -m benchmarks.validate_trace artifacts/bench/traffic_trace.json
    python -m benchmarks.validate_trace trace.json --require admission,finish

Exits 0 when the trace is well-formed, 1 with one error per line on
stderr otherwise.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.serving.telemetry import REQUIRED_SPANS, validate_chrome_trace


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("trace", help="path to a Chrome-trace JSON dump")
    ap.add_argument("--require", default=",".join(REQUIRED_SPANS),
                    help="comma-separated span names that must appear "
                         "(default: the tracer's REQUIRED_SPANS; pass '' "
                         "to check structure only)")
    args = ap.parse_args(argv)

    with open(args.trace) as fh:
        trace = json.load(fh)
    require = tuple(s for s in args.require.split(",") if s)
    errors = validate_chrome_trace(trace, require_spans=require)
    if errors:
        for err in errors:
            print(f"validate_trace: {err}", file=sys.stderr)
        return 1
    n = sum(1 for e in trace.get("traceEvents", ()) if e.get("ph") != "M")
    print(f"validate_trace: OK — {n} events, "
          f"{len(require)} required span(s) present")
    return 0


if __name__ == "__main__":
    sys.exit(main())
