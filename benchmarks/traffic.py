"""Production-traffic benchmark: the serving stack under Zipf/Poisson load.

The other ``serving_bench`` sections measure one mechanism at a time
(one cold compile, one promotion, one refill).  This section measures
the *composition*: a Zipf-popularity catalog of synthetic ICL tasks,
sized to exceed ``prefix_capacity`` and ``host_capacity``, served under
seeded Poisson (or bursty ON-OFF) arrivals with two priority classes —
so online compiles, tier demotions/promotions, priority preemptions and
the budget autotuner all fire in one run, and the scoreboard is the SLO
view an operator would read: TTFT p50/p99, goodput (SLO-attained
requests/s), decode-gap p99, tokens/s/device.

Everything runs on a :class:`~repro.serving.clock.VirtualClock`: time
advances only through the engine's ``charge()`` cost model, so the
reported numbers are *simulated* seconds — a pure function of
``(scenario, seed)``, byte-identical across hosts and CI runs
(``tests/test_traffic.py`` locks this down).  Wall-clock is reported
once, informationally, for the whole section.

Two sub-runs share one trace:

* **fixed** — the configured ``compile_token_budget`` /
  ``promote_layer_budget`` all the way through;
* **autotuned** — the engine halves/doubles those budgets against the
  observed decode-gap (``autotune_budgets=True``), and the row reports
  where the budgets landed.

Run directly (``python -m benchmarks.traffic --smoke``) or through
``python -m benchmarks.serving_bench``, which embeds the result under
its ``traffic`` key.
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time  # reprolint: ignore-file[wall-clock] -- load generator paces against the real clock when run live

import numpy as np

from benchmarks import common as C
from repro.core import memcom
from repro.models import transformer as tfm
from repro.serving import MetricsRegistry, ServingEngine, SLOWatchdog, \
    ShedDegrade, Tracer, TrafficConfig, VirtualClock, default_rules, \
    generate_trace, profile_spans, slo_metrics


def scenario(smoke: bool, *, process: str = "poisson",
             num_tasks: int = None, num_requests: int = None,
             rate_rps: float = None) -> TrafficConfig:
    """The benchmark scenario.  Catalog ≫ prefix/host capacity (set in
    :func:`run_traffic`) so the tail of the Zipf distribution churns
    through demote/spill/promote while the head stays HBM-resident."""
    if smoke:
        base = dict(num_tasks=6, num_requests=16, context_tokens=24,
                    rate_rps=300.0)
    else:
        base = dict(num_tasks=24, num_requests=96, context_tokens=48,
                    rate_rps=200.0)
    if num_tasks is not None:
        base["num_tasks"] = num_tasks
    if num_requests is not None:
        base["num_requests"] = num_requests
    if rate_rps is not None:
        base["rate_rps"] = rate_rps
    return TrafficConfig(process=process, zipf_alpha=1.1,
                         priority_classes=2, priority_weights=(0.25, 0.75),
                         **base)


def _serve_once(cfg, target, mc, m, trace, *, slots, autotune: bool,
                compile_token_budget: int, promote_layer_budget: int,
                prefix_capacity: int, host_capacity: int,
                slo_ttft_s: float, tracer=None, metrics=None,
                watchdog=None) -> dict:
    """One engine lifetime over the trace.  Fresh temp disk dir per run:
    a persistent one would carry spilled shards into the next run and
    break the same-seed determinism the section advertises."""
    disk = tempfile.mkdtemp(prefix="traffic-bench-")
    clock = VirtualClock()
    engine = ServingEngine(
        cfg, target, slots=slots, max_len=m + 32, compressor=mc,
        prefix_capacity=prefix_capacity,
        compile_token_budget=compile_token_budget,
        host_capacity=host_capacity, disk_dir=disk,
        promote_layer_budget=promote_layer_budget,
        clock=clock, priority_aging_s=0.05,
        autotune_budgets=autotune,
        target_decode_gap_s=2e-3 if autotune else None,
        autotune_interval=8,
        tracer=tracer, metrics=metrics, watchdog=watchdog)
    try:
        t0 = time.perf_counter()
        engine.serve(list(trace.requests))
        wall_s = time.perf_counter() - t0
        stats = engine.stats()
        out = slo_metrics(engine.request_log, slo_ttft_s=slo_ttft_s,
                          devices=1, gap_samples=engine.gap_samples)
    finally:
        shutil.rmtree(disk, ignore_errors=True)
    es, ts, cs = stats["engine"], stats["prefix_tiers"], stats["compiler"]
    # the section's whole point is the *composition* under churn — if the
    # catalog stopped exceeding capacity these go quiet and the numbers
    # measure nothing, so fail loudly rather than report a hollow row
    assert cs["jobs"] > 0, "traffic scenario fired no online compiles"
    assert ts["demotes"] > 0, "traffic scenario fired no tier demotions"
    out.update({
        "wall_s": wall_s,
        "decode_steps": es["decode_steps"],
        "tokens_per_step": (es["tokens_generated"]
                            / max(es["decode_steps"], 1)),
        "compiles": cs["jobs"],
        "demotes": ts["demotes"], "spills": ts["spills"],
        "promotes": ts["host_promotes"],
        "autotune_shrinks": es["autotune_shrinks"],
        "autotune_grows": es["autotune_grows"],
        "final_budgets": {
            "compile_token_budget":
                stats["budgets"]["compile_token_budget"],
            "promote_layer_budget":
                stats["budgets"]["promote_layer_budget"]},
    })
    return out


def run_traffic(cfg, target, mc, m, rng, *, smoke: bool = False,
                seed: int = 0, process: str = "poisson",
                num_tasks: int = None, num_requests: int = None,
                rate_rps: float = None, slo_ttft_s: float = 0.02) -> dict:
    """The ``traffic`` section: one seeded trace, served twice (fixed
    budgets, then autotuned budgets) on fresh engines + virtual clocks."""
    tcfg = scenario(smoke, process=process, num_tasks=num_tasks,
                    num_requests=num_requests, rate_rps=rate_rps)
    trace = generate_trace(tcfg, seed, vocab=C.VOCAB)
    sizing = dict(slots=2 if smoke else 4,
                  prefix_capacity=2 if smoke else 4,
                  host_capacity=2 if smoke else 4,
                  compile_token_budget=8 if smoke else 16,
                  promote_layer_budget=1 if smoke else 2,
                  slo_ttft_s=slo_ttft_s)
    out = {"seed": seed, "process": tcfg.process,
           "num_tasks": tcfg.num_tasks, "num_requests": tcfg.num_requests,
           "rate_rps": tcfg.rate_rps, "zipf_alpha": tcfg.zipf_alpha,
           "priority_classes": tcfg.priority_classes, **sizing}
    # Telemetry artifacts come off the *fixed* run: it is the simpler of
    # the two (no autotuner resizing budgets mid-flight), so the trace
    # reads as the canonical request-lifecycle picture, and — being on
    # the virtual clock — the dumped JSON is byte-identical per seed.
    tracer = Tracer()
    registry = MetricsRegistry()
    # SLO watchdog rides the fixed run too: burn-rate alerts land as
    # tracer instants + serving_alerts_total counters, and the alert log
    # is a pure function of (scenario, seed) on the virtual clock
    watchdog = SLOWatchdog(default_rules(slo_ttft_s=slo_ttft_s),
                           metrics=registry, tracer=tracer,
                           degrade_hook=ShedDegrade())
    rows = []
    for mode, autotune in (("fixed", False), ("autotuned", True)):
        r = _serve_once(cfg, target, mc, m, trace, autotune=autotune,
                        tracer=tracer if mode == "fixed" else None,
                        metrics=registry if mode == "fixed" else None,
                        watchdog=watchdog if mode == "fixed" else None,
                        **sizing)
        out[mode] = r
        fb = r["final_budgets"]
        rows.append((
            mode, f"{r['completed']}/{r['requests']}",
            f"{r['ttft_p50_s']*1e3:.2f}", f"{r['ttft_p99_s']*1e3:.2f}",
            f"{r['goodput_rps']:.1f}",
            f"{r['tokens_per_s_per_device']:.0f}",
            f"{r['decode_gap_p99_s']*1e3:.2f}",
            r["preemptions"],
            f"{r['compiles']}/{r['demotes']}/{r['promotes']}",
            f"{fb['compile_token_budget']}/{fb['promote_layer_budget']}"))
    print(C.fmt_table(rows, (
        "budgets", "done", "TTFT p50 ms", "TTFT p99 ms", "goodput r/s",
        "tok/s/dev", "gap p99 ms", "preempt", "compile/demote/promote",
        "final budgets")) + "\n")
    print(f"traffic: {tcfg.num_requests} requests over "
          f"{tcfg.num_tasks} tasks (zipf {tcfg.zipf_alpha}, "
          f"{tcfg.process} @ {tcfg.rate_rps:.0f} r/s), catalog exceeds "
          f"prefix capacity {sizing['prefix_capacity']} — all times are "
          "simulated (virtual clock), identical across runs for one "
          "seed\n")
    os.makedirs(C.ROOT, exist_ok=True)
    trace_path = os.path.join(C.ROOT, "traffic_trace.json")
    tracer.dump(trace_path)
    prom_path = os.path.join(C.ROOT, "traffic_metrics.prom")
    with open(prom_path, "w") as fh:
        fh.write(registry.render_prometheus())
    # per-phase self-time attribution + the alert log, both schema'd
    # artifacts the perf gate and validate_trace consume
    profile = profile_spans(tracer.chrome_trace())
    profile_path = os.path.join(C.ROOT, "traffic_profile.json")
    with open(profile_path, "w") as fh:
        json.dump(profile, fh, sort_keys=True, indent=1)
    alerts_path = os.path.join(C.ROOT, "traffic_alerts.json")
    with open(alerts_path, "w") as fh:
        fh.write(watchdog.dumps())
    out["profile"] = profile
    out["alerts"] = {"fires": sum(1 for e in watchdog.alert_log
                                  if e["kind"] == "fire"),
                     "clears": sum(1 for e in watchdog.alert_log
                                   if e["kind"] == "clear")}
    out["artifacts"] = {"trace": trace_path, "metrics": prom_path,
                        "profile": profile_path, "alerts": alerts_path,
                        "trace_events": len(tracer.events()),
                        "dropped_events": tracer.dropped}
    print(f"traffic: wrote {trace_path} "
          f"({out['artifacts']['trace_events']} events), {prom_path}, "
          f"{profile_path} and {alerts_path} "
          f"({out['alerts']['fires']} alert fires)\n")
    return out


def main(argv=None):
    import dataclasses

    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="random-init target + small scenario (CI speed)")
    ap.add_argument("--ratio", type=int, default=8, choices=sorted(C.RATIOS))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--process", choices=("poisson", "onoff"),
                    default="poisson")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--tasks", type=int, default=None)
    ap.add_argument("--rate", type=float, default=None,
                    help="arrival rate (requests/s of simulated time)")
    ap.add_argument("--slo-ttft", type=float, default=0.02,
                    help="TTFT SLO in simulated seconds (goodput counts "
                         "requests at or under this)")
    args = ap.parse_args(argv)

    if args.smoke:
        cfg = C.target_config()
        target = tfm.init_params(cfg, 0)
    else:
        cfg, target = C.get_or_pretrain_target()
    m = C.RATIOS[args.ratio]
    cfg = cfg.replace(
        memcom=dataclasses.replace(cfg.memcom, num_memory_tokens=m))
    mc = memcom.init_memcom(cfg, target, 1)
    rng = np.random.default_rng(args.seed)
    out = run_traffic(cfg, target, mc, m, rng, smoke=args.smoke,
                      seed=args.seed, process=args.process,
                      num_tasks=args.tasks, num_requests=args.requests,
                      rate_rps=args.rate, slo_ttft_s=args.slo_ttft)
    C.write_result("traffic_bench", {"ratio": args.ratio, "m": m,
                                     "traffic": out})
    return out


if __name__ == "__main__":
    main()
