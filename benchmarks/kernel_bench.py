"""Kernel micro-benchmarks: wall-clock of the streaming-jnp production
paths on CPU (informational — TPU is the target), plus the analytic
FLOPs/bytes and arithmetic intensity per kernel invocation that the
roofline model uses.  The Pallas kernels themselves are *validated* in
tests (interpret mode executes Python per block — timing it is
meaningless), so what's timed here is the same math through XLA:CPU.
"""

from __future__ import annotations

import time  # reprolint: ignore-file[wall-clock] -- benchmarks measure real kernel wall time by definition

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.kernels import jnp_impl, ops


def _timeit(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run():
    rng = np.random.default_rng(0)
    rows = []

    def t(*s):
        return jnp.asarray(rng.standard_normal(s), jnp.float32)

    # MemCom 1-head xattn at paper-ish shapes (scaled to CPU)
    for (B, M, T, D) in [(1, 64, 768, 256), (1, 128, 1536, 256)]:
        q, k, v = t(B, M, D), t(B, T, D), t(B, T, D)
        fn = jax.jit(lambda q, k, v: ops.memcom_xattn(q, k, v, impl="jnp"))
        sec = _timeit(fn, q, k, v)
        flops = 2 * B * (M * D * T * 2)  # QK^T + PV
        bytes_ = 4 * B * (M * D + 2 * T * D + M * D)
        rows.append(("memcom_xattn", f"{B}x{M}x{T}x{D}", sec * 1e3,
                     flops / 1e9, flops / bytes_))

    # flash-style causal self-attention
    for (B, S, H, Dh) in [(1, 1024, 8, 64), (1, 2048, 8, 64)]:
        q, k, v = t(B, S, H, Dh), t(B, S, H, Dh), t(B, S, H, Dh)
        fn = jax.jit(lambda q, k, v: ops.self_attention_causal(
            q, k, v, impl="jnp"))
        sec = _timeit(fn, q, k, v)
        flops = 2 * B * H * S * S * Dh * 2 / 2  # causal half
        bytes_ = 4 * B * S * H * Dh * 4
        rows.append(("causal_attn", f"{B}x{S}x{H}x{Dh}", sec * 1e3,
                     flops / 1e9, flops / bytes_))

    # grouped matmul (MoE)
    for (E, Cc, D, F) in [(8, 256, 256, 512)]:
        x, w = t(E, Cc, D), t(E, D, F)
        fn = jax.jit(lambda x, w: ops.gmm(x, w, impl="jnp"))
        sec = _timeit(fn, x, w)
        flops = 2 * E * Cc * D * F
        bytes_ = 4 * (E * Cc * D + E * D * F + E * Cc * F)
        rows.append(("moe_gmm", f"{E}x{Cc}x{D}x{F}", sec * 1e3,
                     flops / 1e9, flops / bytes_))

    # SSD chunked scan
    for (B, S, H, P, N) in [(1, 2048, 8, 64, 64)]:
        x = t(B, S, H, P)
        dt = jnp.abs(t(B, S, H)) * 0.1
        A = -jnp.abs(t(H))
        Bm, Cm = t(B, S, 1, N), t(B, S, 1, N)
        fn = jax.jit(lambda *a: jnp_impl.ssd_chunked(*a, chunk=128))
        sec = _timeit(fn, x, dt, A, Bm, Cm)
        Q = 128
        flops = B * S * H * (2 * Q * N + 2 * Q * P + 4 * N * P)
        bytes_ = 4 * B * S * H * (P + N * 2 + 1) * 2
        rows.append(("ssd_scan", f"{B}x{S}x{H}x{P}x{N}", sec * 1e3,
                     flops / 1e9, flops / bytes_))

    table = [(n, s, f"{ms:.1f}", f"{gf:.2f}", f"{ai:.1f}")
             for n, s, ms, gf, ai in rows]
    print("\n" + C.fmt_table(
        table, ("kernel", "shape", "ms (CPU jnp)", "GFLOP", "arith-int")) + "\n")
    C.write_result("kernel_bench", {
        "rows": [dict(kernel=n, shape=s, ms=ms, gflop=gf, intensity=ai)
                 for n, s, ms, gf, ai in rows]})
    return rows


if __name__ == "__main__":
    run()
