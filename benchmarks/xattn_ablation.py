"""Paper Table 6 analog: cross-attention module design ablation.

1-head (paper default) vs MHA vs MQA, trained Phase-1-only at 8×
compression — reproducing claim C5 (1-head is the best overall choice).
"""

from __future__ import annotations

import argparse
import dataclasses

from benchmarks import common as C


def run(steps: int = 300, ratio: int = 8, eval_episodes: int = 12):
    cfg0, target = C.get_or_pretrain_target()
    m = C.RATIOS[ratio]

    rows = []
    for kind, heads in (("1head", 1), ("mha", 4), ("mqa", 4)):
        cfg = cfg0.replace(memcom=dataclasses.replace(
            cfg0.memcom, num_memory_tokens=m, xattn_kind=kind,
            xattn_heads=heads))
        comp, _ = C.train_compressor(
            "memcom", target, cfg, steps=steps, phase=1,
            seed={"1head": 1, "mha": 2, "mqa": 3}[kind])
        acc = C.evaluate(
            C.make_memcom_predictor(cfg, target, comp, C.SOURCE_LEN),
            budget=C.SOURCE_LEN, n_episodes=eval_episodes)
        rows.append((kind, acc))
        C.log(f"xattn {kind}: {acc}")

    table = [(n, round(a["mean"], 3), *(round(a[t], 3) for t in C.TASKS))
             for n, a in rows]
    print("\n" + C.fmt_table(table, ("xattn", "mean", *C.TASKS)) + "\n")
    C.write_result("xattn_ablation", {
        "ratio": ratio, "m": m, "steps": steps,
        "rows": [dict(kind=n, acc=a) for n, a in rows]})
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()
    run(steps=args.steps)
