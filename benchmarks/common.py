"""Shared tiny-scale reproduction harness (CPU).

The paper's quantitative setting (2B/7B models, 80B training tokens) is
out of reach in this container, so the benchmarks reproduce the paper's
*qualitative* claims (DESIGN.md §6, claims C1–C5) at toy scale:

1. pretrain a small target LM on a synthetic corpus whose ICL episodes
   (random key→label mappings rendered as [SEP key ARROW label] shots)
   carry the structural core of TREC/Banking77/Clinc-style tasks —
   the model must learn induction to predict labels of seen keys;
2. freeze it, train compressors (MemCom Phase-1/Phase-2, ICAE ladder)
   with next-token loss on the same pretraining distribution — never on
   task data, exactly the paper's §3 protocol;
3. evaluate label accuracy on held-out episodes at 3×/6×/8× compression
   against the fewer-shots baseline and the full-context upper bound.

Artifacts (pretrained target, trained compressors) are cached under
``artifacts/bench`` so individual benchmarks can rerun cheaply.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_tree, save_tree
from repro.config import LayerDesc, LayerLayout, MemComConfig, ModelConfig
from repro.core import icae as icae_lib
from repro.core import memcom
from repro.data import (
    ICLTaskSpec, PretrainStream, SyntheticVocab, eval_accuracy,
)
from repro.models import transformer as tfm
from repro.optim import AdamW, clip_by_global_norm, warmup_constant, \
    warmup_cosine

ROOT = os.environ.get("BENCH_ROOT", "artifacts/bench")

VOCAB = SyntheticVocab(num_keys=64, num_labels=64, num_words=256)

# the evaluation suite: label-set sizes scaled from the paper's Table 1
TASKS = {
    "trec-coarse-like": ICLTaskSpec(VOCAB, num_labels=6, keys_per_label=8),
    "hwu64-like": ICLTaskSpec(VOCAB, num_labels=16, keys_per_label=4),
    "banking77-like": ICLTaskSpec(VOCAB, num_labels=32, keys_per_label=2),
}

SOURCE_LEN = 96  # many-shot budget (tokens) = 24 shots
RATIOS = {3: 32, 6: 16, 8: 12}  # compression ratio -> m memory tokens


def target_config(m_tokens: int = 32) -> ModelConfig:
    return ModelConfig(
        name="bench-target",
        family="dense",
        layout=LayerLayout.uniform(LayerDesc("attn", "dense"), 4),
        d_model=128, num_heads=4, num_kv_heads=4, d_ff=256,
        vocab_size=VOCAB.size, max_seq=512, dtype="float32",
        memcom=MemComConfig(num_memory_tokens=m_tokens),
        source="tiny-scale reproduction target",
    )


def _stream(seed=0):
    return PretrainStream(VOCAB, batch=16, seq_len=SOURCE_LEN + 32,
                          split_choices=(int(SOURCE_LEN * 0.9), SOURCE_LEN,
                                         int(SOURCE_LEN * 1.1)),
                          seed=seed, icl_fraction=0.75)


def _ckpt(name):
    return os.path.join(ROOT, name)


def log(msg):
    print(f"[bench {time.strftime('%H:%M:%S')}] {msg}", flush=True)


# ---------------------------------------------------------------------------
# Stage 1: pretrain the frozen target
# ---------------------------------------------------------------------------


def induction_accuracy(cfg, params, *, seed=777, batches=4,
                       logits_fn=None) -> float:
    """Fraction of *repeat-key* label positions predicted correctly on the
    training distribution — the capability the ICL eval depends on."""
    stream = _stream(seed=seed)
    if logits_fn is None:
        logits_fn = jax.jit(
            lambda p, t: tfm.forward(p, cfg, tokens=t)[0])
    hits = total = 0
    for i in range(batches):
        b = stream.batch_at(i)
        toks = np.concatenate([b["source"], b["target"]], axis=1)
        logits = logits_fn(params, jnp.asarray(toks))
        pred = np.asarray(logits).argmax(-1)[:, :-1]
        nxt = toks[:, 1:]
        is_arrow = toks[:, :-1] == VOCAB.ARROW
        is_label = (nxt >= VOCAB.label_base) & (nxt < VOCAB.word_base)
        # repeat keys only: the first occurrence is unpredictable
        for r in range(toks.shape[0]):
            seen = set()
            for t in np.where(is_arrow[r] & is_label[r])[0]:
                key = toks[r, t - 1]
                if key in seen:
                    hits += int(pred[r, t] == nxt[r, t])
                    total += 1
                seen.add(key)
    return hits / max(total, 1)


def get_or_pretrain_target(steps: int = 4000, force: bool = False):
    """Pretrain (or extend) the frozen target.  Progress is checkpointed
    every 500 steps under ``target`` with the step count in meta, so an
    interrupted/undertrained run resumes instead of restarting."""
    cfg = target_config()
    path = _ckpt("target")
    params = tfm.init_params(cfg, 0)
    start = 0
    if os.path.exists(path) and not force:
        tree, meta = load_tree(path, params)
        params = jax.tree.map(jnp.asarray, tree)
        start = int(meta.get("steps", 0))
        if start >= steps:
            return cfg, params
        log(f"extending target pretraining {start} -> {steps} steps …")
    else:
        log(f"pretraining target LM for {steps} steps …")
    stream = _stream(seed=11)
    opt = AdamW(lr=warmup_cosine(3e-3, 100, steps), weight_decay=0.01)
    state = opt.init(params)  # NB: fresh moments on resume — acceptable here
    probe = jax.jit(lambda p, t: tfm.forward(p, cfg, tokens=t)[0])

    @jax.jit
    def step_fn(params, state, tokens, mask):
        def loss(p):
            logits, aux = tfm.forward(p, cfg, tokens=tokens)
            return memcom.next_token_loss(logits, tokens, mask) + aux["moe_loss"]

        l, g = jax.value_and_grad(loss)(params)
        g, _ = clip_by_global_norm(g, 1.0)
        params, state = opt.step(params, g, state)
        return params, state, l

    for i in range(start, steps):
        b = stream.batch_at(i)
        toks = jnp.asarray(np.concatenate([b["source"], b["target"]], axis=1))
        mask = jnp.asarray((np.asarray(toks) != VOCAB.PAD).astype(np.float32))
        params, state, l = step_fn(params, state, toks, mask)
        if (i + 1) % 500 == 0 or i == steps - 1:
            ind = induction_accuracy(cfg, params, batches=1, logits_fn=probe)
            log(f"  pretrain step {i}: loss {float(l):.4f} "
                f"induction-acc {ind:.3f}")
            save_tree(path, params, meta={"steps": i + 1,
                                          "induction_acc": ind})
    ind = induction_accuracy(cfg, params, logits_fn=probe)
    log(f"final induction accuracy (repeat keys): {ind:.3f}")
    save_tree(path, params, meta={"steps": steps, "induction_acc": ind})
    return cfg, params


# ---------------------------------------------------------------------------
# Stage 2: compressor training (shared loop)
# ---------------------------------------------------------------------------


def train_compressor(kind: str, target_params, cfg: ModelConfig, *,
                     steps: int = 300, lr: float = 2e-3, seed: int = 1,
                     phase: int = 1, variant: str = "icae++",
                     init_from=None, force: bool = False):
    """kind: "memcom" | "icae".  Returns trained compressor params.

    Phase-1 trains {memx, mem_tokens} (MemCom) / {lora|attn, mem_embed}
    (ICAE); Phase-2 (MemCom) unfreezes the two stacks at a lower lr —
    both per the paper §4 / A.2.
    """
    m = cfg.memcom.num_memory_tokens
    flavor = variant if kind == "icae" else cfg.memcom.xattn_kind
    tag = f"{kind}-{flavor}-m{m}-p{phase}-s{steps}-lr{lr}-sd{seed}"
    path = _ckpt(tag)
    if kind == "memcom":
        comp = (init_from if init_from is not None
                else memcom.init_memcom(cfg, target_params, seed))
        mask = memcom.trainable_mask(comp, phase)

        def loss_fn(c, batch):
            c = jax.tree.map(
                lambda x, mk: x if mk else jax.lax.stop_gradient(x), c, mask)
            return memcom.memcom_loss(c, target_params, cfg, batch)
    else:
        comp = icae_lib.init_icae(cfg, target_params, variant=variant,
                                  seed=seed)
        mask = icae_lib.icae_trainable_mask(comp, variant)

        def loss_fn(c, batch):
            c = jax.tree.map(
                lambda x, mk: x if mk else jax.lax.stop_gradient(x), c, mask)
            return icae_lib.icae_loss(c, target_params, cfg, batch)

    if os.path.exists(path) and not force:
        tree, _ = load_tree(path, comp)
        return jax.tree.map(jnp.asarray, tree), None

    log(f"training {tag} for {steps} steps …")
    opt = AdamW(lr=warmup_constant(lr, 30), mask=mask)
    state = opt.init(comp)

    @jax.jit
    def step_fn(comp, state, batch):
        (l, _aux), g = jax.value_and_grad(loss_fn, has_aux=True)(comp, batch)
        g, _ = clip_by_global_norm(g, 1.0)
        comp, state = opt.step(comp, g, state)
        return comp, state, l

    stream = _stream(seed=100 + seed)
    losses = []
    for i in range(steps):
        b = stream.batch_at(i)
        batch = {"source": jnp.asarray(b["source"]),
                 "target": jnp.asarray(b["target"]),
                 "target_mask": jnp.asarray(b["target_mask"])}
        comp, state, l = step_fn(comp, state, batch)
        losses.append(float(l))
        if i % 100 == 0 or i == steps - 1:
            log(f"  {tag} step {i}: loss {losses[-1]:.4f}")
    save_tree(path, comp, meta={"losses_tail": losses[-20:]})
    return comp, losses


# ---------------------------------------------------------------------------
# Stage 3: evaluation — label accuracy through each serving path
# ---------------------------------------------------------------------------


def _pad_context(ctx: np.ndarray, to_len: int) -> np.ndarray:
    """Left-pad with PAD so compile shapes are stable across episodes."""
    out = np.full((to_len,), VOCAB.PAD, np.int32)
    out[: len(ctx)] = ctx
    return out


def make_full_context_predictor(cfg, target_params, ctx_len):
    label_ids = None

    @jax.jit
    def logits_fn(toks):
        logits, _ = tfm.forward(target_params, cfg, tokens=toks)
        return logits[0, -1]

    def predict(context, query):
        toks = np.concatenate([_pad_context(context, ctx_len), query])[None]
        row = np.asarray(logits_fn(jnp.asarray(toks)))
        ids = VOCAB.label_ids()
        return int(ids[np.argmax(row[ids])] - VOCAB.label_base)

    return predict


def make_memcom_predictor(cfg, target_params, comp, ctx_len):
    m = cfg.memcom.num_memory_tokens

    @jax.jit
    def logits_fn(source, query):
        prefix, _ = memcom.compress(comp, cfg, source)
        logits, _ = tfm.forward(target_params, cfg, tokens=query,
                                prefix=prefix, mask_offset=m)
        return logits[0, -1]

    def predict(context, query):
        src = _pad_context(context, ctx_len)[None]
        row = np.asarray(logits_fn(jnp.asarray(src), jnp.asarray(query[None])))
        ids = VOCAB.label_ids()
        return int(ids[np.argmax(row[ids])] - VOCAB.label_base)

    return predict


def make_icae_predictor(cfg, target_params, comp, ctx_len):
    @jax.jit
    def logits_fn(source, query):
        soft = icae_lib.icae_compress(comp, cfg, source)
        q_emb = jnp.take(target_params["embed"]["tokens"], query, axis=0)
        embeds = jnp.concatenate([soft.astype(q_emb.dtype), q_emb], axis=1)
        logits, _ = tfm.forward(target_params, cfg, embeds=embeds)
        return logits[0, -1]

    def predict(context, query):
        src = _pad_context(context, ctx_len)[None]
        row = np.asarray(logits_fn(jnp.asarray(src), jnp.asarray(query[None])))
        ids = VOCAB.label_ids()
        return int(ids[np.argmax(row[ids])] - VOCAB.label_base)

    return predict


def evaluate(predict, *, budget, query_budget=None, n_episodes=12,
             queries_per_episode=12, seed=0):
    out = {}
    for name, task in TASKS.items():
        out[name] = eval_accuracy(
            predict, task, budget=budget, query_budget=query_budget,
            n_episodes=n_episodes, queries_per_episode=queries_per_episode,
            seed=seed)
    out["mean"] = float(np.mean(list(out.values())))
    return out


def write_result(name: str, payload: dict):
    os.makedirs(ROOT, exist_ok=True)
    path = os.path.join(ROOT, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    log(f"wrote {path}")


def fmt_table(rows, header):
    widths = [max(len(str(r[i])) for r in [header] + rows)
              for i in range(len(header))]
    lines = [" | ".join(str(h).ljust(w) for h, w in zip(header, widths))]
    lines.append("-+-".join("-" * w for w in widths))
    for r in rows:
        lines.append(" | ".join(str(c).ljust(w) for c, w in zip(r, widths)))
    return "\n".join(lines)
