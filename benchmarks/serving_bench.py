"""Serving-cost benchmark: the paper's actual deliverable — decode cost
against a compressed m-slot cache vs the full t-token cache, plus a
continuous-batching scenario (two distinct compressed tasks, ragged
prompts, per-slot stop budgets, mid-stream slot refill) measuring the
multi-tenant serving shape end to end, an ``online_compile`` section
(cold-task time-to-first-token and the decode-throughput dip while a
compile is in flight, interleaved vs fully stalled), and a
``prefix_tiering`` section (time-to-first-token down the HBM → host →
disk → recompile ladder, and the decode dip while a demoted prefix
promotes back, interleaved vs stalled), and a ``traffic`` section
(seeded Zipf/Poisson load over a catalog exceeding cache capacity:
TTFT p50/p99, goodput, decode-gap p99 and tokens/s/device on a virtual
clock, fixed vs autotuned budgets — ``benchmarks/traffic.py``).

Measures (CPU wall-clock, informational) and reports the structural
ratios that transfer to TPU: per-step attended KV slots, cache bytes,
attention FLOPs.  The 32k-decode roofline cells in EXPERIMENTS.md §Perf
make the same comparison at production scale from the compiled dry-run.

``--smoke`` swaps the cached pretrained target for a random-init one and
shrinks the sweep — the CI-speed configuration that exercises the whole
serving path (GitHub Actions runs it on every push).
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time  # reprolint: ignore-file[wall-clock] -- SLO bench measures real host latency; deterministic runs inject VirtualClock

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from benchmarks.traffic import run_traffic
from repro.core import memcom
from repro.models import transformer as tfm
from repro.serving import Request, ServingEngine
from repro.serving.engine import materialize_prefix, write_prefix_to_cache
from repro.utils.pytree import tree_bytes


def run(ratio: int = 8, decode_steps: int = 16, smoke: bool = False,
        sharded: bool = True):
    import dataclasses

    if smoke:  # CI configuration: random target, no pretraining artifact
        cfg0 = C.target_config()
        target = tfm.init_params(cfg0, 0)
        decode_steps = 4
    else:
        cfg0, target = C.get_or_pretrain_target()
    m = C.RATIOS[ratio]
    cfg0 = cfg0.replace(
        memcom=dataclasses.replace(cfg0.memcom, num_memory_tokens=m))
    t = C.SOURCE_LEN
    B = 4
    rng = np.random.default_rng(0)
    source = jnp.asarray(rng.integers(4, cfg0.vocab_size, (B, t)), jnp.int32)

    def decode_loop(cache, start):
        @jax.jit
        def step(cache, tok, i):
            logits, aux = tfm.forward(target, cfg0, tokens=tok, cache=cache,
                                      cache_index=i, decode=True)
            return aux["cache"], jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)

        tok = jnp.ones((B, 1), jnp.int32)
        cache, tok = step(cache, tok, start)  # compile
        jax.block_until_ready(tok)
        t0 = time.perf_counter()
        for i in range(decode_steps):
            cache, tok = step(cache, tok, start + 1 + i)
        jax.block_until_ready(tok)
        return (time.perf_counter() - t0) / decode_steps

    # vanilla: prefill t tokens, decode against t-slot cache
    full_cache = tfm.init_cache(cfg0, B, t + decode_steps + 2)
    _, aux = tfm.forward(target, cfg0, tokens=source, cache=full_cache,
                         cache_index=0)
    sec_full = decode_loop(aux["cache"], t)
    bytes_full = tree_bytes(aux["cache"])

    # compressed: m memory slots + decode window
    mc = memcom.init_memcom(cfg0, target, 1)
    prefix, _ = memcom.compress(mc, cfg0, source)
    kv = materialize_prefix(target, cfg0, prefix)
    small_cache = tfm.init_cache(cfg0, B, m + decode_steps + 2)
    small_cache = write_prefix_to_cache(cfg0, small_cache, kv)
    sec_comp = decode_loop(small_cache, m)
    bytes_comp = tree_bytes(small_cache)

    rows = [
        ("full-context", t, f"{sec_full*1e3:.2f}", f"{bytes_full/1e6:.2f}"),
        (f"memcom-{ratio}x", m, f"{sec_comp*1e3:.2f}", f"{bytes_comp/1e6:.2f}"),
    ]
    print("\n" + C.fmt_table(
        rows, ("serving path", "KV slots", "ms/token (CPU)", "cache MB")) + "\n")
    print(f"cache-bytes ratio: {bytes_full / bytes_comp:.2f}x "
          f"(structural, transfers to TPU)\n")

    cb = run_continuous_batching(cfg0, target, mc, m, rng,
                                 num_requests=4 if smoke else 8)
    pvd = run_paged_vs_dense(cfg0, target, mc, m, rng,
                             slot_counts=(1, 4) if smoke else (1, 4, 16),
                             decode_steps=4 if smoke else 8)
    fs = run_fused_spec(cfg0, target, mc, m, rng, smoke=smoke)
    oc = run_online_compile(cfg0, target, mc, m, rng,
                            warm_new=12 if smoke else 24)
    pt = run_prefix_tiering(cfg0, target, mc, m, rng,
                            warm_new=12 if smoke else 24)
    tr = run_traffic(cfg0, target, mc, m, rng, smoke=smoke)
    sd = run_sharded_decode(smoke) if sharded else None

    C.write_result("serving_bench", {
        "ratio": ratio, "m": m, "t": t,
        "ms_full": sec_full * 1e3, "ms_compressed": sec_comp * 1e3,
        "cache_bytes_full": bytes_full, "cache_bytes_compressed": bytes_comp,
        "continuous_batching": cb, "paged_vs_dense": pvd,
        "fused_spec": fs, "online_compile": oc, "prefix_tiering": pt,
        "traffic": tr, "sharded_decode": sd})
    return rows


def run_continuous_batching(cfg, target, mc, m, rng, *, slots=4,
                            num_requests=8):
    """Multi-tenant serving shape: two distinct compressed task prefixes
    seated per slot, ragged prompts, per-slot budgets forcing mid-stream
    refill.  Reports throughput and the admission/refill trace."""
    srcs = [jnp.asarray(rng.integers(4, cfg.vocab_size, (1, C.SOURCE_LEN)),
                        jnp.int32) for _ in range(2)]
    engine = ServingEngine(cfg, target, slots=slots, max_len=m + 48)
    for i, s in enumerate(srcs):
        prefix, _ = memcom.compress(mc, cfg, s)
        engine.add_prefix(f"task{i}", materialize_prefix(target, cfg, prefix))

    reqs = [
        Request(tokens=rng.integers(4, cfg.vocab_size,
                                    int(rng.integers(3, 13))),
                max_new=int(rng.integers(4, 10)),
                prefix=f"task{i % 2}")
        for i in range(num_requests)
    ]
    # warm every prefill bucket the ragged lengths (3..12) can hit, plus
    # the decode step (max_new=2: the first token comes from prefill, so
    # only the second forces a decode), so the timed region measures
    # serving not jit
    engine.serve([Request(tokens=np.arange(4, 8, dtype=np.int32), max_new=2,
                          prefix="task0"),
                  Request(tokens=np.arange(4, 13, dtype=np.int32), max_new=2,
                          prefix="task1")])
    t0 = time.perf_counter()
    out = engine.serve(reqs)
    dt = time.perf_counter() - t0
    generated = int(sum(len(v) for v in out.values()))
    ragged = sorted({len(r.tokens) for r in reqs})
    print(C.fmt_table(
        [(num_requests, 2, slots, ragged, generated, f"{generated/dt:.1f}")],
        ("requests", "tasks", "slots", "prompt lens", "tokens", "tok/s (CPU)"),
    ) + "\n")
    return {"requests": num_requests, "tasks": 2, "slots": slots,
            "generated": generated, "serve_s": dt,
            "tokens_per_s": generated / dt}


def _kv_leaf_bytes(cache):
    """Total bytes of the attention/MLA KV leaves of a Layerwise cache."""
    from repro.serving.prefix_store import _KV_KEYS

    total = 0
    for entry in cache.get("prefix", []):
        for key in _KV_KEYS:
            if key in entry:
                total += entry[key].size * entry[key].dtype.itemsize
    for entry in cache.get("period", {}).values():
        for key in _KV_KEYS:
            if key in entry:
                total += entry[key].size * entry[key].dtype.itemsize
    return total


def run_paged_vs_dense(cfg, target, mc, m, rng, *, slot_counts=(1, 4, 16),
                       decode_steps=8, block_size=8):
    """The paged refactor's headline: N slots seated on *one* compressed
    task.  Dense copies the m-token prefix into every slot's cache stripe
    (prefix memory O(slots)); paged stores it once in shared ref-counted
    blocks (O(tasks)) — the table reports prefix KV bytes, total KV bytes
    per slot, and the batched decode-step latency at each pool size."""
    src = jnp.asarray(rng.integers(4, cfg.vocab_size, (1, C.SOURCE_LEN)),
                      jnp.int32)
    kv = materialize_prefix(target, cfg, memcom.compress(mc, cfg, src)[0])
    prompt = rng.integers(4, cfg.vocab_size, 4).astype(np.int32)
    max_len = m + 24

    rows, out = [], {"block_size": block_size, "m": m,
                     "slot_counts": list(slot_counts), "dense": [], "paged": []}
    for slots in slot_counts:
        for layout in ("dense", "paged"):
            eng = ServingEngine(cfg, target, slots=slots, max_len=max_len,
                                kv_layout=layout,
                                **({"block_size": block_size}
                                   if layout == "paged" else {}))
            eng.add_prefix("task", kv)
            for s in range(slots):
                eng.seat_prefix(s, "task")
                eng._prefill_slot(s, prompt)
            # drive the decode step exactly as serve() does: lengths
            # advance each step and (paged) the active slots' tables grow
            # before the write position crosses into a new block
            lengths = eng.base + len(prompt)  # np, mutated in place
            active = range(slots)
            step = eng._decode_greedy

            def one_step(cache, ids):
                if layout == "paged":
                    eng._ensure_decode_blocks(active, lengths)
                    args = (jnp.asarray(eng.tables),)
                else:
                    args = ()
                ids, cache = step(eng.params, cache, ids,
                                  jnp.asarray(lengths, jnp.int32), *args)
                lengths[:] += 1
                return cache, ids

            tok = jnp.ones((slots, 1), jnp.int32)
            cache, ids = one_step(eng.cache, tok)  # compile, untimed
            jax.block_until_ready(ids)
            t0 = time.perf_counter()
            for _ in range(decode_steps):
                cache, ids = one_step(cache, ids[:, None])
            jax.block_until_ready(ids)
            ms_step = (time.perf_counter() - t0) / decode_steps * 1e3

            kv_total = _kv_leaf_bytes(eng.cache)
            if layout == "paged":
                # shared physical copy: the store's resident blocks
                block_bytes = kv_total // eng.alloc.num_blocks
                prefix_bytes = len(eng.store.blocks("task")) * block_bytes
                used_bytes = eng.alloc.used_count * block_bytes
            else:
                # one stripe per slot: every slot carries its own copy
                prefix_bytes = kv_total // max_len * m
                used_bytes = kv_total
            rows.append((layout, slots, f"{prefix_bytes/1e3:.1f}",
                         f"{used_bytes/1e3/slots:.1f}", f"{ms_step:.2f}"))
            out[layout].append({
                "slots": slots, "prefix_kv_bytes": int(prefix_bytes),
                "kv_bytes_per_slot": used_bytes / slots,
                "ms_per_decode_step": ms_step})

    print(C.fmt_table(rows, ("layout", "slots", "prefix KV (KB, all slots)",
                             "KV/slot (KB)", "ms/step (CPU)")) + "\n")
    d1, d16 = out["dense"][0], out["dense"][-1]
    p1, p16 = out["paged"][0], out["paged"][-1]
    print(f"prefix KV growth 1 -> {slot_counts[-1]} slots: "
          f"dense {d16['prefix_kv_bytes']/d1['prefix_kv_bytes']:.1f}x, "
          f"paged {p16['prefix_kv_bytes']/p1['prefix_kv_bytes']:.2f}x "
          "(shared blocks)\n")
    return out


def run_fused_spec(cfg, target, mc, m, rng, *, smoke=False):
    """The fused-step + speculative-decoding headline numbers.

    * **decode-gap p99 under churn** (virtual clock, so the numbers are
      work-model seconds, reproducible): staggered arrivals mix warm
      admissions and one cold raw-shot compile into a 2-slot engine.
      Unfused, every admission prefill and compile chunk lands *between*
      decode steps and widens the gap; fused, joins stream through the
      decode dispatch and compile chunks ride the same program, so the
      gap stays at the idle engine's (zero charged work between steps).
    * **tokens accepted per step** over the spec_k ladder: greedy
      no-prefix requests self-drafted (the acceptance upper bound) —
      each fused step verifies k drafts + 1, so tokens/step climbs
      toward k+1 while output stays token-identical to k=0.
    """
    from repro.serving import VirtualClock

    max_new = 6 if smoke else 12
    max_len = m + 32 + max_new
    shots_cold = rng.integers(4, cfg.vocab_size,
                              C.SOURCE_LEN).astype(np.int32)
    kv_warm = materialize_prefix(target, cfg, memcom.compress(
        mc, cfg, jnp.asarray(rng.integers(4, cfg.vocab_size,
                                          (1, C.SOURCE_LEN)), jnp.int32))[0])
    prompts = [rng.integers(4, cfg.vocab_size, int(n)).astype(np.int32)
               for n in (5, 9, 7, 11, 6, 8)]

    def churn_engine(fused):
        eng = ServingEngine(cfg, target, slots=2, max_len=max_len,
                            compressor=mc, compile_token_budget=16,
                            clock=VirtualClock(), fused_step=fused,
                            fused_chunk_tokens=8)
        eng.add_prefix("warm", kv_warm)
        return eng

    def churn_reqs():
        return [Request(tokens=p, max_new=max_new, arrival_s=0.0015 * i,
                        **({"prefix": "warm"} if i % 2 == 0 else
                           {"prefix": "cold", "raw_shots": shots_cold}))
                for i, p in enumerate(prompts)]

    # idle reference: slots-many warm requests, no mid-decode admission,
    # no compile — nothing is ever charged between decode steps
    idle = churn_engine(fused=False)
    idle.serve([Request(tokens=p, max_new=max_new, prefix="warm")
                for p in prompts[:2]])
    p99_idle = idle.stats()["engine"]["decode_gap_p99_s"]

    gap_rows, out = [], {"max_new": max_new,
                         "decode_gap_p99_idle_s": p99_idle}
    for fused in (False, True):
        eng = churn_engine(fused)
        eng.serve(churn_reqs())
        es = eng.stats()["engine"]
        key = "fused" if fused else "unfused"
        out[f"decode_gap_p99_{key}_s"] = es["decode_gap_p99_s"]
        out[f"churn_{key}"] = {
            k: es[k] for k in ("decode_steps", "fused_steps",
                               "fused_prefill_chunks", "fused_compile_chunks",
                               "decode_gap_max_s", "decode_gap_p99_s")}
        gap_rows.append((key, es["decode_steps"],
                         es["fused_prefill_chunks"],
                         es["fused_compile_chunks"],
                         f"{es['decode_gap_p99_s']*1e3:.3f}"))
    gap_rows.append(("idle", idle.stats()["engine"]["decode_steps"],
                     "-", "-", f"{p99_idle*1e3:.3f}"))
    print(C.fmt_table(gap_rows, ("engine (churn)", "decode steps",
                                 "prompt chunks fused",
                                 "compile chunks fused",
                                 "decode-gap p99 ms (virtual)")) + "\n")

    ladder_rows, ladder = [], []
    ref = None
    for k in (0, 1, 2, 4):
        kw = ({} if k == 0 else
              {"fused_step": True, "spec_draft": "self", "spec_k": k})
        eng = ServingEngine(cfg, target, slots=2, max_len=max_len, **kw)
        reqs = [Request(tokens=p, max_new=max_new) for p in prompts[:4]]
        res = eng.serve(reqs)
        toks = [list(map(int, res[r.uid])) for r in reqs]
        if k == 0:
            ref = toks
        es = eng.stats()["engine"]
        tps = es["tokens_generated"] / max(es["decode_steps"], 1)
        ladder.append({"k": k, "tokens_per_step": tps,
                       "accept_rate": es["accept_rate"],
                       "decode_steps": es["decode_steps"],
                       "identical": toks == ref})
        ladder_rows.append((k, es["decode_steps"], f"{tps:.2f}",
                            f"{es['accept_rate']:.0%}", toks == ref))
    print(C.fmt_table(ladder_rows, ("spec_k", "decode steps", "tokens/step",
                                    "accept rate", "== k=0 output")) + "\n")
    print(f"fused churn decode-gap p99 {out['decode_gap_p99_fused_s']*1e3:.3f}"
          f" ms vs idle {p99_idle*1e3:.3f} ms (unfused churn "
          f"{out['decode_gap_p99_unfused_s']*1e3:.3f} ms); self-drafted "
          f"greedy workload accepts >1 token/step from spec_k>=1\n")
    out["spec_ladder"] = ladder
    return out


def run_online_compile(cfg, target, mc, m, rng, *, compile_budget=16,
                       warm_new=24):
    """The online prefix compiler on the serving path.  Two measurements:

    * **time-to-first-token**, warm (prefix resident) vs cold (the
      request carries raw shots and the engine compiles them first);
    * **decode dip**: a warm slot decodes ``warm_new`` tokens while a
      cold task compiles — ``interleaved`` bounds *source-pass* work to
      ``compile_budget`` tokens between decode steps, ``stalled``
      compiles the whole task in one gap.  The per-engine decode-gap
      counters make the dip visible: the stalled run fits one decode
      step inside the whole compile where the interleaved run fits one
      per chunk, and the stalled max gap carries the full source pass
      where the interleaved max gap carries one chunk plus the finish
      pass (Memory-LLM + materialize — a single program in either mode,
      since it consumes *all* H^i at once; at toy scale it dominates
      both, so the gap ratio only opens up with the source length).
    """
    shots_warm = jnp.asarray(rng.integers(4, cfg.vocab_size,
                                          (1, C.SOURCE_LEN)), jnp.int32)
    shots_cold = rng.integers(4, cfg.vocab_size, C.SOURCE_LEN).astype(np.int32)
    kv_warm = materialize_prefix(
        target, cfg, memcom.compress(mc, cfg, shots_warm)[0])
    prompt = rng.integers(4, cfg.vocab_size, 4).astype(np.int32)

    def fresh_engine(budget):
        eng = ServingEngine(cfg, target, slots=2, max_len=m + 8 + warm_new + 8,
                            compressor=mc, compile_token_budget=budget)
        eng.add_prefix("warm", kv_warm)
        # untimed mirror of the measured workload (distinct shot content →
        # its own task): compiles the prefill/decode programs *and* this
        # budget's chunk/finish programs, so the timed run measures the
        # serving loop, not jit tracing
        warm_shots = rng.integers(4, cfg.vocab_size,
                                  C.SOURCE_LEN).astype(np.int32)
        eng.serve([Request(tokens=prompt, max_new=warm_new, prefix="warm"),
                   Request(tokens=prompt, max_new=2, raw_shots=warm_shots)])
        eng.reset_stats()
        return eng

    eng = fresh_engine(None)
    t0 = time.perf_counter()
    eng.serve([Request(tokens=prompt, max_new=1, prefix="warm")])
    ttft_warm = time.perf_counter() - t0
    t0 = time.perf_counter()
    eng.serve([Request(tokens=prompt, max_new=1, raw_shots=shots_cold)])
    ttft_cold = time.perf_counter() - t0

    out = {"compile_budget": compile_budget, "source_len": C.SOURCE_LEN,
           "ttft_warm_s": ttft_warm, "ttft_cold_s": ttft_cold}
    rows = [("ttft", "warm", f"{ttft_warm*1e3:.1f}", "-", "-"),
            ("ttft", "cold", f"{ttft_cold*1e3:.1f}", "-", "-")]
    for mode, budget in (("interleaved", compile_budget), ("stalled", None)):
        eng = fresh_engine(budget)
        reqs = [Request(tokens=prompt, max_new=warm_new, prefix="warm"),
                Request(tokens=prompt, max_new=2, raw_shots=shots_cold)]
        t0 = time.perf_counter()
        eng.serve(reqs)
        dt = time.perf_counter() - t0
        es = eng.stats()["engine"]
        gaps = max(es["decode_gaps"], 1)
        out[mode] = {
            "serve_s": dt,
            "decode_steps": es["decode_steps"],
            "decode_steps_during_compile": es["decode_steps_during_compile"],
            "decode_gap_max_s": es["decode_gap_max_s"],
            "decode_gap_mean_s": es["decode_gap_sum_s"] / gaps,
        }
        rows.append((mode, "warm+cold", f"{dt*1e3:.1f}",
                     f"{es['decode_gap_max_s']*1e3:.1f}",
                     es["decode_steps_during_compile"]))
    print(C.fmt_table(rows, ("section", "request", "total ms (CPU)",
                             "max decode gap ms", "decode during compile"))
          + "\n")
    print(f"decode steps inside the compile window: "
          f"{out['interleaved']['decode_steps_during_compile']} interleaved "
          f"vs {out['stalled']['decode_steps_during_compile']} stalled "
          "(stalled pays the whole source pass in one gap; the finish "
          "pass is one gap in both modes)\n")
    return out


def run_prefix_tiering(cfg, target, mc, m, rng, *, promote_budget=2,
                       warm_new=24):
    """The tiered prefix cache's headline numbers.  Two measurements:

    * **time-to-first-token by tier** — the same request served with its
      compressed prefix warm in HBM, demoted to the host tier, spilled
      to a disk shard, and (the tierless baseline) recompiled from raw
      shots.  The tier ladder is the point: every tier hit is a full
      online compile *avoided* — host/disk TTFT only pays promotion
      (a host→HBM copy, plus a shard read) where the recompile row pays
      the whole Source-LLM + Memory-LLM pass.
    * **decode dip during a promotion** — a warm slot decodes
      ``warm_new`` tokens while a cold prefix copies up.  ``interleaved``
      bounds the copy to ``promote_budget`` per-layer chunks between
      decode steps; ``stalled`` copies the whole row in one gap.  The
      decode-gap counters make the dip visible exactly as in the
      ``online_compile`` section.
    """
    import shutil
    import tempfile

    shots_warm = jnp.asarray(rng.integers(4, cfg.vocab_size,
                                          (1, C.SOURCE_LEN)), jnp.int32)
    shots_cold = rng.integers(4, cfg.vocab_size, C.SOURCE_LEN).astype(np.int32)
    kv_warm = materialize_prefix(
        target, cfg, memcom.compress(mc, cfg, shots_warm)[0])
    kv_b = materialize_prefix(target, cfg, memcom.compress(
        mc, cfg, jnp.asarray(rng.integers(4, cfg.vocab_size,
                                          (1, C.SOURCE_LEN)), jnp.int32))[0])
    prompt = rng.integers(4, cfg.vocab_size, 4).astype(np.int32)
    disk = tempfile.mkdtemp(prefix="prefix-tiering-")

    def fresh_engine(budget):
        eng = ServingEngine(cfg, target, slots=2,
                            max_len=m + 8 + warm_new + 8,
                            compressor=mc, compile_token_budget=16,
                            host_capacity=4, disk_dir=disk,
                            promote_layer_budget=budget)
        eng.add_prefix("task", kv_warm)
        # untimed warmup: compiles the prefill/decode programs and this
        # budget's chunk/finish programs (promotion itself jits nothing —
        # it is pure device_put traffic), so the timed serves measure the
        # tier machinery, not tracing
        warm_shots = rng.integers(4, cfg.vocab_size,
                                  C.SOURCE_LEN).astype(np.int32)
        eng.serve([Request(tokens=prompt, max_new=warm_new, prefix="task"),
                   Request(tokens=prompt, max_new=2, raw_shots=warm_shots)])
        # one untimed demote/promote cycle: first-transfer warmup (host→
        # device copies are lazily initialized) stays out of the ladder
        eng.store.demote("task")
        eng.serve([Request(tokens=prompt, max_new=1, prefix="task")])
        eng.reset_stats()
        return eng

    def ttft(eng, **req_kw):
        t0 = time.perf_counter()
        eng.serve([Request(tokens=prompt, max_new=1, **req_kw)])
        return time.perf_counter() - t0

    eng = fresh_engine(None)
    ttft_warm = ttft(eng, prefix="task")
    eng.store.demote("task")  # dense store: seated slots hold copies
    ttft_host = ttft(eng, prefix="task")
    eng.store.demote("task")
    eng.store.spill("task")
    ttft_disk = ttft(eng, prefix="task")
    ttft_recompile = ttft(eng, raw_shots=shots_cold)
    ts = eng.stats()["prefix_tiers"]

    out = {"promote_budget": promote_budget, "source_len": C.SOURCE_LEN,
           "ttft_warm_hbm_s": ttft_warm, "ttft_host_hit_s": ttft_host,
           "ttft_disk_hit_s": ttft_disk, "ttft_recompile_s": ttft_recompile,
           "tier_counters": ts}
    rows = [("ttft", "warm HBM", f"{ttft_warm*1e3:.1f}", "-", "-"),
            ("ttft", "host hit", f"{ttft_host*1e3:.1f}", "-", "-"),
            ("ttft", "disk hit", f"{ttft_disk*1e3:.1f}", "-", "-"),
            ("ttft", "recompile", f"{ttft_recompile*1e3:.1f}", "-", "-")]

    for mode, budget in (("interleaved", promote_budget), ("stalled", None)):
        eng = fresh_engine(budget)
        eng.add_prefix("cold", kv_b)
        eng.store.demote("cold")
        reqs = [Request(tokens=prompt, max_new=warm_new, prefix="task"),
                Request(tokens=prompt, max_new=2, prefix="cold")]
        t0 = time.perf_counter()
        eng.serve(reqs)
        dt = time.perf_counter() - t0
        es = eng.stats()["engine"]
        gaps = max(es["decode_gaps"], 1)
        out[mode] = {
            "serve_s": dt,
            "decode_steps": es["decode_steps"],
            "decode_steps_during_promote": es["decode_steps_during_promote"],
            "decode_gap_max_s": es["decode_gap_max_s"],
            "decode_gap_mean_s": es["decode_gap_sum_s"] / gaps,
            "promote_bytes": eng.stats()["prefix_tiers"]["promote_bytes"],
        }
        rows.append((mode, "warm+cold", f"{dt*1e3:.1f}",
                     f"{es['decode_gap_max_s']*1e3:.1f}",
                     es["decode_steps_during_promote"]))
    shutil.rmtree(disk, ignore_errors=True)

    print(C.fmt_table(rows, ("section", "request", "total ms (CPU)",
                             "max decode gap ms", "decode during promote"))
          + "\n")
    print(f"tier ladder TTFT (CPU ms): HBM {ttft_warm*1e3:.1f} -> host "
          f"{ttft_host*1e3:.1f} -> disk {ttft_disk*1e3:.1f} -> recompile "
          f"{ttft_recompile*1e3:.1f}; every tier hit is one online "
          "compile avoided\n")
    return out


def run_sharded_decode(smoke: bool, *, mesh_sizes=(1, 2, 4),
                       layouts=("dense", "paged")):
    """Per-step decode latency under tensor-parallel serving, dense and
    paged, at mesh sizes 1/2/4 — the structural check that the engine
    runs *unchanged* at every mesh size.

    Each cell is a fresh ``repro.launch.serve --mesh N`` subprocess: the
    host-platform device count locks at the first jax init, so every mesh
    size needs its own forced placeholder topology.  On one physical CPU
    the absolute ms/step therefore measures GSPMD partitioning overhead,
    not speedup (the "devices" share one core); on a real multi-device
    backend the same sweep measures the actual TP scaling, subprocess-free
    flag included.
    """
    requests, max_new = (3, 4) if smoke else (6, 12)
    out, rows = {}, []
    for layout in layouts:
        cells = out.setdefault(layout, [])
        for n in mesh_sizes:
            fd, path = tempfile.mkstemp(suffix=".json")
            os.close(fd)
            cmd = [sys.executable, "-m", "repro.launch.serve",
                   "--arch", "smollm-135m", "--smoke",
                   "--requests", str(requests), "--tasks", "2",
                   "--slots", "2", "--max-new", str(max_new),
                   "--kv-layout", layout, "--mesh", str(n),
                   "--stats", "--metrics", path]
            env = dict(
                os.environ,
                XLA_FLAGS=f"--xla_force_host_platform_device_count={n}")
            try:
                res = subprocess.run(cmd, capture_output=True, text=True,
                                     timeout=900, env=env)
                if res.returncode != 0:
                    raise RuntimeError(
                        f"sharded_decode cell (mesh={n}, {layout}) failed:\n"
                        + res.stderr[-2000:])
                with open(path) as f:
                    metrics = json.load(f)
            finally:
                os.unlink(path)
            es = metrics["stats"]["engine"]
            steps = max(es["decode_steps"], 1)
            cell = {
                "mesh_model": n,
                "decode_steps": es["decode_steps"],
                "decode_time_s": es["decode_time_s"],
                "ms_per_step": es["decode_time_s"] / steps * 1e3,
                "serve_s": metrics["serve_s"],
                "tokens_per_s": metrics["tokens_per_s"],
            }
            cells.append(cell)
            rows.append((layout, f"1x{n}", es["decode_steps"],
                         f"{cell['ms_per_step']:.2f}"))
    print(C.fmt_table(
        rows, ("kv layout", "mesh (data x model)", "decode steps",
               "ms/step (CPU)")) + "\n")
    print("sharded_decode: one subprocess per mesh size (device count "
          "locks at jax init); on a single physical CPU the forced "
          "devices share one core, so ms/step tracks partitioning "
          "overhead — the speedup column needs real devices\n")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="random-init target + shrunk sweep (CI speed)")
    ap.add_argument("--ratio", type=int, default=8, choices=sorted(C.RATIOS))
    ap.add_argument("--no-sharded", action="store_true",
                    help="skip the sharded_decode subprocess sweep (the "
                         "tier-1 CI job passes this; the sharded-smoke job "
                         "runs the full set)")
    args = ap.parse_args()
    run(ratio=args.ratio, smoke=args.smoke, sharded=not args.no_sharded)
