"""Paper Figure 3b / Table 4 analog: the compressor-capacity ladder.

ICAE → ICAE+ → ICAE++ → MemCom, all at the highest (8×) compression ratio
on the most demanding setting — reproducing claim C2 (compressor capacity
matters) and C3 (layer-wise compression beats final-layer compression at
equal inference cost).
"""

from __future__ import annotations

import argparse
import dataclasses

from benchmarks import common as C


def run(steps: int = 300, ratio: int = 8, eval_episodes: int = 12):
    cfg0, target = C.get_or_pretrain_target()
    m = C.RATIOS[ratio]
    cfg = cfg0.replace(
        memcom=dataclasses.replace(cfg0.memcom, num_memory_tokens=m))

    rows = []
    base = C.evaluate(
        C.make_full_context_predictor(cfg, target, m),
        budget=m, query_budget=C.SOURCE_LEN, n_episodes=eval_episodes)
    rows.append((f"baseline-{m}", base))
    full = C.evaluate(
        C.make_full_context_predictor(cfg, target, C.SOURCE_LEN),
        budget=C.SOURCE_LEN, n_episodes=eval_episodes)
    rows.append((f"baseline-{C.SOURCE_LEN}", full))

    for variant in ("icae", "icae+", "icae++"):
        comp, _ = C.train_compressor("icae", target, cfg, steps=steps,
                                     variant=variant)
        acc = C.evaluate(
            C.make_icae_predictor(cfg, target, comp, C.SOURCE_LEN),
            budget=C.SOURCE_LEN, n_episodes=eval_episodes)
        rows.append((variant, acc))
        C.log(f"{variant}: {acc}")

    mc, _ = C.train_compressor("memcom", target, cfg, steps=steps, phase=1)
    acc = C.evaluate(
        C.make_memcom_predictor(cfg, target, mc, C.SOURCE_LEN),
        budget=C.SOURCE_LEN, n_episodes=eval_episodes)
    rows.append(("memcom", acc))
    C.log(f"memcom: {acc}")

    table = [(name, round(acc["mean"], 3), *(round(acc[t], 3) for t in C.TASKS))
             for name, acc in rows]
    print("\n" + C.fmt_table(table, ("method", "mean", *C.TASKS)) + "\n")
    C.write_result("icae_ladder", {
        "ratio": ratio, "m": m, "steps": steps,
        "rows": [dict(method=n, acc=a) for n, a in rows]})
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()
    run(steps=args.steps)
