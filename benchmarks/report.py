"""Render EXPERIMENTS.md §Reproduction tables from artifacts/bench JSONs.

    PYTHONPATH=src python -m benchmarks.report
"""

from __future__ import annotations

import json
import os

from benchmarks.common import ROOT, TASKS


def _load(name):
    path = os.path.join(ROOT, f"{name}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def _acc_cols(acc):
    return (f"{acc['mean']:.3f}",
            *(f"{acc[t]:.3f}" for t in TASKS))


def render() -> str:
    lines = []

    t = _load("compression_tradeoff")
    if t:
        lines += ["### Accuracy vs compression ratio (paper Tables 2/3, Fig. 2)",
                  "",
                  "| method | m | ratio | mean | " + " | ".join(TASKS) + " |",
                  "|---|---|---|---|" + "---|" * len(TASKS)]
        for r in t["rows"]:
            lines.append(f"| {r['method']} | {r['m']} | {r['ratio']} | "
                         + " | ".join(_acc_cols(r["acc"])) + " |")
        lines.append("")

    l = _load("icae_ladder")
    if l:
        lines += [f"### Compressor-capacity ladder @ {l['ratio']}× "
                  "(paper Fig. 3b, Table 4)", "",
                  "| method | mean | " + " | ".join(TASKS) + " |",
                  "|---|---|" + "---|" * len(TASKS)]
        for r in l["rows"]:
            lines.append(f"| {r['method']} | "
                         + " | ".join(_acc_cols(r["acc"])) + " |")
        lines.append("")

    x = _load("xattn_ablation")
    if x:
        lines += [f"### Cross-attention design @ {x['ratio']}× (paper Table 6)",
                  "",
                  "| xattn | mean | " + " | ".join(TASKS) + " |",
                  "|---|---|" + "---|" * len(TASKS)]
        for r in x["rows"]:
            lines.append(f"| {r['kind']} | "
                         + " | ".join(_acc_cols(r["acc"])) + " |")
        lines.append("")

    s = _load("serving_bench")
    if s:
        ratio = s["cache_bytes_full"] / s["cache_bytes_compressed"]
        lines += ["### Compressed-cache serving (the deployment win)", "",
                  f"* KV slots per layer: {s['t']} → {s['m']} "
                  f"({s['t']/s['m']:.1f}× fewer attended slots)",
                  f"* cache bytes: {s['cache_bytes_full']/1e6:.2f} MB → "
                  f"{s['cache_bytes_compressed']/1e6:.2f} MB "
                  f"({ratio:.1f}× — structural, transfers to TPU)",
                  f"* CPU ms/token (informational): {s['ms_full']:.2f} → "
                  f"{s['ms_compressed']:.2f}", ""]
        oc = s.get("online_compile")
        if oc:
            lines += [
                "* online compile (cold task on the serving path): "
                f"TTFT {oc['ttft_warm_s']*1e3:.1f} ms warm → "
                f"{oc['ttft_cold_s']*1e3:.1f} ms cold; max decode gap "
                f"{oc['interleaved']['decode_gap_max_s']*1e3:.1f} ms "
                f"interleaved vs "
                f"{oc['stalled']['decode_gap_max_s']*1e3:.1f} ms stalled",
                ""]

    d = _load("deep_tradeoff")
    if d:
        lines += [f"### Deep-trained headline @ {d['ratio']}× "
                  f"({d['steps']} steps, trajectory probes)", "",
                  "| method | mean | " + " | ".join(TASKS) + " |",
                  "|---|---|" + "---|" * len(TASKS)]
        for r in d["rows"]:
            lines.append(f"| {r['method']} | "
                         + " | ".join(_acc_cols(r["acc"])) + " |")
        for kind, traj in d.get("trajectories", {}).items():
            pts = ", ".join(f"{p['steps']}: {p['acc']['mean']:.3f}"
                            for p in traj)
            lines.append(f"* {kind} accuracy trajectory — {pts}")
        lines.append("")

    k = _load("kernel_bench")
    if k:
        lines += ["### Kernel microbench (CPU jnp paths; TPU is the target)",
                  "",
                  "| kernel | shape | ms | GFLOP | arith-intensity |",
                  "|---|---|---|---|---|"]
        for r in k["rows"]:
            lines.append(f"| {r['kernel']} | {r['shape']} | {r['ms']} | "
                         f"{r['gflop']} | {r['intensity']} |")
        lines.append("")

    return "\n".join(lines)


if __name__ == "__main__":
    print(render())
