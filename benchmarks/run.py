"""Benchmark harness entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One benchmark per paper table/figure (deliverable d):

    compression_tradeoff  — Table 2/3 + Fig. 2 (accuracy vs ratio)
    icae_ladder           — Fig. 3b + Table 4 (compressor-capacity ladder)
    xattn_ablation        — Table 6 (1-head vs MHA vs MQA)
    serving_bench         — the deployment win (compressed vs full cache)
    kernel_bench          — kernel-level FLOPs/bytes/intensity

``--quick`` trains fewer steps / evaluates fewer episodes (CI-sized);
default settings reproduce EXPERIMENTS.md §Reproduction.
"""

from __future__ import annotations

import argparse
import time  # reprolint: ignore-file[wall-clock] -- benchmark driver stamps real run timestamps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    choices=["tradeoff", "ladder", "xattn", "serving",
                             "kernels"])
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()

    # default 150 = the recorded configuration (EXPERIMENTS.md
    # §Reproduction); trained compressors are cached under
    # artifacts/bench so re-runs only re-evaluate
    steps = args.steps or (120 if args.quick else 150)
    episodes = 6 if args.quick else 12
    t0 = time.time()

    from benchmarks import (
        compression_tradeoff, icae_ladder, kernel_bench, serving_bench,
        xattn_ablation,
    )

    if args.only in (None, "kernels"):
        print("=" * 72 + "\n== kernel_bench\n" + "=" * 72)
        kernel_bench.run()
    if args.only in (None, "tradeoff"):
        print("=" * 72 + "\n== compression_tradeoff (paper Table 2/3, Fig 2)\n" + "=" * 72)
        compression_tradeoff.run(
            steps=steps, ratios=(3, 6, 8) if not args.quick else (3, 8),
            with_p2=not args.quick, eval_episodes=episodes)
    if args.only in (None, "ladder"):
        print("=" * 72 + "\n== icae_ladder (paper Fig 3b, Table 4)\n" + "=" * 72)
        icae_ladder.run(steps=steps, eval_episodes=episodes)
    if args.only in (None, "xattn"):
        print("=" * 72 + "\n== xattn_ablation (paper Table 6)\n" + "=" * 72)
        xattn_ablation.run(steps=steps, eval_episodes=episodes)
    if args.only in (None, "serving"):
        print("=" * 72 + "\n== serving_bench (compressed-cache serving)\n" + "=" * 72)
        serving_bench.run()

    print(f"\nall benchmarks done in {time.time() - t0:.0f}s; "
          f"artifacts under artifacts/bench/")


if __name__ == "__main__":
    main()
