"""Tensor-parallel serving: sharded-vs-single-device parity.

All multi-device tests run in a subprocess with a forced host-platform
device topology (the device count locks at the first jax import — see
tests/test_pipeline.py for the same pattern).  One subprocess covers the
whole matrix: the reference single-device engine and the 2-/4-way model
meshes all live on the same forced 4-device host, so the comparison is
apples-to-apples down to the compiled partitioning.

Covered:

* ``ops.decode_attention`` / ``ops.paged_decode_attention`` parity
  (<= 1e-4) for the jnp path under GSPMD and the pallas path under
  ``shard_map`` (interpret mode), heads split 2- and 4-way;
* dense and paged ``ServingEngine`` greedy serving: token-identical to
  the single-device engine on 2- and 4-way model meshes, offline
  prefixes seated per slot;
* online-compiled prefixes (raw_shots through the ``PrefixCompiler``):
  token-identical sharded vs single-device, dense and paged.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.core import memcom
    from repro.kernels import ops
    from repro.launch.mesh import make_serving_mesh
    from repro.models import transformer as tfm
    from repro.serving import Request
    from repro.serving.engine import ServingEngine, materialize_prefix

    report = {}
    rng = np.random.default_rng(0)

    # ---- ops parity: jnp (GSPMD) and pallas (shard_map) decode paths ----
    B, S, Hq, Hkv, D, L = 3, 1, 8, 4, 16, 32
    q = jnp.asarray(rng.standard_normal((B, S, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, L, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, L, Hkv, D)), jnp.float32)
    lengths = jnp.asarray([9, 17, 32], jnp.int32)
    ref = ops.decode_attention(q, k, v, lengths=lengths, impl="dense")
    bs, nb = 4, 8
    pk = jnp.asarray(rng.standard_normal((1 + B * nb, bs, Hkv, D)), jnp.float32)
    pv = jnp.asarray(rng.standard_normal((1 + B * nb, bs, Hkv, D)), jnp.float32)
    tables = jnp.asarray(1 + np.arange(B * nb).reshape(B, nb), jnp.int32)
    pref = ops.paged_decode_attention(q, pk, pv, block_tables=tables,
                                      lengths=lengths, impl="dense")
    for model in (2, 4):
        mesh = make_serving_mesh(model=model)
        out = ops.decode_attention(q, k, v, lengths=lengths,
                                   impl="pallas", mesh=mesh)
        report[f"dense_pallas_{model}"] = float(jnp.abs(out - ref).max())
        out = jax.jit(lambda q, k, v, l: ops.decode_attention(
            q, k, v, lengths=l, impl="jnp", mesh=mesh))(q, k, v, lengths)
        report[f"dense_jnp_{model}"] = float(jnp.abs(out - ref).max())
        out = ops.paged_decode_attention(q, pk, pv, block_tables=tables,
                                         lengths=lengths, impl="pallas",
                                         mesh=mesh)
        report[f"paged_pallas_{model}"] = float(jnp.abs(out - pref).max())
        out = jax.jit(lambda q, k, v, t, l: ops.paged_decode_attention(
            q, k, v, block_tables=t, lengths=l, impl="jnp", mesh=mesh))(
            q, pk, pv, tables, lengths)
        report[f"paged_jnp_{model}"] = float(jnp.abs(out - pref).max())

    # ---- engine parity: offline prefixes, dense + paged ----
    cfg = get_smoke_config("smollm-135m").replace(
        d_model=128, num_heads=8, num_kv_heads=4, d_ff=256)
    params = tfm.init_params(cfg, 0)
    mc = memcom.init_memcom(cfg, params, 1)
    shots = jnp.asarray(rng.integers(4, cfg.vocab_size, (1, 40)), jnp.int32)
    kv = materialize_prefix(params, cfg, memcom.compress(mc, cfg, shots)[0])
    prompts = [rng.integers(4, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 9)]

    def serve_offline(eng):
        reqs = [Request(tokens=p, max_new=4, prefix="task") for p in prompts]
        out = eng.serve(reqs)
        return [out[r.uid].tolist() for r in reqs]  # request order, not uid

    for layout, kw in (("dense", {}),
                       ("paged", dict(kv_layout="paged", block_size=4))):
        eng = ServingEngine(cfg, params, slots=2, max_len=64, **kw)
        eng.add_prefix("task", kv)
        want = serve_offline(eng)
        for model in (2, 4):
            mesh = make_serving_mesh(model=model)
            eng = ServingEngine(cfg, params, slots=2, max_len=64,
                                mesh=mesh, **kw)
            eng.add_prefix("task", kv)
            report[f"engine_{layout}_{model}"] = (serve_offline(eng) == want)

    # ---- engine parity: online-compiled prefixes (raw_shots) ----
    raw = rng.integers(4, cfg.vocab_size, 40).astype(np.int32)
    online = [Request(tokens=p, max_new=3, raw_shots=raw) for p in prompts]

    def serve_online(eng):
        out = eng.serve(online)
        return [out[r.uid].tolist() for r in online]

    for layout, kw in (("dense", {}),
                       ("paged", dict(kv_layout="paged", block_size=4))):
        want = serve_online(ServingEngine(
            cfg, params, slots=2, max_len=96, compressor=mc,
            compile_token_budget=16, **kw))
        mesh = make_serving_mesh(model=2)
        got = serve_online(ServingEngine(
            cfg, params, slots=2, max_len=96, compressor=mc,
            compile_token_budget=16, mesh=mesh, **kw))
        report[f"online_{layout}_2"] = (got == want)

    print(json.dumps(report))
""")


@pytest.mark.slow
def test_sharded_serving_parity(tmp_path):
    """2-/4-way model-mesh serving == single device: kernel-level parity
    <= 1e-4, engine-level greedy tokens identical (offline and online-
    compiled prefixes, dense and paged layouts)."""
    script = tmp_path / "sharded_parity.py"
    script.write_text(SCRIPT)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    res = subprocess.run([sys.executable, str(script)], capture_output=True,
                         text=True, timeout=1800, env=env, cwd=ROOT)
    assert res.returncode == 0, res.stderr[-3000:]
    report = json.loads(res.stdout.strip().splitlines()[-1])
    for key, val in report.items():
        if isinstance(val, bool):
            assert val, f"{key}: sharded tokens differ from single-device"
        else:
            assert val <= 1e-4, f"{key}: parity error {val}"


def test_make_serving_mesh_single_device():
    """A 1x1 serving mesh works on the plain single-CPU test process (the
    mesh-aware engine path must not require forced topologies)."""
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.launch.mesh import make_serving_mesh
    from repro.models import transformer as tfm
    from repro.serving import Request
    from repro.serving.engine import ServingEngine

    mesh = make_serving_mesh(model=1)
    assert dict((n, int(mesh.shape[n])) for n in mesh.axis_names) == \
        {"data": 1, "model": 1}
    cfg = get_smoke_config("smollm-135m")
    params = tfm.init_params(cfg, 0)
    rng = np.random.default_rng(0)
    prompt = rng.integers(4, cfg.vocab_size, 5).astype(np.int32)
    ref = ServingEngine(cfg, params, slots=1, max_len=16).serve(
        [Request(tokens=prompt, max_new=3)])
    eng = ServingEngine(cfg, params, slots=1, max_len=16, mesh=mesh)
    out = eng.serve([Request(tokens=prompt, max_new=3)])
    assert [v.tolist() for v in out.values()] == \
        [v.tolist() for v in ref.values()]
    assert eng.stats()["mesh"] == {"data": 1, "model": 1}


def test_make_serving_mesh_too_many_devices():
    from repro.launch.mesh import make_serving_mesh

    with pytest.raises(ValueError, match="xla_force_host_platform"):
        make_serving_mesh(model=4096)


def test_rules_without_mesh_rejected():
    from repro.configs import get_smoke_config
    from repro.models import transformer as tfm
    from repro.serving.engine import ServingEngine
    from repro.sharding.rules import BASELINE_RULES

    cfg = get_smoke_config("smollm-135m")
    params = tfm.init_params(cfg, 0)
    with pytest.raises(ValueError, match="rules given without a mesh"):
        ServingEngine(cfg, params, slots=1, max_len=16,
                      rules=BASELINE_RULES)
