"""Per-phase profiler tests: interval union/subtraction against hand
arithmetic on synthetic Chrome traces, the fused-join exclusion, instant
counting, schema validation failure modes, and a round-trip through a
real :class:`Tracer` export."""

import json

import pytest

from repro.serving import Tracer, profile_spans, validate_profile_report
from repro.serving.profiler import (
    PROFILE_REPORT_SCHEMA,
    _merge,
    _measure,
    _subtract,
)


def _ev(name, t0_s, dur_s, **args):
    return {"ph": "X", "name": name, "pid": 1, "tid": 1,
            "ts": t0_s * 1e6, "dur": dur_s * 1e6, "args": args}


def _inst(name, t_s):
    return {"ph": "i", "name": name, "pid": 1, "tid": 1,
            "ts": t_s * 1e6, "args": {}}


def _trace(*events):
    return {"traceEvents": list(events)}


# ---------------------------------------------------------------------------
# interval arithmetic
# ---------------------------------------------------------------------------


def test_merge_and_measure_hand_computed():
    merged = _merge([(3.0, 8.0), (0.0, 5.0), (10.0, 11.0)])
    assert merged == [(0.0, 8.0), (10.0, 11.0)]
    assert _measure(merged) == pytest.approx(9.0)
    assert _merge([]) == [] and _measure([]) == 0.0


def test_subtract_hand_computed():
    base = [(0.0, 10.0)]
    # cut the middle, clip an edge, ignore a disjoint cut
    cuts = [(2.0, 4.0), (9.0, 12.0), (20.0, 21.0)]
    assert _subtract(base, cuts) == [(0.0, 2.0), (4.0, 9.0)]
    assert _subtract(base, [(0.0, 10.0)]) == []  # full cover
    assert _subtract(base, []) == base


# ---------------------------------------------------------------------------
# profile_spans
# ---------------------------------------------------------------------------


def test_profile_hand_computed_self_times():
    """decode [0, 10ms] with a compile chunk [2, 4ms] riding inside it:
    decode self-time is 8 ms; compile/prefill/promote keep self==total."""
    report = profile_spans(_trace(
        _ev("decode_step", 0.0, 0.010),
        _ev("compile_chunk", 0.002, 0.002),
        _ev("admission", 0.020, 0.002),
        _ev("promote_chunk", 0.030, 0.001),
    ))
    ph = report["phases"]
    assert ph["decode"] == {"spans": 1,
                            "total_s": pytest.approx(0.010),
                            "self_s": pytest.approx(0.008)}
    assert ph["compile"]["total_s"] == pytest.approx(0.002)
    assert ph["compile"]["self_s"] == pytest.approx(0.002)
    assert ph["prefill"]["spans"] == 1
    assert ph["promote"]["spans"] == 1
    # wall = union of everything: 10 + 2 + 1 ms
    assert report["wall_s"] == pytest.approx(0.013)
    assert validate_profile_report(report) == []


def test_overlapping_decode_spans_union_not_sum():
    report = profile_spans(_trace(
        _ev("decode_step", 0.0, 0.005),
        _ev("fused_step", 0.003, 0.005),   # overlaps the first 2 ms
    ))
    assert report["phases"]["decode"]["spans"] == 2
    assert report["phases"]["decode"]["total_s"] == pytest.approx(0.008)


def test_fused_join_admission_excluded_from_prefill():
    report = profile_spans(_trace(
        _ev("admission", 0.0, 0.002),
        _ev("admission", 0.010, 0.030, fused_join=True),
    ))
    # the join's span covers whole fused-step windows — counting it as
    # prefill would double-book decode time
    assert report["phases"]["prefill"]["spans"] == 1
    assert report["phases"]["prefill"]["total_s"] == pytest.approx(0.002)
    assert report["counts"]["fused_joins"] == 1


def test_instants_counted_not_measured():
    report = profile_spans(_trace(
        _ev("decode_step", 0.0, 0.001),
        _inst("spec_accept", 0.0005),
        _inst("spec_accept", 0.0008),
        _inst("preempt", 0.0002),
        _inst("resume", 0.0004),
        _inst("autotune", 0.0009),
        _inst("finish", 0.001),            # not a counted instant
    ))
    assert report["counts"] == {"spec_accepts": 2, "preempts": 1,
                                "resumes": 1, "autotunes": 1,
                                "fused_joins": 0}
    assert report["wall_s"] == pytest.approx(0.001)


def test_unknown_spans_and_metadata_ignored():
    report = profile_spans(_trace(
        {"ph": "M", "name": "thread_name", "pid": 1, "tid": 1,
         "args": {"name": "engine"}},
        _ev("mystery_span", 0.0, 1.0),
        _ev("decode_step", 0.0, 0.001),
    ))
    assert report["wall_s"] == pytest.approx(0.001)


def test_empty_trace_profiles_to_zero():
    report = profile_spans(_trace())
    assert report["wall_s"] == 0.0
    assert all(st["spans"] == 0 and st["total_s"] == 0.0
               for st in report["phases"].values())
    assert validate_profile_report(report) == []


# ---------------------------------------------------------------------------
# validation + round-trip
# ---------------------------------------------------------------------------


def test_validate_profile_report_catches_malformed():
    good = profile_spans(_trace(_ev("decode_step", 0.0, 0.001)))
    bad = json.loads(json.dumps(good))
    bad["schema"] = "wrong/v9"
    assert any("schema" in e for e in validate_profile_report(bad))
    bad = json.loads(json.dumps(good))
    del bad["phases"]["compile"]
    assert any("missing" in e for e in validate_profile_report(bad))
    bad = json.loads(json.dumps(good))
    bad["phases"]["decode"]["self_s"] = 99.0  # self > total
    assert any("exceeds" in e for e in validate_profile_report(bad))
    bad = json.loads(json.dumps(good))
    bad["phases"]["decode"]["total_s"] = -1.0
    assert any("bad 'total_s'" in e for e in validate_profile_report(bad))
    bad = json.loads(json.dumps(good))
    bad["wall_s"] = 0.0  # smaller than the decode phase total
    assert any("wall_s" in e for e in validate_profile_report(bad))
    bad = json.loads(json.dumps(good))
    bad["counts"]["preempts"] = 1.5
    assert any("counts" in e for e in validate_profile_report(bad))


def test_round_trip_through_real_tracer():
    clock = iter(float(i) for i in range(100))
    tr = Tracer(clock=lambda: next(clock))
    tr.span("engine", "decode_step", 0.0, 0.5)
    tr.span("compiler", "compile_chunk", 0.1, 0.2)
    tr.instant("slot0", "preempt")
    report = profile_spans(tr.chrome_trace())
    assert report["schema"] == PROFILE_REPORT_SCHEMA
    assert validate_profile_report(report) == []
    assert report["phases"]["decode"]["self_s"] == pytest.approx(0.4)
    assert report["counts"]["preempts"] == 1
    # determinism: same trace, same bytes
    again = profile_spans(tr.chrome_trace())
    assert json.dumps(report, sort_keys=True) == \
        json.dumps(again, sort_keys=True)
