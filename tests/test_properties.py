"""Property-based tests (hypothesis) on the system's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dep: pip install hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import jnp_impl, ref, ops
from repro.optim import AdamW, ErrorFeedbackInt8, clip_by_global_norm


SHORT = settings(max_examples=20, deadline=None)


@SHORT
@given(
    sq=st.integers(1, 40), skv=st.integers(1, 48),
    hq_groups=st.integers(1, 3), hkv=st.integers(1, 3),
    d=st.sampled_from([8, 16, 32]), causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_streaming_equals_dense(sq, skv, hq_groups, hkv, d,
                                          causal, seed):
    """Online-softmax streaming == dense softmax for arbitrary shapes."""
    rng = np.random.default_rng(seed)
    B, Hq = 1, hq_groups * hkv
    q = jnp.asarray(rng.standard_normal((B, sq, Hq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, skv, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, skv, hkv, d)), jnp.float32)
    q_pos = jnp.broadcast_to(jnp.arange(sq), (B, sq)).astype(jnp.int32)
    kv_pos = jnp.broadcast_to(jnp.arange(skv), (B, skv)).astype(jnp.int32)
    dense = ref.attention_ref(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                              causal=causal)
    stream = jnp_impl.attention_chunked(
        q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=causal, kv_chunk=7)
    np.testing.assert_allclose(np.asarray(stream), np.asarray(dense),
                               atol=3e-5, rtol=3e-5)


@SHORT
@given(
    m=st.integers(1, 24), t=st.integers(1, 40),
    d=st.sampled_from([8, 32]), seed=st.integers(0, 2**31 - 1),
)
def test_xattn_rows_are_convex_combinations(m, t, d, seed):
    """Cross-attn output rows lie in the convex hull of V rows: the row
    max/min of O is bounded by the column max/min of V (softmax weights
    are a convex combination)."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((1, m, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, t, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, t, d)), jnp.float32)
    o = ref.memcom_xattn_ref(q, k, v)
    hi = v.max(axis=1, keepdims=True) + 1e-5
    lo = v.min(axis=1, keepdims=True) - 1e-5
    assert bool(jnp.all(o <= hi)) and bool(jnp.all(o >= lo))


@SHORT
@given(
    s=st.integers(2, 48), h=st.integers(1, 3),
    p=st.sampled_from([4, 8]), n=st.sampled_from([4, 8]),
    split=st.floats(0.2, 0.8), seed=st.integers(0, 2**31 - 1),
)
def test_ssd_state_handoff_is_exact(s, h, p, n, split, seed):
    """Running SSD over [a;b] == running over a, handing the state to b —
    the invariant behind the hybrid (Jamba) MemCom adaptation."""
    rng = np.random.default_rng(seed)
    cut = max(1, min(s - 1, int(s * split)))
    x = jnp.asarray(rng.standard_normal((1, s, h, p)) * 0.5, jnp.float32)
    dt = jnp.asarray(np.abs(rng.standard_normal((1, s, h))) * 0.2, jnp.float32)
    A = -jnp.abs(jnp.asarray(rng.standard_normal(h), jnp.float32))
    Bm = jnp.asarray(rng.standard_normal((1, s, 1, n)) * 0.5, jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((1, s, 1, n)) * 0.5, jnp.float32)
    y_full, hf_full = ref.ssd_ref(x, dt, A, Bm, Cm)
    _, h_mid = ref.ssd_ref(x[:, :cut], dt[:, :cut], A, Bm[:, :cut], Cm[:, :cut])
    y_b, hf_b = ref.ssd_ref(x[:, cut:], dt[:, cut:], A, Bm[:, cut:],
                            Cm[:, cut:], init_state=h_mid)
    np.testing.assert_allclose(np.asarray(y_b), np.asarray(y_full[:, cut:]),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hf_b), np.asarray(hf_full),
                               atol=1e-4, rtol=1e-4)


@SHORT
@given(
    parts=st.integers(2, 4), skv=st.integers(8, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_lse_combine_partition_invariance(parts, skv, seed):
    """Attention over any partition of the KV set, LSE-merged, equals
    attention over the whole set (flash-decoding invariant)."""
    rng = np.random.default_rng(seed)
    B, S, H, D = 1, 4, 2, 8
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, skv, H, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, skv, H, D)), jnp.float32)
    kv_pos = jnp.arange(skv)[None].astype(jnp.int32)
    q_pos = jnp.full((B, S), skv, jnp.int32)
    whole = ref.attention_ref(q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=True)
    cuts = sorted(set([0, skv] + list(
        np.random.default_rng(seed + 1).integers(1, skv, parts - 1))))
    partials = []
    for lo, hi in zip(cuts[:-1], cuts[1:]):
        o, l = jnp_impl.attention_chunked(
            q, k[:, lo:hi], v[:, lo:hi], q_pos=q_pos,
            kv_pos=kv_pos[:, lo:hi], causal=True,
            kv_chunk=max(hi - lo, 1), return_lse=True)
        partials.append((o, l))
    merged = jnp_impl.combine_attention_partials(partials)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(whole),
                               atol=3e-5, rtol=3e-5)


@SHORT
@given(seed=st.integers(0, 2**31 - 1), clip=st.floats(0.1, 10.0))
def test_clip_by_global_norm_bound(seed, clip):
    rng = np.random.default_rng(seed)
    tree = {"a": jnp.asarray(rng.standard_normal((5, 3)) * 10, jnp.float32),
            "b": jnp.asarray(rng.standard_normal(7) * 10, jnp.float32)}
    clipped, gnorm = clip_by_global_norm(tree, clip)
    total = float(jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree.leaves(clipped))))
    assert total <= clip * 1.001
    if float(gnorm) <= clip:  # under the threshold: identity
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(clipped)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


@SHORT
@given(seed=st.integers(0, 2**31 - 1))
def test_error_feedback_compression_unbiased_over_steps(seed):
    """int8 + error feedback: the accumulated applied updates converge to
    the accumulated true gradients (residual stays bounded)."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal((64,)), jnp.float32)
    comp = ErrorFeedbackInt8()
    err = comp.init({"g": g})
    applied = jnp.zeros_like(g)
    for _ in range(30):
        compressed, err = comp.compress({"g": g}, err)
        applied = applied + comp.decompress(compressed)["g"]
    np.testing.assert_allclose(np.asarray(applied / 30), np.asarray(g),
                               atol=0.05)


@SHORT
@given(seed=st.integers(0, 2**31 - 1), steps=st.integers(1, 5))
def test_adamw_matches_numpy_reference(seed, steps):
    rng = np.random.default_rng(seed)
    p0 = rng.standard_normal((6,)).astype(np.float32)
    gs = [rng.standard_normal((6,)).astype(np.float32) for _ in range(steps)]
    opt = AdamW(lr=1e-2, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01)
    params = {"w": jnp.asarray(p0)}
    state = opt.init(params)
    for g in gs:
        params, state = opt.step(params, {"w": jnp.asarray(g)}, state)
    # numpy reference
    m = np.zeros_like(p0); v = np.zeros_like(p0); p = p0.copy()
    for t, g in enumerate(gs, 1):
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g * g
        mh = m / (1 - 0.9**t)
        vh = v / (1 - 0.999**t)
        p = p - 1e-2 * (mh / (np.sqrt(vh) + 1e-8) + 0.01 * p)
    np.testing.assert_allclose(np.asarray(params["w"]), p, atol=1e-5,
                               rtol=1e-5)
