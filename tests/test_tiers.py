"""Tiered prefix cache tests: bit-exact HBM→host→disk→HBM round trips
(dense + paged), token-identical serving from every tier (jnp +
pallas-interpret), park/wake FIFO on cold-prefix misses, decode/promote
interleaving, the seated-eviction guard, disk-shard restart recovery,
and codec round trips for the shared compress/decompress helpers."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import compress_bytes, decompress_bytes
from repro.configs import get_smoke_config
from repro.core import memcom
from repro.models import transformer as tfm
from repro.serving import (
    PrefixSeatedError,
    Request,
    ServingEngine,
    materialize_prefix,
)
from repro.serving.prefix_store import take_prefix_row
from repro.utils.pytree import tree_flatten_with_names


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("smollm-135m")
    params = tfm.init_params(cfg, 0)
    mc = memcom.init_memcom(cfg, params, 1)
    return cfg, params, mc


def _compress_kv(cfg, params, mc, shots):
    prefix, _ = memcom.compress(mc, cfg, jnp.asarray(shots[None]))
    return materialize_prefix(params, cfg, prefix)


def _assert_rows_bit_exact(a, b):
    fa, fb = tree_flatten_with_names(a), tree_flatten_with_names(b)
    assert [n for n, _ in fa] == [n for n, _ in fb]
    for (name, la), (_, lb) in zip(fa, fb):
        la, lb = np.asarray(la), np.asarray(lb)
        assert la.dtype == lb.dtype and la.shape == lb.shape, name
        np.testing.assert_array_equal(la, lb, err_msg=name)


# ---------------------------------------------------------------------------
# Codec round trips (the shared checkpoint/disk-tier helpers)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("codec", ["zstd", "zlib", "raw"])
def test_codec_round_trip(codec):
    if codec == "zstd":
        pytest.importorskip("zstandard")
    payload = np.random.default_rng(0).bytes(4096) + b"\x00" * 4096
    tag, blob = compress_bytes(payload, codec)
    assert tag == codec
    assert decompress_bytes(blob, tag) == payload
    if codec != "raw":
        assert len(blob) < len(payload)  # the zero run must compress


def test_codec_default_and_unknown():
    tag, blob = compress_bytes(b"x" * 100)  # default codec
    assert tag in ("zstd", "zlib")
    assert decompress_bytes(blob, tag) == b"x" * 100
    with pytest.raises(ValueError, match="unknown checkpoint codec"):
        compress_bytes(b"", "lz4")
    with pytest.raises(ValueError, match="unknown checkpoint codec"):
        decompress_bytes(b"", "lz4")


# ---------------------------------------------------------------------------
# Bit-exact tier round trips
# ---------------------------------------------------------------------------


def test_dense_round_trip_bit_exact(setup, rng, tmp_path):
    """HBM→host→disk→HBM leaves a dense prefix row byte-identical."""
    cfg, params, mc = setup
    m = cfg.memcom.num_memory_tokens
    kv = _compress_kv(cfg, params, mc,
                      rng.integers(4, cfg.vocab_size, 40).astype(np.int32))
    ref = jax.tree.map(np.asarray, take_prefix_row(kv, 0))

    eng = ServingEngine(cfg, params, slots=1, max_len=m + 24,
                        host_capacity=4, disk_dir=str(tmp_path))
    eng.add_prefix("t", kv)
    eng.store.demote("t")
    assert eng.store.tier_of("t") == "host"
    _assert_rows_bit_exact(ref, eng.store._host["t"])
    eng.store.spill("t")
    assert eng.store.tier_of("t") == "disk"
    assert "t" not in eng.store  # HBM residency only

    eng.store.submit_promotion("t")
    eng.store.promote_step(None)
    promoted = eng.store.promoted_row("t")
    _assert_rows_bit_exact(ref, promoted)
    eng.store.put_row("t", promoted)
    eng.store.mark_promoted("t")
    _assert_rows_bit_exact(ref, eng.store.get("t"))
    ts = eng.stats()["prefix_tiers"]
    assert ts["demotes"] == 1 and ts["spills"] == 1 and ts["disk_loads"] == 1


def test_paged_round_trip_bit_exact(setup, rng, tmp_path):
    """The paged gather (pool blocks → host row) and re-scatter land on
    the dense reference row bit for bit, through the disk tier."""
    cfg, params, mc = setup
    m = cfg.memcom.num_memory_tokens
    kv = _compress_kv(cfg, params, mc,
                      rng.integers(4, cfg.vocab_size, 40).astype(np.int32))
    ref = jax.tree.map(np.asarray, take_prefix_row(kv, 0))

    eng = ServingEngine(cfg, params, slots=1, max_len=m + 24,
                        kv_layout="paged", host_capacity=4,
                        disk_dir=str(tmp_path))
    eng.add_prefix("t", kv)
    eng.store.demote("t")  # pool-block gather → host row
    _assert_rows_bit_exact(ref, eng.store._host["t"])
    eng.store.spill("t")
    assert eng.store.tier_of("t") == "disk"

    eng.store.submit_promotion("t")
    eng.store.promote_step(None)
    _assert_rows_bit_exact(ref, eng.store.promoted_row("t"))
    eng.cache = eng.store.put_row("t", eng.store.promoted_row("t"), eng.cache)
    eng.store.mark_promoted("t")
    # gather it back out of the (new) pool blocks: still bit-exact
    eng.store.demote("t")
    _assert_rows_bit_exact(ref, eng.store._host["t"])


@pytest.mark.parametrize("arch", ["deepseek-v2-236b", "jamba-1.5-large-398b"])
@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_family_round_trip_bit_exact(arch, layout, rng, tmp_path):
    """MLA latents (ckv/kr, prefix+period sections) and hybrid SSM state
    survive the full demote→spill→promote cycle bit-exactly and serve
    token-identically — the per-family leaf keys all take the same path
    the GQA k/v leaves do."""
    cfg = get_smoke_config(arch)
    params = tfm.init_params(cfg, 0)
    mc = memcom.init_memcom(cfg, params, 1)
    m = cfg.memcom.num_memory_tokens
    shots = rng.integers(4, cfg.vocab_size, 40).astype(np.int32)
    prompt = rng.integers(4, cfg.vocab_size, 5).astype(np.int32)
    kv = _compress_kv(cfg, params, mc, shots)
    ref = jax.tree.map(np.asarray, take_prefix_row(kv, 0))

    eng = ServingEngine(cfg, params, slots=2, max_len=m + 24,
                        kv_layout=layout, host_capacity=4,
                        disk_dir=str(tmp_path), promote_layer_budget=1)
    eng.add_prefix("t", kv)
    want = next(iter(eng.serve(
        [Request(tokens=prompt, max_new=5, prefix="t")]).values()))
    eng.serve([Request(tokens=prompt, max_new=1)])  # unseat
    eng.store.demote("t")
    _assert_rows_bit_exact(ref, eng.store._host["t"])
    eng.store.spill("t")
    out = eng.serve([Request(tokens=prompt, max_new=5, prefix="t")])
    np.testing.assert_array_equal(next(iter(out.values())), want)


# ---------------------------------------------------------------------------
# Token-identical serving from every tier
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("layout", ["dense", "paged"])
@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_serve_token_identical_across_tiers(setup, rng, tmp_path,
                                            layout, impl):
    """The same greedy request emits identical tokens whether its prefix
    is warm in HBM, promoted from host, loaded from disk, or compiled
    fresh from raw shots — dense and paged, jnp and pallas-interpret."""
    cfg, params, mc = setup
    m = cfg.memcom.num_memory_tokens
    shots = rng.integers(4, cfg.vocab_size, 40).astype(np.int32)
    prompt = rng.integers(4, cfg.vocab_size, 5).astype(np.int32)
    kv = _compress_kv(cfg, params, mc, shots)

    eng = ServingEngine(cfg, params, slots=2, max_len=m + 24,
                        kv_layout=layout, impl=impl, compressor=mc,
                        compile_token_budget=16, host_capacity=4,
                        disk_dir=str(tmp_path / layout),
                        promote_layer_budget=1)
    eng.add_prefix("t", kv)

    def one(prefix="t", raw=None):
        out = eng.serve([Request(tokens=prompt, max_new=5, prefix=prefix,
                                 raw_shots=raw)])
        return next(iter(out.values()))

    warm = one()
    eng.serve([Request(tokens=prompt, max_new=1)])  # unseat slot 0
    eng.store.demote("t")
    assert eng.store.tier_of("t") == "host"
    host_hit = one()
    eng.serve([Request(tokens=prompt, max_new=1)])
    eng.store.demote("t")
    eng.store.spill("t")
    assert eng.store.tier_of("t") == "disk"
    disk_hit = one()
    fresh = one(prefix=None, raw=shots)  # content-addressed fresh compile

    np.testing.assert_array_equal(host_hit, warm)
    np.testing.assert_array_equal(disk_hit, warm)
    np.testing.assert_array_equal(fresh, warm)
    ts = eng.stats()["prefix_tiers"]
    assert ts["host_promotes"] == 2 and ts["disk_loads"] == 1
    assert eng.stats()["compiler"]["compiled"] == 1  # fresh path only


def test_raw_shots_prefer_promotion_over_recompile(setup, rng):
    """A request that carries raw_shots for a task sitting in the host
    tier promotes instead of recompiling — the whole point of demoting
    rather than destroying."""
    cfg, params, mc = setup
    m = cfg.memcom.num_memory_tokens
    shots = rng.integers(4, cfg.vocab_size, 40).astype(np.int32)
    prompt = rng.integers(4, cfg.vocab_size, 5).astype(np.int32)

    eng = ServingEngine(cfg, params, slots=1, max_len=m + 24,
                        compressor=mc, host_capacity=4)
    cold = Request(tokens=prompt, max_new=3, raw_shots=shots)
    want = eng.serve([cold])[cold.uid]
    eng.serve([Request(tokens=prompt, max_new=1)])  # unseat
    eng.store.demote(cold.prefix)

    again = Request(tokens=prompt, max_new=3, raw_shots=shots.copy())
    got = eng.serve([again])[again.uid]
    np.testing.assert_array_equal(got, want)
    assert eng.stats()["compiler"]["jobs"] == 1  # no second compile
    assert eng.stats()["prefix_tiers"]["host_promotes"] == 1


# ---------------------------------------------------------------------------
# Park/wake FIFO order on a cold-prefix miss
# ---------------------------------------------------------------------------


def test_park_wake_fifo_on_cold_miss(setup, rng):
    """A request parked on a promoting prefix wakes at its original
    arrival position: it precedes later arrivals but never overtakes an
    earlier one, and warm traffic is admitted while it waits."""
    cfg, params, mc = setup
    m = cfg.memcom.num_memory_tokens
    prompt = rng.integers(4, cfg.vocab_size, 5).astype(np.int32)
    kv_a = _compress_kv(cfg, params, mc,
                        rng.integers(4, cfg.vocab_size, 40).astype(np.int32))
    kv_b = _compress_kv(cfg, params, mc,
                        rng.integers(4, cfg.vocab_size, 40).astype(np.int32))

    eng = ServingEngine(cfg, params, slots=1, max_len=m + 24,
                        host_capacity=4, promote_layer_budget=1)
    eng.add_prefix("A", kv_a)
    eng.add_prefix("B", kv_b)
    eng.store.demote("B")

    r1 = Request(tokens=prompt, max_new=2, prefix="B")   # parks
    r2 = Request(tokens=prompt, max_new=2, prefix="A")   # warm, runs first
    r3 = Request(tokens=prompt, max_new=2, prefix="B")   # parks (joined)
    eng.serve([r1, r2, r3])

    parked = [e[1] for e in eng.trace if e[0] == "park"]
    assert parked == [r1.uid, r3.uid]
    admits = [e[1] for e in eng.trace if e[0] == "admit"]
    # one slot: strict admission order — warm r2 immediately, then the
    # woken cold requests in arrival order
    assert admits == [r2.uid, r1.uid, r3.uid]
    assert eng.stats()["prefix_tiers"]["host_promotes"] == 1  # single-flight


# ---------------------------------------------------------------------------
# Decode keeps stepping during a budgeted promotion
# ---------------------------------------------------------------------------


def test_decode_continues_during_promotion(setup, rng):
    """With promote_layer_budget set, a seated slot keeps emitting tokens
    while a cold prefix copies up: decode steps land *between* promote
    chunks, and the warm request's output is byte-identical to a serve
    with no promotion in flight."""
    cfg, params, mc = setup
    m = cfg.memcom.num_memory_tokens
    prompt = rng.integers(4, cfg.vocab_size, 5).astype(np.int32)
    kv_a = _compress_kv(cfg, params, mc,
                        rng.integers(4, cfg.vocab_size, 40).astype(np.int32))
    kv_b = _compress_kv(cfg, params, mc,
                        rng.integers(4, cfg.vocab_size, 48).astype(np.int32))

    eng = ServingEngine(cfg, params, slots=2, max_len=m + 40,
                        host_capacity=4, promote_layer_budget=1)
    eng.add_prefix("A", kv_a)
    eng.add_prefix("B", kv_b)
    eng.store.demote("B")
    warm = Request(tokens=prompt, max_new=12, prefix="A")
    cold = Request(tokens=prompt, max_new=3, prefix="B")
    out = eng.serve([warm, cold])

    promote_idx = [i for i, e in enumerate(eng.trace) if e[0] == "promote"]
    decode_between = [i for i, e in enumerate(eng.trace) if e[0] == "decode"
                      and promote_idx[0] < i < promote_idx[-1]]
    assert len(promote_idx) >= 2, eng.trace  # budget=1 forces chunking
    assert decode_between, eng.trace
    assert eng.stats()["engine"]["decode_steps_during_promote"] >= 2

    solo = ServingEngine(cfg, params, slots=1, max_len=m + 40)
    solo.add_prefix("A", kv_a)
    want = solo.serve([Request(tokens=prompt, max_new=12, prefix="A")])
    np.testing.assert_array_equal(out[warm.uid], next(iter(want.values())))


# ---------------------------------------------------------------------------
# Seated guard, LRU demotion, spill pressure, restart recovery
# ---------------------------------------------------------------------------


def test_seated_prefix_never_demoted(setup, rng):
    """Evicting (= demoting) a prefix whose blocks are seated in a live
    slot still raises PrefixSeatedError, and no cold copy is created —
    a prefix is never demoted out from under a slot."""
    cfg, params, mc = setup
    m = cfg.memcom.num_memory_tokens
    kv = _compress_kv(cfg, params, mc,
                      rng.integers(4, cfg.vocab_size, 40).astype(np.int32))
    eng = ServingEngine(cfg, params, slots=1, max_len=m + 24,
                        kv_layout="paged", host_capacity=4)
    eng.add_prefix("t", kv)
    eng.seat_prefix(0, "t")
    with pytest.raises(PrefixSeatedError):
        eng.store.demote("t")
    assert eng.store.tier_of("t") == "hbm"
    assert not eng.store.host_names()


def test_paged_lru_demotes_instead_of_destroying(setup, rng):
    """prefix_capacity=1: registering task B LRU-evicts task A — with
    tiers configured A lands in the host tier instead of vanishing, and
    serving A afterwards promotes it back (no recompile possible: the
    engine has no compressor)."""
    cfg, params, mc = setup
    m = cfg.memcom.num_memory_tokens
    prompt = rng.integers(4, cfg.vocab_size, 5).astype(np.int32)
    kv_a = _compress_kv(cfg, params, mc,
                        rng.integers(4, cfg.vocab_size, 40).astype(np.int32))
    kv_b = _compress_kv(cfg, params, mc,
                        rng.integers(4, cfg.vocab_size, 40).astype(np.int32))

    ref = ServingEngine(cfg, params, slots=1, max_len=m + 24,
                        kv_layout="paged")
    ref.add_prefix("A", kv_a)
    want = ref.serve([Request(tokens=prompt, max_new=4, prefix="A")])

    eng = ServingEngine(cfg, params, slots=1, max_len=m + 24,
                        kv_layout="paged", prefix_capacity=1,
                        host_capacity=4)
    eng.add_prefix("A", kv_a)
    eng.add_prefix("B", kv_b)  # LRU-demotes A
    assert eng.store.tier_of("A") == "host"
    assert eng.store.tier_of("B") == "hbm"
    out = eng.serve([Request(tokens=prompt, max_new=4, prefix="A")])
    np.testing.assert_array_equal(next(iter(out.values())),
                                  next(iter(want.values())))
    # B was LRU-demoted in turn to make room for A's promotion
    assert eng.store.tier_of("B") == "host"


def test_dense_lru_capacity(setup, rng):
    """The dense store now takes prefix_capacity too: over-capacity puts
    evict (and, tiered, demote) the least-recently-used entry."""
    cfg, params, mc = setup
    m = cfg.memcom.num_memory_tokens
    kv = _compress_kv(cfg, params, mc,
                      rng.integers(4, cfg.vocab_size, 40).astype(np.int32))
    eng = ServingEngine(cfg, params, slots=1, max_len=m + 24,
                        prefix_capacity=2, host_capacity=4)
    eng.add_prefix("A", kv)
    eng.add_prefix("B", kv)
    eng.add_prefix("C", kv)  # evicts A (LRU)
    assert sorted(eng.store.hbm.names()) == ["B", "C"]
    assert eng.store.tier_of("A") == "host"


def test_host_pressure_spills_to_disk(setup, rng, tmp_path):
    """Demotions past host_capacity push the LRU host row to disk; with
    no disk tier it is dropped and counted."""
    cfg, params, mc = setup
    m = cfg.memcom.num_memory_tokens
    kv = _compress_kv(cfg, params, mc,
                      rng.integers(4, cfg.vocab_size, 40).astype(np.int32))
    eng = ServingEngine(cfg, params, slots=1, max_len=m + 24,
                        host_capacity=1, disk_dir=str(tmp_path))
    for name in ("A", "B", "C"):
        eng.add_prefix(name, kv)
        eng.store.demote(name)
    assert eng.store.tier_of("C") == "host"
    assert {eng.store.tier_of(n) for n in "AB"} == {"disk"}
    assert eng.stats()["prefix_tiers"]["spills"] == 2

    eng2 = ServingEngine(cfg, params, slots=1, max_len=m + 24,
                         host_capacity=1)  # no disk tier
    eng2.add_prefix("A", kv)
    eng2.add_prefix("B", kv)
    eng2.store.demote("A")
    eng2.store.demote("B")  # pushes A out with nowhere to go
    assert eng2.store.tier_of("A") is None
    assert eng2.stats()["prefix_tiers"]["host_drops"] == 1


def test_disk_shards_survive_restart(setup, rng, tmp_path):
    """A fresh engine pointed at an existing disk_dir indexes the shards
    and serves their tasks token-identically — no recompile."""
    cfg, params, mc = setup
    m = cfg.memcom.num_memory_tokens
    prompt = rng.integers(4, cfg.vocab_size, 5).astype(np.int32)
    kv = _compress_kv(cfg, params, mc,
                      rng.integers(4, cfg.vocab_size, 40).astype(np.int32))

    eng = ServingEngine(cfg, params, slots=1, max_len=m + 24,
                        host_capacity=0, disk_dir=str(tmp_path))
    eng.add_prefix("t", kv)
    want = eng.serve([Request(tokens=prompt, max_new=4, prefix="t")])
    eng.serve([Request(tokens=prompt, max_new=1)])  # unseat
    eng.store.demote("t")  # straight to disk
    assert os.listdir(str(tmp_path))

    eng2 = ServingEngine(cfg, params, slots=1, max_len=m + 24,
                         host_capacity=0, disk_dir=str(tmp_path))
    assert eng2.store.tier_of("t") == "disk"
    out = eng2.serve([Request(tokens=prompt, max_new=4, prefix="t")])
    np.testing.assert_array_equal(next(iter(out.values())),
                                  next(iter(want.values())))
    assert eng2.stats()["compiler"] is None  # nothing to compile with


def test_install_defers_on_queued_work(setup, rng):
    """Regression: a promoted prefix whose install cannot evict (the
    sole HBM entry is pinned by a *queued* request) must defer — the
    drain runs before admission, so the queue can be non-empty with
    every slot free — not crash serve().  The queued request runs,
    unpins, and the install lands."""
    cfg, params, mc = setup
    m = cfg.memcom.num_memory_tokens
    prompt = rng.integers(4, cfg.vocab_size, 5).astype(np.int32)
    kv_a = _compress_kv(cfg, params, mc,
                        rng.integers(4, cfg.vocab_size, 40).astype(np.int32))
    kv_c = _compress_kv(cfg, params, mc,
                        rng.integers(4, cfg.vocab_size, 40).astype(np.int32))

    eng = ServingEngine(cfg, params, slots=1, max_len=m + 24,
                        prefix_capacity=1, host_capacity=4,
                        promote_layer_budget=1)
    eng.add_prefix("A", kv_a)
    eng.add_prefix("C", kv_c)  # LRU-demotes A to host
    eng.store.demote("C")      # now: HBM empty, host = {A, C}
    # promote A back so serving can start from it HBM-resident
    out = eng.serve([Request(tokens=prompt, max_new=2, prefix="A")])
    r1 = Request(tokens=prompt, max_new=8, prefix="A")
    r2 = Request(tokens=prompt, max_new=2, prefix="C")  # parks, promotes
    r3 = Request(tokens=prompt, max_new=2, prefix="A")  # queued: pins A
    out = eng.serve([r1, r2, r3])
    assert len(out) == 3 and all(len(v) for v in out.values())
    assert eng.store.tier_of("C") == "hbm"  # install landed eventually


def test_unknown_cold_prefix_still_raises(setup, rng):
    """Tiering must not swallow genuinely unknown prefixes."""
    cfg, params, _ = setup
    eng = ServingEngine(cfg, params, slots=1, max_len=32, host_capacity=4)
    with pytest.raises(KeyError, match="nope"):
        eng.serve([Request(tokens=[5], max_new=1, prefix="nope")])


# ---------------------------------------------------------------------------
# Promotion under a model mesh lands pre-sharded (forced 4-device host)
# ---------------------------------------------------------------------------

_SHARDED_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax
import jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.core import memcom
from repro.launch.mesh import make_serving_mesh
from repro.models import transformer as tfm
from repro.serving import Request, ServingEngine, materialize_prefix

report = {}
rng = np.random.default_rng(0)
cfg = get_smoke_config("smollm-135m").replace(
    d_model=128, num_heads=8, num_kv_heads=4, d_ff=256)
params = tfm.init_params(cfg, 0)
mc = memcom.init_memcom(cfg, params, 1)
m = cfg.memcom.num_memory_tokens
shots = jnp.asarray(rng.integers(4, cfg.vocab_size, (1, 40)), jnp.int32)
kv = materialize_prefix(params, cfg, memcom.compress(mc, cfg, shots)[0])
prompt = rng.integers(4, cfg.vocab_size, 5).astype(np.int32)


def tiered_cycle(eng):
    # warm -> unseat -> demote -> promoted serve, returning both outputs
    warm = eng.serve([Request(tokens=prompt, max_new=5, prefix="t")])
    eng.serve([Request(tokens=prompt, max_new=1)])
    eng.store.demote("t")
    hit = eng.serve([Request(tokens=prompt, max_new=5, prefix="t")])
    return (next(iter(warm.values())).tolist(),
            next(iter(hit.values())).tolist())


ref = ServingEngine(cfg, params, slots=2, max_len=m + 24, host_capacity=4,
                    promote_layer_budget=1)
ref.add_prefix("t", kv)
want_warm, want_hit = tiered_cycle(ref)
report["single_device_identical"] = want_warm == want_hit

for layout, kw in (("dense", {}),
                   ("paged", dict(kv_layout="paged", block_size=4))):
    for model in (2, 4):
        mesh = make_serving_mesh(model=model)
        eng = ServingEngine(cfg, params, slots=2, max_len=m + 24, mesh=mesh,
                            host_capacity=4, promote_layer_budget=1, **kw)
        eng.add_prefix("t", kv)
        got_warm, got_hit = tiered_cycle(eng)
        report[f"{layout}_{model}_tokens"] = (
            got_warm == want_warm and got_hit == want_warm)
        # the promoted row landed pre-sharded: every kv_heads leaf of the
        # store entry (dense) splits "model" on its head axis
        if layout == "dense":
            entry = eng.store.get("t")
            specs = [tuple(x.sharding.spec)
                     for e in ([entry["period"][k] for k in entry.get("period", {})]
                               + entry.get("prefix", []))
                     for key, x in e.items() if key in ("k", "v")]
            report[f"sharded_landing_{model}"] = (
                bool(specs) and all("model" in s for s in specs))
        report[f"{layout}_{model}_promotes"] = (
            eng.stats()["prefix_tiers"]["host_promotes"] == 1)

print(json.dumps(report))
"""


@pytest.mark.slow
def test_tiered_promotion_sharded(tmp_path):
    """Forced-4-device host: tiered serving is token-identical to single
    device on 2-/4-way model meshes (dense + paged), and the promoted
    rows land with their head axes split over "model" — pre-sharded, no
    replicated detour."""
    import subprocess
    import sys

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    script = tmp_path / "tiered_sharded.py"
    script.write_text(_SHARDED_SCRIPT)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    res = subprocess.run([sys.executable, str(script)], capture_output=True,
                         text=True, timeout=1800, env=env, cwd=root)
    assert res.returncode == 0, res.stderr[-3000:]
    import json

    report = json.loads(res.stdout.strip().splitlines()[-1])
    for key, val in report.items():
        assert val, f"{key} failed"
