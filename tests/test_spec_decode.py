"""Fused decode-step + speculative decoding tests: greedy parity with the
classic engine (dense/paged x jnp/pallas-interpret), KV-rollback exactness
at paged block boundaries, fused token accounting under random chunk
schedules (hypothesis), the jit-compile bucket-ladder regression, and the
masked paged-scatter lane contract."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import memcom
from repro.kernels import ops
from repro.models import transformer as tfm
from repro.serving import Request, VirtualClock
from repro.serving.engine import ServingEngine

PROMPT_LENS = (5, 11, 8, 3, 7, 9)
MAX_NEW = 6


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("smollm-135m")
    params = tfm.init_params(cfg, 0)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(4, cfg.vocab_size, n).astype(np.int32)
               for n in PROMPT_LENS]
    return cfg, params, prompts


@pytest.fixture(scope="module")
def ref(setup):
    """Greedy reference per prompt from the classic (non-fused) engine.
    Greedy decode is deterministic per request, so fused/spec/churn runs
    must reproduce these tokens exactly regardless of batching schedule."""
    cfg, params, prompts = setup
    eng = ServingEngine(cfg, params, slots=len(prompts), max_len=40)
    reqs = [Request(tokens=p, max_new=MAX_NEW) for p in prompts]
    out = eng.serve(reqs)
    return [list(map(int, out[r.uid])) for r in reqs]


def _serve(eng, prompts, idx, **req_kw):
    reqs = [Request(tokens=prompts[i], max_new=MAX_NEW,
                    **{k: (v[j] if isinstance(v, list) else v)
                       for k, v in req_kw.items()})
            for j, i in enumerate(idx)]
    out = eng.serve(reqs)
    return [list(map(int, out[r.uid])) for r in reqs]


# ---------------------------------------------------------------------------
# Greedy parity: fused step and speculative decoding are pure perf features
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_fused_join_greedy_identity(setup, ref, layout):
    """Staggered arrivals into a 2-slot fused engine force the chunked
    join path; every request's greedy tokens match the classic engine."""
    cfg, params, prompts = setup
    eng = ServingEngine(cfg, params, slots=2, max_len=40, kv_layout=layout,
                        clock=VirtualClock(), fused_step=True,
                        fused_chunk_tokens=4)
    idx = [0, 1, 2, 3, 4]
    got = _serve(eng, prompts, idx,
                 arrival_s=[0.002 * j for j in range(len(idx))])
    assert got == [ref[i] for i in idx]
    es = eng.stats()["engine"]
    assert es["fused_prefill_chunks"] > 0  # joins actually streamed


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_spec_greedy_identity(setup, ref, layout, impl):
    """Self-drafted speculative decoding is token-identical to the plain
    engine, and on plain prompts the self-draft accepts everything."""
    cfg, params, prompts = setup
    eng = ServingEngine(cfg, params, slots=2, max_len=40, kv_layout=layout,
                        impl=impl, fused_step=True, spec_draft="self",
                        spec_k=2)
    idx = [0, 1]
    assert _serve(eng, prompts, idx) == [ref[i] for i in idx]
    es = eng.stats()["engine"]
    assert es["draft_proposed"] > 0
    assert es["draft_accepted"] == es["draft_proposed"]  # drafter == target
    assert es["accept_rate"] == 1.0


def test_spec_cross_drafter_identity(setup, ref):
    """A drafter with different weights mostly misses — acceptance drops,
    rollback engages — but greedy output never changes."""
    cfg, params, prompts = setup
    drafter = (cfg, tfm.init_params(cfg, 123))
    eng = ServingEngine(cfg, params, slots=2, max_len=40, fused_step=True,
                        spec_draft=drafter, spec_k=2)
    idx = [0, 1, 2]
    assert _serve(eng, prompts, idx) == [ref[i] for i in idx]
    es = eng.stats()["engine"]
    assert es["draft_proposed"] > 0
    assert es["draft_accepted"] < es["draft_proposed"]  # rollbacks happened


def test_spec_sampled_runs_and_conserves(setup):
    """Sampled acceptance (temperature > 0) completes every request with
    exactly max_new tokens and keeps the draft counters consistent."""
    cfg, params, prompts = setup
    eng = ServingEngine(cfg, params, slots=2, max_len=40, kv_layout="paged",
                        fused_step=True, spec_draft="self", spec_k=2)
    # sharp temperature: the random smoke weights are near-uniform, so a
    # soft temperature would put ~1/vocab mass on the drafted argmax token
    # and (correctly) accept nothing; at 0.05 the sampled rule fires
    got = _serve(eng, prompts, [0, 1, 2], temperature=0.05)
    assert all(len(t) == MAX_NEW for t in got)
    es = eng.stats()["engine"]
    assert 0 < es["draft_accepted"] <= es["draft_proposed"]


# ---------------------------------------------------------------------------
# KV rollback at paged block boundaries
# ---------------------------------------------------------------------------


def test_paged_block_boundary_rollback(setup, ref):
    """block_size=4 with spec_k=3: accepted runs repeatedly straddle block
    boundaries and rejected drafts leave garbage in the next block.  A
    low-acceptance drafter forces rollbacks right at the boundary; tokens
    must still be bit-identical to the classic engine."""
    cfg, params, prompts = setup
    drafter = (cfg, tfm.init_params(cfg, 7))
    eng = ServingEngine(cfg, params, slots=2, max_len=40, kv_layout="paged",
                        block_size=4, fused_step=True, spec_draft=drafter,
                        spec_k=3)
    idx = [1, 2, 0, 4]
    assert _serve(eng, prompts, idx) == [ref[i] for i in idx]

    # and the all-accept extreme: lengths jump k+1 per step across blocks
    eng = ServingEngine(cfg, params, slots=2, max_len=40, kv_layout="paged",
                        block_size=4, fused_step=True, spec_draft="self",
                        spec_k=3)
    assert _serve(eng, prompts, idx) == [ref[i] for i in idx]
    assert eng.stats()["engine"]["accept_rate"] == 1.0


# ---------------------------------------------------------------------------
# Token accounting under random chunk schedules (hypothesis)
# ---------------------------------------------------------------------------

def _check_token_conservation(setup, ref, idx, chunk, stagger, spec_k):
    """Whatever chunk schedule the fused step runs — random prompt mix,
    chunk width, arrival stagger, with or without speculation — tokens are
    conserved: every request emits exactly max_new, outputs match the
    greedy reference, every joined prompt token is streamed exactly once,
    and the decode counter equals total output minus the first tokens."""
    cfg, params, prompts = setup
    kw = {} if spec_k == 0 else {"spec_draft": "self", "spec_k": spec_k}
    eng = ServingEngine(cfg, params, slots=2, max_len=40, kv_layout="paged",
                        clock=VirtualClock(), fused_step=True,
                        fused_chunk_tokens=chunk, **kw)
    got = _serve(eng, prompts, idx,
                 arrival_s=[stagger * j for j in range(len(idx))])
    assert got == [ref[i] for i in idx]
    es = eng.stats()["engine"]
    assert es["tokens_generated"] == len(idx) * MAX_NEW - len(idx)
    joined = sum(t[3] for t in eng.trace if t[0] == "join")
    assert es["fused_prefill_tokens"] == joined


try:
    from hypothesis import HealthCheck, given, settings, strategies as st

    SHORT = settings(max_examples=6, deadline=None,
                     suppress_health_check=[HealthCheck.too_slow])

    @SHORT
    @given(idx=st.lists(st.integers(0, len(PROMPT_LENS) - 1),
                        min_size=3, max_size=5),
           chunk=st.sampled_from([2, 4]),
           stagger=st.sampled_from([0.0005, 0.002]),
           spec_k=st.sampled_from([0, 2]))
    def test_fused_token_conservation(setup, ref, idx, chunk, stagger,
                                      spec_k):
        _check_token_conservation(setup, ref, idx, chunk, stagger, spec_k)

except ImportError:
    # hypothesis is optional: fall back to seeded random schedules so the
    # property is still exercised
    _sched_rng = np.random.default_rng(42)
    _CASES = [(list(_sched_rng.integers(0, len(PROMPT_LENS), size=n)),
               int(_sched_rng.choice([2, 4])),
               float(_sched_rng.choice([0.0005, 0.002])),
               int(_sched_rng.choice([0, 2])))
              for n in (3, 4, 5, 4, 3, 5)]

    @pytest.mark.parametrize("idx,chunk,stagger,spec_k", _CASES)
    def test_fused_token_conservation(setup, ref, idx, chunk, stagger,
                                      spec_k):
        _check_token_conservation(setup, ref, idx, chunk, stagger, spec_k)


# ---------------------------------------------------------------------------
# jit-compile accounting and the bucket-ladder regression
# ---------------------------------------------------------------------------


def test_jit_compiles_bucket_ladder(setup):
    """stats() reports per-family compile counts, and the pow2 bucket
    ladder caps them: six distinct prompt lengths through the fused+spec
    engine compile only a handful of programs, and replaying the same
    workload compiles nothing new."""
    cfg, params, prompts = setup
    eng = ServingEngine(cfg, params, slots=2, max_len=48,
                        clock=VirtualClock(), fused_step=True,
                        fused_chunk_tokens=4, spec_draft="self", spec_k=2)
    idx = list(range(len(PROMPT_LENS)))
    arrivals = [0.002 * j for j in range(len(idx))]
    _serve(eng, prompts, idx, arrival_s=arrivals)
    jc = eng.stats()["engine"]["jit_compiles"]
    assert jc and all(isinstance(v, int) for v in jc.values())
    # spec lanes dominate the width bucket, so the chunk ladder collapses
    # onto very few fused geometries
    assert jc.get("fused", 0) <= 2
    assert jc.get("draft", 0) <= 1
    assert sum(jc.values()) <= 12

    _serve(eng, prompts, idx, arrival_s=arrivals)  # replay: all warm
    assert eng.stats()["engine"]["jit_compiles"] == jc


# ---------------------------------------------------------------------------
# Masked paged-scatter lane contract
# ---------------------------------------------------------------------------


def test_paged_scatter_valid_routes_to_trash(rng):
    """Lanes >= valid[b] are geometry padding: they land in physical block
    0 (the allocator's trash block) and never touch an allocated block."""
    B, S, bs, nb, H, D = 2, 4, 4, 3, 2, 4
    pool = np.asarray(rng.standard_normal((B * nb + 1, bs, H, D)),
                      np.float32)
    tables = (np.arange(B * nb).reshape(B, nb) + 1).astype(np.int32)
    new = np.asarray(rng.standard_normal((B, S, H, D)), np.float32)
    starts = jnp.asarray([2, 5], jnp.int32)
    valid = jnp.asarray([3, 0], jnp.int32)

    out = np.asarray(ops.paged_scatter(
        jnp.asarray(pool), jnp.asarray(new), jnp.asarray(tables), starts,
        valid=valid))
    # slot 0: lanes 0..2 land at logical positions 2..4 (straddling blocks)
    for s in range(3):
        pos = 2 + s
        np.testing.assert_array_equal(out[tables[0, pos // bs], pos % bs],
                                      new[0, s])
    # slot 0 lane 3 and all of slot 1 are invalid: every allocated block
    # equals the original pool except the three written rows
    untouched = out.copy()
    for s in range(3):
        pos = 2 + s
        untouched[tables[0, pos // bs], pos % bs] = \
            pool[tables[0, pos // bs], pos % bs]
    np.testing.assert_array_equal(untouched[1:], pool[1:])


def test_paged_scatter_valid_clamps_table_column(rng):
    """Regression: an invalid lane whose position runs past the table
    width must not let take_along_axis's clamp route it into the *last*
    column's real block."""
    B, S, bs, nb, H, D = 1, 4, 2, 2, 1, 2
    pool = np.asarray(rng.standard_normal((nb + 1, bs, H, D)), np.float32)
    tables = jnp.asarray([[1, 2]], jnp.int32)  # table width 2 == max_len 4
    new = np.asarray(rng.standard_normal((B, S, H, D)), np.float32)
    # start at the last valid position: lanes 1..3 run to positions 4..6,
    # i.e. columns 2..3 — past the table
    out = np.asarray(ops.paged_scatter(
        jnp.asarray(pool), jnp.asarray(new), tables,
        jnp.asarray([3], jnp.int32), valid=jnp.asarray([1], jnp.int32)))
    np.testing.assert_array_equal(out[1], pool[1])        # block 1 intact
    np.testing.assert_array_equal(out[2, 0], pool[2, 0])  # pos 2 intact
    np.testing.assert_array_equal(out[2, 1], new[0, 0])   # the one write
