"""Per-architecture smoke tests (deliverable f): reduced same-family
configs, one forward + one train step on CPU, asserting shapes + no NaNs;
plus decode-path parity against the full forward for every family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core import memcom
from repro.models import transformer as tfm
from repro.optim import AdamW


def _inputs(cfg, rng, B=2, S=24):
    kw = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                jnp.int32)}
    if cfg.encoder is not None:
        kw["encoder_frames"] = jnp.asarray(
            rng.standard_normal((B, 8, cfg.d_model)) * 0.1, jnp.float32)
    return kw


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch, rng):
    cfg = get_smoke_config(arch)
    cfg.validate()
    params = tfm.init_params(cfg, 0)
    kw = _inputs(cfg, rng)
    logits, aux = tfm.forward(params, cfg, **kw)
    assert logits.shape == (2, 24, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert np.isfinite(float(aux["moe_loss"]))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch, rng):
    cfg = get_smoke_config(arch)
    params = tfm.init_params(cfg, 0)
    kw = _inputs(cfg, rng)
    opt = AdamW(lr=1e-3)
    state = opt.init(params)

    def loss_fn(p):
        logits, aux = tfm.forward(p, cfg, **kw)
        return memcom.next_token_loss(logits, kw["tokens"]) + aux["moe_loss"]

    l0, grads = jax.value_and_grad(loss_fn)(params)
    params2, state = opt.step(params, grads, state)
    l1 = loss_fn(params2)
    assert np.isfinite(float(l0)) and np.isfinite(float(l1))
    assert float(l1) < float(l0), "one optimizer step must reduce the loss"


@pytest.mark.parametrize("arch", ["smollm-135m", "deepseek-v2-236b",
                                  "mamba2-370m", "jamba-1.5-large-398b",
                                  "whisper-medium", "qwen2-vl-2b",
                                  "gemma2-2b"])
def test_prefill_decode_parity(arch, rng):
    """prefill(S tokens) then decode(1 token) == full forward(S+1).

    MoE capacity is raised to lossless (C ≥ all tokens) for this test:
    capacity-drop is a function of batch composition, so a 12- vs 13-token
    forward legitimately drops different tokens at production capacity.
    """
    import dataclasses

    cfg = get_smoke_config(arch)
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    params = tfm.init_params(cfg, 0)
    B, S = 2, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)), jnp.int32)
    kw = {}
    if cfg.encoder is not None:
        kw["encoder_frames"] = jnp.asarray(
            rng.standard_normal((B, 8, cfg.d_model)) * 0.1, jnp.float32)

    full, _ = tfm.forward(params, cfg, tokens=toks, **kw)

    cache = tfm.init_cache(cfg, B, S + 8)
    pre, aux = tfm.forward(params, cfg, tokens=toks[:, :S], cache=cache,
                           cache_index=0, **kw)
    cache = aux["cache"]
    dec, aux = tfm.forward(params, cfg, tokens=toks[:, S:S + 1], cache=cache,
                           cache_index=S, decode=True,
                           encoder_out=aux.get("encoder_out"))
    np.testing.assert_allclose(np.asarray(pre), np.asarray(full[:, :S]),
                               atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, S]),
                               atol=2e-4, rtol=2e-3)


def test_param_count_matches_published_scale():
    """Full configs land near their advertised parameter counts."""
    expect = {
        "smollm-135m": (0.10e9, 0.18e9),
        "smollm-360m": (0.30e9, 0.45e9),
        "stablelm-1.6b": (1.2e9, 2.1e9),
        "gemma2-2b": (2.0e9, 3.5e9),
        "mistral-7b": (6.5e9, 8.0e9),
        "mistral-nemo-12b": (11e9, 13.5e9),
        "mamba2-370m": (0.3e9, 0.5e9),
        "deepseek-v2-236b": (200e9, 260e9),
        "jamba-1.5-large-398b": (330e9, 430e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]B"


def test_mrope_positions_qwen():
    """M-RoPE: 3-D position streams accepted and text-diagonal by default."""
    cfg = get_smoke_config("qwen2-vl-2b")
    assert cfg.mrope_sections and sum(cfg.mrope_sections) == cfg.hd // 2
    params = tfm.init_params(cfg, 0)
    B, S = 1, 8
    toks = jnp.zeros((B, S), jnp.int32)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (3, B, S))
    out, _ = tfm.forward(params, cfg, tokens=toks, positions=pos)
    out_default, _ = tfm.forward(params, cfg, tokens=toks)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_default),
                               atol=1e-5)
