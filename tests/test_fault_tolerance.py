"""Fault-tolerance integration tests: atomic checkpoints, restart-exact
resume (bitwise-identical loss curve), preemption handling, rotation."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_tree, save_tree
from repro.configs import get_smoke_config
from repro.core import memcom
from repro.data import PretrainStream, SyntheticVocab
from repro.models import transformer as tfm
from repro.optim import AdamW
from repro.train import Trainer, TrainerConfig, build_train_step


def _stream(seed=7):
    return PretrainStream(SyntheticVocab(), batch=4, seq_len=32,
                          split_choices=(16, 20), seed=seed)


def _setup(tmp_path, num_steps=12, ckpt_every=4):
    cfg = get_smoke_config("smollm-135m").replace(
        vocab_size=SyntheticVocab().size)
    params = tfm.init_params(cfg, 0)
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(params)
    stream = _stream()

    def loss_fn(p, batch):
        logits, aux = tfm.forward(p, cfg, tokens=batch["tokens"])
        return (memcom.next_token_loss(logits, batch["tokens"])
                + aux["moe_loss"], {})

    step = jax.jit(build_train_step(loss_fn, opt))
    tc = TrainerConfig(num_steps=num_steps, ckpt_every=ckpt_every,
                       log_every=1)

    def batch_at(i):
        b = stream.batch_at(i)
        toks = np.concatenate([b["source"], b["target"]], axis=1)
        return {"tokens": jnp.asarray(toks)}

    return Trainer(step, params, opt_state, batch_at, str(tmp_path), tc), cfg


def test_checkpoint_roundtrip(tmp_path, rng):
    tree = {
        "a": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32),
        "nested": {"b": jnp.arange(7, dtype=jnp.int32),
                   "c": jnp.asarray(rng.standard_normal(3), jnp.bfloat16)},
    }
    save_tree(str(tmp_path / "t"), tree, meta={"step": 3})
    out, meta = load_tree(str(tmp_path / "t"))
    assert meta["step"] == 3
    for (na, a), (nb, b) in zip(
            jax.tree_util.tree_flatten_with_path(tree)[0],
            jax.tree_util.tree_flatten_with_path(out)[0]):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_restart_reproduces_loss_curve(tmp_path):
    """Kill at step 6 of 12, restart from checkpoint ⇒ the final params and
    per-step losses match the uninterrupted run exactly."""
    t_full, _ = _setup(tmp_path / "full")
    t_full.run()
    full_final = jax.tree.leaves(t_full.params)[0]

    t_a, _ = _setup(tmp_path / "resume", num_steps=12, ckpt_every=6)
    t_a.tc = TrainerConfig(num_steps=6, ckpt_every=6, log_every=1)
    t_a.run()  # first half, checkpoint at 6
    t_b, _ = _setup(tmp_path / "resume", num_steps=12, ckpt_every=6)
    resumed_from = t_b.restore_if_available()
    assert resumed_from == 6
    last = t_b.run()
    assert last["step"] == 12
    resumed_final = jax.tree.leaves(t_b.params)[0]
    np.testing.assert_array_equal(np.asarray(full_final),
                                  np.asarray(resumed_final))


def test_preemption_flag_saves_and_exits(tmp_path):
    trainer, _ = _setup(tmp_path, num_steps=50, ckpt_every=100)
    trainer.mgr.flag_preemption()
    out = trainer.run()
    assert out.get("preempted_at") == 0
    # a checkpoint must exist despite never reaching ckpt_every
    step, _, _ = trainer.mgr.restore_latest(
        {"params": trainer.params, "opt": trainer.opt_state})
    assert step == 0


def test_rotation_keeps_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    steps = mgr.available_steps()
    assert steps == [3, 4]


def test_atomic_save_ignores_partial(tmp_path):
    """A crash mid-save leaves a tmp dir the manager must ignore."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = {"x": jnp.arange(4, dtype=jnp.float32)}
    mgr.save(1, tree)
    (tmp_path / "step_00000002.tmp").mkdir()
    (tmp_path / "step_00000002.tmp" / "garbage").write_text("x")
    step, out, _ = mgr.restore_latest({"x": tree["x"]})
    assert step == 1


def test_elastic_reshard_load(tmp_path, rng):
    """A checkpoint saved from one layout loads onto a differently-sharded
    abstract tree (shape-checked, host-gathered)."""
    tree = {"w": jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)}
    save_tree(str(tmp_path / "e"), tree, meta={})
    # simulate a new mesh: load with device_put onto the (single) device
    out, _ = load_tree(str(tmp_path / "e"))
    resharded = jax.device_put(out["w"], jax.devices()[0])
    np.testing.assert_array_equal(np.asarray(resharded), np.asarray(tree["w"]))


def test_data_stream_seekable():
    s = _stream(seed=3)
    b10 = s.batch_at(10)
    s2 = _stream(seed=3)
    b10b = s2.batch_at(10)
    for k in ("source", "target", "target_mask"):
        np.testing.assert_array_equal(b10[k], b10b[k])


def test_straggler_watchdog_counts(tmp_path, monkeypatch):
    trainer, _ = _setup(tmp_path, num_steps=6, ckpt_every=100)
    # fake clock: step 4 takes 9 s, every other step 0.1 s
    seq = [0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 1.0, 10.0, 10.1, 10.2]
    state = {"i": -1}

    def fake_monotonic():
        state["i"] += 1
        i = min(state["i"], len(seq) - 1)
        return seq[i] + max(0, state["i"] - len(seq) + 1) * 0.05

    import repro.train.trainer as trainer_mod

    monkeypatch.setattr(trainer_mod.time, "monotonic", fake_monotonic)
    out = trainer.run()
    assert out["stragglers"] >= 1
