"""HTTP telemetry plane tests: every endpoint against a fake engine
(status codes, content types, JSON shapes, the numpy-scalar encoder),
lifecycle (ephemeral ports, context manager, restart guard), and one
integration test scraping a *live* real engine mid-``serve()`` from
another thread — proving the server never perturbs the token stream."""

import json
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import memcom
from repro.models import transformer as tfm
from repro.serving import (
    MetricsRegistry,
    ServingEngine,
    SLOWatchdog,
    TelemetryServer,
    Tracer,
    TrafficConfig,
    VirtualClock,
    default_rules,
    generate_trace,
    validate_chrome_trace,
)


def _get(port, path, timeout=5.0):
    """(status, content_type, body) for a GET against the local server."""
    try:
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}{path}", timeout=timeout) as resp:
            return resp.status, resp.headers["Content-Type"], \
                resp.read().decode("utf-8")
    except urllib.error.HTTPError as e:
        return e.code, e.headers["Content-Type"], \
            e.read().decode("utf-8")


class _FakeEngine:
    """The exact read-only surface TelemetryServer touches."""

    def __init__(self, *, with_watchdog=False, last_step_t=None):
        self.metrics = MetricsRegistry()
        self.metrics.counter("demo_total", "demo").inc(3)
        self.clock = VirtualClock()
        self.clock.advance(2.5)
        self.tracer = Tracer(clock=self.clock)
        self.tracer.span("engine", "decode_step", 1.0, 1.5)
        self.last_step_t = last_step_t
        self.slots = 2
        self.watchdog = None
        if with_watchdog:
            self.watchdog = SLOWatchdog(default_rules(),
                                        clock=self.clock,
                                        metrics=self.metrics)

    def stats(self):
        return {"engine": {"decode_steps": 7,
                           "np_scalar": np.int64(4)}}


@pytest.fixture()
def served():
    eng = _FakeEngine(with_watchdog=True, last_step_t=2.0)
    with TelemetryServer(eng, port=0) as srv:
        yield eng, srv


def test_metrics_endpoint_prometheus_text(served):
    eng, srv = served
    status, ctype, body = _get(srv.bound_port, "/metrics")
    assert status == 200 and ctype.startswith("text/plain")
    assert "demo_total 3" in body
    # the watchdog registers its counter eagerly: scrapeable pre-alert
    assert "serving_alerts_total" in body


def test_healthz_liveness_on_injected_clock(served):
    eng, srv = served
    status, ctype, body = _get(srv.bound_port, "/healthz")
    assert status == 200 and ctype == "application/json"
    doc = json.loads(body)
    assert doc["status"] == "ok"
    assert doc["now"] == pytest.approx(2.5)
    assert doc["last_step_t"] == pytest.approx(2.0)
    assert doc["last_step_age_s"] == pytest.approx(0.5)
    assert doc["slots"] == 2
    assert doc["page_active"] is False and doc["alerts"] == 0


def test_healthz_idle_before_first_step():
    eng = _FakeEngine(last_step_t=None)
    with TelemetryServer(eng, port=0) as srv:
        doc = json.loads(_get(srv.bound_port, "/healthz")[2])
    assert doc["status"] == "idle"
    assert doc["last_step_age_s"] is None
    assert "page_active" not in doc  # no watchdog attached


def test_debug_state_jsonifies_numpy(served):
    eng, srv = served
    status, ctype, body = _get(srv.bound_port, "/debug/state")
    assert status == 200 and ctype == "application/json"
    doc = json.loads(body)
    assert doc["engine"]["decode_steps"] == 7
    assert doc["engine"]["np_scalar"] == 4  # .item()'d, not repr'd


def test_debug_trace_is_valid_chrome_trace(served):
    eng, srv = served
    status, _, body = _get(srv.bound_port, "/debug/trace")
    assert status == 200
    trace = json.loads(body)
    assert validate_chrome_trace(trace, require_spans=("decode_step",)) == []


def test_unknown_route_404_and_post_405(served):
    eng, srv = served
    status, _, body = _get(srv.bound_port, "/nope")
    assert status == 404 and "/nope" in body
    req = urllib.request.Request(
        f"http://127.0.0.1:{srv.bound_port}/metrics", data=b"x",
        method="POST")
    with pytest.raises(urllib.error.HTTPError) as ei:
        urllib.request.urlopen(req, timeout=5)
    assert ei.value.code == 405


def test_route_exception_becomes_500():
    eng = _FakeEngine()
    eng.stats = lambda: (_ for _ in ()).throw(KeyError("boom"))
    with TelemetryServer(eng, port=0) as srv:
        status, _, body = _get(srv.bound_port, "/debug/state")
    assert status == 500 and "KeyError" in body


def test_lifecycle_restart_guard_and_stop_idempotent():
    eng = _FakeEngine()
    srv = TelemetryServer(eng, port=0)
    port = srv.start()
    assert port == srv.bound_port and port > 0
    with pytest.raises(RuntimeError):
        srv.start()
    srv.stop()
    srv.stop()  # idempotent
    # the port is released: a fresh server can bind it again
    srv2 = TelemetryServer(eng, port=port)
    assert srv2.start() == port
    srv2.stop()


# ---------------------------------------------------------------------------
# live engine: scrape while serve() runs, token stream unperturbed
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("smollm-135m")
    params = tfm.init_params(cfg, 0)
    mc = memcom.init_memcom(cfg, params, 1)
    return cfg, params, mc


def _serve(cfg, params, mc, disk_dir, server=False, scrapes=None):
    m = cfg.memcom.num_memory_tokens
    trace = generate_trace(
        TrafficConfig(num_tasks=5, num_requests=12, context_tokens=24,
                      rate_rps=300.0, priority_classes=2), seed=0)
    eng = ServingEngine(
        cfg, params, slots=2, max_len=m + 32, compressor=mc,
        compile_token_budget=8, prefix_capacity=2, host_capacity=2,
        disk_dir=str(disk_dir), promote_layer_budget=1,
        clock=VirtualClock(), priority_aging_s=0.05,
        tracer=Tracer(), metrics=MetricsRegistry(),
        watchdog=SLOWatchdog(default_rules(), metrics=None))
    if not server:
        out = eng.serve(list(trace.requests))
        return [list(out[r.uid]) for r in trace.requests]
    with TelemetryServer(eng, port=0) as srv:
        box = {}

        def _run():
            box["out"] = eng.serve(list(trace.requests))

        t = threading.Thread(target=_run)
        t.start()
        while t.is_alive():
            scrapes.append(_get(srv.bound_port, "/healthz")[0])
            scrapes.append(_get(srv.bound_port, "/metrics")[0])
        t.join()
        # post-run scrape sees the finished engine's full state
        doc = json.loads(_get(srv.bound_port, "/debug/state")[2])
        assert doc["engine"]["decode_steps"] > 0
        trace_doc = json.loads(_get(srv.bound_port, "/debug/trace")[2])
        assert validate_chrome_trace(
            trace_doc, require_spans=("decode_step", "admission")) == []
    return [list(box["out"][r.uid]) for r in trace.requests]


def test_live_scrape_does_not_perturb_tokens(setup, tmp_path):
    cfg, params, mc = setup
    plain = _serve(cfg, params, mc, tmp_path / "plain")
    scrapes = []
    scraped = _serve(cfg, params, mc, tmp_path / "scraped",
                     server=True, scrapes=scrapes)
    assert scraped == plain  # scraping is read-only by construction
    assert scrapes and all(s == 200 for s in scrapes)
