"""Perf-regression gate tests: verdicts against hand-built bench docs —
within-tolerance pass, bad-direction regression, improvements never
flagged, missing metrics as regressions, scenario-mismatch refusal, and
the CLI's exit-code contract.  Deterministic by construction: the same
pair of files always yields the same verdict."""

import json
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
if str(ROOT) not in sys.path:
    sys.path.insert(0, str(ROOT))

from tools.bench_compare import (  # noqa: E402
    DEFAULT_REL_TOL,
    GATED_METRICS,
    SCENARIO_KEYS,
    compare,
    find_traffic_section,
    main,
    scenario_mismatches,
)

SCEN = dict(seed=0, process="poisson", num_tasks=6, num_requests=16,
            rate_rps=300.0, zipf_alpha=1.1, priority_classes=2, slots=2,
            prefix_capacity=2, host_capacity=2, compile_token_budget=8,
            promote_layer_budget=1, slo_ttft_s=0.02)

FIXED = dict(decode_gap_p99_s=0.01, ttft_p99_s=0.02, goodput_rps=100.0,
             tokens_per_step=1.5, tokens_per_s_per_device=900.0,
             completed=16)


def _section(fixed_over=None, **over):
    fixed = dict(FIXED, **(fixed_over or {}))
    sec = {**SCEN, "fixed": fixed}
    sec.update(over)
    return sec


def _write(tmp_path, name, doc):
    p = tmp_path / name
    p.write_text(json.dumps(doc))
    return str(p)


# ---------------------------------------------------------------------------
# section discovery + scenario identity
# ---------------------------------------------------------------------------


def test_find_traffic_section_both_layouts():
    sec = _section()
    assert find_traffic_section({"traffic": sec}) is sec  # serving_bench
    assert find_traffic_section(sec) is sec               # bare section
    assert find_traffic_section({"other": 1}) is None


def test_scenario_mismatch_lists_differing_keys():
    a, b = _section(), _section(seed=7, rate_rps=10.0)
    mism = scenario_mismatches(a, b)
    assert len(mism) == 2
    assert any(m.startswith("seed:") for m in mism)
    assert scenario_mismatches(a, _section()) == []
    assert set(SCEN) == set(SCENARIO_KEYS)  # test doc covers every key


# ---------------------------------------------------------------------------
# compare() verdicts
# ---------------------------------------------------------------------------


def test_identical_runs_have_no_regressions():
    lines, regs = compare(_section(), _section())
    assert regs == []
    assert len([ln for ln in lines if "-> ok" in ln]) == len(GATED_METRICS)


def test_bad_direction_drift_is_regression():
    # gap p99 +10% (lower is better) and goodput -10% (higher is better)
    cur = _section(fixed_over=dict(decode_gap_p99_s=0.011,
                                   goodput_rps=90.0))
    lines, regs = compare(cur, _section())
    assert {r[0] for r in regs} == {"decode_gap_p99_s", "goodput_rps"}
    assert sum("REGRESSION" in ln for ln in lines) == 2


def test_good_direction_drift_never_flags():
    cur = _section(fixed_over=dict(decode_gap_p99_s=0.001,
                                   ttft_p99_s=0.001, goodput_rps=500.0,
                                   tokens_per_step=3.0,
                                   tokens_per_s_per_device=2000.0,
                                   completed=17))
    _, regs = compare(cur, _section())
    assert regs == []


def test_rel_tol_is_the_boundary():
    cur = _section(fixed_over=dict(decode_gap_p99_s=0.01 * 1.04))
    assert compare(cur, _section(), rel_tol=DEFAULT_REL_TOL)[1] == []
    assert compare(cur, _section(), rel_tol=0.01)[1] != []


def test_missing_metric_is_a_regression():
    cur = _section()
    del cur["fixed"]["tokens_per_step"]
    _, regs = compare(cur, _section())
    assert regs == [("tokens_per_step", FIXED["tokens_per_step"],
                     None, "missing")]


def test_zero_baseline_tolerates_absolute_slack_only():
    base = _section(fixed_over=dict(decode_gap_p99_s=0.0))
    cur = _section(fixed_over=dict(decode_gap_p99_s=1e-12))
    assert compare(cur, base)[1] == []  # inside the 1e-9 absolute slack
    cur = _section(fixed_over=dict(decode_gap_p99_s=0.5))
    assert compare(cur, base)[1] != []


def test_profile_drift_is_informational_only():
    prof = {"phases": {"decode": {"self_s": 0.03}}}
    cur = _section(profile={"phases": {"decode": {"self_s": 0.06}}})
    lines, regs = compare(cur, _section(profile=prof))
    assert regs == []  # profile drift informs, never gates
    assert any("[info] decode_self_s" in ln and "+100.00%" in ln
               for ln in lines)


# ---------------------------------------------------------------------------
# CLI exit codes
# ---------------------------------------------------------------------------


def test_cli_exit_codes(tmp_path, capsys):
    base = _write(tmp_path, "base.json", {"traffic": _section()})
    same = _write(tmp_path, "same.json", {"traffic": _section()})
    worse = _write(tmp_path, "worse.json", {"traffic": _section(
        fixed_over=dict(ttft_p99_s=0.2))})
    other = _write(tmp_path, "other.json", {"traffic": _section(seed=9)})
    empty = _write(tmp_path, "empty.json", {"ratio": 8})

    assert main([same, "--baseline", base]) == 0
    assert main([worse, "--baseline", base]) == 1
    assert main([other, "--baseline", base]) == 2   # scenario mismatch
    assert main([empty, "--baseline", base]) == 2   # no traffic section
    assert main([str(tmp_path / "nope.json"), "--baseline", base]) == 2
    # verdicts are deterministic: same files, same verdict
    assert main([worse, "--baseline", base]) == 1
    out = capsys.readouterr().out
    assert "REGRESSION" in out and "within tolerance" in out
