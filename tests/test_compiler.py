"""Online prefix compiler tests: chunked-compress parity (jnp +
pallas-interpret), online == offline serving (token-exact, attn/MLA/
hybrid, dense + paged), single-flight dedup, decode/compile
interleaving, and mid-compile LRU eviction pressure."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import memcom
from repro.models import transformer as tfm
from repro.serving import (
    PrefixCompiler,
    Request,
    ServingEngine,
    materialize_prefix,
)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("smollm-135m")
    params = tfm.init_params(cfg, 0)
    mc = memcom.init_memcom(cfg, params, 1)
    return cfg, params, mc


def _assert_tree_close(a, b, atol):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=atol, rtol=atol)


# ---------------------------------------------------------------------------
# Chunked compress parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_chunked_compress_parity(setup, rng, impl):
    """compress in 16-token slices (Source-LLM cache carried across
    chunks) == one-shot compress, on the streaming-jnp and
    pallas-interpret backends."""
    cfg, params, mc = setup
    src = jnp.asarray(rng.integers(4, cfg.vocab_size, (2, 48)), jnp.int32)
    one, _ = memcom.compress(mc, cfg, src, impl=impl)
    chk, _ = memcom.compress_chunked(mc, cfg, src, chunk_size=16, impl=impl)
    _assert_tree_close(one, chk, 1e-4)


@pytest.mark.parametrize("arch", ["deepseek-v2-236b", "jamba-1.5-large-398b"])
def test_chunked_compress_parity_families(arch, rng):
    """MLA latent caches and hybrid SSM state survive chunk boundaries:
    the recurrence/latents carried across chunks land on the one-shot
    result — with a ragged final chunk (40 = 16 + 16 + 8).

    The MoE layers of the MLA config are swapped for dense MLPs here:
    top-k expert routing amplifies 1e-7 attention-order noise into a
    discontinuous 3e-3 jump whenever a router score sits at a tie, which
    measures the router's chaos, not chunking (the end-to-end greedy
    serving test below keeps the stock MoE config).
    """
    import dataclasses

    cfg = get_smoke_config(arch)
    layout = dataclasses.replace(
        cfg.layout,
        prefix=tuple(dataclasses.replace(d, mlp="dense")
                     if d.mlp == "moe" else d for d in cfg.layout.prefix),
        period=tuple(dataclasses.replace(d, mlp="dense")
                     if d.mlp == "moe" else d for d in cfg.layout.period))
    cfg = cfg.replace(layout=layout)
    params = tfm.init_params(cfg, 0)
    mc = memcom.init_memcom(cfg, params, 1)
    src = jnp.asarray(rng.integers(4, cfg.vocab_size, (1, 40)), jnp.int32)
    one, _ = memcom.compress(mc, cfg, src)
    chk, _ = memcom.compress_chunked(mc, cfg, src, chunk_size=16)
    _assert_tree_close(one, chk, 1e-4)


# ---------------------------------------------------------------------------
# Online serving == offline serving
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["smollm-135m", "deepseek-v2-236b",
                                  "jamba-1.5-large-398b"])
@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_online_compile_matches_offline(arch, layout, rng):
    """A raw_shots request (compile on the serving path, chunked) emits
    exactly the tokens of the offline compress → materialize →
    add_prefix path, per family and KV layout."""
    cfg = get_smoke_config(arch)
    params = tfm.init_params(cfg, 0)
    mc = memcom.init_memcom(cfg, params, 1)
    m = cfg.memcom.num_memory_tokens
    shots = rng.integers(4, cfg.vocab_size, 40).astype(np.int32)
    prompt = rng.integers(4, cfg.vocab_size, 5).astype(np.int32)

    offline = ServingEngine(cfg, params, slots=1, max_len=m + 24,
                            kv_layout=layout)
    kv = materialize_prefix(params, cfg,
                            memcom.compress(mc, cfg, jnp.asarray(shots[None]))[0])
    offline.add_prefix("task", kv)
    want = offline.serve([Request(tokens=prompt, max_new=5, prefix="task")])

    online = ServingEngine(cfg, params, slots=1, max_len=m + 24,
                           kv_layout=layout, compressor=mc,
                           compile_token_budget=16)
    req = Request(tokens=prompt, max_new=5, prefix="task", raw_shots=shots)
    got = online.serve([req])
    np.testing.assert_array_equal(got[req.uid], next(iter(want.values())))
    assert online.stats()["compiler"]["compiled"] == 1


# ---------------------------------------------------------------------------
# Single-flight dedup
# ---------------------------------------------------------------------------


def test_single_flight_dedup(setup, rng):
    """Two requests waiting on one (content-addressed) task trigger one
    compilation and one store entry; both outputs match the offline
    reference."""
    cfg, params, mc = setup
    m = cfg.memcom.num_memory_tokens
    shots = rng.integers(4, cfg.vocab_size, 40).astype(np.int32)
    prompt = rng.integers(4, cfg.vocab_size, 6).astype(np.int32)

    eng = ServingEngine(cfg, params, slots=2, max_len=m + 24,
                        compressor=mc, compile_token_budget=16)
    r1 = Request(tokens=prompt, max_new=4, raw_shots=shots)
    r2 = Request(tokens=prompt, max_new=4, raw_shots=shots.copy())
    assert r1.prefix == r2.prefix  # same bytes -> same auto name
    out = eng.serve([r1, r2])

    stats = eng.stats()
    assert stats["compiler"]["jobs"] == 1
    assert stats["compiler"]["deduped"] == 1
    assert stats["prefix_store"]["puts"] == 1

    kv = materialize_prefix(params, cfg,
                            memcom.compress(mc, cfg, jnp.asarray(shots[None]))[0])
    solo = ServingEngine(cfg, params, slots=1, max_len=m + 24)
    solo.add_prefix("ref", kv)
    want = solo.serve([Request(tokens=prompt, max_new=4, prefix="ref")])
    want = next(iter(want.values()))
    np.testing.assert_array_equal(out[r1.uid], want)
    np.testing.assert_array_equal(out[r2.uid], want)


def test_compiler_unit_budget_and_states(setup):
    """PrefixCompiler alone: budget-bounded chunking, job state
    transitions, single-flight joins, install bookkeeping."""
    cfg, params, mc = setup
    comp = PrefixCompiler(mc, cfg, params)
    toks = np.arange(4, 44, dtype=np.int32)
    job = comp.submit("t", toks)
    assert job.status == "queued" and comp.pending()
    assert comp.submit("t", toks) is job  # joined, not restarted
    assert comp.stats["deduped"] == 1

    assert comp.step(16) == []  # 16 of 40 tokens
    assert job.status == "compiling" and job.consumed == 16
    assert comp.step(None) == ["t"]  # run to completion
    assert job.status == "compiled" and job.remaining == 0
    assert comp.ready() == ["t"] and job.materialized is not None
    comp.mark_installed("t")
    assert job.status == "installed" and not comp.pending()
    # resubmit after install = recompile (the store evicted it)
    assert comp.submit("t", toks) is not job


# ---------------------------------------------------------------------------
# Decode keeps stepping during a compile (the tentpole's acceptance)
# ---------------------------------------------------------------------------


def test_decode_continues_during_compile(setup, rng):
    """With compile_token_budget set, a seated slot keeps emitting tokens
    while a cold task compiles: decode steps land *between* compile
    chunks, and the warm request's output is byte-identical to a serve
    with no compile in flight."""
    cfg, params, mc = setup
    m = cfg.memcom.num_memory_tokens
    shots_a = rng.integers(4, cfg.vocab_size, 40).astype(np.int32)
    shots_b = rng.integers(4, cfg.vocab_size, 48).astype(np.int32)
    prompt = rng.integers(4, cfg.vocab_size, 5).astype(np.int32)
    kv_a = materialize_prefix(
        params, cfg, memcom.compress(mc, cfg, jnp.asarray(shots_a[None]))[0])

    eng = ServingEngine(cfg, params, slots=2, max_len=m + 40,
                        compressor=mc, compile_token_budget=8)
    eng.add_prefix("A", kv_a)
    warm = Request(tokens=prompt, max_new=20, prefix="A")
    cold = Request(tokens=prompt, max_new=3, raw_shots=shots_b)
    out = eng.serve([warm, cold])

    compile_idx = [i for i, e in enumerate(eng.trace) if e[0] == "compile"]
    decode_between = [i for i, e in enumerate(eng.trace)
                      if e[0] == "decode" and compile_idx[0] < i < compile_idx[-1]]
    assert len(compile_idx) >= 3, eng.trace  # 48 tokens / 8-token budget
    assert decode_between, eng.trace  # decode interleaved with compilation
    assert eng.stats()["engine"]["decode_steps_during_compile"] >= 3

    solo = ServingEngine(cfg, params, slots=1, max_len=m + 40)
    solo.add_prefix("A", kv_a)
    want = solo.serve([Request(tokens=prompt, max_new=20, prefix="A")])
    np.testing.assert_array_equal(out[warm.uid], next(iter(want.values())))


# ---------------------------------------------------------------------------
# Mid-compile LRU eviction pressure (paged)
# ---------------------------------------------------------------------------


def test_mid_compile_lru_eviction_pressure(setup, rng):
    """prefix_capacity=1: task B compiles while task A (the sole resident
    prefix) is seated and decoding.  B's install is deferred — evicting A
    under a live slot would raise PrefixSeatedError — until A's request
    finishes; then A is evicted, B seats, and B's waiter completes with
    the exact offline output."""
    cfg, params, mc = setup
    m = cfg.memcom.num_memory_tokens
    shots_a = rng.integers(4, cfg.vocab_size, 40).astype(np.int32)
    shots_b = rng.integers(4, cfg.vocab_size, 40).astype(np.int32)
    prompt = rng.integers(4, cfg.vocab_size, 5).astype(np.int32)
    kv_a = materialize_prefix(
        params, cfg, memcom.compress(mc, cfg, jnp.asarray(shots_a[None]))[0])

    eng = ServingEngine(cfg, params, slots=1, max_len=m + 24,
                        kv_layout="paged", prefix_capacity=1,
                        compressor=mc, compile_token_budget=8)
    eng.add_prefix("A", kv_a)
    ra = Request(tokens=prompt, max_new=10, prefix="A")
    rb = Request(tokens=prompt, max_new=4, prefix="B", raw_shots=shots_b)
    out = eng.serve([ra, rb])

    stats = eng.stats()
    assert stats["prefix_store"]["evictions"] >= 1  # A made way for B
    assert "B" in eng.store and "A" not in eng.store
    # B compiled while A was decoding (not after)
    assert stats["engine"]["decode_steps_during_compile"] >= 2

    kv_b = materialize_prefix(
        params, cfg, memcom.compress(mc, cfg, jnp.asarray(shots_b[None]))[0])
    solo = ServingEngine(cfg, params, slots=1, max_len=m + 24,
                         kv_layout="paged")
    solo.add_prefix("B", kv_b)
    want = solo.serve([Request(tokens=prompt, max_new=4, prefix="B")])
    np.testing.assert_array_equal(out[rb.uid], next(iter(want.values())))


# ---------------------------------------------------------------------------
# Counters
# ---------------------------------------------------------------------------


def test_pin_does_not_outlive_install(setup, rng):
    """The LRU pin protecting a waiting request's prefix is scoped to the
    install itself: after serve() returns, add_prefix can evict the (now
    unseated, unreferenced) prefix instead of raising PrefixSeatedError."""
    cfg, params, mc = setup
    m = cfg.memcom.num_memory_tokens
    shots = rng.integers(4, cfg.vocab_size, 40).astype(np.int32)
    prompt = rng.integers(4, cfg.vocab_size, 5).astype(np.int32)
    eng = ServingEngine(cfg, params, slots=1, max_len=m + 24,
                        kv_layout="paged", prefix_capacity=1,
                        compressor=mc)
    eng.serve([Request(tokens=prompt, max_new=2, raw_shots=shots)])
    eng.serve([Request(tokens=prompt, max_new=2)])  # unseats the slot
    kv = materialize_prefix(params, cfg,
                            memcom.compress(mc, cfg, jnp.asarray(shots[None]))[0])
    eng.add_prefix("C", kv)  # must LRU-evict, not raise
    assert "C" in eng.store and len(eng.store) == 1


def test_raw_shots_without_compressor_raises(setup, rng):
    cfg, params, _ = setup
    eng = ServingEngine(cfg, params, slots=1, max_len=32)
    req = Request(tokens=[5], max_new=1,
                  raw_shots=rng.integers(4, cfg.vocab_size, 8))
    with pytest.raises(ValueError, match="compressor"):
        eng.serve([req])


def test_store_counters_via_stats(setup, rng):
    """hit/miss/put counters flow from the store through
    ServingEngine.stats(); a resident prefix counts a hit, a raw-shots
    cold task a miss."""
    cfg, params, mc = setup
    m = cfg.memcom.num_memory_tokens
    shots = rng.integers(4, cfg.vocab_size, 40).astype(np.int32)
    prompt = rng.integers(4, cfg.vocab_size, 5).astype(np.int32)
    eng = ServingEngine(cfg, params, slots=1, max_len=m + 24,
                        compressor=mc)
    cold = Request(tokens=prompt, max_new=2, raw_shots=shots)
    eng.serve([cold])
    warm = Request(tokens=prompt, max_new=2, prefix=cold.prefix)
    eng.serve([warm])
    s = eng.stats()["prefix_store"]
    assert s["misses"] == 1 and s["hits"] == 1 and s["puts"] == 1
    e = eng.stats()["engine"]
    assert e["prefills"] == 2 and e["tokens_generated"] >= 2
