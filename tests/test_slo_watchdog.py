"""SLO burn-rate watchdog tests: hand-computed burn arithmetic over
explicit timestamps, the two-window fire condition, clear hysteresis
(including the silent-window clear), the ShedDegrade hook against a fake
engine, alert-log schema validation, and — on the real engine under the
churn scenario — byte-identical alert logs across two runs of one
(scenario, seed)."""

import json

import pytest

from repro.configs import get_smoke_config
from repro.core import memcom
from repro.models import transformer as tfm
from repro.serving import (
    BurnRateRule,
    MetricsRegistry,
    ServingEngine,
    ShedDegrade,
    SLOWatchdog,
    Tracer,
    TrafficConfig,
    VirtualClock,
    default_rules,
    generate_trace,
    validate_alert_log,
)


def _rule(**kw):
    base = dict(name="lat", metric="ttft", threshold=1.0, budget=0.5,
                fast_window_s=2.0, slow_window_s=10.0,
                fire_burn=1.0, clear_burn=0.5, severity="page")
    base.update(kw)
    return BurnRateRule(**base)


# ---------------------------------------------------------------------------
# burn arithmetic, hand-computed
# ---------------------------------------------------------------------------


def test_fire_with_hand_computed_burns():
    wd = SLOWatchdog([_rule()])
    wd.observe("ttft", 2.0, t=1.0)   # violates (> 1.0)
    wd.observe("ttft", 0.5, t=1.5)   # ok
    # fast [0, 2]: 1 bad of 2 -> frac 0.5 / budget 0.5 = burn 1.0
    # slow [-8, 2]: same two samples -> burn 1.0; both >= fire_burn
    events = wd.step(now=2.0)
    assert [e["kind"] for e in events] == ["fire"]
    assert events[0]["burn_fast"] == pytest.approx(1.0)
    assert events[0]["burn_slow"] == pytest.approx(1.0)
    assert events[0]["rule"] == "lat" and events[0]["severity"] == "page"
    assert wd.firing("lat") and wd.page_active


def test_clear_hysteresis_needs_burn_at_or_below_clear():
    wd = SLOWatchdog([_rule()])
    wd.observe("ttft", 2.0, t=1.0)
    wd.observe("ttft", 0.5, t=1.5)
    assert wd.step(now=2.0)[0]["kind"] == "fire"
    # burn still 1.0 > clear_burn 0.5 in [0.5, 2.5]: no clear yet
    assert wd.step(now=2.5) == []
    # three good samples push the bad one out of the fast window:
    # fast [1.5, 3.5] holds 4 samples, 0 bad -> burn 0.0 <= 0.5
    for t in (3.0, 3.2, 3.4):
        wd.observe("ttft", 0.5, t=t)
    events = wd.step(now=3.5)
    assert [e["kind"] for e in events] == ["clear"]
    assert events[0]["burn_fast"] == pytest.approx(0.0)
    assert not wd.firing("lat") and not wd.page_active


def test_silent_fast_window_clears_but_never_fires():
    wd = SLOWatchdog([_rule()])
    # an empty window is not evidence either way: no samples, no fire
    assert wd.step(now=1.0) == []
    wd.observe("ttft", 2.0, t=1.0)
    wd.observe("ttft", 2.0, t=1.5)
    assert wd.step(now=2.0)[0]["kind"] == "fire"
    # far future: fast window empty -> clear with burn_fast None
    events = wd.step(now=100.0)
    assert [e["kind"] for e in events] == ["clear"]
    assert events[0]["burn_fast"] is None


def test_fire_requires_both_windows_hot():
    wd = SLOWatchdog([_rule()])
    # seven good samples age into the slow window only
    for i in range(7):
        wd.observe("ttft", 0.5, t=1.0 + i)
    wd.observe("ttft", 2.0, t=9.0)
    wd.observe("ttft", 2.0, t=9.5)
    # fast [8, 10]: 2/2 bad -> burn 4.0; slow [0, 10]: 2/9 bad ->
    # (2/9)/0.5 = 0.444 < fire_burn -> the blip filter holds
    assert wd.step(now=10.0) == []
    assert not wd.firing("lat")


def test_lt_op_fires_on_throughput_floor():
    wd = SLOWatchdog([_rule(name="floor", metric="tokens_per_step",
                            threshold=0.5, op="lt", severity="ticket")])
    wd.observe("tokens_per_step", 0.2, t=1.0)
    wd.observe("tokens_per_step", 0.1, t=1.5)
    events = wd.step(now=2.0)
    assert [e["kind"] for e in events] == ["fire"]
    assert events[0]["severity"] == "ticket"
    assert not wd.page_active  # ticket severity never pages


def test_unwatched_metric_is_dropped():
    wd = SLOWatchdog([_rule()])
    wd.observe("decode_gap", 99.0, t=1.0)  # no rule watches this signal
    assert wd._samples == {}
    assert wd.step(now=2.0) == []


def test_rule_validation():
    with pytest.raises(ValueError):
        _rule(budget=0.0)
    with pytest.raises(ValueError):
        _rule(fast_window_s=5.0, slow_window_s=1.0)
    with pytest.raises(ValueError):
        _rule(severity="sev1")
    with pytest.raises(ValueError):
        _rule(op="ge")
    with pytest.raises(ValueError):
        _rule(clear_burn=2.0, fire_burn=1.0)
    with pytest.raises(ValueError):
        SLOWatchdog([_rule(), _rule()])  # duplicate names
    with pytest.raises(ValueError):
        SLOWatchdog([_rule()]).now()  # no clock, no explicit t


# ---------------------------------------------------------------------------
# emission: counters, tracer instants, the alert log
# ---------------------------------------------------------------------------


def _fire_once(wd):
    wd.observe("ttft", 2.0, t=1.0)
    wd.observe("ttft", 2.0, t=1.5)
    return wd.step(now=2.0)


def test_alert_counter_renders_before_and_after_fire():
    reg = MetricsRegistry()
    wd = SLOWatchdog([_rule()], metrics=reg)
    # eagerly registered: scrapeable before any alert
    assert "serving_alerts_total" in reg.render_prometheus()
    _fire_once(wd)
    assert ('serving_alerts_total{rule="lat",severity="page"} 1'
            in reg.render_prometheus())


def test_tracer_gets_alert_instants():
    tr = Tracer(clock=lambda: 0.0)
    wd = SLOWatchdog([_rule()], tracer=tr)
    _fire_once(wd)
    wd.step(now=100.0)
    names = [e["name"] for e in tr.events()]
    assert names == ["alert_fire:lat", "alert_clear:lat"]
    assert tr.events()[0]["track"] == "watchdog"


def test_report_roundtrip_and_determinism():
    def run():
        wd = SLOWatchdog([_rule()])
        _fire_once(wd)
        wd.step(now=100.0)
        return wd
    a, b = run(), run()
    assert a.dumps() == b.dumps()  # byte-identical serialization
    doc = json.loads(a.dumps())
    assert validate_alert_log(doc) == []
    assert doc["fires"] == 1 and doc["clears"] == 1


def test_validate_alert_log_catches_malformed():
    wd = SLOWatchdog([_rule()])
    _fire_once(wd)
    good = wd.report()
    bad = json.loads(json.dumps(good))
    bad["events"][0]["kind"] = "oops"
    assert any("bad kind" in e for e in validate_alert_log(bad))
    bad = json.loads(json.dumps(good))
    bad["events"].append(dict(bad["events"][0]))  # double fire
    assert any("double fire" in e for e in validate_alert_log(bad))
    bad = json.loads(json.dumps(good))
    bad["events"].append(dict(bad["events"][0], kind="clear", t=0.0))
    assert any("not monotonic" in e for e in validate_alert_log(bad))
    bad = json.loads(json.dumps(good))
    bad["events"][0]["kind"] = "clear"
    assert any("clear without fire" in e for e in validate_alert_log(bad))
    bad = json.loads(json.dumps(good))
    bad["fires"] = 7
    assert any("fires count" in e for e in validate_alert_log(bad))
    bad = json.loads(json.dumps(good))
    bad["events"][0]["rule"] = "mystery"
    assert any("unknown rule" in e for e in validate_alert_log(bad))


# ---------------------------------------------------------------------------
# degradation hook
# ---------------------------------------------------------------------------


class _FakeEngine:
    def __init__(self):
        self.shed_floor = None
        self.degrade_hint = False
        self.metrics = MetricsRegistry()


def test_shed_degrade_on_page_fire_and_clear():
    eng = _FakeEngine()
    wd = SLOWatchdog([_rule()], degrade_hook=ShedDegrade())
    wd.attach_engine(eng)
    _fire_once(wd)
    assert eng.shed_floor == 1 and eng.degrade_hint is True
    snap = eng.metrics.snapshot()
    assert snap["serving_degradations_total"]["series"]["action=shed"] == 1
    wd.step(now=100.0)  # silent window -> clear -> restore
    assert eng.shed_floor is None and eng.degrade_hint is False
    snap = eng.metrics.snapshot()
    assert snap["serving_degradations_total"]["series"]["action=restore"] == 1


def test_ticket_alert_never_sheds():
    eng = _FakeEngine()
    wd = SLOWatchdog([_rule(severity="ticket")],
                     degrade_hook=ShedDegrade())
    wd.attach_engine(eng)
    _fire_once(wd)
    assert eng.shed_floor is None and eng.degrade_hint is False


def test_shed_persists_until_last_page_clears():
    rules = [_rule(name="a"), _rule(name="b", fast_window_s=1.0)]
    eng = _FakeEngine()
    wd = SLOWatchdog(rules, degrade_hook=ShedDegrade())
    wd.attach_engine(eng)
    wd.observe("ttft", 2.0, t=1.0)
    wd.observe("ttft", 2.0, t=1.5)
    wd.step(now=2.0)  # both fire
    assert wd.firing("a") and wd.firing("b") and eng.shed_floor == 1
    # b's 1s fast window empties first: one page still active -> no undo
    wd.step(now=3.1)
    assert not wd.firing("b") and wd.firing("a")
    assert eng.shed_floor == 1
    wd.step(now=100.0)
    assert not wd.page_active and eng.shed_floor is None


# ---------------------------------------------------------------------------
# on the real engine: alert log is a pure function of (scenario, seed)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("smollm-135m")
    params = tfm.init_params(cfg, 0)
    mc = memcom.init_memcom(cfg, params, 1)
    return cfg, params, mc


def _churn_with_watchdog(cfg, params, mc, disk_dir):
    """The test_traffic churn scenario with an SLO set so tight that the
    watchdog must fire: every TTFT violates a 0.5 ms SLO."""
    m = cfg.memcom.num_memory_tokens
    trace = generate_trace(
        TrafficConfig(num_tasks=5, num_requests=12, context_tokens=24,
                      rate_rps=300.0, priority_classes=2), seed=0)
    wd = SLOWatchdog(default_rules(slo_ttft_s=0.0005, slo_gap_s=0.0005),
                     metrics=MetricsRegistry(),
                     degrade_hook=ShedDegrade())
    eng = ServingEngine(
        cfg, params, slots=2, max_len=m + 32, compressor=mc,
        compile_token_budget=8, prefix_capacity=2,
        host_capacity=2, disk_dir=str(disk_dir),
        promote_layer_budget=1, clock=VirtualClock(),
        priority_aging_s=0.05, watchdog=wd)
    out = eng.serve(list(trace.requests))
    tokens = [list(out[r.uid]) for r in trace.requests]
    return wd, eng, tokens


def test_engine_alert_log_deterministic_and_fires(setup, tmp_path):
    cfg, params, mc = setup
    wd1, eng1, tok1 = _churn_with_watchdog(cfg, params, mc,
                                           tmp_path / "a")
    wd2, eng2, tok2 = _churn_with_watchdog(cfg, params, mc,
                                           tmp_path / "b")
    assert wd1.report()["fires"] > 0, "tight SLO produced no alerts"
    assert wd1.dumps() == wd2.dumps()  # byte-identical alert sequences
    assert validate_alert_log(wd1.report()) == []
    assert tok1 == tok2
    # the paging TTFT rule fired, so the degradation hook acted
    assert wd1._alerts_total is not None
    assert "serving_degradations_total" in eng1.metrics.snapshot()
    # engine completed every request even while shedding admissions
    assert len(tok1) == 12 and all(len(t) > 0 for t in tok1)
