"""MoE dispatch tests: dense-reference equivalence at lossless capacity,
group-local == global dispatch, capacity-drop accounting, shared experts."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import LayerDesc, LayerLayout, MoEConfig, ModelConfig
from repro.models.moe import apply_moe, init_moe, _capacity
from repro.models.param import ParamBuilder
from repro.utils.rng import Keys


def _cfg(E=8, k=2, cf=1.25, groups=1, shared=0):
    return ModelConfig(
        name="moe-test", family="moe",
        layout=LayerLayout.uniform(LayerDesc("attn", "moe"), 1),
        d_model=32, num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64,
        moe=MoEConfig(num_experts=E, top_k=k, expert_d_ff=64,
                      capacity_factor=cf, dispatch_groups=groups,
                      num_shared_experts=shared, shared_d_ff=64),
        dtype="float32")


def _params(cfg, seed=0):
    b = ParamBuilder(Keys(seed), jnp.float32)
    init_moe(b, cfg)
    params, _ = b.build()
    return params["moe"]


def _dense_reference(p, cfg, x):
    """Every token through its top-k experts, no capacity limit."""
    m = cfg.moe
    B, S, D = x.shape
    xf = x.reshape(-1, D)
    logits = (xf @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gates, ids = jax.lax.top_k(probs, m.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    # run every expert on every token, then select
    h = jnp.einsum("nd,edf->nef", xf, p["wg"])
    h = jax.nn.silu(h) * jnp.einsum("nd,edf->nef", xf, p["wi"])
    y_all = jnp.einsum("nef,efd->ned", h, p["wo"])  # (N, E, D)
    y = jnp.take_along_axis(y_all, ids[..., None], axis=1)  # (N, k, D)
    return (y * gates[..., None]).sum(1).reshape(B, S, D)


def test_moe_matches_dense_at_lossless_capacity(rng):
    cfg = _cfg(E=8, k=2, cf=8.0 / 2.0)  # C >= N·k/E·(E/k) = N: no drops
    p = _params(cfg)
    x = jnp.asarray(rng.standard_normal((2, 16, 32)) * 0.5, jnp.float32)
    y, aux = apply_moe(p, cfg, x)
    y_ref = _dense_reference(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-5, rtol=1e-5)
    assert float(aux) >= 0


@pytest.mark.parametrize("groups", [2, 4])
def test_grouped_dispatch_matches_global(rng, groups):
    """At lossless capacity, G-group dispatch == global dispatch exactly
    (same tokens reach the same experts; only the sort is local)."""
    cfg1 = _cfg(E=8, k=2, cf=4.0)
    cfgG = _cfg(E=8, k=2, cf=4.0, groups=groups)
    p = _params(cfg1)
    x = jnp.asarray(rng.standard_normal((2, 16, 32)) * 0.5, jnp.float32)
    y1, a1 = apply_moe(p, cfg1, x)
    yG, aG = apply_moe(p, cfgG, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(yG),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(float(a1), float(aG), rtol=1e-6)


def test_capacity_drops_zero_dropped_tokens(rng):
    """At capacity_factor→0 every token is dropped: output = 0 (plus
    shared expert if any) — the drop path must not corrupt outputs."""
    cfg = _cfg(E=8, k=2, cf=1e-9)
    p = _params(cfg)
    x = jnp.asarray(rng.standard_normal((1, 4, 32)), jnp.float32)
    # capacity rounds up to 8, so shrink further: N=4 tokens, C=8 means
    # nothing actually drops here — use many tokens instead
    x_big = jnp.asarray(rng.standard_normal((1, 512, 32)), jnp.float32)
    y, _ = apply_moe(p, cfg, x_big)
    # C=8 slots per expert × 8 experts = 64 of 1024 assignments survive
    kept_rows = (np.abs(np.asarray(y)).sum(-1) > 0).sum()
    assert kept_rows <= 64 * 2  # each kept assignment affects ≤1 token/expert


def test_shared_expert_always_on(rng):
    cfg = _cfg(E=4, k=1, cf=1e-9, shared=1)
    p = _params(cfg)
    x = jnp.asarray(rng.standard_normal((1, 256, 32)), jnp.float32)
    y, _ = apply_moe(p, cfg, x)
    # even fully-dropped tokens get the shared-expert path
    assert (np.abs(np.asarray(y)).sum(-1) > 0).all()


def test_capacity_formula():
    m = MoEConfig(num_experts=16, top_k=2, expert_d_ff=64,
                  capacity_factor=1.25)
    C = _capacity(m, 1024)
    assert C == 160  # 1.25 * 1024 * 2 / 16 = 160 (already 8-aligned)
    assert _capacity(m, 10) == 8  # floor
