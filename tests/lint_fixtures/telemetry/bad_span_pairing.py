"""Fixture: every way to get async span pairing wrong (span-pairing fires).

A begin with no end anywhere in the module, an end with no begin, an
early return that skips the same-function end, a dynamic span name, and
a name outside the REQUIRED_SPANS taxonomy.
"""


def park_forever(tracer, req, aid):
    # no end_async("waiting_on_prefix") anywhere in this module
    tracer.begin_async("scheduler", "waiting_on_prefix", aid,
                       prefix=req.prefix)


def orphan_end(tracer, aid):
    # no begin_async("promote_chunk") anywhere in this module
    tracer.end_async("promoter", "promote_chunk", aid)


def leaky_exit(tracer, job, aid):
    tracer.begin_async("compiler", "compile_chunk", aid)
    if job.cancelled:
        return None  # span still open on this path
    tracer.end_async("compiler", "compile_chunk", aid)
    return job.result()


def dynamic_name(tracer, name, aid):
    tracer.begin_async("engine", name, aid)  # not statically checkable


def off_taxonomy(tracer, aid):
    tracer.begin_async("engine", "mystery_phase", aid)
    tracer.end_async("engine", "mystery_phase", aid)
