"""Fixture: disciplined async span pairing (span-pairing stays quiet).

Same-function pairing keeps the end on every exit path (the end lives
in a ``finally``), and the cross-function park/wake pair is legal
because the module contains both sides of the name.
"""


def guarded_wait(tracer, clock, job, aid):
    t0 = clock()
    tracer.begin_async("scheduler", "waiting_on_prefix", aid, t=t0)
    try:
        if job.cancelled:
            return None
        return job.result()
    finally:
        tracer.end_async("scheduler", "waiting_on_prefix", aid)


def straight_line(tracer, job, aid):
    tracer.begin_async("compiler", "compile_chunk", aid)
    result = job.result()
    tracer.end_async("compiler", "compile_chunk", aid)
    return result


def park(tracer, req, aid):
    # begin here, matching end in wake() below: cross-function pairing
    # within one module is the engine's park/wake idiom
    tracer.begin_async("scheduler", "waiting_on_prefix", aid,
                       prefix=req.prefix)


def wake(tracer, req, aid):
    tracer.end_async("scheduler", "waiting_on_prefix", aid,
                     prefix=req.prefix)
