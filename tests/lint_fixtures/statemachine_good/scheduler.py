"""GOOD scheduler: every stage move is a literal legal edge."""

STAGES = ("new", "queued", "waiting_on_prefix", "running", "finished")

LEGAL_TRANSITIONS = {
    ("new", "queued"),
    ("new", "waiting_on_prefix"),
    ("waiting_on_prefix", "queued"),
    ("queued", "running"),
    ("running", "queued"),
    ("running", "finished"),
}


class Scheduler:
    def _transition(self, uid, src, dst):
        pass

    def submit(self, request):
        self._transition(request.uid, "new", "queued")

    def park(self, request):
        self._transition(request.uid, "new", "waiting_on_prefix")

    def wake(self, name):
        for req in self._waiting.pop(name, []):
            self._transition(req.uid, "waiting_on_prefix", "queued")

    def admit(self):
        req = self._queue.pop(0)
        self._transition(req.uid, "queued", "running")

    def preempt(self, slot):
        req = self._slots[slot]
        self._transition(req.uid, "running", "queued")

    def finish(self, slot):
        req = self._slots[slot]
        self._transition(req.uid, "running", "finished")
