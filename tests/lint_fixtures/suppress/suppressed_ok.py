"""Suppression with a named rule and a written reason -> clean."""
import time


def stamp():
    return time.time()  # reprolint: ignore[wall-clock] -- fixture: sanctioned example


def stamp_line_above():
    # reprolint: ignore[wall-clock] -- fixture: reason on the line above
    return time.monotonic()
