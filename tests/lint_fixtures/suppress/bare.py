"""Suppressions without a reason or without a rule id are themselves
findings (bare-suppression)."""
import time


def no_reason():
    return time.time()  # reprolint: ignore[wall-clock]


def no_rule():
    return time.monotonic()  # reprolint: ignore -- too lazy to name the rule
