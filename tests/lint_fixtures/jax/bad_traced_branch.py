"""BAD: python control flow on traced values inside jitted functions."""
import jax
import jax.numpy as jnp


@jax.jit
def relu_branch(x):
    if x > 0:
        return x
    return jnp.zeros_like(x)


def clipped(x, limit):
    while x < limit:
        x = x * 2
    return x


clipped_jit = jax.jit(clipped)


@jax.jit
def checked(x):
    assert x >= 0
    return jnp.sqrt(x)
