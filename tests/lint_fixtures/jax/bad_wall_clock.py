"""BAD: direct wall-clock reads -> wall-clock findings."""
import time
from datetime import datetime


def stamp():
    return time.time()


def tick():
    return time.perf_counter()


def today():
    return datetime.now()
