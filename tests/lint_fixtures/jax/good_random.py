"""GOOD: seeded generators threaded explicitly."""
import random

import numpy as np


def seeded_numpy(seed):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(4,))


def seeded_stdlib(seed):
    rng = random.Random(seed)
    return rng.randint(0, 10)
