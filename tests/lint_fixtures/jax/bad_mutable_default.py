"""BAD: mutable defaults shared across calls."""
import numpy as np


def accumulate(x, seen=[]):
    seen.append(x)
    return seen


def tally(x, counts={}):
    counts[x] = counts.get(x, 0) + 1
    return counts


def batch(x, buf=np.zeros(4)):
    return buf + x


def gather(x, *, out=list()):
    out.append(x)
    return out
