"""BAD: jax.jit tracing known-static config params."""
import jax


def run(cfg, x):
    return x * cfg.scale


step = jax.jit(run)


@jax.jit
def decode(config, tokens):
    return tokens[: config.window]
