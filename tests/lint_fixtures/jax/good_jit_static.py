"""GOOD: config params declared static."""
from functools import partial

import jax


def run(cfg, x):
    return x * cfg.scale


step = jax.jit(run, static_argnames=("cfg",))
step_by_num = jax.jit(run, static_argnums=(0,))


@partial(jax.jit, static_argnames=("config",))
def decode(config, tokens):
    return tokens[: config.window]
