"""GOOD: the sanctioned clock-injection pattern — time.perf_counter is
*referenced* as a default callable, never called here."""
import time


class Timed:
    def __init__(self, clock=None):
        self.clock = clock if clock is not None else time.perf_counter

    def stamp(self):
        return self.clock()
