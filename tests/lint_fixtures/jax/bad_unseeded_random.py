"""BAD: global-state / unseeded RNG -> unseeded-random findings."""
import random

import numpy as np


def legacy_numpy():
    np.random.seed(0)
    return np.random.rand(4)


def unseeded_generator():
    return np.random.default_rng()


def stdlib_global():
    return random.randint(0, 10)


def unseeded_instance():
    return random.Random()
