"""GOOD: trace-time python (shape/len/static args/None checks) and
lax.cond for value-dependent branches."""
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax


@jax.jit
def shape_branch(x):
    if x.shape[0] > 2:
        return x[:2]
    return x


@jax.jit
def len_branch(xs):
    if len(xs.shape) == 2:
        return xs.sum(-1)
    return xs


@partial(jax.jit, static_argnames=("causal",))
def masked(x, causal):
    if causal:
        return jnp.tril(x)
    return x


@jax.jit
def optional(x, bias=None):
    if bias is not None:
        x = x + bias
    return lax.cond(jnp.all(x > 0), lambda v: v, jnp.abs, x)
