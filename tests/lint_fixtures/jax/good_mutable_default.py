"""GOOD: None / immutable defaults, containers built in the body."""


def accumulate(x, seen=None):
    seen = [] if seen is None else seen
    seen.append(x)
    return seen


def masked(x, axes=(0, 1)):
    return x, axes


def tagged(x, tags=frozenset()):
    return x, tags
