"""BAD: block acquisitions leaking on return / exception edges."""


class Pool:
    def leak_on_return(self, n):
        blocks = self.alloc.alloc(n)
        if n > 4:
            return None
        self._tables[0] = blocks

    def leak_on_exception_edge(self, store, name, entry, n):
        blocks = self.alloc.alloc(n)
        store.put(name, entry)
        self._tables[0] = blocks

    def discarded(self):
        self.alloc.alloc(2)
