"""GOOD: one np.asarray sync per batch, async step results."""
import numpy as np


class Engine:
    def step(self, tokens):
        logits = self._decode(tokens)
        return np.asarray(logits)

    def scale(self, x):
        return float(x) + int(2)
