"""GOOD: every acquisition is released or transferred on all paths."""


class Pool:
    def seat(self, name, n):
        blocks = self.alloc.alloc(n)
        self._tables[name] = blocks

    def seat_shared(self, blocks):
        for b in blocks:
            self.alloc.incref(b)
        self._slots.append(blocks)

    def scoped(self, n):
        blocks = self.alloc.alloc(n)
        try:
            return self._score(blocks)
        finally:
            for b in blocks:
                self.alloc.decref(b)

    def raiser_after_release(self, store, name, entry, n):
        blocks = self.alloc.alloc(n)
        for b in blocks:
            self.alloc.decref(b)
        store.put(name, entry)
