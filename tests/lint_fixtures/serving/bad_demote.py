"""BAD: demote hook fired without the seated guard."""


class Store:
    def evict(self, name):
        entry = self._entries.pop(name)
        if self.demote_hook is not None:
            self.demote_hook(name, entry)
