"""GOOD: seated guard raises before the demote hook can fire."""


class PrefixSeatedError(RuntimeError):
    pass


class Store:
    def evict(self, name):
        if self._seated(name):
            raise PrefixSeatedError(name)
        if self.demote_hook is not None:
            self.demote_hook(name, self._entries[name])
        del self._entries[name]
