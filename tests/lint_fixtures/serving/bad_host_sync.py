"""BAD: device->host syncs in the decode hot path."""


class Engine:
    def step(self, tokens):
        return float(self._decode(tokens))

    def drain(self, arr):
        return [x.item() for x in arr]
