"""Reference twins for the kernel fixtures."""


def launch_ref(x):
    return x
