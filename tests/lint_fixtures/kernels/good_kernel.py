"""GOOD kernel: compat-shim params, arity-correct index maps, a
registered reference twin."""
from jax.experimental import pallas as pl

from repro.kernels.pltpu_compat import CompilerParams as _CompilerParams


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def launch(x):
    grid = (4, 2)
    return pl.pallas_call(
        _copy_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((128, 128), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((128, 128), lambda i, j: (i, j)),
        out_shape=x,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(x)
