"""jnp twins for the kernel fixtures (none registered yet)."""
