"""BAD kernel: direct pltpu.CompilerParams, index-map arity mismatch,
no registered reference twin."""
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def launch_bad(x):
    params = pltpu.CompilerParams(dimension_semantics=("parallel",))
    return pl.pallas_call(
        _copy_kernel,
        grid=(4, 2),
        in_specs=[pl.BlockSpec((128, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((128, 128), lambda i, j: (i, j)),
        out_shape=x,
        compiler_params=params,
    )(x)
