"""Mini twin registry for the kernel-rule fixtures."""

REFERENCE_TWINS = {
    "good_kernel:launch": "ref:launch_ref",
}
