"""BAD scheduler: illegal edge, non-literal stages, unrecorded method."""

STAGES = ("new", "queued", "running", "finished")

LEGAL_TRANSITIONS = {
    ("new", "queued"),
    ("queued", "running"),
    ("running", "finished"),
}


class Scheduler:
    def _transition(self, uid, src, dst):
        pass

    def submit(self, request):
        # ("finished" -> "running") is not an edge in the table
        self._transition(request.uid, "finished", "running")
        self._queue.append(request)

    def park(self, request):
        # moves the request but never records it via _transition()
        self._waiting.append(request)

    def wake(self, name):
        src, dst = self._edge_for(name)
        # stages computed at runtime — statically uncheckable
        self._transition(name, src, dst)
