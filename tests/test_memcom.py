"""MemCom core tests: compression shapes, trainability masks, serving
parity, xattn variants, the ICAE ladder, and loss-learns checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import MemComConfig
from repro.configs import get_smoke_config
from repro.core import icae as icae_lib
from repro.core import memcom
from repro.models import transformer as tfm
from repro.optim import AdamW
from repro.serving.engine import materialize_prefix
from repro.utils.pytree import tree_flatten_with_names


def _batch(cfg, rng, B=2, T=24, S=12):
    return {
        "source": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32),
        "target": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }


def test_compress_shapes(rng):
    cfg = get_smoke_config("smollm-135m")
    m = cfg.memcom.num_memory_tokens
    params = tfm.init_params(cfg, 0)
    mc = memcom.init_memcom(cfg, params, 1)
    batch = _batch(cfg, rng)
    prefix, _ = memcom.compress(mc, cfg, batch["source"])
    # every (attn) layer gets its own (B, m, D) compressed rep
    reps = prefix["period"]["l0"]["h"]
    assert reps.shape == (cfg.layout.repeats, 2, m, cfg.d_model)
    assert not bool(jnp.isnan(reps).any())


def test_trainable_mask_phases():
    cfg = get_smoke_config("smollm-135m")
    params = tfm.init_params(cfg, 0)
    mc = memcom.init_memcom(cfg, params, 1)
    m1 = memcom.trainable_mask(mc, phase=1)
    flat = dict(tree_flatten_with_names(m1))
    assert flat["mem_tokens"] is True
    assert all(v for k, v in flat.items() if k.startswith("memx"))
    assert not any(v for k, v in flat.items() if k.startswith("source"))
    assert not any(v for k, v in flat.items() if k.startswith("memory_llm"))
    m2 = memcom.trainable_mask(mc, phase=2)
    assert all(bool(v) for v in jax.tree.leaves(m2))


def test_phase1_grads_only_on_trainables(rng):
    """Phase-1: stop-gradient on frozen leaves ⇒ zero weight grads for the
    two LLM stacks, nonzero for memx + mem_tokens."""
    cfg = get_smoke_config("smollm-135m")
    params = tfm.init_params(cfg, 0)
    mc = memcom.init_memcom(cfg, params, 1)
    batch = _batch(cfg, rng)
    mask = memcom.trainable_mask(mc, 1)

    def loss(mc_):
        mc_ = jax.tree.map(
            lambda x, m: x if m else jax.lax.stop_gradient(x), mc_, mask)
        l, _ = memcom.memcom_loss(mc_, params, cfg, batch)
        return l

    grads = jax.grad(loss)(mc)
    gflat = dict(tree_flatten_with_names(grads))
    mflat = dict(tree_flatten_with_names(mask))
    nonzero_trainable = 0
    for name, g in gflat.items():
        gn = float(jnp.abs(g).max())
        if mflat[name]:
            nonzero_trainable += gn > 0
        else:
            assert gn == 0.0, f"frozen leaf {name} received grad {gn}"
    assert nonzero_trainable > 0


def test_memcom_loss_decreases(rng):
    """A few Phase-1 steps on one batch must reduce the loss (learnability)."""
    cfg = get_smoke_config("smollm-135m")
    params = tfm.init_params(cfg, 0)
    mc = memcom.init_memcom(cfg, params, 1)
    batch = _batch(cfg, rng, T=32, S=16)
    mask = memcom.trainable_mask(mc, 1)
    opt = AdamW(lr=3e-3, mask=mask)
    state = opt.init(mc)

    @jax.jit
    def step(mc, state):
        def loss(mc_):
            mc_ = jax.tree.map(
                lambda x, m: x if m else jax.lax.stop_gradient(x), mc_, mask)
            l, _ = memcom.memcom_loss(mc_, params, cfg, batch)
            return l

        l, g = jax.value_and_grad(loss)(mc)
        mc, state = opt.step(mc, g, state)
        return mc, state, l

    losses = []
    for _ in range(8):
        mc, state, l = step(mc, state)
        losses.append(float(l))
    assert losses[-1] < losses[0] - 0.05, losses


def test_frozen_target_unchanged_by_training(rng):
    """The Target-LLM is an argument, never updated — paper's core premise."""
    cfg = get_smoke_config("smollm-135m")
    params = tfm.init_params(cfg, 0)
    before = jax.tree.map(lambda x: np.asarray(x).copy(), params)
    mc = memcom.init_memcom(cfg, params, 1)
    batch = _batch(cfg, rng)
    mask = memcom.trainable_mask(mc, 2)
    opt = AdamW(lr=1e-3, mask=mask)
    state = opt.init(mc)
    l, g = jax.value_and_grad(
        lambda m: memcom.memcom_loss(m, params, cfg, batch)[0])(mc)
    mc, state = opt.step(mc, g, state)
    for (n, a), (_, b) in zip(tree_flatten_with_names(before),
                              tree_flatten_with_names(params)):
        np.testing.assert_array_equal(a, np.asarray(b), err_msg=n)


def test_serving_prefix_parity(rng):
    """Target attending to {"h": O^i} (training path, K/V through frozen
    projections) == attending to the materialized compressed KV cache
    (serving path)."""
    cfg = get_smoke_config("smollm-135m")
    params = tfm.init_params(cfg, 0)
    mc = memcom.init_memcom(cfg, params, 1)
    batch = _batch(cfg, rng)
    prefix, _ = memcom.compress(mc, cfg, batch["source"])
    m = cfg.memcom.num_memory_tokens

    logits_h, _ = tfm.forward(params, cfg, tokens=batch["target"],
                              prefix=prefix, mask_offset=m)
    kv = materialize_prefix(params, cfg, prefix)
    logits_kv, _ = tfm.forward(params, cfg, tokens=batch["target"],
                               prefix=kv, mask_offset=m)
    np.testing.assert_allclose(np.asarray(logits_h), np.asarray(logits_kv),
                               atol=2e-4, rtol=2e-3)


@pytest.mark.parametrize("arch", ["deepseek-v2-236b", "jamba-1.5-large-398b",
                                  "whisper-medium", "qwen2-vl-2b"])
def test_memcom_families(arch, rng):
    """MemCom applies across families: MLA two-level compression, hybrid
    SSM state handoff, enc-dec, M-RoPE (DESIGN.md §4)."""
    cfg = get_smoke_config(arch)
    params = tfm.init_params(cfg, 0)
    mc = memcom.init_memcom(cfg, params, 1)
    batch = _batch(cfg, rng)
    if cfg.encoder is not None:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((2, 8, cfg.d_model)) * 0.1, jnp.float32)
    loss, aux = memcom.memcom_loss(mc, params, cfg, batch)
    assert np.isfinite(float(loss))
    if arch == "jamba-1.5-large-398b":
        prefix, _ = memcom.compress(mc, cfg, batch["source"])
        descs = cfg.layout.period
        for j, d in enumerate(descs):
            entry = prefix["period"][f"l{j}"]
            assert ("ssm" in entry) == (d.mixer == "mamba")
            assert ("h" in entry) == (d.mixer in ("attn", "mla"))


@pytest.mark.parametrize("kind,heads", [("1head", 1), ("mha", 4), ("mqa", 4)])
def test_xattn_variants(kind, heads, rng):
    """Paper App. D ablation: all three cross-attn designs are runnable."""
    cfg = get_smoke_config("smollm-135m")
    cfg = cfg.replace(memcom=MemComConfig(
        num_memory_tokens=8, xattn_kind=kind, xattn_heads=heads))
    params = tfm.init_params(cfg, 0)
    mc = memcom.init_memcom(cfg, params, 1)
    loss, _ = memcom.memcom_loss(mc, params, cfg, _batch(cfg, rng))
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("variant", ["icae", "icae+", "icae++"])
def test_icae_ladder(variant, rng):
    """ICAE → ICAE+ → ICAE++ (paper §5.1): all runnable; trainable-param
    count strictly increases along the ladder."""
    cfg = get_smoke_config("smollm-135m")
    params = tfm.init_params(cfg, 0)
    ic = icae_lib.init_icae(cfg, params, variant=variant, seed=1)
    loss, _ = icae_lib.icae_loss(ic, params, cfg, _batch(cfg, rng))
    assert np.isfinite(float(loss))
    mask = icae_lib.icae_trainable_mask(ic, variant)
    n_tr = sum(int(np.prod(l.shape))
               for (n, l), (_, m) in zip(tree_flatten_with_names(ic),
                                         tree_flatten_with_names(mask)) if m)
    test_icae_ladder.counts[variant] = n_tr


test_icae_ladder.counts = {}


def test_icae_ladder_ordering():
    c = test_icae_ladder.counts
    if len(c) == 3:
        assert c["icae"] < c["icae+"] < c["icae++"]
