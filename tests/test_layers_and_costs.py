"""Layer-level math properties (RoPE, norms, softcap) and analytic cost
model invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import SHAPES
from repro.configs import get_config
from repro.launch import costs
from repro.models.layers import apply_rope, sinusoidal_pos_embed, softcap


def test_rope_relative_position_property(rng):
    """q·k after RoPE depends only on the position *difference*."""
    B, H, D = 1, 1, 32
    q = jnp.asarray(rng.standard_normal((B, 1, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, 1, H, D)), jnp.float32)

    def score(pq, pk):
        qr = apply_rope(q, jnp.array([[pq]]), 10_000.0)
        kr = apply_rope(k, jnp.array([[pk]]), 10_000.0)
        return float(jnp.einsum("bshd,bshd->", qr, kr))

    assert abs(score(3, 7) - score(103, 107)) < 1e-3
    assert abs(score(0, 4) - score(50, 54)) < 1e-3
    assert abs(score(3, 7) - score(3, 8)) > 1e-4  # different offsets differ


def test_mrope_text_diagonal_equals_rope(rng):
    """Identical t/h/w position streams reduce M-RoPE to standard RoPE."""
    B, S, H, D = 2, 6, 2, 32
    x = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    pos3 = jnp.broadcast_to(pos, (3, B, S))
    a = apply_rope(x, pos, 10_000.0)
    b = apply_rope(x, pos3, 10_000.0, mrope_sections=(8, 4, 4))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_softcap_bounds_and_identity():
    x = jnp.linspace(-1000, 1000, 101)
    y = softcap(x, 50.0)
    assert float(jnp.abs(y).max()) <= 50.0
    assert bool(jnp.all(jnp.diff(y) >= 0))  # monotone
    np.testing.assert_array_equal(np.asarray(softcap(x, 0.0)), np.asarray(x))


def test_sinusoidal_shape_and_range():
    pe = sinusoidal_pos_embed(16, 32)
    assert pe.shape == (16, 32)
    assert float(jnp.abs(pe).max()) <= 1.0


# ---------------------------------------------------------------------------
# analytic cost model
# ---------------------------------------------------------------------------


def _shape(name):
    return next(s for s in SHAPES if s.name == name)


def test_lm_train_flops_close_to_6nd():
    """Dense LM training ≈ 6·N·D·tokens (attention adds the seq term)."""
    cfg = get_config("smollm-360m")
    shape = _shape("train_4k")
    cc = costs.lm_train_cost(cfg, shape)
    ratio = cc.flops / cc.model_flops
    assert 1.0 <= ratio < 1.6, ratio  # attention + logits overhead


def test_memcom_train_flops_exceed_lm_train():
    """The three-stack compressor must cost more than plain LM training
    on the same tokens (paper §6 training-cost limitation)."""
    cfg = get_config("smollm-360m")
    shape = _shape("train_4k")
    lm = costs.lm_train_cost(cfg, shape)
    mc = costs.memcom_train_cost(cfg, shape, phase=2)
    assert mc.flops > lm.flops
    p1 = costs.memcom_train_cost(cfg, shape, phase=1)
    assert p1.flops < mc.flops  # phase-1 backprops less


def test_decode_is_low_intensity():
    """32k decode: arithmetic intensity (flops/byte) must be tiny —
    the memory-bound regime the paper attacks."""
    cfg = get_config("mistral-nemo-12b")
    shape = _shape("decode_32k")
    cc = costs.decode_cost(cfg, shape)
    intensity = cc.flops / cc.hbm_bytes
    assert intensity < 10, intensity


def test_moe_active_vs_total_params():
    cfg = get_config("deepseek-v2-236b")
    total = cfg.param_count()
    active = cfg.active_param_count()
    assert active < total / 5  # 160-expert top-6 ⇒ big sparsity gap
    dense = get_config("mistral-nemo-12b")
    assert dense.param_count() == dense.active_param_count()


@pytest.mark.parametrize("kind", ["memcom_train", "lm_train", "prefill",
                                  "decode"])
def test_cell_cost_positive(kind):
    cfg = get_config("jamba-1.5-large-398b")
    shape = _shape("train_4k" if "train" in kind else "decode_32k")
    cc = costs.cell_cost(cfg, shape, kind)
    assert cc.flops > 0 and cc.hbm_bytes > 0 and cc.model_flops > 0
