"""Property-based tests (hypothesis) for the paged-cache block allocator:
no double-free, no leak, and exact conservation across randomized
seat/refill sequences."""

import pytest

pytest.importorskip("hypothesis", reason="optional dep: pip install hypothesis")
from hypothesis import given, settings, strategies as st

from repro.serving.block_pool import (
    BlockAllocationError,
    BlockAllocator,
    OutOfBlocksError,
)

SHORT = settings(max_examples=100, deadline=None)


@SHORT
@given(
    num_blocks=st.integers(4, 64),
    block_size=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
    num_ops=st.integers(1, 60),
)
def test_allocator_conserves_blocks(num_blocks, block_size, seed, num_ops):
    """Random seat/refill traffic (alloc N, incref shared, decref, free a
    whole slot) against a reference model: refcounts always match, the
    pool never leaks and never double-frees.

    Mirrors engine behaviour: "slots" hold block lists (prefix blocks
    increffed on seat, private blocks alloced on prefill) and a refill
    decrefs everything the slot held.
    """
    import random

    rng = random.Random(seed)
    a = BlockAllocator(num_blocks, block_size)
    model = {}  # block -> refcount (the reference bookkeeping)
    slots = [[] for _ in range(3)]  # block refs held per simulated slot

    def check():
        assert a.used_count == len(model)
        for b, c in model.items():
            assert a.refcount(b) == c, (b, c)
        # conservation: every non-reserved block is free xor referenced
        assert a.free_count + len(model) == num_blocks - 1

    for _ in range(num_ops):
        op = rng.choice(("prefill", "seat_shared", "refill", "oversubscribe"))
        slot = rng.randrange(len(slots))
        if op == "prefill":  # allocate 1-3 private blocks into a slot
            n = rng.randint(1, 3)
            if n <= a.free_count:
                got = a.alloc(n)
                assert len(set(got)) == n
                for b in got:
                    assert b != 0 and model.get(b, 0) == 0  # never live
                    model[b] = 1
                    slots[slot].append(b)
            else:
                with pytest.raises(OutOfBlocksError):
                    a.alloc(n)
        elif op == "seat_shared":  # share another slot's block (prefix seat)
            other = slots[(slot + 1) % len(slots)]
            if other:
                b = rng.choice(other)
                a.incref(b)
                model[b] += 1
                slots[slot].append(b)
        elif op == "refill":  # drop everything the slot holds
            for b in slots[slot]:
                a.decref(b)
                model[b] -= 1
                if model[b] == 0:
                    del model[b]
            slots[slot] = []
        else:  # misuse must raise, not corrupt
            freed = set(range(1, num_blocks)) - set(model)
            if freed:
                b = rng.choice(sorted(freed))
                with pytest.raises(BlockAllocationError):
                    a.decref(b)  # double free
                with pytest.raises(BlockAllocationError):
                    a.incref(b)  # incref of unallocated
        check()

    # drain: every slot refills -> the pool must return to pristine
    for slot in range(len(slots)):
        for b in slots[slot]:
            a.decref(b)
            model[b] -= 1
            if model[b] == 0:
                del model[b]
        slots[slot] = []
    check()
    assert a.free_count == num_blocks - 1 and a.used_count == 0
