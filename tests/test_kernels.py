"""Per-kernel validation: Pallas (interpret mode) and streaming-jnp paths
against the pure-jnp oracles in repro.kernels.ref, swept over shapes and
dtypes (deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import jnp_impl, ops, ref
from repro.kernels import flash_attention as fa
from repro.kernels import memcom_xattn as mxk
from repro.kernels import moe_gmm, ssd_scan

jax.config.update("jax_enable_x64", False)


def _rand(rng, *shape, dtype=np.float32, scale=0.5):
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)


TOL = {"float32": 2e-5, "bfloat16": 2e-2}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    # (B, Sq, Skv, Hq, Hkv, D, causal, softcap)
    (1, 64, 64, 4, 4, 32, True, 0.0),     # MHA causal
    (2, 96, 96, 4, 2, 64, True, 0.0),     # GQA causal
    (2, 128, 128, 8, 1, 32, True, 50.0),  # MQA + softcap (gemma2)
    (1, 37, 53, 4, 2, 64, False, 0.0),    # cross, ragged shapes
    (2, 1, 80, 4, 2, 64, True, 0.0),      # decode row
    (1, 200, 100, 2, 2, 128, True, 0.0),  # Sq > Skv
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_flash_attention_vs_ref(rng, case, dtype):
    B, Sq, Skv, Hq, Hkv, D, causal, softcap = case
    dt = jnp.dtype(dtype)
    q = _rand(rng, B, Sq, Hq, D).astype(dt)
    k = _rand(rng, B, Skv, Hkv, D).astype(dt)
    v = _rand(rng, B, Skv, Hkv, D).astype(dt)
    if causal and Sq == 1:  # decode: q sits at the cache frontier
        q_pos = jnp.full((B, Sq), Skv - 30, jnp.int32)
        kv_pos = jnp.where(jnp.arange(Skv) < Skv - 29, jnp.arange(Skv), -1)
        kv_pos = jnp.broadcast_to(kv_pos, (B, Skv)).astype(jnp.int32)
    else:
        q_pos = jnp.broadcast_to(jnp.arange(Sq), (B, Sq)).astype(jnp.int32)
        kv_pos = jnp.broadcast_to(jnp.arange(Skv), (B, Skv)).astype(jnp.int32)
    o_ref = ref.attention_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        q_pos=q_pos, kv_pos=kv_pos, causal=causal, softcap=softcap)
    o_pal = fa.flash_attention(
        q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=causal, softcap=softcap,
        block_q=32, block_k=32, interpret=True)
    np.testing.assert_allclose(
        np.asarray(o_pal, np.float32), np.asarray(o_ref), atol=TOL[dtype],
        rtol=TOL[dtype])


@pytest.mark.parametrize("case", ATTN_CASES[:4])
def test_jnp_chunked_vs_ref(rng, case):
    B, Sq, Skv, Hq, Hkv, D, causal, softcap = case
    q = _rand(rng, B, Sq, Hq, D)
    k = _rand(rng, B, Skv, Hkv, D)
    v = _rand(rng, B, Skv, Hkv, D)
    q_pos = jnp.broadcast_to(jnp.arange(Sq), (B, Sq)).astype(jnp.int32)
    kv_pos = jnp.broadcast_to(jnp.arange(Skv), (B, Skv)).astype(jnp.int32)
    o_ref = ref.attention_ref(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                              causal=causal, softcap=softcap)
    o_jnp = jnp_impl.attention_chunked(
        q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=causal, softcap=softcap,
        kv_chunk=32)
    np.testing.assert_allclose(np.asarray(o_jnp), np.asarray(o_ref),
                               atol=2e-5, rtol=2e-5)


def test_causal_blocked_vs_ref(rng):
    B, S, Hq, Hkv, D = 2, 128, 4, 2, 32
    q, k, v = _rand(rng, B, S, Hq, D), _rand(rng, B, S, Hkv, D), _rand(rng, B, S, Hkv, D)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
    o_ref = ref.attention_ref(q, k, v, q_pos=pos, kv_pos=pos, causal=True)
    for q_chunk, kv_chunk in [(32, 32), (64, 32), (128, 128)]:
        o = jnp_impl.attention_causal_blocked(
            q, k, v, q_chunk=q_chunk, kv_chunk=kv_chunk)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                                   atol=2e-5, rtol=2e-5)


def test_attention_with_prefix_exact(rng):
    """Prefix+self decomposition (LSE merge) == dense attention over the
    concatenated [prefix ; self] sequence."""
    B, S, m, Hq, Hkv, D = 2, 48, 16, 4, 2, 32
    q = _rand(rng, B, S, Hq, D)
    k_self, v_self = _rand(rng, B, S, Hkv, D), _rand(rng, B, S, Hkv, D)
    k_pre, v_pre = _rand(rng, B, m, Hkv, D), _rand(rng, B, m, Hkv, D)
    out = ops.attention_with_prefix(q, k_self, v_self, k_pre, v_pre,
                                    impl="jnp")
    # dense reference over concatenated kv
    k_cat = jnp.concatenate([k_pre, k_self], axis=1)
    v_cat = jnp.concatenate([v_pre, v_self], axis=1)
    kv_pos = jnp.concatenate(
        [jnp.arange(m)[None].repeat(B, 0),
         (m + jnp.arange(S))[None].repeat(B, 0)], axis=1).astype(jnp.int32)
    q_pos = (m + jnp.arange(S))[None].repeat(B, 0).astype(jnp.int32)
    o_ref = ref.attention_ref(q, k_cat, v_cat, q_pos=q_pos, kv_pos=kv_pos,
                              causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(o_ref),
                               atol=2e-5, rtol=2e-5)


def test_lse_merge_matches_monolithic(rng):
    """combine_attention_partials is an exact merge, not an approximation."""
    B, S, H, D = 1, 32, 2, 16
    q = _rand(rng, B, S, H, D)
    k = _rand(rng, B, 64, H, D)
    v = _rand(rng, B, 64, H, D)
    pos = jnp.arange(64)[None].astype(jnp.int32)
    q_pos = jnp.full((B, S), 63, jnp.int32)
    whole = ref.attention_ref(q, k, v, q_pos=q_pos, kv_pos=pos, causal=True)
    parts = []
    for lo, hi in [(0, 32), (32, 64)]:
        o, l = jnp_impl.attention_chunked(
            q, k[:, lo:hi], v[:, lo:hi], q_pos=q_pos, kv_pos=pos[:, lo:hi],
            causal=True, kv_chunk=16, return_lse=True)
        parts.append((o, l))
    merged = jnp_impl.combine_attention_partials(parts)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(whole),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("S", [1, 3])
@pytest.mark.parametrize("impl", ["dense", "jnp", "pallas"])
def test_decode_attention_per_slot_lengths(rng, impl, S):
    """Continuous-batching decode: slot b sees exactly cache[:lengths[b]],
    whatever the other slots' lengths, on every backend."""
    B, L, Hq, Hkv, D = 4, 53, 6, 2, 32
    q = _rand(rng, B, S, Hq, D)
    k = _rand(rng, B, L, Hkv, D)
    v = _rand(rng, B, L, Hkv, D)
    lengths = jnp.asarray([S, 17, 40, 53], jnp.int32)  # ragged, incl. edges
    slot = jnp.arange(L, dtype=jnp.int32)
    kv_pos = jnp.where(slot[None] < lengths[:, None], slot[None], -1)
    q_pos = lengths[:, None] - S + jnp.arange(S, dtype=jnp.int32)[None]
    want = ref.attention_ref(q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=True)
    got = ops.decode_attention(q, k, v, lengths=lengths, impl=impl,
                               kv_chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_decode_attention_ignores_unseated_tail(rng):
    """Garbage beyond each slot's length must not leak into the output —
    the per-slot masking the serving engine relies on for slot isolation."""
    B, L, Hq, Hkv, D = 2, 48, 4, 2, 16
    q = _rand(rng, B, 1, Hq, D)
    k = _rand(rng, B, L, Hkv, D)
    v = _rand(rng, B, L, Hkv, D)
    lengths = jnp.asarray([9, 21], jnp.int32)
    base = ops.decode_attention(q, k, v, lengths=lengths, impl="jnp",
                                kv_chunk=8)
    mask = (jnp.arange(L)[None, :, None, None] >= lengths[:, None, None, None])
    k2 = jnp.where(mask, 1e3, k)  # blow up the unseated tail
    v2 = jnp.where(mask, -1e3, v)
    poisoned = ops.decode_attention(q, k2, v2, lengths=lengths, impl="jnp",
                                    kv_chunk=8)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(poisoned))


def test_decode_attention_empty_slot_is_zero(rng):
    """A slot with no valid KV (lengths=0) returns zeros like the oracle,
    not a uniform average of garbage values."""
    q = _rand(rng, 2, 1, 4, 16)
    k = _rand(rng, 2, 24, 2, 16)
    v = _rand(rng, 2, 24, 2, 16)
    lengths = jnp.asarray([0, 5], jnp.int32)
    out = ops.decode_attention(q, k, v, lengths=lengths, impl="jnp",
                               kv_chunk=8)
    assert np.all(np.asarray(out)[0] == 0)
    kv_pos = jnp.where(jnp.arange(24)[None] < lengths[:, None],
                       jnp.arange(24)[None], -1).astype(jnp.int32)
    want = ref.attention_ref(q, k, v, q_pos=lengths[:, None] - 1,
                             kv_pos=kv_pos, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# memcom cross-attention
# ---------------------------------------------------------------------------

XATTN_CASES = [
    (1, 8, 64, 64), (2, 48, 100, 64), (2, 32, 128, 256), (1, 17, 33, 128),
]


@pytest.mark.parametrize("case", XATTN_CASES)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_memcom_xattn_vs_ref(rng, case, dtype):
    B, M, T, D = case
    dt = jnp.dtype(dtype)
    q, k, v = (_rand(rng, B, M, D).astype(dt), _rand(rng, B, T, D).astype(dt),
               _rand(rng, B, T, D).astype(dt))
    o_ref = ref.memcom_xattn_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32))
    o_pal = mxk.memcom_xattn(q, k, v, block_m=16, block_t=32, interpret=True)
    np.testing.assert_allclose(np.asarray(o_pal, np.float32),
                               np.asarray(o_ref), atol=TOL[dtype],
                               rtol=TOL[dtype])
    o_jnp = ops.memcom_xattn(q, k, v, impl="jnp")
    np.testing.assert_allclose(np.asarray(o_jnp, np.float32),
                               np.asarray(o_ref), atol=TOL[dtype],
                               rtol=TOL[dtype])


# ---------------------------------------------------------------------------
# grouped matmul
# ---------------------------------------------------------------------------

GMM_CASES = [(1, 8, 16, 8), (3, 20, 48, 36), (4, 64, 128, 64), (2, 7, 9, 5)]


@pytest.mark.parametrize("case", GMM_CASES)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_gmm_vs_ref(rng, case, dtype):
    E, C, D, F = case
    dt = jnp.dtype(dtype)
    x, w = _rand(rng, E, C, D).astype(dt), _rand(rng, E, D, F).astype(dt)
    g_ref = ref.gmm_ref(x.astype(jnp.float32), w.astype(jnp.float32))
    g_pal = moe_gmm.gmm(x, w, block_c=8, block_d=16, block_f=16,
                        interpret=True)
    np.testing.assert_allclose(np.asarray(g_pal, np.float32),
                               np.asarray(g_ref), atol=10 * TOL[dtype],
                               rtol=10 * TOL[dtype])


# ---------------------------------------------------------------------------
# Mamba2 SSD
# ---------------------------------------------------------------------------

SSD_CASES = [
    # (B, S, H, P, G, N, chunk)
    (1, 32, 2, 8, 1, 8, 8),
    (2, 70, 4, 16, 2, 8, 16),
    (1, 64, 4, 32, 4, 16, 32),
    (2, 33, 2, 8, 1, 4, 16),  # ragged
]


@pytest.mark.parametrize("case", SSD_CASES)
@pytest.mark.parametrize("with_init", [False, True])
def test_ssd_vs_ref(rng, case, with_init):
    B, S, H, P, G, N, chunk = case
    x = _rand(rng, B, S, H, P)
    dt = jnp.abs(_rand(rng, B, S, H)) * 0.2
    A = -jnp.abs(jnp.asarray(rng.standard_normal(H), np.float32))
    Bm, Cm = _rand(rng, B, S, G, N), _rand(rng, B, S, G, N)
    h0 = _rand(rng, B, H, P, N) if with_init else None
    y_ref, hf_ref = ref.ssd_ref(x, dt, A, Bm, Cm, init_state=h0)
    y_pal, hf_pal = ssd_scan.ssd(x, dt, A, Bm, Cm, init_state=h0,
                                 chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(np.asarray(hf_pal), np.asarray(hf_ref),
                               atol=5e-5, rtol=5e-5)
    y_jnp, hf_jnp = jnp_impl.ssd_chunked(x, dt, A, Bm, Cm, init_state=h0,
                                         chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_jnp), np.asarray(y_ref),
                               atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(np.asarray(hf_jnp), np.asarray(hf_ref),
                               atol=5e-5, rtol=5e-5)


def test_ssd_decode_matches_scan(rng):
    """Token-by-token recurrent decode == chunked prefill outputs."""
    B, S, H, P, G, N = 1, 16, 2, 8, 1, 8
    x = _rand(rng, B, S, H, P)
    dt = jnp.abs(_rand(rng, B, S, H)) * 0.2
    A = -jnp.abs(jnp.asarray(rng.standard_normal(H), np.float32))
    Bm, Cm = _rand(rng, B, S, G, N), _rand(rng, B, S, G, N)
    y_ref, hf_ref = ref.ssd_ref(x, dt, A, Bm, Cm)
    state = jnp.zeros((B, H, P, N), jnp.float32)
    ys = []
    for t in range(S):
        y, state = jnp_impl.ssd_decode_step(
            state, x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t])
        ys.append(y)
    y_dec = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_ref),
                               atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(np.asarray(state), np.asarray(hf_ref),
                               atol=5e-5, rtol=5e-5)
