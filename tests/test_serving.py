"""Serving engine tests: compressed-cache seating, generation parity,
continuous batching (ragged admission, per-slot stop, prefix isolation,
mid-stream slot refill)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import memcom
from repro.models import transformer as tfm
from repro.serving import Request
from repro.serving.engine import (
    ServingEngine, materialize_prefix, write_prefix_to_cache,
)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("smollm-135m")
    params = tfm.init_params(cfg, 0)
    mc = memcom.init_memcom(cfg, params, 1)
    return cfg, params, mc


def test_greedy_generate_matches_full_forward(setup, rng):
    """Engine greedy decode == argmax over an uncached full forward,
    token by token."""
    cfg, params, _ = setup
    B, S, new = 2, 10, 4
    prompts = rng.integers(4, cfg.vocab_size, (B, S)).astype(np.int32)
    eng = ServingEngine(cfg, params, slots=B, max_len=S + new + 2)
    out = eng.generate(prompts, max_new=new)

    toks = jnp.asarray(prompts)
    ref_out = []
    for _ in range(new):
        logits, _ = tfm.forward(params, cfg, tokens=toks)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        ref_out.append(np.asarray(nxt))
        toks = jnp.concatenate([toks, nxt[:, None]], axis=1)
    np.testing.assert_array_equal(out, np.stack(ref_out, axis=1))


def test_compressed_serving_pipeline(setup, rng):
    """Offline compress → materialize → seat in cache → serve: logits match
    the training-path prefix attention."""
    cfg, params, mc = setup
    m = cfg.memcom.num_memory_tokens
    B = 2
    source = jnp.asarray(rng.integers(4, cfg.vocab_size, (B, 40)), jnp.int32)
    target = jnp.asarray(rng.integers(4, cfg.vocab_size, (B, 8)), jnp.int32)

    prefix, _ = memcom.compress(mc, cfg, source)
    # training path: attend to {"h": O^i} through frozen projections
    logits_train, _ = tfm.forward(params, cfg, tokens=target, prefix=prefix,
                                  mask_offset=m)
    # serving path: materialized KV seated at cache[0:m), prefill after it
    kv = materialize_prefix(params, cfg, prefix)
    cache = tfm.init_cache(cfg, B, m + 16)
    cache = write_prefix_to_cache(cfg, cache, kv)
    logits_serve, _ = tfm.forward(params, cfg, tokens=target, cache=cache,
                                  cache_index=m, mask_offset=m)
    np.testing.assert_allclose(np.asarray(logits_serve),
                               np.asarray(logits_train), atol=2e-4, rtol=2e-3)


def test_engine_seat_compressed(setup, rng):
    cfg, params, mc = setup
    B = 2
    source = jnp.asarray(rng.integers(4, cfg.vocab_size, (B, 40)), jnp.int32)
    prefix, _ = memcom.compress(mc, cfg, source)
    kv = materialize_prefix(params, cfg, prefix)
    eng = ServingEngine(cfg, params, slots=B,
                        max_len=cfg.memcom.num_memory_tokens + 24)
    eng.seat_compressed(kv)
    assert eng.base_len == cfg.memcom.num_memory_tokens
    prompts = rng.integers(4, cfg.vocab_size, (B, 8)).astype(np.int32)
    out = eng.generate(prompts, max_new=3)
    assert out.shape == (B, 3)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------


def _greedy_reference(cfg, params, prompt, max_new):
    """Token-by-token argmax over an uncached full forward (one row)."""
    toks = jnp.asarray(prompt, jnp.int32)[None]
    out = []
    for _ in range(max_new):
        logits, _ = tfm.forward(params, cfg, tokens=toks)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        out.append(int(nxt[0]))
        toks = jnp.concatenate([toks, nxt[:, None]], axis=1)
    return np.asarray(out, np.int32)


def test_ragged_prompt_parity(setup, rng):
    """Ragged prompts batched into one engine == per-row full forward:
    per-slot lengths mask each slot to its own tokens only."""
    cfg, params, _ = setup
    lens, new = (5, 11, 8), 4
    prompts = [rng.integers(4, cfg.vocab_size, n).astype(np.int32)
               for n in lens]
    eng = ServingEngine(cfg, params, slots=3, max_len=32)
    out = eng.serve([Request(tokens=p, max_new=new) for p in prompts])
    assert len(out) == 3
    for uid, p in zip(sorted(out), prompts):
        np.testing.assert_array_equal(
            out[uid], _greedy_reference(cfg, params, p, new))


def test_per_slot_stop_tokens(setup, rng):
    """A slot hitting its stop token terminates alone; the other slots'
    continuations are unchanged (the old engine only stopped when *all*
    slots emitted the stop token)."""
    cfg, params, _ = setup
    prompts = [rng.integers(4, cfg.vocab_size, n).astype(np.int32)
               for n in (6, 9)]
    eng = ServingEngine(cfg, params, slots=2, max_len=40)
    free = eng.serve([Request(tokens=p, max_new=6) for p in prompts])
    free = [free[uid] for uid in sorted(free)]

    # choose a stop token that fires mid-stream for slot 0 only
    stop = int(free[0][2])
    if stop in free[1]:
        pytest.skip("degenerate draw: stop token appears in both slots")
    eng2 = ServingEngine(cfg, params, slots=2, max_len=40)
    out = eng2.serve([Request(tokens=p, max_new=6, stop_token=stop)
                      for p in prompts])
    out = [out[uid] for uid in sorted(out)]
    # slot 0 stops right after emitting `stop` (inclusive) ...
    np.testing.assert_array_equal(out[0], free[0][:3])
    # ... while slot 1 runs its full budget, unperturbed
    np.testing.assert_array_equal(out[1], free[1])


def test_per_slot_prefix_isolation(setup, rng):
    """Two tasks seated in different slots of one batch: each slot's output
    equals a solo engine serving only that task — no cross-attention."""
    cfg, params, mc = setup
    m = cfg.memcom.num_memory_tokens
    srcs = [jnp.asarray(rng.integers(4, cfg.vocab_size, (1, 40)), jnp.int32)
            for _ in range(2)]
    mats = [materialize_prefix(params, cfg, memcom.compress(mc, cfg, s)[0])
            for s in srcs]
    prompt = rng.integers(4, cfg.vocab_size, 7).astype(np.int32)

    eng = ServingEngine(cfg, params, slots=2, max_len=m + 24)
    eng.add_prefix("taskA", mats[0])
    eng.add_prefix("taskB", mats[1])
    reqs = [Request(tokens=prompt, max_new=5, prefix=name)
            for name in ("taskA", "taskB")]
    both = eng.serve(reqs)

    for name, mat, req in zip(("taskA", "taskB"), mats, reqs):
        solo = ServingEngine(cfg, params, slots=1, max_len=m + 24)
        solo.add_prefix(name, mat)
        ref_out = solo.serve([Request(tokens=prompt, max_new=5, prefix=name)])
        np.testing.assert_array_equal(both[req.uid],
                                      next(iter(ref_out.values())))


def test_slot_refill_mid_stream(setup, rng):
    """More requests than slots: a finished slot admits the next queued
    request mid-decode, and every request's output matches a solo run."""
    cfg, params, mc = setup
    m = cfg.memcom.num_memory_tokens
    src = jnp.asarray(rng.integers(4, cfg.vocab_size, (1, 40)), jnp.int32)
    mat = materialize_prefix(params, cfg, memcom.compress(mc, cfg, src)[0])

    eng = ServingEngine(cfg, params, slots=2, max_len=m + 32)
    eng.add_prefix("task", mat)
    reqs = [
        Request(tokens=rng.integers(4, cfg.vocab_size, 4).astype(np.int32),
                max_new=2, prefix="task"),          # finishes first -> frees
        Request(tokens=rng.integers(4, cfg.vocab_size, 6).astype(np.int32),
                max_new=7, prefix="task"),          # keeps its slot busy
        Request(tokens=rng.integers(4, cfg.vocab_size, 5).astype(np.int32),
                max_new=3, prefix="task"),          # admitted mid-stream
    ]
    out = eng.serve(reqs)
    assert sorted(len(out[r.uid]) for r in reqs) == [2, 3, 7]
    for r in reqs:
        solo = ServingEngine(cfg, params, slots=1, max_len=m + 32)
        solo.add_prefix("task", mat)
        ref_out = solo.serve([Request(tokens=r.tokens, max_new=r.max_new,
                                      prefix="task")])
        np.testing.assert_array_equal(out[r.uid],
                                      next(iter(ref_out.values())))


def test_recurrent_refill_without_prefix_is_context_free(rng):
    """A no-prefix request refilled into a used slot of a recurrent model
    must not continue the previous occupant's SSM state."""
    cfg = get_smoke_config("mamba2-370m")
    params = tfm.init_params(cfg, 0)
    p1 = rng.integers(4, cfg.vocab_size, 6).astype(np.int32)
    p2 = rng.integers(4, cfg.vocab_size, 6).astype(np.int32)
    eng = ServingEngine(cfg, params, slots=1, max_len=32)
    out = eng.serve([Request(tokens=p1, max_new=3),
                     Request(tokens=p2, max_new=3)])
    fresh = ServingEngine(cfg, params, slots=1, max_len=32)
    want = fresh.serve([Request(tokens=p2, max_new=3)])
    np.testing.assert_array_equal(list(out.values())[1],
                                  list(want.values())[0])


def test_recurrent_idle_slot_not_polluted_across_serves(rng):
    """The batched decode step advances *every* slot's recurrent state,
    idle ones included — a later admission into a slot that merely sat
    idle must still start from clean state."""
    cfg = get_smoke_config("mamba2-370m")
    params = tfm.init_params(cfg, 0)
    p1 = rng.integers(4, cfg.vocab_size, 6).astype(np.int32)
    p2 = rng.integers(4, cfg.vocab_size, 6).astype(np.int32)
    eng = ServingEngine(cfg, params, slots=2, max_len=32)
    eng.serve([Request(tokens=p1, max_new=3)])  # slot 1 idles through decode
    out = eng.serve([Request(tokens=p1, max_new=3),
                     Request(tokens=p2, max_new=3)])
    fresh = ServingEngine(cfg, params, slots=2, max_len=32)
    want = fresh.serve([Request(tokens=p1, max_new=3),
                        Request(tokens=p2, max_new=3)])
    for got, exp in zip(sorted(out), sorted(want)):
        np.testing.assert_array_equal(out[got], want[exp])


def test_hybrid_refill_clears_recurrent_state(rng):
    """Hybrid (mamba+attn) slot refill: a refilled slot must not inherit
    the previous occupant's SSM/conv state — identical requests served
    before and after a slot turnover produce identical tokens."""
    cfg = get_smoke_config("jamba-1.5-large-398b")
    params = tfm.init_params(cfg, 0)
    mc = memcom.init_memcom(cfg, params, 1)
    m = cfg.memcom.num_memory_tokens
    mats = []
    for _ in range(2):
        src = jnp.asarray(rng.integers(4, cfg.vocab_size, (1, 24)), jnp.int32)
        mats.append(materialize_prefix(params, cfg,
                                       memcom.compress(mc, cfg, src)[0]))
    eng = ServingEngine(cfg, params, slots=2, max_len=m + 24)
    eng.add_prefix("A", mats[0])
    eng.add_prefix("B", mats[1])
    prompt = rng.integers(4, cfg.vocab_size, 6).astype(np.int32)
    reqs = [Request(tokens=prompt, max_new=3, prefix="A"),
            Request(tokens=prompt, max_new=3, prefix="B"),
            Request(tokens=prompt, max_new=3, prefix="A")]  # refills a slot
    out = eng.serve(reqs)
    np.testing.assert_array_equal(out[reqs[0].uid], out[reqs[2].uid])


def test_seat_compressed_survives_re_serve(rng):
    """seat_compressed context is restored for later serves even on a
    recurrent/hybrid model whose slot states were advanced by the first
    generation (rows are kept in the PrefixStore and re-seated)."""
    cfg = get_smoke_config("jamba-1.5-large-398b")
    params = tfm.init_params(cfg, 0)
    mc = memcom.init_memcom(cfg, params, 1)
    src = jnp.asarray(rng.integers(4, cfg.vocab_size, (2, 24)), jnp.int32)
    kv = materialize_prefix(params, cfg, memcom.compress(mc, cfg, src)[0])
    m = cfg.memcom.num_memory_tokens
    prompts = rng.integers(4, cfg.vocab_size, (2, 5)).astype(np.int32)
    eng = ServingEngine(cfg, params, slots=2, max_len=m + 24)
    eng.seat_compressed(kv)
    first = eng.generate(prompts, max_new=4)
    second = eng.generate(prompts, max_new=4)
    np.testing.assert_array_equal(first, second)


def test_mamba_state_snapshot_serving(rng):
    """SSM family: post-prompt state snapshot == recomputing the prompt
    (O(1)-memory context 'compression' native to the family)."""
    cfg = get_smoke_config("mamba2-370m")
    params = tfm.init_params(cfg, 0)
    B, S1, S2 = 1, 16, 6
    a = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S1)), jnp.int32)
    b = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S2)), jnp.int32)

    # full forward over [a; b]
    full, _ = tfm.forward(params, cfg, tokens=jnp.concatenate([a, b], 1))
    # prefill a (snapshot state), then prefill b from the snapshot
    cache = tfm.init_cache(cfg, B, S1 + S2)
    _, aux = tfm.forward(params, cfg, tokens=a, cache=cache, cache_index=0)
    out_b, _ = tfm.forward(params, cfg, tokens=b, cache=aux["cache"],
                           cache_index=S1, mask_offset=S1)
    np.testing.assert_allclose(np.asarray(out_b), np.asarray(full[:, S1:]),
                               atol=2e-4, rtol=2e-3)


# ---------------------------------------------------------------------------
# Sampling determinism (per-request RNG streams)
# ---------------------------------------------------------------------------


def test_sampled_tokens_independent_of_submission_order(setup, rng):
    """Temperature sampling derives a per-request stream from (seed, uid):
    submitting the same requests in a different order — which changes
    admission order, slot assignment, and decode interleaving — must not
    change any request's sampled tokens (one shared stream would let the
    first slot to sample steal the next draw)."""
    cfg, params, _ = setup
    prompts = [rng.integers(4, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 9, 7)]
    reqs = [Request(tokens=p, max_new=m, temperature=0.8)
            for p, m in zip(prompts, (4, 6, 3))]

    def serve(order):
        eng = ServingEngine(cfg, params, slots=2, max_len=32)
        return eng.serve([reqs[i] for i in order], seed=7)

    a = serve([0, 1, 2])
    b = serve([2, 0, 1])
    for r in reqs:
        np.testing.assert_array_equal(a[r.uid], b[r.uid])


def test_sampling_deterministic_across_serves(setup, rng):
    """Same engine, same requests, same seed -> identical sampled tokens
    (streams are derived, not consumed from engine state)."""
    cfg, params, _ = setup
    reqs = [Request(tokens=rng.integers(4, cfg.vocab_size, 6)
                    .astype(np.int32), max_new=4, temperature=1.1)
            for _ in range(2)]
    eng = ServingEngine(cfg, params, slots=2, max_len=32)
    a = eng.serve(reqs, seed=3)
    b = eng.serve(reqs, seed=3)
    for r in reqs:
        np.testing.assert_array_equal(a[r.uid], b[r.uid])
    c = eng.serve(reqs, seed=4)  # and the seed still matters
    assert any(not np.array_equal(a[r.uid], c[r.uid]) for r in reqs)


# ---------------------------------------------------------------------------
# generate() edge cases
# ---------------------------------------------------------------------------


def test_generate_zero_max_new(setup, rng):
    """max_new=0 returns a well-shaped (slots, 0) array instead of tripping
    Request validation / crashing in the pad-and-stack."""
    cfg, params, _ = setup
    prompts = rng.integers(4, cfg.vocab_size, (2, 5)).astype(np.int32)
    eng = ServingEngine(cfg, params, slots=2, max_len=16)
    out = eng.generate(prompts, max_new=0)
    assert out.shape == (2, 0) and out.dtype == np.int32


def test_generate_all_slots_stop_immediately(setup, rng):
    """Every slot hitting its stop token on the very first sampled token:
    rows are length 1 (stop inclusive) and stacking stays well-shaped."""
    cfg, params, _ = setup
    prompts = rng.integers(4, cfg.vocab_size, (2, 5)).astype(np.int32)
    eng = ServingEngine(cfg, params, slots=2, max_len=16)
    free = eng.generate(prompts, max_new=1)
    eng2 = ServingEngine(cfg, params, slots=2, max_len=16)
    for stop in map(int, set(free[:, 0])):
        out = eng2.generate(prompts, max_new=4, stop_token=stop)
        assert out.shape[0] == 2 and 1 <= out.shape[1] <= 4
