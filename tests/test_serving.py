"""Serving engine tests: compressed-cache seating, generation parity,
slot batching."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import memcom
from repro.models import transformer as tfm
from repro.serving.engine import (
    ServingEngine, materialize_prefix, write_prefix_to_cache,
)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("smollm-135m")
    params = tfm.init_params(cfg, 0)
    mc = memcom.init_memcom(cfg, params, 1)
    return cfg, params, mc


def test_greedy_generate_matches_full_forward(setup, rng):
    """Engine greedy decode == argmax over an uncached full forward,
    token by token."""
    cfg, params, _ = setup
    B, S, new = 2, 10, 4
    prompts = rng.integers(4, cfg.vocab_size, (B, S)).astype(np.int32)
    eng = ServingEngine(cfg, params, slots=B, max_len=S + new + 2)
    out = eng.generate(prompts, max_new=new)

    toks = jnp.asarray(prompts)
    ref_out = []
    for _ in range(new):
        logits, _ = tfm.forward(params, cfg, tokens=toks)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        ref_out.append(np.asarray(nxt))
        toks = jnp.concatenate([toks, nxt[:, None]], axis=1)
    np.testing.assert_array_equal(out, np.stack(ref_out, axis=1))


def test_compressed_serving_pipeline(setup, rng):
    """Offline compress → materialize → seat in cache → serve: logits match
    the training-path prefix attention."""
    cfg, params, mc = setup
    m = cfg.memcom.num_memory_tokens
    B = 2
    source = jnp.asarray(rng.integers(4, cfg.vocab_size, (B, 40)), jnp.int32)
    target = jnp.asarray(rng.integers(4, cfg.vocab_size, (B, 8)), jnp.int32)

    prefix, _ = memcom.compress(mc, cfg, source)
    # training path: attend to {"h": O^i} through frozen projections
    logits_train, _ = tfm.forward(params, cfg, tokens=target, prefix=prefix,
                                  mask_offset=m)
    # serving path: materialized KV seated at cache[0:m), prefill after it
    kv = materialize_prefix(params, cfg, prefix)
    cache = tfm.init_cache(cfg, B, m + 16)
    cache = write_prefix_to_cache(cfg, cache, kv)
    logits_serve, _ = tfm.forward(params, cfg, tokens=target, cache=cache,
                                  cache_index=m, mask_offset=m)
    np.testing.assert_allclose(np.asarray(logits_serve),
                               np.asarray(logits_train), atol=2e-4, rtol=2e-3)


def test_engine_seat_compressed(setup, rng):
    cfg, params, mc = setup
    B = 2
    source = jnp.asarray(rng.integers(4, cfg.vocab_size, (B, 40)), jnp.int32)
    prefix, _ = memcom.compress(mc, cfg, source)
    kv = materialize_prefix(params, cfg, prefix)
    eng = ServingEngine(cfg, params, slots=B,
                        max_len=cfg.memcom.num_memory_tokens + 24)
    eng.seat_compressed(kv)
    assert eng.base_len == cfg.memcom.num_memory_tokens
    prompts = rng.integers(4, cfg.vocab_size, (B, 8)).astype(np.int32)
    out = eng.generate(prompts, max_new=3)
    assert out.shape == (B, 3)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()


def test_mamba_state_snapshot_serving(rng):
    """SSM family: post-prompt state snapshot == recomputing the prompt
    (O(1)-memory context 'compression' native to the family)."""
    cfg = get_smoke_config("mamba2-370m")
    params = tfm.init_params(cfg, 0)
    B, S1, S2 = 1, 16, 6
    a = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S1)), jnp.int32)
    b = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S2)), jnp.int32)

    # full forward over [a; b]
    full, _ = tfm.forward(params, cfg, tokens=jnp.concatenate([a, b], 1))
    # prefill a (snapshot state), then prefill b from the snapshot
    cache = tfm.init_cache(cfg, B, S1 + S2)
    _, aux = tfm.forward(params, cfg, tokens=a, cache=cache, cache_index=0)
    out_b, _ = tfm.forward(params, cfg, tokens=b, cache=aux["cache"],
                           cache_index=S1, mask_offset=S1)
    np.testing.assert_allclose(np.asarray(out_b), np.asarray(full[:, S1:]),
                               atol=2e-4, rtol=2e-3)
