"""REPRO_SANITIZE=1 runtime sanitizer: corrupt the pool / drive the
scheduler off the legal stage machine and assert the sanitizer trips —
and that with the flag off, the same hooks cost nothing and stay silent.

The flag is sampled once at object construction, so every test builds
its objects *after* flipping the environment.
"""

import numpy as np
import pytest

from repro.serving.block_pool import BlockAllocator
from repro.serving.sanitize import SanitizerError, sanitizer_enabled
from repro.serving.scheduler import (
    LEGAL_TRANSITIONS, STAGES, Request, Scheduler)


@pytest.fixture
def sanitize(monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")


def req(**kw):
    kw.setdefault("tokens", np.array([1, 2, 3], np.int32))
    kw.setdefault("max_new", 4)
    return Request(**kw)


# ---------------------------------------------------------------------------
# flag plumbing
# ---------------------------------------------------------------------------


def test_flag_parsing(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    assert not sanitizer_enabled()
    monkeypatch.setenv("REPRO_SANITIZE", "0")
    assert not sanitizer_enabled()
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    assert sanitizer_enabled()


def test_sanitizer_error_is_assertion():
    assert issubclass(SanitizerError, AssertionError)


# ---------------------------------------------------------------------------
# block pool
# ---------------------------------------------------------------------------


def test_clean_pool_passes_under_sanitizer(sanitize):
    a = BlockAllocator(num_blocks=8, block_size=4)
    blocks = a.alloc(3)
    a.incref(blocks[0])
    a.decref(blocks[0])
    for b in blocks:
        a.decref(b)
    assert a.free_count == 7


def test_corrupted_refcount_trips(sanitize):
    a = BlockAllocator(num_blocks=8, block_size=4)
    blocks = a.alloc(2)
    a._ref[blocks[0]] = 0  # corrupt: non-positive refcount
    with pytest.raises(SanitizerError, match="non-positive"):
        a.alloc(1)


def test_free_list_duplicate_trips(sanitize):
    a = BlockAllocator(num_blocks=8, block_size=4)
    a._free[1] = a._free[0]  # corrupt: duplicate free block
    with pytest.raises(SanitizerError, match="duplicate"):
        a.alloc(1)


def test_lost_block_trips(sanitize):
    a = BlockAllocator(num_blocks=8, block_size=4)
    blocks = a.alloc(2)
    del a._ref[blocks[0]]  # corrupt: block vanished from both sets
    with pytest.raises(SanitizerError, match="partition"):
        a.decref(blocks[1])


def test_free_and_referenced_overlap_trips(sanitize):
    a = BlockAllocator(num_blocks=8, block_size=4)
    blocks = a.alloc(1)
    a._free.append(blocks[0])  # corrupt: free AND refcounted
    with pytest.raises(SanitizerError, match="both free and referenced"):
        a.incref(blocks[0])


def test_restore_validates_snapshot(sanitize):
    a = BlockAllocator(num_blocks=8, block_size=4)
    snap = a.snapshot()
    ref, free = snap
    ref[2] = 0  # corrupt the snapshot itself
    with pytest.raises(SanitizerError):
        a.restore((ref, free))


def test_sanitizer_off_is_silent(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    a = BlockAllocator(num_blocks=8, block_size=4)
    blocks = a.alloc(2)
    a._ref[blocks[0]] = 0  # same corruption as above
    a.alloc(1)  # no invariant re-check -> no raise
    with pytest.raises(SanitizerError):
        a.check_invariants()  # on-demand check still available


# ---------------------------------------------------------------------------
# scheduler stage machine
# ---------------------------------------------------------------------------


def test_table_is_well_formed():
    assert set(STAGES) == {s for e in LEGAL_TRANSITIONS for s in e}
    for src, dst in LEGAL_TRANSITIONS:
        assert src in STAGES and dst in STAGES


def test_legal_lifecycle_passes(sanitize):
    s = Scheduler(num_slots=2, clock=lambda: 0.0)
    r = req()
    s.submit(r)
    admitted = s.admit()
    assert [a.uid for _, a in admitted] == [r.uid]
    slot = admitted[0][0]
    s.preempt(slot)
    admitted = s.admit()
    slot = admitted[0][0]
    s.record_token(slot, 7)
    s.finish(slot)
    assert s._stage[r.uid] == "finished"


def test_double_submit_trips(sanitize):
    s = Scheduler(num_slots=2, clock=lambda: 0.0)
    r = req()
    s.submit(r)
    with pytest.raises(SanitizerError, match="stage 'queued'"):
        s.submit(r)


def test_park_after_submit_trips(sanitize):
    s = Scheduler(num_slots=2, clock=lambda: 0.0)
    r = req(prefix="task-a")
    s.submit(r)
    with pytest.raises(SanitizerError):
        s.park(r)  # "new" -> waiting, but the request is already queued


def test_wake_without_park_trips(sanitize):
    s = Scheduler(num_slots=2, clock=lambda: 0.0)
    r = req(prefix="task-b")
    s.park(r)
    s.wake("task-b")
    with pytest.raises(SanitizerError):
        # force a second wake of the same request object
        s._waiting.setdefault("task-b", []).append(r)
        s.wake("task-b")


def test_sanitizer_off_scheduler_silent(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    s = Scheduler(num_slots=2, clock=lambda: 0.0)
    r = req()
    s.submit(r)
    s.submit(r)  # double submit: bad, but unchecked without the flag
    assert s.pending == 2
