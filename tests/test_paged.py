"""Paged KV-cache tests: block allocator, ops-level paged/dense decode
parity over ragged lengths (jnp + pallas-interpret), engine parity,
copy-on-write isolation, admission gating, and PrefixStore LRU eviction
with the seated-refcount guard."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import memcom
from repro.kernels import ops
from repro.models import transformer as tfm
from repro.serving import (
    BlockAllocationError,
    BlockAllocator,
    OutOfBlocksError,
    PrefixSeatedError,
    Request,
    ServingEngine,
    materialize_prefix,
)


# ---------------------------------------------------------------------------
# Block allocator
# ---------------------------------------------------------------------------


def test_allocator_basics():
    a = BlockAllocator(8, 4)  # block 0 reserved -> 7 usable
    assert a.free_count == 7
    blocks = a.alloc(3)
    assert len(set(blocks)) == 3 and 0 not in blocks
    assert a.free_count == 4
    a.incref(blocks[0])
    a.decref(blocks[0])
    assert a.refcount(blocks[0]) == 1  # still held once
    a.decref(blocks[0])
    assert a.refcount(blocks[0]) == 0 and a.free_count == 5
    with pytest.raises(BlockAllocationError):
        a.decref(blocks[0])  # double free
    with pytest.raises(BlockAllocationError):
        a.incref(blocks[0])  # unallocated
    with pytest.raises(OutOfBlocksError):
        a.alloc(6)
    assert a.blocks_for(0) == 0
    assert a.blocks_for(4) == 1
    assert a.blocks_for(5) == 2


# ---------------------------------------------------------------------------
# Ops-level parity: paged vs dense decode over ragged lengths
# ---------------------------------------------------------------------------


def _paged_copy(k, v, bs, rng):
    """Split a dense (B, L, H, D) cache into a shuffled block pool plus
    per-slot tables (pool block order deliberately non-contiguous)."""
    B, L = k.shape[:2]
    nb = L // bs
    perm = rng.permutation(B * nb) + 1  # keep block 0 as the trash block
    tables = perm.reshape(B, nb).astype(np.int32)
    pool_k = np.zeros((B * nb + 1, bs) + k.shape[2:], k.dtype)
    pool_v = np.zeros((B * nb + 1, bs) + v.shape[2:], v.dtype)
    for b in range(B):
        for j in range(nb):
            pool_k[tables[b, j]] = k[b, j * bs:(j + 1) * bs]
            pool_v[tables[b, j]] = v[b, j * bs:(j + 1) * bs]
    return jnp.asarray(pool_k), jnp.asarray(pool_v), jnp.asarray(tables)


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
@pytest.mark.parametrize("hq,hkv", [(4, 2), (4, 1)])  # GQA and MQA folds
def test_paged_decode_matches_dense(rng, impl, hq, hkv):
    B, L, D, bs = 4, 64, 16, 8
    lengths = jnp.asarray([1, 13, 40, 64], jnp.int32)  # ragged, incl. edges
    k = np.asarray(rng.standard_normal((B, L, hkv, D)), np.float32)
    v = np.asarray(rng.standard_normal((B, L, hkv, D)), np.float32)
    q = jnp.asarray(rng.standard_normal((B, 1, hq, D)), jnp.float32)
    pool_k, pool_v, tables = _paged_copy(k, v, bs, rng)

    want = ops.decode_attention(q, jnp.asarray(k), jnp.asarray(v),
                                lengths=lengths, impl="jnp")
    got = ops.paged_decode_attention(q, pool_k, pool_v, block_tables=tables,
                                     lengths=lengths, impl=impl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_paged_scatter_then_decode(rng):
    """paged_scatter lands tokens at per-slot positions: scattering into
    the pool equals writing the dense cache rows."""
    B, L, H, D, bs = 2, 32, 2, 8, 8
    starts = jnp.asarray([5, 11], jnp.int32)
    k = np.asarray(rng.standard_normal((B, L, H, D)), np.float32)
    new = jnp.asarray(rng.standard_normal((B, 1, H, D)), jnp.float32)
    pool, _, tables = _paged_copy(k, k, bs, rng)
    pool = ops.paged_scatter(pool, new, tables, starts)
    view = np.asarray(ops.paged_gather(pool, tables))
    for b in range(B):
        np.testing.assert_array_equal(view[b, int(starts[b])],
                                      np.asarray(new)[b, 0])


# ---------------------------------------------------------------------------
# Engine-level parity and isolation
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("smollm-135m")
    params = tfm.init_params(cfg, 0)
    mc = memcom.init_memcom(cfg, params, 1)
    return cfg, params, mc


def _materialize(setup, rng, n=40):
    cfg, params, mc = setup
    src = jnp.asarray(rng.integers(4, cfg.vocab_size, (1, n)), jnp.int32)
    return materialize_prefix(params, cfg, memcom.compress(mc, cfg, src)[0])


def test_paged_engine_matches_dense_ragged(setup, rng):
    """Ragged prompts + shared prefix + mid-stream refill: token streams
    identical across layouts (block_size 16 > m=8 so the prefix tail block
    is partial — seat/COW/refill all exercised)."""
    cfg, params, _ = setup
    m = cfg.memcom.num_memory_tokens
    mat = _materialize(setup, rng)
    prompts = [rng.integers(4, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 11, 3)]
    outs = []
    for layout, kw in (("dense", {}), ("paged", {"block_size": 16})):
        eng = ServingEngine(cfg, params, slots=2, max_len=m + 24,
                            kv_layout=layout, **kw)
        eng.add_prefix("task", mat)
        reqs = [Request(tokens=p, max_new=4, prefix="task") for p in prompts]
        out = eng.serve(reqs)
        outs.append([out[r.uid] for r in reqs])
    for d, p in zip(*outs):
        np.testing.assert_array_equal(d, p)


def test_cow_isolation(setup, rng):
    """Two slots seated on one task: slot 0 prefills + decodes (forcing a
    copy-on-write of the shared partial tail block); slot 1's visible
    prefix blocks stay bit-identical and its block table still names the
    original shared blocks."""
    cfg, params, _ = setup
    m = cfg.memcom.num_memory_tokens
    mat = _materialize(setup, rng)
    # block_size 16 > m=8: the whole prefix lives in one *partial* block,
    # so slot 0's first prompt token must trigger the COW
    eng = ServingEngine(cfg, params, slots=2, max_len=m + 24,
                        kv_layout="paged", block_size=16)
    eng.add_prefix("task", mat)
    eng.seat_prefix(0, "task")
    eng.seat_prefix(1, "task")
    shared = eng.store.blocks("task")
    assert eng._slot_blocks[0] == shared and eng._slot_blocks[1] == shared

    def slot1_view():
        """Slot 1's visible cache content: every KV leaf of its blocks."""
        tables = jnp.asarray(eng.tables[1:2])
        leaves = []
        for entry in eng.cache.get("prefix", []):
            for key in ("k", "v", "ckv", "kr"):
                if key in entry:
                    leaves.append(np.asarray(
                        ops.paged_gather(entry[key], tables))[:, :m])
        for entry in eng.cache.get("period", {}).values():
            for key in ("k", "v", "ckv", "kr"):
                if key in entry:
                    for r in range(entry[key].shape[0]):
                        leaves.append(np.asarray(
                            ops.paged_gather(entry[key][r], tables))[:, :m])
        return leaves

    before = slot1_view()
    out = eng.serve([Request(tokens=rng.integers(4, cfg.vocab_size, 6)
                             .astype(np.int32), max_new=5, prefix="task")])
    assert len(out) == 1
    # slot 0 went through serve -> COW: its tail block is now private
    assert eng._slot_blocks[0] != shared
    assert eng._slot_blocks[1] == shared  # untouched
    after = slot1_view()
    for a, b in zip(before, after):
        np.testing.assert_array_equal(a, b)  # bit-identical


def test_refill_frees_private_blocks_not_prefix(setup, rng):
    """More requests than slots: refills free each slot's private blocks
    back to the pool while the store's prefix blocks stay resident — the
    allocator ends exactly where a fresh double-seat would."""
    cfg, params, _ = setup
    m = cfg.memcom.num_memory_tokens
    mat = _materialize(setup, rng)
    eng = ServingEngine(cfg, params, slots=2, max_len=m + 24,
                        kv_layout="paged", block_size=8)
    eng.add_prefix("task", mat)
    prefix_blocks = set(eng.store.blocks("task"))
    reqs = [Request(tokens=rng.integers(4, cfg.vocab_size, 4)
                    .astype(np.int32), max_new=2, prefix="task")
            for _ in range(6)]
    eng.serve(reqs)
    # prefix blocks still resident (store ref) and seated in the 2 slots
    for b in prefix_blocks:
        assert eng.alloc.refcount(b) >= 1
    # every non-prefix allocated block is accounted to a live slot table
    live = set(eng._slot_blocks[0]) | set(eng._slot_blocks[1]) | prefix_blocks
    assert eng.alloc.used_count == len(live)


def test_admission_gated_on_free_blocks(setup, rng):
    """A pool that only fits one request's window at a time still serves
    every request (admission defers, slots refill), and an impossible
    request fails fast instead of deadlocking."""
    cfg, params, _ = setup
    m = cfg.memcom.num_memory_tokens
    mat = _materialize(setup, rng)
    # prefix: 1 block; each request needs <= 2 private blocks (bucket 8 +
    # decode) + COW headroom — 4 free blocks serve exactly one at a time
    eng = ServingEngine(cfg, params, slots=2, max_len=m + 16,
                        kv_layout="paged", block_size=8, num_blocks=6)
    eng.add_prefix("task", mat)
    prompts = [rng.integers(4, cfg.vocab_size, 5).astype(np.int32)
               for _ in range(3)]
    reqs = [Request(tokens=p, max_new=3, prefix="task") for p in prompts]
    out = eng.serve(reqs)
    assert len(out) == 3
    solo = ServingEngine(cfg, params, slots=1, max_len=m + 16,
                         kv_layout="paged", block_size=8)
    solo.add_prefix("task", mat)
    want = solo.serve([Request(tokens=prompts[0], max_new=3, prefix="task")])
    np.testing.assert_array_equal(out[reqs[0].uid],
                                  next(iter(want.values())))


def test_admission_reserves_decode_windows(setup, rng):
    """Two long-decoding requests whose prefill fits but whose *combined*
    decode windows exceed the pool: the gate must reserve each admitted
    request's whole window, deferring the second request instead of
    letting both slots race the pool empty mid-decode."""
    cfg, params, _ = setup
    # 4 usable blocks; each request: 8-token prompt (1 block) + decode to
    # 18 tokens (3 blocks total) -> both prefills fit (2 blocks), but the
    # decode windows need 6 > 4
    eng = ServingEngine(cfg, params, slots=2, max_len=24,
                        kv_layout="paged", block_size=8, num_blocks=5)
    prompts = [rng.integers(4, cfg.vocab_size, 8).astype(np.int32)
               for _ in range(2)]
    reqs = [Request(tokens=p, max_new=10) for p in prompts]
    out = eng.serve(reqs)  # unfixed: OutOfBlocksError mid-decode
    assert sorted(len(v) for v in out.values()) == [10, 10]
    for p, r in zip(prompts, reqs):
        solo = ServingEngine(cfg, params, slots=1, max_len=24,
                             kv_layout="paged", block_size=8)
        want = solo.serve([Request(tokens=p, max_new=10)])
        np.testing.assert_array_equal(out[r.uid], next(iter(want.values())))


def test_admission_gate_impossible_request(setup, rng):
    cfg, params, _ = setup
    m = cfg.memcom.num_memory_tokens
    mat = _materialize(setup, rng)
    # 2 usable blocks: 1 holds the prefix, and a 9-token prompt (bucket 16)
    # needs 2 more — impossible even after reclaiming free slots
    tiny = ServingEngine(cfg, params, slots=1, max_len=m + 16,
                         kv_layout="paged", block_size=8, num_blocks=3)
    tiny.add_prefix("task", mat)
    big = rng.integers(4, cfg.vocab_size, 9).astype(np.int32)
    with pytest.raises(OutOfBlocksError):
        tiny.serve([Request(tokens=big, max_new=3, prefix="task")])


def test_paged_hybrid_recurrent_state(rng):
    """Hybrid (attn+mamba) paged serving: recurrent leaves stay per-slot
    and a slot turnover still clears them — identical requests before and
    after a refill produce identical tokens."""
    cfg = get_smoke_config("jamba-1.5-large-398b")
    params = tfm.init_params(cfg, 0)
    mc = memcom.init_memcom(cfg, params, 1)
    m = cfg.memcom.num_memory_tokens
    mats = []
    for _ in range(2):
        src = jnp.asarray(rng.integers(4, cfg.vocab_size, (1, 24)), jnp.int32)
        mats.append(materialize_prefix(params, cfg,
                                       memcom.compress(mc, cfg, src)[0]))
    eng = ServingEngine(cfg, params, slots=2, max_len=m + 24,
                        kv_layout="paged", block_size=16)
    eng.add_prefix("A", mats[0])
    eng.add_prefix("B", mats[1])
    prompt = rng.integers(4, cfg.vocab_size, 6).astype(np.int32)
    reqs = [Request(tokens=prompt, max_new=3, prefix="A"),
            Request(tokens=prompt, max_new=3, prefix="B"),
            Request(tokens=prompt, max_new=3, prefix="A")]  # refills a slot
    out = eng.serve(reqs)
    np.testing.assert_array_equal(out[reqs[0].uid], out[reqs[2].uid])


def test_paged_mla_engine_parity(rng):
    """MLA latent cache paged vs dense (absorbed decode walks the latent
    block pool)."""
    cfg = get_smoke_config("deepseek-v2-236b")
    params = tfm.init_params(cfg, 0)
    prompts = [rng.integers(4, cfg.vocab_size, n).astype(np.int32)
               for n in (4, 9)]
    outs = []
    for layout in ("dense", "paged"):
        eng = ServingEngine(cfg, params, slots=2, max_len=24,
                            kv_layout=layout)
        out = eng.serve([Request(tokens=p, max_new=3) for p in prompts])
        outs.append([out[k] for k in sorted(out)])
    for d, p in zip(*outs):
        np.testing.assert_array_equal(d, p)


# ---------------------------------------------------------------------------
# PrefixStore LRU eviction + seated guard
# ---------------------------------------------------------------------------


def test_prefix_store_lru_eviction_and_seated_guard(setup, rng):
    cfg, params, _ = setup
    m = cfg.memcom.num_memory_tokens
    eng = ServingEngine(cfg, params, slots=2, max_len=m + 16,
                        kv_layout="paged", block_size=8, prefix_capacity=2)
    mats = [_materialize(setup, rng) for _ in range(3)]
    eng.add_prefix("t0", mats[0])
    eng.add_prefix("t1", mats[1])
    eng.seat_prefix(0, "t0")

    # capacity 2: inserting t2 must evict the LRU *unseated* entry (t1,
    # even though t0 is older) and free its blocks
    free_before = eng.alloc.free_count
    eng.add_prefix("t2", mats[2])
    assert "t1" not in eng.store and "t0" in eng.store and "t2" in eng.store
    # t1's blocks went back to the pool and t2 drew the same number (the
    # LIFO free list may hand t2 the very same ids)
    assert eng.alloc.free_count == free_before

    # explicit eviction of a seated prefix refuses
    with pytest.raises(PrefixSeatedError):
        eng.store.evict("t0")
    assert eng.store.seated("t0") and not eng.store.seated("t2")

    # all resident prefixes seated + at capacity -> put raises
    eng.seat_prefix(1, "t2")
    with pytest.raises(PrefixSeatedError):
        eng.add_prefix("t3", mats[1])

    # unseating (slot refill onto another task) makes t0 evictable again
    eng.seat_prefix(0, "t2")
    assert not eng.store.seated("t0")
    eng.add_prefix("t3", mats[1])
    assert "t0" not in eng.store


# ---------------------------------------------------------------------------
# Exact block_size boundaries (seat / prefill / decode accounting audit)
# ---------------------------------------------------------------------------


def _block_leaves(eng, blocks):
    """Bit-exact content of the given pool blocks across every KV leaf."""
    out = []
    for entry in eng.cache.get("prefix", []):
        for key in ("k", "v", "ckv", "kr"):
            if key in entry:
                out.append(np.asarray(entry[key][np.asarray(blocks)]))
    for entry in eng.cache.get("period", {}).values():
        for key in ("k", "v", "ckv", "kr"):
            if key in entry:
                out.append(np.asarray(entry[key][:, np.asarray(blocks)]))
    return out


def test_exact_block_multiple_prefix_no_cow(setup, rng):
    """Prefix length an exact block multiple: the tail block is *full*, so
    seating and prefilling behind it must neither copy-on-write nor touch
    the shared blocks — and the served tokens still match the dense
    engine."""
    cfg, params, _ = setup
    m = cfg.memcom.num_memory_tokens
    bs = m // 2 if m % 2 == 0 else m  # m % bs == 0 either way
    assert m % bs == 0
    mat = _materialize(setup, rng)
    prompts = [rng.integers(4, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 3)]

    dense = ServingEngine(cfg, params, slots=2, max_len=m + 24)
    dense.add_prefix("task", mat)
    reqs = [Request(tokens=p, max_new=4, prefix="task") for p in prompts]
    want = dense.serve(reqs)

    eng = ServingEngine(cfg, params, slots=2, max_len=m + 24,
                        kv_layout="paged", block_size=bs)
    eng.add_prefix("task", mat)
    shared = eng.store.blocks("task")
    assert len(shared) == m // bs  # exactly full blocks, no partial tail
    before = _block_leaves(eng, shared)
    reqs2 = [Request(tokens=p, max_new=4, prefix="task") for p in prompts]
    got = eng.serve(reqs2)
    for r, r2 in zip(reqs, reqs2):
        np.testing.assert_array_equal(want[r.uid], got[r2.uid])
    # both slots still point at the shared blocks for the prefix region —
    # no COW fired (a full tail block is never written into)
    for slot in range(2):
        assert eng._slot_blocks[slot][:len(shared)] == shared
    after = _block_leaves(eng, shared)
    for a, b in zip(before, after):
        np.testing.assert_array_equal(a, b)
    # the +1 tail-COW reserve only applies to partial tails
    probe = Request(tokens=prompts[0], max_new=4, prefix="task")
    need = eng._blocks_needed(probe, m)
    n = len(probe.tokens)
    cap = eng.max_len - m
    from repro.serving.compiler import pow2_bucket
    width = max(1, min(pow2_bucket(n, 8), cap))
    expect = (eng.alloc.blocks_for(m + max(width, n + probe.max_new))
              - eng.alloc.blocks_for(m))
    assert need == expect  # no spurious +1 at the exact boundary


def test_decode_across_block_boundary_exact_base(setup, rng):
    """Recurrent-free exact-width prefill (prompt + decode budget chosen so
    decode writes cross into a fresh block exactly at a boundary): the
    decode-time allocation draws down the admission reservation and the
    tokens match dense."""
    cfg, params, _ = setup
    m = cfg.memcom.num_memory_tokens
    bs = 4
    mat = _materialize(setup, rng)
    # width buckets to 8; n + max_new = 12 > 8 forces decode allocations,
    # and m + 8 .. m + 12 crosses a block boundary when m % 4 == 0
    prompt = rng.integers(4, cfg.vocab_size, 7).astype(np.int32)
    dense = ServingEngine(cfg, params, slots=1, max_len=m + 24)
    dense.add_prefix("task", mat)
    want = next(iter(dense.serve(
        [Request(tokens=prompt, max_new=5, prefix="task")]).values()))

    eng = ServingEngine(cfg, params, slots=1, max_len=m + 24,
                        kv_layout="paged", block_size=bs)
    eng.add_prefix("task", mat)
    got = next(iter(eng.serve(
        [Request(tokens=prompt, max_new=5, prefix="task")]).values()))
    np.testing.assert_array_equal(want, got)
    assert int(eng._reserved[0]) == 0  # finished slot returned its reserve


def test_admission_need_is_exact_at_block_boundary(setup, rng):
    """Pool sized to the *exact* worst-case need admits and serves; one
    block fewer fails fast with OutOfBlocksError — i.e. the admission
    accounting neither under- nor over-reserves at an exact-multiple
    base."""
    cfg, params, _ = setup
    m = cfg.memcom.num_memory_tokens
    bs = m if m > 0 else 4  # prefix occupies exactly one full block
    mat = _materialize(setup, rng)
    prompt = rng.integers(4, cfg.vocab_size, 3).astype(np.int32)

    probe = ServingEngine(cfg, params, slots=1, max_len=m + 16,
                          kv_layout="paged", block_size=bs)
    probe.add_prefix("task", mat)
    req = Request(tokens=prompt, max_new=2, prefix="task")
    need = probe._blocks_needed(req, m)
    store_blocks = len(probe.store.blocks("task"))

    exact = 1 + store_blocks + need  # trash + resident prefix + window
    eng = ServingEngine(cfg, params, slots=1, max_len=m + 16,
                        kv_layout="paged", block_size=bs, num_blocks=exact)
    eng.add_prefix("task", mat)
    out = eng.serve([Request(tokens=prompt, max_new=2, prefix="task")])
    assert len(next(iter(out.values()))) == 2

    tight = ServingEngine(cfg, params, slots=1, max_len=m + 16,
                          kv_layout="paged", block_size=bs,
                          num_blocks=exact - 1)
    tight.add_prefix("task", mat)
    with pytest.raises(OutOfBlocksError):
        tight.serve([Request(tokens=prompt, max_new=2, prefix="task")])
