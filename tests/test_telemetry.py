"""Telemetry tests: metrics-registry semantics (counters/gauges/
histograms/label sets, Prometheus text exposition), MetricGroup's
dict-facade contract, tracer ring-buffer (flight recorder) behaviour and
Chrome-trace schema, byte-identical trace dumps across same-seed churn
simulations, tracer-on/off token identity (dense, paged, fused+spec),
and the ``stats()`` deep-copy regression."""

import json
import math

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import memcom
from repro.models import transformer as tfm
from repro.serving import (
    MetricsRegistry,
    Request,
    ServingEngine,
    Tracer,
    TrafficConfig,
    VirtualClock,
    generate_trace,
    validate_chrome_trace,
)
from repro.serving.telemetry import (
    NULL_TRACER,
    REQUIRED_SPANS,
    Counter,
    Gauge,
    Histogram,
    MetricGroup,
)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("smollm-135m")
    params = tfm.init_params(cfg, 0)
    mc = memcom.init_memcom(cfg, params, 1)
    return cfg, params, mc


#: same churn scenario as tests/test_traffic.py: catalog exceeds
#: prefix/host capacity and two priority classes queue hot, so online
#: compiles, demotions, host→HBM promotions and preemptions all fire —
#: which is what makes its trace cover the full REQUIRED_SPANS taxonomy
CHURN = TrafficConfig(num_tasks=5, num_requests=12, context_tokens=24,
                      rate_rps=300.0, priority_classes=2)


def _churn_engine(cfg, params, mc, disk_dir, **kw):
    m = cfg.memcom.num_memory_tokens
    base = dict(slots=2, max_len=m + 32, compressor=mc,
                compile_token_budget=8, prefix_capacity=2,
                host_capacity=2, disk_dir=str(disk_dir),
                promote_layer_budget=1, clock=VirtualClock(),
                priority_aging_s=0.05)
    base.update(kw)
    return ServingEngine(cfg, params, **base)


def _churn_run(cfg, params, mc, disk_dir, **kw):
    """One churn simulation; returns (engine, tokens in trace order)."""
    trace = generate_trace(CHURN, 0)
    eng = _churn_engine(cfg, params, mc, disk_dir, **kw)
    out = eng.serve(list(trace.requests))
    return eng, [list(map(int, out[r.uid])) for r in trace.requests]


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------


def test_counter_gauge_basics():
    reg = MetricsRegistry()
    c = reg.counter("requests_total", "requests", labelnames=("priority",))
    c.inc(priority=0)
    c.inc(2, priority=0)
    c.inc(priority=1)
    assert c.value(priority=0) == 3 and c.value(priority=1) == 1
    with pytest.raises(ValueError):
        c.inc(-1, priority=0)          # counters only go up
    with pytest.raises(ValueError):
        c.inc(1, wrong_label=0)        # undeclared label set
    g = reg.gauge("queue_depth")
    g.set(4)
    g.inc()
    g.dec(2)
    assert g.value() == 3


def test_registry_idempotent_and_kind_mismatch():
    reg = MetricsRegistry()
    a = reg.counter("jobs_total", labelnames=("family",))
    b = reg.counter("jobs_total", labelnames=("family",))
    assert a is b                      # same name -> same metric object
    with pytest.raises(ValueError):
        reg.gauge("jobs_total")        # kind mismatch
    with pytest.raises(ValueError):
        reg.counter("jobs_total")      # label-set mismatch


def test_histogram_hand_computed_quantiles():
    """Bucket-interpolated quantiles against hand arithmetic on buckets
    (1, 2, 5): observations [1, 2, 3] put one count in each of the first
    three buckets, so p99's rank 2.97 lands in (2, 5] with 2 below."""
    h = Histogram("lat", buckets=(1.0, 2.0, 5.0))
    for v in (1.0, 2.0, 3.0):
        h.observe(v)
    assert math.isclose(h.percentile(99), 2.0 + 3.0 * 0.97)
    assert math.isclose(h.percentile(50), 1.0 + 1.0 * 0.5)
    snap = h.snapshot()
    assert snap["le"] == [1.0, 2.0, 5.0, "+Inf"]
    assert snap["counts"] == [1, 1, 1, 0]
    assert snap["count"] == 3 and math.isclose(snap["sum"], 6.0)
    h.observe(100.0)                   # +Inf bucket clamps to top bound
    assert h.quantile(1.0) == 5.0
    assert Histogram("empty", buckets=(1.0,)).quantile(0.5) == 0.0
    with pytest.raises(ValueError):
        Histogram("bad", buckets=(2.0, 1.0))  # not strictly increasing


def test_histogram_quantile_edge_cases():
    """Regression: an empty histogram reports 0.0 from any quantile
    (never NaN or a crash), and a single finite bucket reports its bound
    — interpolating against the fabricated 0 lower edge would invent
    precision the buckets don't have."""
    assert Histogram("e", buckets=(1.0, 2.0)).quantile(0.5) == 0.0
    assert Histogram("e2", buckets=(1.0, 2.0)).quantile(0.99) == 0.0
    h = Histogram("one", buckets=(4.0,))
    assert h.quantile(0.5) == 0.0       # still empty -> 0.0
    h.observe(3.0)
    assert h.quantile(0.5) == 4.0       # single bucket -> the bound
    h.observe(100.0)                    # lands in +Inf
    assert h.quantile(0.99) == 4.0      # clamps to the only finite bound
    assert h.quantile(0.0) == 4.0
    # labeled series keep per-series behavior: one observed, one empty
    h2 = Histogram("lab", buckets=(2.0,), labelnames=("k",))
    h2.observe(1.0, k="a")
    assert h2.quantile(0.5, k="a") == 2.0
    assert h2.quantile(0.5, k="b") == 0.0


def test_prometheus_text_exposition():
    reg = MetricsRegistry()
    reg.counter("zebra_total", "last alphabetically").inc(7)
    c = reg.counter("apple_total", "first", labelnames=("kind",))
    c.inc(1, kind="b")
    c.inc(2, kind="a")
    h = reg.histogram("lat_seconds", "latency", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    text = reg.render_prometheus()
    assert text.endswith("\n")
    lines = text.splitlines()
    # metrics render in name order regardless of registration order
    assert lines[0] == "# HELP apple_total first"
    assert lines[1] == "# TYPE apple_total counter"
    # label sets in sorted order
    assert lines[2] == 'apple_total{kind="a"} 2'
    assert lines[3] == 'apple_total{kind="b"} 1'
    # histogram buckets are cumulative and end with +Inf, then sum/count
    assert 'lat_seconds_bucket{le="0.1"} 1' in lines
    assert 'lat_seconds_bucket{le="1"} 2' in lines
    assert 'lat_seconds_bucket{le="+Inf"} 2' in lines
    assert "lat_seconds_sum 0.55" in lines
    assert "lat_seconds_count 2" in lines
    assert "zebra_total 7" in lines
    # deterministic: rendering twice is byte-identical
    assert text == reg.render_prometheus()


def test_metric_group_preserves_dict_contract():
    """The stats-dict facade: every `stats["k"] += 1` call site keeps
    working, values keep their python type, and the same numbers show up
    under `{prefix}_{key}` in the registry."""
    reg = MetricsRegistry()
    grp = reg.group("store", {"hits": 0, "misses": 0, "ratio": 0.0})
    grp["hits"] += 3
    grp["misses"] += 1
    grp["ratio"] = 0.75
    assert dict(grp) == {"hits": 3, "misses": 1, "ratio": 0.75}
    assert isinstance(grp["hits"], int)       # type preserved: resets via
    assert type(grp["hits"])(0) == 0          # type(v)(0) stay exact
    assert len(grp) == 3 and "hits" in grp
    assert reg.get("store_hits").value() == 3
    with pytest.raises(KeyError):
        grp["unknown"]
    with pytest.raises(TypeError):
        del grp["hits"]                       # keys fixed at registration
    assert "store_hits 3" in reg.render_prometheus()


# ---------------------------------------------------------------------------
# Tracer: flight recorder + Chrome-trace schema
# ---------------------------------------------------------------------------


def test_flight_recorder_ring_buffer():
    clock = VirtualClock()
    tr = Tracer(clock, capacity=4)
    for i in range(10):
        clock.advance(0.001)
        tr.instant("engine", f"ev{i}")
    assert len(tr.events()) == 4
    assert tr.dropped == 6
    assert [e["name"] for e in tr.events()] == ["ev6", "ev7", "ev8", "ev9"]
    assert tr.chrome_trace()["otherData"]["dropped_events"] == 6
    tr.clear()
    assert tr.events() == [] and tr.dropped == 0
    with pytest.raises(ValueError):
        Tracer(capacity=0)


def test_chrome_trace_schema(tmp_path):
    clock = VirtualClock()
    tr = Tracer(clock, dump_path=str(tmp_path / "flight.json"))
    tr.span("engine", "decode_step", 0.0, 0.001, active=2)
    tr.instant("slot0", "finish", rid=0)
    tr.begin_async("scheduler", "waiting_on_prefix", 7, prefix="t")
    clock.advance(0.002)
    tr.end_async("scheduler", "waiting_on_prefix", 7)
    tr.span("weird-track", "custom", 0.0, 0.001)
    trace = tr.chrome_trace()
    assert validate_chrome_trace(trace) == []
    evs = trace["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    names = {e["args"]["name"] for e in meta if e["name"] == "thread_name"}
    assert {"engine", "slot0", "scheduler", "weird-track"} <= names
    by_name = {e["name"]: e for e in evs if e["ph"] != "M"}
    assert by_name["decode_step"]["ph"] == "X"
    assert math.isclose(by_name["decode_step"]["dur"], 1000.0)  # µs
    assert by_name["decode_step"]["args"] == {"active": 2}
    assert by_name["finish"]["s"] == "t"                 # instant scope
    assert by_name["waiting_on_prefix"]["id"] == "7"     # async pairing
    # fixed tids: shared tracks stay put, slots offset, unknowns >= 1024
    tid = {e["args"]["name"]: e["tid"]
           for e in meta if e["name"] == "thread_name"}
    assert tid["engine"] == 1 and tid["scheduler"] == 4
    assert tid["slot0"] == 16 and tid["weird-track"] >= 1024
    # dump round-trips through JSON and dump_on_error is best-effort
    path = tr.dump_on_error()
    assert json.load(open(path)) == trace
    assert Tracer(clock).dump_on_error() is None         # no path set


def test_validate_chrome_trace_catches_malformed():
    assert validate_chrome_trace({}) == ["traceEvents missing or empty"]
    bad = {"traceEvents": [
        {"ph": "X", "pid": 1, "tid": 1, "name": "a", "ts": 0.0},  # no dur
        {"ph": "b", "pid": 1, "tid": 1, "name": "w", "ts": 0.0},  # no id
        {"ph": "i", "pid": 1, "tid": 1, "name": "x"},             # no ts
    ]}
    errs = validate_chrome_trace(bad, require_spans=("missing_span",))
    assert any("missing 'dur'" in e for e in errs)
    assert any("missing 'id'" in e for e in errs)
    assert any("missing 'ts'" in e for e in errs)
    assert any("missing_span" in e for e in errs)


def test_null_tracer_is_inert():
    assert NULL_TRACER.enabled is False
    NULL_TRACER.span("engine", "x", 0.0)
    NULL_TRACER.instant("engine", "y")
    assert NULL_TRACER.events() == []
    assert NULL_TRACER.chrome_trace() == {"traceEvents": []}
    assert NULL_TRACER.dump_on_error() is None


def test_virtual_clock_charge_counters():
    clock = VirtualClock()
    reg = MetricsRegistry()
    clock.attach_metrics(reg)
    clock.attach_metrics(reg)                 # idempotent per registry
    clock.charge("decode_step", 3)
    clock.charge("compile_token", 8)
    units = reg.get("virtual_clock_charged_units_total")
    secs = reg.get("virtual_clock_charged_seconds_total")
    assert units.value(kind="decode_step") == 3.0
    assert math.isclose(secs.value(kind="decode_step"),
                        3 * clock.costs["decode_step"])
    assert math.isclose(clock.now,
                        3 * clock.costs["decode_step"]
                        + 8 * clock.costs["compile_token"])


# ---------------------------------------------------------------------------
# stats() deep copy
# ---------------------------------------------------------------------------


def test_stats_returns_deep_copy(setup):
    """Mutating the dict `stats()` returned must not corrupt the live
    registry — the bench mutates/serializes these dicts freely."""
    cfg, params, _ = setup
    eng = ServingEngine(cfg, params, slots=1, max_len=40,
                        clock=VirtualClock())
    eng.serve([Request(tokens=np.array([5, 6, 7], np.int32), max_new=4)])
    s1 = eng.stats()
    golden = json.dumps(s1, sort_keys=True)
    s1["engine"]["decode_steps"] = -999       # vandalize every level
    s1["budgets"]["compile_token_budget"] = -1
    s1["prefix_store"].clear()
    assert json.dumps(eng.stats(), sort_keys=True) == golden


# ---------------------------------------------------------------------------
# Trace determinism + token identity under churn
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def churn_traced(setup, tmp_path_factory):
    """Two traced same-seed churn sims + one untraced, shared by the
    determinism / identity / coverage tests below (each sim is a full
    engine lifetime — run them once)."""
    cfg, params, mc = setup
    root = tmp_path_factory.mktemp("churn-traced")
    runs = []
    for sub in ("a", "b"):
        tracer, reg = Tracer(), MetricsRegistry()
        _, tokens = _churn_run(cfg, params, mc, root / sub,
                               tracer=tracer, metrics=reg)
        runs.append({"dumps": tracer.dumps(), "tokens": tokens,
                     "registry": reg})
    _, tokens_off = _churn_run(cfg, params, mc, root / "off")
    return runs[0], runs[1], tokens_off


def test_trace_byte_identical_across_same_seed_runs(churn_traced):
    a, b, _ = churn_traced
    assert a["dumps"] == b["dumps"]           # byte-for-byte
    assert len(a["dumps"]) > 1000             # and non-trivial


def test_trace_covers_request_lifecycle(churn_traced):
    """The churn trace contains every span the taxonomy guarantees:
    admission, waiting_on_prefix, compile_chunk, promote_chunk,
    preempt, resume, decode_step."""
    a, _, _ = churn_traced
    trace = json.loads(a["dumps"])
    assert validate_chrome_trace(trace, require_spans=REQUIRED_SPANS) == []


def test_tracer_on_off_token_identity_dense(churn_traced):
    """Telemetry only reads the clock: the traced churn run emits
    exactly the tokens of the untraced one."""
    a, _, tokens_off = churn_traced
    assert a["tokens"] == tokens_off


def test_tracer_on_off_token_identity_paged(setup, tmp_path):
    cfg, params, mc = setup
    tracer = Tracer()
    _, on = _churn_run(cfg, params, mc, tmp_path / "on",
                       kv_layout="paged", tracer=tracer)
    _, off = _churn_run(cfg, params, mc, tmp_path / "off",
                        kv_layout="paged")
    assert on == off
    assert validate_chrome_trace(tracer.chrome_trace()) == []


def test_tracer_on_off_token_identity_fused_spec(setup):
    """Fused step + self-speculative decoding, traced vs untraced —
    and the trace carries the spec_accept + fused_step events."""
    cfg, params, _ = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(4, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 11, 8)]

    def run(tracer=None):
        eng = ServingEngine(cfg, params, slots=2, max_len=40,
                            clock=VirtualClock(), fused_step=True,
                            spec_draft="self", spec_k=2, tracer=tracer)
        reqs = [Request(tokens=p, max_new=6) for p in prompts]
        out = eng.serve(reqs)
        return [list(map(int, out[r.uid])) for r in reqs]

    tracer = Tracer()
    assert run(tracer) == run(None)
    names = {e["name"] for e in tracer.events()}
    assert "spec_accept" in names
    assert "fused_step" in names


def test_churn_prometheus_exposition(churn_traced):
    """The registry a churn engine filled renders every subsystem's
    series: engine/compiler/store/tier counters, scheduler gauges, the
    decode-gap histogram and the virtual-clock charge counters."""
    a, b, _ = churn_traced
    text = a["registry"].render_prometheus()
    for needle in (
            "# TYPE serving_engine_decode_steps gauge",
            "# TYPE serving_compiler_jobs gauge",
            "serving_prefix_store_hits",
            "serving_prefix_tiers_demotes",
            "serving_sched_submitted_total",
            "serving_sched_preemptions_total",
            "# TYPE serving_decode_gap_seconds histogram",
            'serving_decode_gap_seconds_bucket{le="+Inf"}',
            'serving_ttft_seconds_count{priority="0"}',
            'virtual_clock_charged_units_total{kind="decode_step"}',
            "serving_jit_compiles_total{",
    ):
        assert needle in text, f"missing {needle!r}"
    # deterministic end to end: same seed -> same exposition
    assert text == b["registry"].render_prometheus()
