"""Sharding-context helpers: no-op guarantees off-mesh, ablation switch."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.sharding import ctx


def test_constrain_noop_without_context(rng):
    x = jnp.asarray(rng.standard_normal((2, 4, 8)), jnp.float32)
    assert ctx.constrain(x) is x
    assert ctx.head_sharded(jnp.zeros((1, 2, 4, 8))) is not None


def test_moe_plan_noop_without_context(rng):
    x = jnp.asarray(rng.standard_normal((2, 4, 8)), jnp.float32)
    out, groups = ctx.moe_dispatch_plan(x)
    assert out is x and groups is None


def test_moe_plan_disabled_switch():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = NamedSharding(mesh, P("data", "model", None))
    x = jnp.zeros((4, 4, 8))
    with ctx.act_sharding(sh):
        with ctx.moe_plan_disabled():
            out, groups = ctx.moe_dispatch_plan(x)
            assert out is x and groups is None
    # context restored
    out, groups = ctx.moe_dispatch_plan(x)
    assert out is x and groups is None


def test_act_sharding_context_restores():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = NamedSharding(mesh, P("data", None, None))
    x = jnp.zeros((2, 4, 8))
    with ctx.act_sharding(sh):
        # eager with_sharding_constraint may return its input unchanged on a
        # trivial mesh, so check the traced program instead of object identity
        jaxpr = str(jax.make_jaxpr(ctx.constrain)(x))
        assert "sharding_constraint" in jaxpr  # constraint applied
        np.testing.assert_array_equal(np.asarray(ctx.constrain(x)),
                                      np.asarray(x))
    assert ctx.constrain(x) is x  # restored


def test_constrain_skips_mismatched_rank():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = NamedSharding(mesh, P("data", None, None))
    with ctx.act_sharding(sh):
        x2d = jnp.zeros((2, 4))
        assert ctx.constrain(x2d) is x2d
