"""Pipeline parallelism: GPipe-over-ppermute == sequential scan, forward
and gradient, on a 4-device host mesh (subprocess — the main process
keeps its single real device)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.sharding.pipeline import pipeline_apply, stage_scan

    mesh = jax.make_mesh((4,), ("pod",))
    R, B, S, D = 8, 8, 4, 16
    rng = np.random.default_rng(0)
    params = {
        "w": jnp.asarray(rng.standard_normal((R, D, D)) * 0.2, jnp.float32),
        "b": jnp.asarray(rng.standard_normal((R, D)) * 0.1, jnp.float32),
    }
    h0 = jnp.asarray(rng.standard_normal((B, S, D)), jnp.float32)

    def apply_layer(lp, h):
        return jnp.tanh(h @ lp["w"] + lp["b"])

    def sequential(params, h):
        def body(h, lp):
            return apply_layer(lp, h), None
        h, _ = jax.lax.scan(body, h, params)
        return h

    stage_fn = stage_scan(apply_layer)
    pipelined = lambda p, h: pipeline_apply(
        stage_fn, p, h, mesh=mesh, axis="pod", microbatches=4)

    y_seq = sequential(params, h0)
    y_pipe = jax.jit(pipelined)(params, h0)
    fwd_err = float(jnp.abs(y_seq - y_pipe).max())

    # gradients through the pipeline (ppermute transpose = reverse ring)
    def loss_seq(p):
        return jnp.sum(sequential(p, h0) ** 2)
    def loss_pipe(p):
        return jnp.sum(pipelined(p, h0) ** 2)
    g_seq = jax.grad(loss_seq)(params)
    g_pipe = jax.jit(jax.grad(loss_pipe))(params)
    g_err = max(float(jnp.abs(a - b).max())
                for a, b in zip(jax.tree.leaves(g_seq),
                                jax.tree.leaves(g_pipe)))
    print(json.dumps({"fwd_err": fwd_err, "grad_err": g_err}))
""")


@pytest.mark.slow
def test_pipeline_matches_sequential(tmp_path):
    script = tmp_path / "pipe.py"
    script.write_text(SCRIPT)
    res = subprocess.run(
        [sys.executable, str(script)], capture_output=True, text=True,
        timeout=600, env={**os.environ, "PYTHONPATH": "src"},
        cwd="/root/repo")
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["fwd_err"] < 1e-5, out
    assert out["grad_err"] < 1e-4, out
