"""Property-based tests (hypothesis) for the priority scheduler: aging
bounds starvation, FIFO holds within a priority class under arbitrary
admit interleavings, and preempt/resume conserves every emitted token
across randomized submit/admit/record/preempt sequences."""

import itertools

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dep: pip install hypothesis")
from hypothesis import given, settings, strategies as st

from repro.serving.scheduler import Request, Scheduler

SHORT = settings(max_examples=100, deadline=None)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _req(**kw):
    kw.setdefault("max_new", 3)
    return Request(tokens=np.arange(3, dtype=np.int32), **kw)


@SHORT
@given(priority=st.integers(0, 8),
       interval=st.floats(0.01, 10.0),
       extra=st.floats(0.0, 100.0))
def test_aging_bounds_starvation(priority, interval, extra):
    """Waiting ``priority * interval`` seconds always ages a request to
    class 0 — no base class can be starved longer than that by urgent
    arrivals.  Aging is also monotone: waiting never raises the class."""
    clk = FakeClock()
    s = Scheduler(1, clock=clk, aging_interval_s=interval)
    r = _req(priority=priority)
    s.submit(r)
    before = s.effective_class(r)
    clk.t = priority * interval + extra
    after = s.effective_class(r)
    assert after == 0
    assert after <= before <= priority


@SHORT
@given(data=st.data())
def test_fifo_within_class_any_interleaving(data):
    """Whatever the admit/finish interleaving and the class mix, two
    requests of the *same* class are always admitted in submission
    order (uids are monotone in submission order here)."""
    s = Scheduler(data.draw(st.integers(1, 4), label="slots"))
    n = data.draw(st.integers(1, 16), label="requests")
    cls_of, admitted = {}, []

    def drain_admits():
        for slot, req in s.admit():
            admitted.append(req.uid)
            s.record_token(slot, 1)  # max_new=1: finish immediately
            s.finish(slot)

    for i in range(n):
        r = _req(max_new=1, priority=data.draw(st.integers(0, 2),
                                               label=f"class[{i}]"))
        cls_of[r.uid] = r.priority
        s.submit(r)
        if data.draw(st.booleans(), label=f"admit after {i}?"):
            drain_admits()
    while s.pending:
        drain_admits()
    assert len(admitted) == n
    for c in (0, 1, 2):
        same_class = [u for u in admitted if cls_of[u] == c]
        assert same_class == sorted(same_class)


@SHORT
@given(data=st.data())
def test_preempt_resume_conserves_tokens(data):
    """Random submit/admit/record/preempt traffic: every request finishes
    exactly once with exactly the tokens recorded for it, in order —
    preemption and resumption never lose, duplicate or reorder a token,
    and a resumed slot always starts from the stashed emission."""
    s = Scheduler(2)
    reqs = [_req(max_new=4, priority=data.draw(st.integers(0, 2),
                                               label=f"class[{i}]"))
            for i in range(data.draw(st.integers(1, 6), label="requests"))]
    for r in reqs:
        s.submit(r)
    emitted_ref = {r.uid: [] for r in reqs}
    finished = {}
    tok = itertools.count(100)

    def step_active():
        for slot in list(s.active_slots()):
            t = next(tok)
            emitted_ref[s.request_in(slot).uid].append(t)
            if s.record_token(slot, t):
                req, out = s.finish(slot)
                assert req.uid not in finished
                finished[req.uid] = list(out)

    for _ in range(data.draw(st.integers(0, 40), label="ops")):
        op = data.draw(st.sampled_from(["admit", "step", "preempt"]))
        if op == "admit":
            for slot, req in s.admit():
                # a resumed slot starts exactly from its stash
                assert list(s.emitted_tokens(slot)) == emitted_ref[req.uid]
        elif op == "step":
            step_active()
        elif s.active_slots():
            s.preempt(data.draw(st.sampled_from(s.active_slots())))
    while s.has_work():  # drain: admit + one decode step makes progress
        s.admit()
        step_active()
    assert set(finished) == {r.uid for r in reqs}
    for r in reqs:
        assert finished[r.uid] == emitted_ref[r.uid]
        assert len(finished[r.uid]) == r.max_new
