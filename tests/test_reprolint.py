"""reprolint test suite: every rule family against good/bad fixtures,
plus suppression, baseline, and CLI semantics.

Fixtures live in ``tests/lint_fixtures/`` (skipped by the main lint run);
path-gated rules are exercised through the fixtures' real paths (the
``serving``/``kernels`` parent dirs and ``scheduler.py`` basenames are
what the gates key on) or through :func:`lint_source` with a fake path.
"""

import json
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
if str(ROOT) not in sys.path:
    sys.path.insert(0, str(ROOT))

from tools.reprolint import RULES  # noqa: E402
from tools.reprolint.core import (  # noqa: E402
    Baseline, BaselineError, Finding, lint_file, lint_source)
from tools.reprolint.__main__ import main as reprolint_main  # noqa: E402

FIX = ROOT / "tests" / "lint_fixtures"


def rules_hit(path: Path) -> set:
    return {f.rule for f in lint_file(path)}


# ---------------------------------------------------------------------------
# family 1: jax / determinism hazards
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bad,good,rule_id", [
    ("jax/bad_wall_clock.py", "jax/good_wall_clock.py", "wall-clock"),
    ("jax/bad_unseeded_random.py", "jax/good_random.py", "unseeded-random"),
    ("jax/bad_traced_branch.py", "jax/good_traced_branch.py",
     "traced-branch"),
    ("jax/bad_mutable_default.py", "jax/good_mutable_default.py",
     "mutable-default"),
    ("serving/bad_host_sync.py", "serving/good_host_sync.py",
     "host-sync-decode"),
    ("serving/bad_refcount.py", "serving/good_refcount.py",
     "refcount-balance"),
    ("serving/bad_demote.py", "serving/good_demote.py", "demote-guard"),
    ("statemachine_bad/scheduler.py", "statemachine_good/scheduler.py",
     "state-machine"),
    ("telemetry/bad_span_pairing.py", "telemetry/good_span_pairing.py",
     "span-pairing"),
    ("kernels/bad_kernel.py", "kernels/good_kernel.py", "pltpu-compat"),
    ("kernels/bad_kernel.py", "kernels/good_kernel.py", "blockspec-arity"),
    ("kernels/bad_kernel.py", "kernels/good_kernel.py", "ref-twin"),
])
def test_rule_fires_on_bad_not_good(bad, good, rule_id):
    assert rule_id in rules_hit(FIX / bad), f"{rule_id} missed {bad}"
    assert rule_id not in rules_hit(FIX / good), \
        f"{rule_id} false-positive on {good}"


def test_jit_static_hint_both_forms():
    hit = rules_hit(FIX / "jax/bad_jit_static.py")
    assert "jit-static-hint" in hit            # jax.jit(run) call form
    assert "jit-static-hint-decorator" in hit  # @jax.jit decorator form
    good = rules_hit(FIX / "jax/good_jit_static.py")
    assert "jit-static-hint" not in good
    assert "jit-static-hint-decorator" not in good


def test_wall_clock_allowed_in_clock_module():
    src = "import time\ndef now():\n    return time.monotonic()\n"
    assert lint_source("src/repro/serving/clock.py", src,
                       rule_ids=["wall-clock"]) == []
    assert lint_source("src/repro/serving/engine.py", src,
                       rule_ids=["wall-clock"]) != []


def test_traced_branch_counts():
    finds = [f for f in lint_file(FIX / "jax/bad_traced_branch.py")
             if f.rule == "traced-branch"]
    # the if, the while, and the assert
    assert len(finds) == 3


def test_refcount_exception_edge_and_discard():
    msgs = [f.message for f in lint_file(FIX / "serving/bad_refcount.py")
            if f.rule == "refcount-balance"]
    assert len(msgs) == 3
    assert any("may raise" in m for m in msgs)
    assert any("discarded" in m for m in msgs)
    assert any("return" in m for m in msgs)


def test_span_pairing_finding_details():
    msgs = [f.message for f in
            lint_file(FIX / "telemetry/bad_span_pairing.py")
            if f.rule == "span-pairing"]
    assert any("no matching end_async" in m for m in msgs)
    assert any("no matching begin_async" in m for m in msgs)
    assert any("still open at return" in m for m in msgs)
    assert any("string literal" in m for m in msgs)
    assert sum("REQUIRED_SPANS" in m for m in msgs) == 2  # begin + end


def test_span_pairing_taxonomy_mirrors_telemetry():
    """The linter's literal mirror of REQUIRED_SPANS (kept so reprolint
    stays stdlib-only) must track the runtime taxonomy."""
    from tools.reprolint.serving_rules import _REQUIRED_SPANS
    from repro.serving.telemetry import REQUIRED_SPANS
    assert _REQUIRED_SPANS == REQUIRED_SPANS


def test_span_pairing_only_in_serving_dirs():
    src = ("def f(tracer, aid):\n"
           "    tracer.begin_async('engine', 'mystery_phase', aid)\n")
    assert lint_source("pkg/other/util.py", src,
                       rule_ids=["span-pairing"]) == []
    assert lint_source("pkg/serving/util.py", src,
                       rule_ids=["span-pairing"]) != []


def test_state_machine_requires_table():
    src = ("class Scheduler:\n"
           "    def submit(self, request):\n"
           "        self._queue.append(request)\n")
    finds = lint_source("pkg/scheduler.py", src, rule_ids=["state-machine"])
    assert any("STAGES" in f.message for f in finds)
    # not a scheduler file -> rule does not apply at all
    assert lint_source("pkg/other.py", src, rule_ids=["state-machine"]) == []


def test_state_machine_bad_details():
    msgs = [f.message for f in
            lint_file(FIX / "statemachine_bad/scheduler.py")]
    assert any("illegal stage transition" in m for m in msgs)
    assert any("string literals" in m for m in msgs)
    assert any("park" in m for m in msgs)  # unrecorded stage move


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------


def test_suppression_with_reason_is_clean():
    assert lint_file(FIX / "suppress/suppressed_ok.py") == []


def test_bare_suppressions_are_findings():
    finds = lint_file(FIX / "suppress/bare.py")
    assert {f.rule for f in finds} == {"bare-suppression"}
    assert len(finds) == 2  # missing reason + missing rule id


def test_suppression_only_covers_named_rule():
    src = ("import time\n"
           "def f():\n"
           "    return time.time()  "
           "# reprolint: ignore[unseeded-random] -- wrong rule\n")
    finds = lint_source("x.py", src)
    assert "wall-clock" in {f.rule for f in finds}


def test_file_level_suppression():
    src = ("# reprolint: ignore-file[wall-clock] -- this file measures "
           "real time\n"
           "import time\n"
           "def f():\n"
           "    return time.time()\n"
           "def g():\n"
           "    return time.monotonic()\n")
    assert lint_source("x.py", src) == []


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------


def test_baseline_roundtrip_and_multiset(tmp_path):
    findings = [f for f in lint_file(FIX / "jax/bad_wall_clock.py")
                if f.rule == "wall-clock"]
    assert findings
    bl_path = tmp_path / "baseline.json"
    Baseline.dump(findings, bl_path)
    data = json.loads(bl_path.read_text())
    for e in data["findings"]:
        e["justification"] = "fixture: grandfathered for the test"
    bl_path.write_text(json.dumps(data))
    bl = Baseline.load(bl_path)
    fresh, matched = bl.filter(findings)
    assert fresh == [] and matched == len(findings)
    # multiset semantics: a second copy of a baselined finding is NEW
    dup = findings + [findings[0]]
    fresh, matched = bl.filter(dup)
    assert len(fresh) == 1 and matched == len(findings)


def test_baseline_requires_justification(tmp_path):
    bl_path = tmp_path / "baseline.json"
    bl_path.write_text(json.dumps({"findings": [
        {"rule": "wall-clock", "path": "x.py", "context": "time.time()",
         "justification": "   "}]}))
    with pytest.raises(BaselineError):
        Baseline.load(bl_path)


def test_baseline_key_survives_line_shift():
    a = Finding("wall-clock", "x.py", 10, "m", context="t = time.time()")
    b = Finding("wall-clock", "x.py", 99, "m", context="t = time.time()")
    assert a.key() == b.key()


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def test_cli_exit_codes(tmp_path, capsys):
    bad = FIX / "jax" / "bad_wall_clock.py"
    good = FIX / "suppress" / "suppressed_ok.py"
    assert reprolint_main([str(bad), "--no-baseline"]) == 1
    assert reprolint_main([str(good), "--no-baseline"]) == 0
    assert reprolint_main([str(bad), "--rule", "no-such-rule"]) == 2
    assert reprolint_main([str(tmp_path)]) == 2  # no python files
    assert reprolint_main(["--list-rules"]) == 0
    capsys.readouterr()


def test_cli_update_baseline_then_clean(tmp_path, capsys):
    bad = FIX / "jax" / "bad_wall_clock.py"
    bl = tmp_path / "bl.json"
    assert reprolint_main([str(bad), "--update-baseline",
                           "--baseline", str(bl)]) == 0
    data = json.loads(bl.read_text())
    for e in data["findings"]:
        e["justification"] = "fixture: accepted for this test"
    bl.write_text(json.dumps(data))
    assert reprolint_main([str(bad), "--baseline", str(bl)]) == 0
    capsys.readouterr()


def test_cli_json_format(capsys):
    bad = FIX / "jax" / "bad_wall_clock.py"
    assert reprolint_main([str(bad), "--no-baseline",
                           "--format", "json"]) == 1
    out = capsys.readouterr().out
    payload = json.loads(out[out.index("["):])
    assert all(f["rule"] == "wall-clock" for f in payload)


# ---------------------------------------------------------------------------
# the repo itself stays lint-clean (the tentpole's lock-in)
# ---------------------------------------------------------------------------


def test_repo_is_lint_clean(capsys):
    rc = reprolint_main([str(ROOT / "src"), str(ROOT / "tests"),
                         str(ROOT / "benchmarks"),
                         "--baseline",
                         str(ROOT / "tools/reprolint/baseline.json")])
    out = capsys.readouterr()
    assert rc == 0, f"repo not lint-clean:\n{out.out}\n{out.err}"


def test_rule_catalog_documented():
    """Every registered rule appears in docs/LINTS.md."""
    doc = (ROOT / "docs" / "LINTS.md").read_text(encoding="utf-8")
    for rid in RULES:
        assert f"`{rid}`" in doc, f"rule {rid} missing from docs/LINTS.md"


def test_reference_twins_resolve():
    """The real REFERENCE_TWINS registry must name importable callables."""
    from repro.kernels import registry
    for key in registry.REFERENCE_TWINS:
        assert callable(registry.resolve(key)), key
